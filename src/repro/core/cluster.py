"""Cluster model: nodes, placement, failures, stragglers, elastic lifecycle.

Nodes hold instances (bin-packed by memory).  A failure kills a node: its
instances vanish and their in-flight requests are re-queued — the control
plane must recreate capacity (fault tolerance is exercised in tests and the
large-scale example).  Straggler nodes multiply execution latency.

A node moves through an elastic lifecycle when a fleet autoscaler
(``repro.fleet``) is attached:

    provisioning --ready--> up --drain--> draining --empty--> gone

Only ``up`` nodes accept placements; ``draining`` nodes let in-flight work
finish and are terminated once their memory drains to zero.  The static
seed behavior (every node born ``up``, fleet never touched) is unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

PROVISIONING, UP, DRAINING, GONE = "provisioning", "up", "draining", "gone"


@dataclasses.dataclass
class Node:
    node_id: int
    memory_mb: float
    slowdown: float = 1.0          # >1 = straggler
    alive: bool = True
    used_mb: float = 0.0
    state: str = UP                # provisioning | up | draining | gone
    spot: bool = False             # spot-tier node (repro.fleet.spot)

    def fits(self, mb: float) -> bool:
        return self.alive and self.state == UP \
            and self.used_mb + mb <= self.memory_mb

    @property
    def billable(self) -> bool:
        """Cloud billing starts at launch and stops at termination."""
        return self.alive and self.state != GONE


class Cluster:
    def __init__(self, num_nodes: int, node_memory_mb: float = 192_000.0,
                 straggler_frac: float = 0.0, straggler_slowdown: float = 3.0,
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        self.node_memory_mb = node_memory_mb
        self.nodes: list[Node] = []
        for i in range(num_nodes):
            slow = straggler_slowdown if rng.uniform() < straggler_frac else 1.0
            self.nodes.append(Node(i, node_memory_mb, slow))
        self._rr = 0

    def place(self, memory_mb: float) -> Optional[Node]:
        """Round-robin first-fit (spreads churn across workers, like the
        default kube-scheduler LeastAllocated behavior)."""
        n = len(self.nodes)
        for k in range(n):
            node = self.nodes[(self._rr + k) % n]
            if node.fits(memory_mb):
                self._rr = (self._rr + k + 1) % n
                node.used_mb += memory_mb
                return node
        return None

    def release(self, node: Node, memory_mb: float) -> None:
        node.used_mb = max(0.0, node.used_mb - memory_mb)

    def fail_node(self, node_id: int) -> Node:
        node = self.nodes[node_id]
        node.alive = False
        node.used_mb = 0.0
        return node

    def recover_node(self, node_id: int) -> None:
        self.nodes[node_id].alive = True

    # -- elastic lifecycle (driven by repro.fleet) ------------------------------

    def add_node(self, memory_mb: Optional[float] = None, slowdown: float = 1.0,
                 state: str = PROVISIONING) -> Node:
        node = Node(len(self.nodes), memory_mb or self.node_memory_mb,
                    slowdown, state=state)
        self.nodes.append(node)
        return node

    def start_drain(self, node: Node) -> None:
        if node.state == UP:
            node.state = DRAINING

    def terminate(self, node: Node) -> None:
        node.state = GONE
        node.alive = False
        node.used_mb = 0.0

    def nodes_in(self, *states: str) -> list[Node]:
        return [n for n in self.nodes if n.alive and n.state in states]

    @property
    def billable_count(self) -> int:
        return sum(1 for n in self.nodes if n.billable)

    @property
    def total_memory_mb(self) -> float:
        return sum(n.memory_mb for n in self.nodes if n.alive)

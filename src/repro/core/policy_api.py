"""Policy-as-pytree: the pluggable, differentiable autoscaling-policy API.

A policy FAMILY is a registered, self-describing object instead of a bare
``kind`` integer branched inside the simulator.  Each family bundles:

* **axes** — the declared parameters (``AxisSpec``: bounds, sweepable /
  learnable flags).  ``repro.opt.space`` derives its search space and
  active-knob tables from these declarations instead of hand-written maps,
  and ``repro.opt.learned`` trains the ``learnable`` leaves by ``jax.grad``
  through the chunked scan.  Params flow through the scan as a traced
  PYTREE (``{axis: leaf}``), so arbitrary-shaped policies — a weight
  pytree, not just four scalar knobs — vmap as batch axes.
* **decide** — a pure ``(params, PolicyObs) -> JaxDecision`` step usable
  from the traced ``lax.scan`` (``repro.core.simjax``); bit-for-bit the
  math that used to live in ``simjax._make_step``'s per-kind branches.
* **oracle_factory** — lowers the same spec to the discrete-event oracle's
  stateful per-function ``Policy`` objects (``eventsim`` and the real
  ``control_plane`` share them), so every registered family is replayable
  through BOTH engines and must hold the parity band.
* **metadata** the frontier engine used to hard-code: synchronous-tail
  behavior (``synchronous_tail`` drives the finite-sample percentile
  correction), the async cold-start factor, and whether the family reads
  the concurrency window buffer (``uses_window`` sizes the scan carry).

New policies (spot-aware, cc-fidelity, bursty-gap variants, learned
controllers) become registry entries — not simulator surgery.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, NamedTuple, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.policies import (SPOT_HEADROOM_HORIZON_S,
                                 AsyncConcurrencyPolicy, HybridHistogramPolicy,
                                 LearnedKeepalivePolicy, Policy,
                                 SpotAwarePolicy, SyncKeepalivePolicy,
                                 init_theta, learned_keepalive)
from repro.core.trace import KA_GRID

# hybrid floor on the adaptive keepalive, mirroring HybridHistogramPolicy
# .min_s (its max_s cap maps to the ``keepalive_s`` axis)
HYBRID_MIN_KA_S = 30.0


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One declared policy parameter: its bounds and its role.

    ``sweepable`` axes are grid axes the frontier engine may batch over;
    ``learnable`` axes are pytree leaves ``jax.grad`` trains through the
    scan.  Bounds are validated at ``JaxPolicy`` construction and on every
    sweep point, so a NaN or out-of-range knob fails loudly instead of
    propagating through the scan to the CI gate's fail-closed check."""
    name: str
    lo: float
    hi: float
    sweepable: bool = True
    learnable: bool = False
    doc: str = ""


class PolicyObs(NamedTuple):
    """What one simulated tick shows a policy (all (F,)-vectorized).

    ``demand`` is the engine-computed count of arrivals/backlog not covered
    by existing or in-flight capacity — the creation request a synchronous
    policy answers; ``avg`` is the window-averaged concurrency an async
    reconciler tracks; ``lam`` is the long-run mean arrival rate per
    function (the renewal-expiry and learned-feature input)."""
    arr: Any            # arrivals this tick
    queue: Any          # backlog after dispatch
    inst: Any           # warm instances
    pending: Any        # instances still cold-starting
    idle: Any           # integral idle count (async retire cap)
    idle_frac: Any      # expected fractional idle mass (sync expiry flux)
    free: Any           # free warm slots before this tick's dispatch
    avg: Any            # window-averaged concurrency
    demand: Any         # unserved demand requesting creation
    lam: Any            # long-run mean arrival rate per function
    gap_p99: Any        # empirical p99 inter-arrival gap per function
    alive_tab: Any      # (F, K) E[min(gap, KA_GRID[k])] per function
    tail_tab: Any       # (F, K) P(gap > KA_GRID[k]) per function
    dt: float           # tick length (static)


class JaxDecision(NamedTuple):
    """A policy step's output: instances to create / retire per function,
    plus how many seconds of the cold start the family hides (the hybrid's
    pre-warm lead; charged back as standing pre-warmed memory)."""
    create: Any
    retire: Any
    cold_hide: Any = 0.0


def renewal_expiry_rate(lam_inst, ka, dt_cap: float = 60.0):
    """Fluid keepalive expiry, renewal-matched for POISSON gaps: rate
    lam/(e^{lam*ka}-1) per idle instance reproduces the oracle's
    continuous-idleness timer in expectation (1/ka as lam->0, ~never for
    chatty fns).  Kept as the analytic reference; the families below use
    ``empirical_expiry_rate``, which generalizes this to the trace's
    actual gap distribution and coincides with it when gaps are
    exponential."""
    return lam_inst / jnp.expm1(jnp.minimum(lam_inst * ka, dt_cap))


def _interp_table(tab, ka):
    """Per-function linear interpolation of a (F, K) gap table over KA_GRID
    at the traced keepalive ``ka`` (scalar or (F,)); piecewise-linear, so
    the expiry flux stays differentiable w.r.t. the keepalive."""
    grid = jnp.asarray(KA_GRID, jnp.float32)
    ka_c = jnp.clip(ka, grid[0], grid[-1])
    idx = jnp.clip(jnp.searchsorted(grid, ka_c, side="right") - 1,
                   0, len(KA_GRID) - 2)
    g0, g1 = grid[idx], grid[idx + 1]
    rows = jnp.arange(tab.shape[0])
    e0, e1 = tab[rows, idx], tab[rows, idx + 1]
    w = (ka_c - g0) / (g1 - g0)
    return e0 + w * (e1 - e0)


def empirical_expiry_rate(obs: "PolicyObs", ka):
    """Fluid keepalive expiry matched to the EMPIRICAL gap distribution.

    An oracle instance's idle cycle lasts E[min(gap, ka)] and ends in a
    teardown with probability P(gap > ka), so the renewal-exact expiry
    rate per idle instance is

        r = P(gap > ka) / E[min(gap, ka)]

    with both moments measured from the trace (``trace.gap_tables``).  For
    exponential gaps this IS the analytic ``renewal_expiry_rate``
    lam/(e^{lam*ka}-1); for the bursty / time-warped distributions the
    analytic form under-expires (clustered gaps rarely exceed a short ka
    where an exponential tail would), and matching only the cycle length
    would over-expire burst-heavy functions.  Instance thinning keeps the
    classic scaling approximation: per-instance gaps at 1/inst the rate,
    i.e. gap_inst ~ inst * gap, so both tables are read at ka/inst — an
    identity for exponential gaps."""
    inst = jnp.maximum(obs.inst, 1.0)
    ka_arg = ka / inst
    e_alive = inst * _interp_table(obs.alive_tab, ka_arg)
    p_tail = _interp_table(obs.tail_tab, ka_arg)
    return p_tail / jnp.maximum(e_alive, 1e-9)


class PolicyFamily:
    """Base class: metadata + the two lowering directions (traced decide,
    oracle factory).  Subclass and ``register_family`` to add a policy."""

    #: registry key; static under jit (selects the compiled branch)
    name: str = ""
    #: legacy integer id (``JaxPolicy.kind``); None for post-redesign families
    kind: Optional[int] = None
    #: per-request latency tails are iid (sync cold starts) rather than
    #: correlated backlog episodes — drives the finite-sample percentile
    #: correction in the slowdown estimator
    synchronous_tail: bool = True
    #: multiplier on the modelled cold-start wait (an async reconciler adds
    #: the reconcile-tick delay before the sandbox is even requested)
    cold_factor: float = 1.0
    #: reads the window-averaged concurrency: the scan carries a real
    #: window buffer (length window_s/dt) instead of a depth-1 stub
    uses_window: bool = False
    axes: Tuple[AxisSpec, ...] = ()

    # -- declarations ------------------------------------------------------

    def axis(self, name: str) -> AxisSpec:
        for a in self.axes:
            if a.name == name:
                return a
        raise KeyError(f"policy family {self.name!r} has no axis {name!r}")

    def axis_names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    def sweepable_axes(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes if a.sweepable)

    def learnable_axes(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes if a.learnable)

    # -- lowering ----------------------------------------------------------

    def init_params(self, policy) -> dict:
        """The params pytree for one ``JaxPolicy`` — {axis name: leaf}.
        Default: pull each declared axis off the policy's field of the same
        name, falling back to the policy's ``extra`` mapping for axes the
        legacy field set does not carry (families with structured leaves
        override)."""
        out = {}
        extra = getattr(policy, "extra", None) or {}
        for a in self.axes:
            if hasattr(policy, a.name):
                out[a.name] = float(getattr(policy, a.name))
            elif a.name in extra:
                out[a.name] = float(extra[a.name])
            else:
                raise ValueError(
                    f"policy family {self.name!r}: no value for axis "
                    f"{a.name!r} — pass it via JaxPolicy(extra={{...}})")
        return out

    def decide(self, params: Mapping, obs: PolicyObs) -> JaxDecision:
        raise NotImplementedError

    def oracle_factory(self, spec) -> Callable[[int], Policy]:
        """Lower an engine-neutral ``PolicySpec`` to per-function oracle
        policy objects (the ``eventsim`` / ``control_plane`` side)."""
        raise NotImplementedError

    # -- validation --------------------------------------------------------

    def validate(self, params: Mapping) -> None:
        """Reject NaN / out-of-bounds leaves at construction time (the scan
        would otherwise propagate a NaN keepalive silently until the CI
        gate's final fail-closed check)."""
        for a in self.axes:
            if a.name not in params:
                raise ValueError(f"policy family {self.name!r}: missing "
                                 f"param {a.name!r}")
            for leaf in _leaves(params[a.name]):
                vals = np.asarray(leaf, np.float64)
                if not np.all(np.isfinite(vals)):
                    raise ValueError(
                        f"policy family {self.name!r}: non-finite value in "
                        f"param {a.name!r} ({vals!r})")
                if np.any(vals < a.lo) or np.any(vals > a.hi):
                    raise ValueError(
                        f"policy family {self.name!r}: param {a.name!r} out "
                        f"of bounds [{a.lo}, {a.hi}] (got {vals!r})")
        extra = set(params) - set(self.axis_names())
        if extra:
            raise ValueError(f"policy family {self.name!r}: unknown params "
                             f"{sorted(extra)}; declared axes are "
                             f"{sorted(self.axis_names())}")


def _leaves(x):
    if isinstance(x, Mapping):
        for v in x.values():
            yield from _leaves(v)
    elif isinstance(x, (list, tuple)):
        for v in x:
            yield from _leaves(v)
    else:
        yield x


# every family shares the container-concurrency axis: the ENGINE reads it
# (slot capacity, memory packing), so it acts under any policy and
# ``register_family`` requires it to be declared (reuse this spec)
CC_AXIS = AxisSpec("cc", 1.0, 64.0, doc="container concurrency (slots per "
                   "instance; engine-level packing knob)")
_CC_AXIS = CC_AXIS


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FAMILIES: dict[str, PolicyFamily] = {}
_BY_KIND: dict[int, PolicyFamily] = {}


def register_family(family: PolicyFamily) -> PolicyFamily:
    if not family.name:
        raise ValueError("policy family needs a name")
    if family.name in _FAMILIES:
        raise ValueError(f"duplicate policy family {family.name!r}")
    if "cc" not in family.axis_names():
        raise ValueError(
            f"policy family {family.name!r} must declare a 'cc' axis — the "
            f"engine reads params['cc'] for slot capacity and memory "
            f"packing (reuse policy_api.CC_AXIS)")
    _FAMILIES[family.name] = family
    if family.kind is not None:
        if family.kind in _BY_KIND:
            raise ValueError(f"duplicate legacy kind {family.kind}")
        _BY_KIND[family.kind] = family
    return family


def get_family(key: Union[str, int]) -> PolicyFamily:
    """Look a family up by registry name (or legacy integer kind)."""
    if isinstance(key, (int, np.integer)) and not isinstance(key, bool):
        try:
            return _BY_KIND[int(key)]
        except KeyError:
            raise KeyError(f"unknown policy kind {key}; registered kinds: "
                           f"{sorted(_BY_KIND)}") from None
    try:
        return _FAMILIES[key]
    except KeyError:
        raise KeyError(f"unknown policy family {key!r}; registered: "
                       f"{sorted(_FAMILIES)}") from None


def list_families() -> list[str]:
    return sorted(_FAMILIES)


def sweepable_policy_axes() -> set:
    """Union of every registered family's sweepable axes — the policy side
    of ``repro.opt.space.SWEEPABLE`` (derived, not hand-written)."""
    out: set = set()
    for fam in _FAMILIES.values():
        out |= set(fam.sweepable_axes())
    return out


# ---------------------------------------------------------------------------
# the three ported families (bit-for-bit the former _make_step branches)
# ---------------------------------------------------------------------------


class SyncKeepaliveFamily(PolicyFamily):
    """AWS-Lambda-like (paper §2.1.1): create on the request critical path,
    retire idle instances by the renewal-matched keepalive expiry flux."""
    name = "sync"
    kind = 0
    synchronous_tail = True
    axes = (AxisSpec("keepalive_s", 1.0, 86_400.0,
                     doc="idle-instance retention"), _CC_AXIS)

    def _ka_eff(self, params, obs):
        return params["keepalive_s"]

    def decide(self, params, obs):
        ka_eff = self._ka_eff(params, obs)
        r = empirical_expiry_rate(obs, ka_eff)
        # survival form of the expiry flux: equals r*dt to first order but
        # saturates at the idle mass, so a large empirical rate (bursty
        # functions under a short keepalive) can never retire instances
        # that do not exist and drive the carry negative
        retire = obs.idle_frac * -jnp.expm1(-r * obs.dt)
        return JaxDecision(create=obs.demand, retire=retire)

    def oracle_factory(self, spec):
        return lambda f: SyncKeepalivePolicy(
            keepalive_s=spec.keepalive_s,
            container_concurrency=spec.container_concurrency)


class AsyncWindowFamily(PolicyFamily):
    """Knative-KPA-like (paper §2.1.2): reconcile instance count to
    ceil(window_avg(concurrency) / (target * cc)) each tick."""
    name = "async"
    kind = 1
    synchronous_tail = False     # backlog episodes correlate request tails
    cold_factor = 1.5            # reconcile tick precedes the sandbox request
    uses_window = True
    axes = (AxisSpec("target", 0.05, 4.0, doc="utilization target"), _CC_AXIS)

    def decide(self, params, obs):
        desired = jnp.ceil(obs.avg / (params["target"] * params["cc"]) - 1e-9)
        have = obs.inst + obs.pending
        create = jnp.maximum(desired - have, 0.0)
        retire = jnp.minimum(jnp.maximum(have - desired, 0.0), obs.idle)
        return JaxDecision(create=create, retire=retire)

    def oracle_factory(self, spec):
        return lambda f: AsyncConcurrencyPolicy(
            window_s=spec.window_s, target=spec.target,
            container_concurrency=spec.container_concurrency,
            tick_s=spec.tick_s)


class HybridHistogramFamily(SyncKeepaliveFamily):
    """Shahrad'20 hybrid histogram (beyond-paper): keepalive ~ the p99 of
    the function's idle-gap distribution (clipped to [HYBRID_MIN_KA_S,
    keepalive_s]) plus a pre-warm lead that hides part of the cold start."""
    name = "hybrid"
    kind = 2
    axes = (AxisSpec("keepalive_s", 1.0, 86_400.0,
                     doc="cap on the adaptive keepalive (maps to max_s)"),
            _CC_AXIS,
            AxisSpec("prewarm_s", 0.0, 300.0, doc="pre-warm lead"))

    def _ka_eff(self, params, obs):
        # the oracle keeps warm for ~the p99 of the function's OBSERVED
        # idle-gap histogram x 1.1 headroom; the fluid twin uses the
        # trace-side empirical gap quantile (``trace.gap_quantile``) rather
        # than a Poisson quantile at the mean rate — on time-warped /
        # bursty traces the Poisson -ln(0.01)/lam overstates chatty
        # functions' gaps severalfold and breaks the parity band
        return jnp.clip(1.1 * obs.gap_p99,
                        HYBRID_MIN_KA_S, params["keepalive_s"])

    def decide(self, params, obs):
        base = super().decide(params, obs)
        return base._replace(cold_hide=params["prewarm_s"])

    def oracle_factory(self, spec):
        return lambda f: HybridHistogramPolicy(
            max_s=spec.keepalive_s,
            container_concurrency=spec.container_concurrency)


# ---------------------------------------------------------------------------
# the first post-redesign client: a gradient-learned keepalive policy
# ---------------------------------------------------------------------------


class LearnedKeepaliveFamily(SyncKeepaliveFamily):
    """Per-function adaptive keepalive as a tiny MLP over the observed
    arrival rate — the smooth, parameterized generalization of the hybrid
    heuristic.  ``theta`` is a LEARNABLE pytree leaf axis: it rides the
    scan as traced leaves, so ``jax.grad`` through ``simulate_chunked``'s
    step math trains it on a differentiable cost+latency surrogate
    (``repro.opt.learned``); the oracle spot-check machinery gates what the
    trained policy may claim.  The network itself lives in
    ``repro.core.policies.learned_keepalive`` so the oracle twin evaluates
    identical arithmetic."""
    name = "learned"
    kind = 3
    axes = (_CC_AXIS,
            AxisSpec("theta", -1e3, 1e3, sweepable=False, learnable=True,
                     doc="MLP weights: per-function keepalive from rate"))

    def init_params(self, policy) -> dict:
        theta = policy.theta if policy.theta is not None else init_theta()
        return {"cc": float(policy.cc), "theta": theta}

    def _ka_eff(self, params, obs):
        # the feature is the FUNCTION's rate (what the oracle twin can
        # estimate online); the expiry conversion stays per-instance
        return learned_keepalive(params["theta"], obs.lam, xp=jnp)

    def oracle_factory(self, spec):
        theta = getattr(spec, "theta", None)
        return lambda f: LearnedKeepalivePolicy(
            theta=theta, container_concurrency=spec.container_concurrency)


# ---------------------------------------------------------------------------
# spot-aware scaling: insure warm capacity against the preemption hazard
# ---------------------------------------------------------------------------


class SpotAwareFamily(SyncKeepaliveFamily):
    """Sync keepalive scaling for a fleet buying ``spot_fraction`` of its
    nodes on a preemptible tier with ``hazard_per_hour`` reclaims per
    node-hour.  Two effects:

    * the ENGINE reads the two spot axes (like it reads ``cc``): the fleet
      layer splits node purchases across tiers at ``spot_fraction`` and
      integrates the eviction flux at ``hazard_per_hour`` — warm instances
      on reclaimed capacity die, their in-flight work re-queues as
      scale-up pressure (``repro.fleet.spot`` is the discrete twin);
    * the POLICY over-provisions warm headroom to the expected instance
      loss over ``SPOT_HEADROOM_HORIZON_S``, so evictions land on
      pre-warmed spares instead of the request critical path.

    Declaring the axes sweepable puts (spot_fraction, hazard_per_hour) on
    the frontier grid: the engine trades the spot discount against the
    eviction-driven cold-start storms it causes."""
    name = "spot_aware"
    kind = None                      # post-redesign family: no legacy id
    axes = (AxisSpec("keepalive_s", 1.0, 86_400.0,
                     doc="idle-instance retention"), _CC_AXIS,
            AxisSpec("spot_fraction", 0.0, 1.0,
                     doc="share of the node fleet bought on the spot tier"),
            AxisSpec("hazard_per_hour", 0.0, 60.0,
                     doc="spot preemption rate (reclaims per node-hour)"))

    def decide(self, params, obs):
        base = super().decide(params, obs)
        # top idle capacity up to the expected eviction loss over the
        # headroom horizon — rounded to whole instances and netted against
        # the INTEGRAL idle count, mirroring the oracle twin's arithmetic
        # (a continuous target would hold fractional headroom the oracle
        # never buys)
        target = jnp.round(obs.inst * params["spot_fraction"]
                           * params["hazard_per_hour"] / 3600.0
                           * SPOT_HEADROOM_HORIZON_S)
        extra = jnp.maximum(target - obs.idle - obs.pending, 0.0)
        return base._replace(create=base.create + extra)

    def oracle_factory(self, spec):
        extra = dict(getattr(spec, "extra", None) or {})
        sf = float(extra.get("spot_fraction", 0.0))
        hz = float(extra.get("hazard_per_hour", 0.0))
        return lambda f: SpotAwarePolicy(
            keepalive_s=spec.keepalive_s,
            container_concurrency=spec.container_concurrency,
            spot_fraction=sf, hazard_per_hour=hz)


register_family(SyncKeepaliveFamily())
register_family(AsyncWindowFamily())
register_family(HybridHistogramFamily())
register_family(LearnedKeepaliveFamily())
register_family(SpotAwareFamily())

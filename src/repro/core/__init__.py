# The paper's primary contribution: serverless autoscaling policies, the
# control plane that runs them, trace synthesis, metrics, and the two
# simulators (discrete-event oracle + vectorized lax.scan fleet simulator).
from repro.core.policies import (  # noqa: F401
    AsyncConcurrencyPolicy,
    HybridHistogramPolicy,
    Policy,
    PolicyDecision,
    SyncKeepalivePolicy,
    make_policy,
)

"""Azure-Functions-like workload synthesis + In-Vitro-style sampling.

The paper replays a 400-function sample (300k invocations / 80 min) and a
2000-function sample (3.5M invocations) of the Azure Functions trace
[Shahrad'20] produced with In-Vitro [Ustiugov'23].  The real trace is not
shippable here, so we synthesize a workload with its published marginals:

* per-function average rates are heavy-tailed (log-uniform over ~4 decades;
  a small head of functions carries most of the load),
* inter-arrivals per function are bursty (doubly-stochastic: diurnal-ish
  slow modulation x Poisson),
* execution durations are lognormal (median ~600 ms, long tail, capped),
* memory per instance follows the Azure quantiles (~128-512 MB).

``sample_functions`` implements the In-Vitro idea: stratified sampling over
the rate distribution so a small sample preserves the load *shape* of the
full population.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    num_functions: int = 400
    duration_s: float = 4800.0          # 80 minutes
    seed: int = 0
    target_total_rps: float = 62.5      # ~300k invocations / 80 min
    min_rate: float = 1.0 / 900.0       # 1 per 15 min
    max_rate: float = 4.0               # hot functions
    dur_median_s: float = 0.6
    dur_sigma: float = 1.0
    dur_cap_s: float = 30.0
    burst_period_s: float = 300.0
    burst_amp: float = 0.6              # 0 = pure Poisson


@dataclasses.dataclass
class FunctionProfile:
    rate: np.ndarray          # (F,) mean requests/s
    dur_median: np.ndarray    # (F,) seconds
    dur_sigma: np.ndarray     # (F,)
    memory_mb: np.ndarray     # (F,)
    phase: np.ndarray         # (F,) burst phase offset


@dataclasses.dataclass
class Trace:
    """Flat invocation stream, sorted by time."""
    t: np.ndarray             # (N,) arrival seconds
    fn: np.ndarray            # (N,) function ids
    dur: np.ndarray           # (N,) pure execution seconds
    profile: FunctionProfile
    duration_s: float

    @property
    def num_functions(self) -> int:
        return len(self.profile.rate)

    def __len__(self) -> int:
        return len(self.t)


@dataclasses.dataclass
class RateTrace:
    """Pre-binned arrival counts — the planet-scale workload carrier.

    A flat event stream at 100k functions / 50M invocations costs more to
    synthesize and sort than the fluid simulator costs to replay it, and
    the chunked scan only ever consumes per-tick counts anyway.  RateTrace
    holds the (T, F) count matrix directly (synthesized vectorized, Poisson
    per tick) plus the per-function profile; ``weights`` carries the member
    multiplicity when functions have been clustered into super-functions
    (``repro.scenarios.cluster``), in which case ``counts`` columns are the
    bucket-MEAN per-tick arrivals (fractional) of one representative.

    The discrete-event oracle cannot replay a RateTrace (there is no event
    stream); the runner drops the eventsim leg for rate-based scenarios.
    """
    counts: np.ndarray        # (T, F) arrivals per tick (float for clustered)
    tick_s: float
    profile: FunctionProfile
    duration_s: float
    weights: np.ndarray | None = None   # (F,) member multiplicity (None = 1)

    @property
    def num_functions(self) -> int:
        return len(self.profile.rate)

    def __len__(self) -> int:
        w = 1.0 if self.weights is None else self.weights[None, :]
        return int(round(float((self.counts * w).sum())))


def make_profile(cfg: TraceConfig) -> FunctionProfile:
    rng = np.random.default_rng(cfg.seed)
    f = cfg.num_functions
    # log-uniform rates, rescaled to the target aggregate load
    rate = np.exp(rng.uniform(np.log(cfg.min_rate), np.log(cfg.max_rate), f))
    rate *= cfg.target_total_rps / rate.sum()
    dur_median = np.clip(
        np.exp(rng.normal(np.log(cfg.dur_median_s), 0.8, f)), 0.05, cfg.dur_cap_s)
    dur_sigma = np.full(f, cfg.dur_sigma)
    memory_mb = rng.choice([128, 128, 128, 256, 256, 512], size=f).astype(np.float64)
    phase = rng.uniform(0, 2 * np.pi, f)
    return FunctionProfile(rate, dur_median, dur_sigma, memory_mb, phase)


def synthesize(cfg: TraceConfig, profile: FunctionProfile | None = None) -> Trace:
    rng = np.random.default_rng(cfg.seed + 1)
    prof = profile or make_profile(cfg)
    f = len(prof.rate)
    ts, fns, durs = [], [], []
    for i in range(f):
        # doubly-stochastic arrivals: thinned Poisson with sinusoidal intensity
        lam_max = prof.rate[i] * (1 + cfg.burst_amp)
        n = rng.poisson(lam_max * cfg.duration_s)
        if n == 0:
            continue
        t = np.sort(rng.uniform(0, cfg.duration_s, n))
        intensity = (1 + cfg.burst_amp * np.sin(
            2 * np.pi * t / cfg.burst_period_s + prof.phase[i])) / (1 + cfg.burst_amp)
        keep = rng.uniform(size=n) < intensity
        t = t[keep]
        if len(t) == 0:
            continue
        d = np.clip(rng.lognormal(np.log(prof.dur_median[i]), prof.dur_sigma[i],
                                  len(t)), 0.02, cfg.dur_cap_s)
        ts.append(t)
        fns.append(np.full(len(t), i, np.int32))
        durs.append(d)
    t = np.concatenate(ts)
    order = np.argsort(t, kind="stable")
    return Trace(t[order], np.concatenate(fns)[order],
                 np.concatenate(durs)[order], prof, cfg.duration_s)


def synthesize_rates(cfg: TraceConfig, tick_s: float = 1.0,
                     profile: FunctionProfile | None = None) -> RateTrace:
    """Vectorized counterpart of :func:`synthesize` producing a
    :class:`RateTrace`: per-tick Poisson counts under the same sinusoidal
    intensity modulation, drawn in time blocks so the intermediate
    intensity buffer stays bounded (~32 MB) even at 100k functions.

    The marginals match ``synthesize`` (same profile, same mean intensity
    per tick); the streams are not sample-path identical — rate-based
    scenarios are fluid-engine workloads, not oracle replays."""
    rng = np.random.default_rng(cfg.seed + 1)
    prof = profile or make_profile(cfg)
    f = len(prof.rate)
    t_ticks = int(np.ceil(cfg.duration_s / tick_s))
    counts = np.empty((t_ticks, f), np.int32)
    block = max(1, int(4_000_000 // max(f, 1)))
    for b0 in range(0, t_ticks, block):
        b1 = min(b0 + block, t_ticks)
        t_mid = (np.arange(b0, b1, dtype=np.float64) + 0.5) * tick_s
        mod = 1.0 + cfg.burst_amp * np.sin(
            2 * np.pi * t_mid[:, None] / cfg.burst_period_s + prof.phase[None, :])
        lam = np.clip(prof.rate[None, :] * mod, 0.0, None) * tick_s
        counts[b0:b1] = rng.poisson(lam).astype(np.int32)
    return RateTrace(counts, float(tick_s), prof, float(cfg.duration_s))


def sample_functions(full: FunctionProfile, n: int, seed: int = 0) -> FunctionProfile:
    """In-Vitro-style stratified sample: preserve the rate distribution by
    sampling uniformly within rate quantile strata."""
    rng = np.random.default_rng(seed)
    order = np.argsort(full.rate)
    strata = np.array_split(order, n)
    idx = np.array([rng.choice(s) for s in strata if len(s)])
    # rescale so the sample carries the same load per function on average
    return FunctionProfile(full.rate[idx], full.dur_median[idx],
                           full.dur_sigma[idx], full.memory_mb[idx],
                           full.phase[idx])


def concat_profiles(a: FunctionProfile, b: FunctionProfile) -> FunctionProfile:
    """Stack two function populations; ids of *b* shift by ``len(a.rate)``."""
    return FunctionProfile(*(np.concatenate([getattr(a, f.name),
                                             getattr(b, f.name)])
                             for f in dataclasses.fields(FunctionProfile)))


def merge_traces(a: Trace, b: Trace) -> Trace:
    """Interleave two invocation streams onto one shared cluster, re-keying
    *b*'s function ids past *a*'s population (multi-tenant composition)."""
    t = np.concatenate([a.t, b.t])
    fn = np.concatenate([a.fn, b.fn + a.num_functions]).astype(np.int32)
    dur = np.concatenate([a.dur, b.dur])
    order = np.argsort(t, kind="stable")
    return Trace(t[order], fn[order], dur[order],
                 concat_profiles(a.profile, b.profile),
                 max(a.duration_s, b.duration_s))


def rate_matrix(trace, tick_s: float = 1.0) -> np.ndarray:
    """(T, F) arrival counts per tick — the input format of the vectorized
    simulator (repro.core.simjax).  RateTraces already ARE count matrices:
    returned as-is at their native tick, sum-pooled when the requested tick
    is an integer multiple, refused otherwise (counts cannot be split)."""
    if isinstance(trace, RateTrace):
        ratio = tick_s / trace.tick_s
        if abs(ratio - round(ratio)) > 1e-9 or round(ratio) < 1:
            raise ValueError(
                f"RateTrace binned at {trace.tick_s}s cannot be re-binned to "
                f"{tick_s}s (only integer multiples of the native tick)")
        r = int(round(ratio))
        counts = trace.counts
        if r == 1:
            return counts
        t = counts.shape[0]
        pad = (-t) % r
        if pad:
            counts = np.concatenate(
                [counts, np.zeros((pad, counts.shape[1]), counts.dtype)])
        return counts.reshape(-1, r, counts.shape[1]).sum(axis=1)
    t_ticks = int(np.ceil(trace.duration_s / tick_s))
    out = np.zeros((t_ticks, trace.num_functions), np.int32)
    tick = np.minimum((trace.t / tick_s).astype(np.int64), t_ticks - 1)
    np.add.at(out, (tick, trace.fn), 1)
    return out


def _function_gaps(trace: Trace):
    """One shared extraction pass behind the gap statistics: per-function
    inter-arrival gaps, time-ordered.  Yields (fn, gaps, gap_end_times)
    per function with >= 2 arrivals — the sort/group work every caller of
    ``gap_quantile``/``gap_tables`` would otherwise redo on multi-million
    event traces."""
    order = np.lexsort((trace.t, trace.fn))
    fn, t = trace.fn[order], trace.t[order]
    gaps = np.diff(t)
    same = np.diff(fn) == 0
    gfn, gv, gt = fn[1:][same], gaps[same], t[1:][same]
    starts = np.flatnonzero(np.r_[True, np.diff(gfn) != 0]) \
        if len(gfn) else np.zeros(0, np.int64)
    bounds = np.r_[starts, len(gfn)]
    for s, e in zip(bounds[:-1], bounds[1:]):
        yield int(gfn[s]), gv[s:e], gt[s:e]


def gap_quantile(trace: Trace, q: float = 0.99, window: int = 256,
                 stride: int = 16, gaps=None) -> np.ndarray:
    """(F,) empirical q-quantile of each function's inter-arrival gap, as
    the oracle hybrid policy OBSERVES it: over a rolling ``window`` of the
    most recent gaps (its histogram is a maxlen-256 deque), averaged across
    the trace's measurement half.

    An adaptive-keepalive fluid model needs the gap distribution the oracle
    sees, not a Poisson quantile at the mean rate — on time-warped / bursty
    traces the two differ severalfold, and for chatty functions the rolling
    window tracks the current phase where a whole-trace quantile would mix
    day and night gaps.  Functions with fewer than two arrivals report the
    trace duration (a gap never observed; callers clip to their keepalive
    cap)."""
    out = np.full(trace.num_functions, trace.duration_s, np.float64)
    half = trace.duration_s / 2
    for i, g, gtime in (gaps if gaps is not None else _function_gaps(trace)):
        if len(g) <= window:
            out[i] = np.quantile(g, q)
            continue
        ends = np.arange(window, len(g) + 1,
                         max(1, min(stride, (len(g) - window) // 8 + 1)))
        # windows the measurement half consults (fall back to the tail)
        meas = ends[gtime[ends - 1] >= half]
        if len(meas) == 0:
            meas = ends[-1:]
        out[i] = np.mean([np.quantile(g[m - window:m], q) for m in meas])
    return out


#: keepalive grid for ``gap_tables`` (log-spaced ms .. a day)
KA_GRID = np.geomspace(1e-3, 86_400.0, 56)


def gap_tables(trace: Trace, grid: np.ndarray = KA_GRID,
               gaps=None) -> tuple[np.ndarray, np.ndarray]:
    """Two (F, K) tables over the keepalive grid, per function:

    * ``alive``: E[min(gap, grid[k])] — mean renewal-cycle length under a
      keepalive of grid[k] (the cycle ends at the next arrival or the
      timer, whichever first);
    * ``tail``:  P(gap > grid[k]) — the probability that cycle ends in an
      expiry.

    Their ratio tail/alive is the renewal-exact expiry rate for the
    function's ACTUAL gap distribution (``policy_api
    .empirical_expiry_rate``): the analytic Poisson form under-expires
    strongly bursty traces (diurnal warps, production tails) under short
    keepalives, while matching cycle length alone over-expires burst-heavy
    functions whose clustered gaps shrink the mean cycle without adding
    expiry events.  Interpolating both inside the scan reproduces
    lam/(e^{lam*ka}-1) exactly when gaps ARE exponential and the measured
    truth when they are not.  Functions with fewer than two arrivals get
    alive = ka, tail = 1 (a gap never observed: the pure idle-timer
    limit)."""
    f = trace.num_functions
    alive = np.broadcast_to(grid, (f, len(grid))).copy()
    tail = np.ones((f, len(grid)))
    for i, gv, _ in (gaps if gaps is not None else _function_gaps(trace)):
        g = np.sort(gv)
        csum = np.concatenate([[0.0], np.cumsum(g)])
        k = np.searchsorted(g, grid, side="right")
        # mean of min(gap, ka): gaps below ka contribute themselves,
        # gaps above contribute ka
        alive[i] = (csum[k] + grid * (len(g) - k)) / len(g)
        tail[i] = (len(g) - k) / len(g)
    return alive, tail


def gap_statistics(trace, q: float = 0.99,
                   grid: np.ndarray = KA_GRID):
    """(gap_p99, alive_tab, tail_tab) from ONE extraction pass — what the
    fluid engines consume per simulate/sweep/training call; calling
    ``gap_quantile`` and ``gap_tables`` separately would redo the
    O(N log N) sort+group on multi-million-event traces.

    RateTraces have no event stream to measure, so they get the analytic
    Poisson forms at each function's mean rate (exact for the per-tick
    Poisson counts ``synthesize_rates`` draws): gap quantile
    -ln(1-q)/lam, alive E[min(gap, ka)] = (1 - e^{-lam ka})/lam, tail
    P(gap > ka) = e^{-lam ka}.  Zero-rate functions report the trace
    duration / pure idle-timer limits, matching the empirical convention
    for functions with fewer than two arrivals."""
    if isinstance(trace, RateTrace):
        lam = np.asarray(trace.counts, np.float64).mean(axis=0) / trace.tick_s
        f, k = len(lam), len(grid)
        pos = lam > 0
        gq = np.full(f, trace.duration_s, np.float64)
        gq[pos] = np.minimum(-np.log1p(-q) / lam[pos], trace.duration_s)
        alive = np.broadcast_to(grid, (f, k)).copy()
        tail = np.ones((f, k))
        lg = lam[pos, None] * grid[None, :]
        alive[pos] = -np.expm1(-lg) / lam[pos, None]
        tail[pos] = np.exp(-lg)
        return gq, alive, tail
    per_fn = list(_function_gaps(trace))
    return (gap_quantile(trace, q, gaps=per_fn),
            *gap_tables(trace, grid, gaps=per_fn))

"""Azure-Functions-like workload synthesis + In-Vitro-style sampling.

The paper replays a 400-function sample (300k invocations / 80 min) and a
2000-function sample (3.5M invocations) of the Azure Functions trace
[Shahrad'20] produced with In-Vitro [Ustiugov'23].  The real trace is not
shippable here, so we synthesize a workload with its published marginals:

* per-function average rates are heavy-tailed (log-uniform over ~4 decades;
  a small head of functions carries most of the load),
* inter-arrivals per function are bursty (doubly-stochastic: diurnal-ish
  slow modulation x Poisson),
* execution durations are lognormal (median ~600 ms, long tail, capped),
* memory per instance follows the Azure quantiles (~128-512 MB).

``sample_functions`` implements the In-Vitro idea: stratified sampling over
the rate distribution so a small sample preserves the load *shape* of the
full population.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    num_functions: int = 400
    duration_s: float = 4800.0          # 80 minutes
    seed: int = 0
    target_total_rps: float = 62.5      # ~300k invocations / 80 min
    min_rate: float = 1.0 / 900.0       # 1 per 15 min
    max_rate: float = 4.0               # hot functions
    dur_median_s: float = 0.6
    dur_sigma: float = 1.0
    dur_cap_s: float = 30.0
    burst_period_s: float = 300.0
    burst_amp: float = 0.6              # 0 = pure Poisson


@dataclasses.dataclass
class FunctionProfile:
    rate: np.ndarray          # (F,) mean requests/s
    dur_median: np.ndarray    # (F,) seconds
    dur_sigma: np.ndarray     # (F,)
    memory_mb: np.ndarray     # (F,)
    phase: np.ndarray         # (F,) burst phase offset


@dataclasses.dataclass
class Trace:
    """Flat invocation stream, sorted by time."""
    t: np.ndarray             # (N,) arrival seconds
    fn: np.ndarray            # (N,) function ids
    dur: np.ndarray           # (N,) pure execution seconds
    profile: FunctionProfile
    duration_s: float

    @property
    def num_functions(self) -> int:
        return len(self.profile.rate)

    def __len__(self) -> int:
        return len(self.t)


def make_profile(cfg: TraceConfig) -> FunctionProfile:
    rng = np.random.default_rng(cfg.seed)
    f = cfg.num_functions
    # log-uniform rates, rescaled to the target aggregate load
    rate = np.exp(rng.uniform(np.log(cfg.min_rate), np.log(cfg.max_rate), f))
    rate *= cfg.target_total_rps / rate.sum()
    dur_median = np.clip(
        np.exp(rng.normal(np.log(cfg.dur_median_s), 0.8, f)), 0.05, cfg.dur_cap_s)
    dur_sigma = np.full(f, cfg.dur_sigma)
    memory_mb = rng.choice([128, 128, 128, 256, 256, 512], size=f).astype(np.float64)
    phase = rng.uniform(0, 2 * np.pi, f)
    return FunctionProfile(rate, dur_median, dur_sigma, memory_mb, phase)


def synthesize(cfg: TraceConfig, profile: FunctionProfile | None = None) -> Trace:
    rng = np.random.default_rng(cfg.seed + 1)
    prof = profile or make_profile(cfg)
    f = len(prof.rate)
    ts, fns, durs = [], [], []
    for i in range(f):
        # doubly-stochastic arrivals: thinned Poisson with sinusoidal intensity
        lam_max = prof.rate[i] * (1 + cfg.burst_amp)
        n = rng.poisson(lam_max * cfg.duration_s)
        if n == 0:
            continue
        t = np.sort(rng.uniform(0, cfg.duration_s, n))
        intensity = (1 + cfg.burst_amp * np.sin(
            2 * np.pi * t / cfg.burst_period_s + prof.phase[i])) / (1 + cfg.burst_amp)
        keep = rng.uniform(size=n) < intensity
        t = t[keep]
        if len(t) == 0:
            continue
        d = np.clip(rng.lognormal(np.log(prof.dur_median[i]), prof.dur_sigma[i],
                                  len(t)), 0.02, cfg.dur_cap_s)
        ts.append(t)
        fns.append(np.full(len(t), i, np.int32))
        durs.append(d)
    t = np.concatenate(ts)
    order = np.argsort(t, kind="stable")
    return Trace(t[order], np.concatenate(fns)[order],
                 np.concatenate(durs)[order], prof, cfg.duration_s)


def sample_functions(full: FunctionProfile, n: int, seed: int = 0) -> FunctionProfile:
    """In-Vitro-style stratified sample: preserve the rate distribution by
    sampling uniformly within rate quantile strata."""
    rng = np.random.default_rng(seed)
    order = np.argsort(full.rate)
    strata = np.array_split(order, n)
    idx = np.array([rng.choice(s) for s in strata if len(s)])
    # rescale so the sample carries the same load per function on average
    return FunctionProfile(full.rate[idx], full.dur_median[idx],
                           full.dur_sigma[idx], full.memory_mb[idx],
                           full.phase[idx])


def concat_profiles(a: FunctionProfile, b: FunctionProfile) -> FunctionProfile:
    """Stack two function populations; ids of *b* shift by ``len(a.rate)``."""
    return FunctionProfile(*(np.concatenate([getattr(a, f.name),
                                             getattr(b, f.name)])
                             for f in dataclasses.fields(FunctionProfile)))


def merge_traces(a: Trace, b: Trace) -> Trace:
    """Interleave two invocation streams onto one shared cluster, re-keying
    *b*'s function ids past *a*'s population (multi-tenant composition)."""
    t = np.concatenate([a.t, b.t])
    fn = np.concatenate([a.fn, b.fn + a.num_functions]).astype(np.int32)
    dur = np.concatenate([a.dur, b.dur])
    order = np.argsort(t, kind="stable")
    return Trace(t[order], fn[order], dur[order],
                 concat_profiles(a.profile, b.profile),
                 max(a.duration_s, b.duration_s))


def rate_matrix(trace: Trace, tick_s: float = 1.0) -> np.ndarray:
    """(T, F) arrival counts per tick — the input format of the vectorized
    simulator (repro.core.simjax)."""
    t_ticks = int(np.ceil(trace.duration_s / tick_s))
    out = np.zeros((t_ticks, trace.num_functions), np.int32)
    tick = np.minimum((trace.t / tick_s).astype(np.int64), t_ticks - 1)
    np.add.at(out, (tick, trace.fn), 1)
    return out

"""The paper's metric suite (§3.6).

* end-to-end performance: geometric mean over functions of the per-function
  99th-percentile slowdown ((end - arrival) / pure duration); 1.0 = unloaded.
* normalized memory usage: time-averaged total instance memory / time-averaged
  memory of instances actively serving a request.
* instance creation rate (events/s over the measurement window).
* normalized CPU overhead: system CPU (worker + master) / useful function CPU,
  plus the worker/master breakdown (paper: ~80/20).

Beyond-paper: when a node fleet (repro.fleet) is attached, the result also
carries node-hours and the mean billable node count, the inputs to the
dollar-cost model in ``repro.fleet.costs``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.eventsim import SimResult


@dataclasses.dataclass
class Metrics:
    slowdown_geomean_p99: float
    normalized_memory: float
    creation_rate: float
    cpu_overhead: float
    cpu_overhead_worker: float
    cpu_overhead_master: float
    worker_share: float
    queueing_p50: float
    queueing_p99: float
    cold_fraction: float
    completed: int
    # requests the static cluster refused (creation failed, nothing queued
    # them).  Non-zero dropped explains NaN queueing/cold columns: an
    # all-drop run has no records at all, which would otherwise read as
    # silently "no data".
    dropped: int = 0
    # node-fleet layer (NaN/0 when simulating a static cluster)
    nodes_mean: float = math.nan
    node_hours: float = 0.0
    node_provisions: int = 0
    node_terminations: int = 0
    # spot tier (0 for an on-demand-only fleet)
    spot_node_hours: float = 0.0
    node_evictions: int = 0

    def row(self) -> dict:
        return dataclasses.asdict(self)


def per_function_p99_slowdown(result: SimResult, min_requests: int = 5) -> np.ndarray:
    """Vectorized sort/groupby: one lexsort over (fn, slowdown), then each
    function's p99 by linear interpolation inside its sorted run — exactly
    ``np.percentile(v, 99)`` per group, without the per-record Python loop
    (the fig9-scale oracle replay has ~3.5M records)."""
    n = len(result.records)
    if n == 0:
        return np.zeros(0)
    fn = np.fromiter((r.fn for r in result.records), np.int64, n)
    arrival = np.fromiter((r.arrival for r in result.records), np.float64, n)
    end = np.fromiter((r.end for r in result.records), np.float64, n)
    dur = np.fromiter((r.dur for r in result.records), np.float64, n)
    ok = ~np.isnan(end)
    slow = np.maximum((end[ok] - arrival[ok]) / np.maximum(dur[ok], 1e-6), 1.0)
    fn = fn[ok]
    if not len(fn):
        return np.zeros(0)
    order = np.lexsort((slow, fn))
    fn, slow = fn[order], slow[order]
    starts = np.flatnonzero(np.r_[True, fn[1:] != fn[:-1]])
    counts = np.diff(np.r_[starts, len(fn)])
    keep = counts >= min_requests
    starts, counts = starts[keep], counts[keep]
    pos = starts + 0.99 * (counts - 1)       # percentile index, per group
    lo = np.floor(pos).astype(np.int64)
    hi = np.minimum(lo + 1, starts + counts - 1)
    frac = pos - lo
    return slow[lo] * (1.0 - frac) + slow[hi] * frac


def compute(result: SimResult) -> Metrics:
    slows = per_function_p99_slowdown(result)
    geo = float(np.exp(np.mean(np.log(np.maximum(slows, 1.0))))) if len(slows) else math.nan

    total = result.mem_samples_total_mb
    busy = result.mem_samples_busy_mb
    norm_mem = float(total.mean() / max(busy.mean(), 1e-9)) if len(total) else math.nan

    window = max(result.measure_window_s, 1e-9)
    rate = result.creations / window

    useful = max(result.cpu_useful_s, 1e-9)
    w = result.cpu_worker_overhead_s
    m = result.cpu_master_overhead_s
    qd = np.asarray([r.start - r.arrival for r in result.records
                     if not math.isnan(r.start)])
    colds = np.asarray([r.cold for r in result.records], dtype=bool)

    return Metrics(
        slowdown_geomean_p99=geo,
        normalized_memory=norm_mem,
        creation_rate=rate,
        cpu_overhead=(w + m) / useful,
        cpu_overhead_worker=w / useful,
        cpu_overhead_master=m / useful,
        worker_share=w / max(w + m, 1e-9),
        queueing_p50=float(np.percentile(qd, 50)) if len(qd) else math.nan,
        queueing_p99=float(np.percentile(qd, 99)) if len(qd) else math.nan,
        cold_fraction=float(colds.mean()) if len(colds) else math.nan,
        completed=len(result.records),
        dropped=result.dropped,
        nodes_mean=float(result.node_samples.mean())
        if len(result.node_samples) else math.nan,
        node_hours=result.node_seconds / 3600.0,
        node_provisions=result.node_provisions,
        node_terminations=result.node_terminations,
        spot_node_hours=result.spot_node_seconds / 3600.0,
        node_evictions=result.node_evictions,
    )


def queueing_cdf(result: SimResult, points: int = 200):
    qd = np.sort(np.asarray([r.start - r.arrival for r in result.records
                             if not math.isnan(r.start)]))
    if len(qd) == 0:
        return np.zeros(0), np.zeros(0)
    idx = np.linspace(0, len(qd) - 1, points).astype(int)
    return qd[idx], (idx + 1) / len(qd)

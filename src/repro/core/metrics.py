"""The paper's metric suite (§3.6).

* end-to-end performance: geometric mean over functions of the per-function
  99th-percentile slowdown ((end - arrival) / pure duration); 1.0 = unloaded.
* normalized memory usage: time-averaged total instance memory / time-averaged
  memory of instances actively serving a request.
* instance creation rate (events/s over the measurement window).
* normalized CPU overhead: system CPU (worker + master) / useful function CPU,
  plus the worker/master breakdown (paper: ~80/20).

Beyond-paper: when a node fleet (repro.fleet) is attached, the result also
carries node-hours and the mean billable node count, the inputs to the
dollar-cost model in ``repro.fleet.costs``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.eventsim import SimResult


@dataclasses.dataclass
class Metrics:
    slowdown_geomean_p99: float
    normalized_memory: float
    creation_rate: float
    cpu_overhead: float
    cpu_overhead_worker: float
    cpu_overhead_master: float
    worker_share: float
    queueing_p50: float
    queueing_p99: float
    cold_fraction: float
    completed: int
    # node-fleet layer (NaN/0 when simulating a static cluster)
    nodes_mean: float = math.nan
    node_hours: float = 0.0
    node_provisions: int = 0
    node_terminations: int = 0
    # spot tier (0 for an on-demand-only fleet)
    spot_node_hours: float = 0.0
    node_evictions: int = 0

    def row(self) -> dict:
        return dataclasses.asdict(self)


def per_function_p99_slowdown(result: SimResult, min_requests: int = 5) -> np.ndarray:
    by_fn: dict[int, list[float]] = {}
    for r in result.records:
        if math.isnan(r.end):
            continue
        slow = max((r.end - r.arrival) / max(r.dur, 1e-6), 1.0)
        by_fn.setdefault(r.fn, []).append(slow)
    out = []
    for fn, v in by_fn.items():
        if len(v) >= min_requests:
            out.append(float(np.percentile(v, 99)))
    return np.asarray(out)


def compute(result: SimResult) -> Metrics:
    slows = per_function_p99_slowdown(result)
    geo = float(np.exp(np.mean(np.log(np.maximum(slows, 1.0))))) if len(slows) else math.nan

    total = result.mem_samples_total_mb
    busy = result.mem_samples_busy_mb
    norm_mem = float(total.mean() / max(busy.mean(), 1e-9)) if len(total) else math.nan

    window = max(result.measure_window_s, 1e-9)
    rate = result.creations / window

    useful = max(result.cpu_useful_s, 1e-9)
    w = result.cpu_worker_overhead_s
    m = result.cpu_master_overhead_s
    qd = np.asarray([r.start - r.arrival for r in result.records
                     if not math.isnan(r.start)])
    colds = np.asarray([r.cold for r in result.records], dtype=bool)

    return Metrics(
        slowdown_geomean_p99=geo,
        normalized_memory=norm_mem,
        creation_rate=rate,
        cpu_overhead=(w + m) / useful,
        cpu_overhead_worker=w / useful,
        cpu_overhead_master=m / useful,
        worker_share=w / max(w + m, 1e-9),
        queueing_p50=float(np.percentile(qd, 50)) if len(qd) else math.nan,
        queueing_p99=float(np.percentile(qd, 99)) if len(qd) else math.nan,
        cold_fraction=float(colds.mean()) if len(colds) else math.nan,
        completed=len(result.records),
        nodes_mean=float(result.node_samples.mean())
        if len(result.node_samples) else math.nan,
        node_hours=result.node_seconds / 3600.0,
        node_provisions=result.node_provisions,
        node_terminations=result.node_terminations,
        spot_node_hours=result.spot_node_seconds / 3600.0,
        node_evictions=result.node_evictions,
    )


def queueing_cdf(result: SimResult, points: int = 200):
    qd = np.sort(np.asarray([r.start - r.arrival for r in result.records
                             if not math.isnan(r.start)]))
    if len(qd) == 0:
        return np.zeros(0), np.zeros(0)
    idx = np.linspace(0, len(qd) - 1, points).astype(int)
    return qd[idx], (idx + 1) / len(qd)

"""Unified run specification for the scenario / sweep / frontier entry points.

Seven PRs of feature threading left ``run_scenario`` / ``evaluate_scenario``
/ ``simulate_chunked`` / ``frontier`` each with a long tail of loose kwargs
(scale, engines, billing, telemetry, tier, obs, ...) declared slightly
differently at every layer.  ``RunSpec`` is the one frozen carrier for all
of them — including the planet-scale knobs (``devices`` for the
device-sharded scan, ``cluster`` for long-tail super-function bucketing) —
so new knobs land in exactly one place.

Old call sites keep working: every redesigned entry point accepts its
legacy kwargs, forwards them into a ``RunSpec`` through
:func:`resolve_spec`, and emits a ``DeprecationWarning`` once per entry
point per process.  Passing ``spec=`` together with a legacy kwarg is an
error (two sources of truth), and unknown kwargs now fail loudly instead
of being swallowed.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Optional, Tuple

__all__ = ["RunSpec", "resolve_spec", "warn_once"]

#: entry points that have already emitted their deprecation warning this
#: process (cleared by tests to re-arm the warning)
_WARNED: set = set()


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything a scenario run / sweep / frontier search needs beyond the
    scenario identity itself.

    scale        workload multiplier applied to the scenario's base trace
    engines      which engines to run ("eventsim" oracle, "simjax" fluid)
    billing      BillingProfile or registered profile name (None = ideal)
    telemetry    in-scan telemetry slots (0 = off, bit-for-bit baseline)
    tier         spot capacity tier (name or CapacityTier) to impose
    obs          SpanRecorder capturing oracle lifecycle spans
    force_oracle run the discrete-event oracle even where the scenario
                 marks it infeasible at this scale
    devices      shard the chunked scan over this many local devices
                 (0 = legacy unsharded dispatch; single runs shard the
                 function axis, fleet sweeps shard the point axis)
    cluster      bucket functions whose mean request rate is below this
                 many rps into weighted super-functions before the fluid
                 replay (0 = off; exact in the fluid limit, drops the
                 event-level oracle leg)
    """

    scale: float = 1.0
    engines: Tuple[str, ...] = ("eventsim", "simjax")
    billing: Any = None
    telemetry: int = 0
    tier: Any = None
    obs: Any = None
    force_oracle: bool = False
    devices: int = 0
    cluster: float = 0.0

    def __post_init__(self):
        engines = self.engines
        if isinstance(engines, str):
            engines = (engines,)
        object.__setattr__(self, "engines", tuple(engines))
        scale = float(self.scale)
        if not (math.isfinite(scale) and scale > 0):
            raise ValueError(f"RunSpec.scale must be finite and > 0, got {self.scale!r}")
        object.__setattr__(self, "scale", scale)
        telemetry = int(self.telemetry)
        if telemetry < 0:
            raise ValueError(f"RunSpec.telemetry must be >= 0, got {self.telemetry!r}")
        object.__setattr__(self, "telemetry", telemetry)
        devices = int(self.devices)
        if devices < 0:
            raise ValueError(f"RunSpec.devices must be >= 0, got {self.devices!r}")
        object.__setattr__(self, "devices", devices)
        cluster = float(self.cluster)
        if not (math.isfinite(cluster) and cluster >= 0):
            raise ValueError(f"RunSpec.cluster must be finite and >= 0, got {self.cluster!r}")
        object.__setattr__(self, "cluster", cluster)
        object.__setattr__(self, "force_oracle", bool(self.force_oracle))

    def replace(self, **changes) -> "RunSpec":
        return dataclasses.replace(self, **changes)


def warn_once(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit ``message`` as a DeprecationWarning the first time ``key`` is
    seen this process; later hits are silent (one nag per entry point, not
    one per call in a sweep loop)."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def resolve_spec(func: str, spec: Optional[RunSpec], legacy: dict) -> RunSpec:
    """Merge an entry point's legacy loose kwargs into a RunSpec.

    ``legacy`` maps RunSpec field name -> value-or-None, where None means
    "caller did not pass it" (every legacy kwarg defaults to None in the
    redesigned signatures).  Passing both ``spec=`` and a legacy kwarg is
    ambiguous and raises; legacy-only calls warn once per ``func`` and are
    forwarded verbatim.
    """
    given = {k: v for k, v in legacy.items() if v is not None}
    if spec is not None:
        if given:
            raise TypeError(
                f"{func}() got both spec= and legacy keyword(s) "
                f"{sorted(given)}; pass everything through RunSpec")
        if not isinstance(spec, RunSpec):
            raise TypeError(f"{func}() spec= must be a RunSpec, got {type(spec).__name__}")
        return spec
    if given:
        warn_once(func, f"{func}(): loose keyword(s) {sorted(given)} are "
                        f"deprecated; pass spec=RunSpec(...) instead")
    return RunSpec(**given)

"""Unified run specification for the scenario / sweep / frontier entry points.

Seven PRs of feature threading left ``run_scenario`` / ``evaluate_scenario``
/ ``simulate_chunked`` / ``frontier`` each with a long tail of loose kwargs
(scale, engines, billing, telemetry, tier, obs, ...) declared slightly
differently at every layer.  ``RunSpec`` is the one frozen carrier for all
of them — including the planet-scale knobs (``devices`` for the
device-sharded scan, ``cluster`` for long-tail super-function bucketing) —
so new knobs land in exactly one place.

``spec=RunSpec(...)`` is the ONLY calling convention: the transitional
loose-kwarg shims (and their once-per-process deprecation machinery) were
removed after the soak period, so a stale ``run_scenario(scale=0.5)`` call
now fails with an ordinary ``TypeError`` instead of warning.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

__all__ = ["RunSpec"]


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything a scenario run / sweep / frontier search needs beyond the
    scenario identity itself.

    scale        workload multiplier applied to the scenario's base trace
    engines      which engines to run ("eventsim" oracle, "simjax" fluid)
    billing      BillingProfile or registered profile name (None = ideal)
    telemetry    in-scan telemetry slots (0 = off, bit-for-bit baseline)
    tier         spot capacity tier (name or CapacityTier) to impose
    obs          SpanRecorder capturing oracle lifecycle spans
    force_oracle run the discrete-event oracle even where the scenario
                 marks it infeasible at this scale
    devices      shard the chunked scan over this many local devices
                 (0 = legacy unsharded dispatch; single runs shard the
                 function axis, fleet sweeps shard the point axis)
    cluster      bucket functions whose mean request rate is below this
                 many rps into weighted super-functions before the fluid
                 replay (0 = off; exact in the fluid limit, drops the
                 event-level oracle leg)
    """

    scale: float = 1.0
    engines: Tuple[str, ...] = ("eventsim", "simjax")
    billing: Any = None
    telemetry: int = 0
    tier: Any = None
    obs: Any = None
    force_oracle: bool = False
    devices: int = 0
    cluster: float = 0.0

    def __post_init__(self):
        engines = self.engines
        if isinstance(engines, str):
            engines = (engines,)
        object.__setattr__(self, "engines", tuple(engines))
        scale = float(self.scale)
        if not (math.isfinite(scale) and scale > 0):
            raise ValueError(f"RunSpec.scale must be finite and > 0, got {self.scale!r}")
        object.__setattr__(self, "scale", scale)
        telemetry = int(self.telemetry)
        if telemetry < 0:
            raise ValueError(f"RunSpec.telemetry must be >= 0, got {self.telemetry!r}")
        object.__setattr__(self, "telemetry", telemetry)
        devices = int(self.devices)
        if devices < 0:
            raise ValueError(f"RunSpec.devices must be >= 0, got {self.devices!r}")
        object.__setattr__(self, "devices", devices)
        cluster = float(self.cluster)
        if not (math.isfinite(cluster) and cluster >= 0):
            raise ValueError(f"RunSpec.cluster must be finite and >= 0, got {self.cluster!r}")
        object.__setattr__(self, "cluster", cluster)
        object.__setattr__(self, "force_oracle", bool(self.force_oracle))

    def replace(self, **changes) -> "RunSpec":
        return dataclasses.replace(self, **changes)

"""Autoscaling policies — the paper's primary subject.

Two families (paper §2.1) plus one beyond-paper baseline:

* ``SyncKeepalivePolicy`` (AWS-Lambda-like, §2.1.1): instance creation on the
  request critical path; idle instances retained for ``keepalive_s``.
* ``AsyncConcurrencyPolicy`` (Knative/GCR-like, §2.1.2): a dedicated
  autoscaler computes ``desired_f = ceil(avg_concurrency_f(window) /
  (utilization_target * container_concurrency))`` and reconciles.
* ``HybridHistogramPolicy`` (Shahrad'20, beyond-paper): per-function idle-time
  histogram decides a pre-warm delay + adaptive keepalive window.

Policies are deliberately tiny pure-state machines so the SAME object drives
(a) the discrete-event oracle, (b) the vectorized lax.scan simulator (via
their jnp twin in ``simjax``), and (c) the real JAX serving control plane.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PolicyDecision:
    create: int = 0            # instances to create now
    retire: int = 0            # idle instances to retire now


class Policy:
    """Per-function autoscaling policy instance."""

    #: synchronous policies gate request handling on instance creation
    synchronous: bool = False
    container_concurrency: int = 1

    def on_arrival(self, t: float, idle: int, busy_slots: int, starting: int,
                   queued: int) -> PolicyDecision:
        return PolicyDecision()

    def on_tick(self, t: float, concurrency: float, instances: int,
                starting: int, idle: int) -> PolicyDecision:
        return PolicyDecision()

    def keepalive(self, t: float) -> float:
        """How long an idle instance is retained."""
        return math.inf

    def on_idle_expired(self, t: float, idle_for: float) -> bool:
        """True -> tear the instance down."""
        return True


@dataclasses.dataclass
class SyncKeepalivePolicy(Policy):
    """Fixed-keepalive synchronous scaling (paper's Kn-Sync / AWS Lambda)."""
    keepalive_s: float = 600.0
    container_concurrency: int = 1
    synchronous: bool = True

    def __post_init__(self):
        Policy.__init__(self)

    def on_arrival(self, t, idle, busy_slots, starting, queued):
        # no free slot anywhere -> create exactly one instance for this request
        if idle == 0 and busy_slots == 0:
            return PolicyDecision(create=1)
        return PolicyDecision()

    def keepalive(self, t):
        return self.keepalive_s


@dataclasses.dataclass
class AsyncConcurrencyPolicy(Policy):
    """Knative KPA-style window-averaged concurrency scaling.

    desired = ceil(window_avg(concurrency) / (target * container_concurrency))
    Scale-down is damped by the window average itself (longer window = more
    inertia), mirroring Knative's stable mode; panic mode is disabled in the
    paper's setup and here.
    """
    window_s: float = 60.0
    target: float = 0.7
    container_concurrency: int = 1
    tick_s: float = 2.0
    synchronous: bool = False

    def __post_init__(self):
        Policy.__init__(self)
        n = max(1, int(round(self.window_s / self.tick_s)))
        self._buf: deque[float] = deque(maxlen=n)

    def on_tick(self, t, concurrency, instances, starting, idle):
        self._buf.append(concurrency)
        avg = sum(self._buf) / len(self._buf)
        desired = math.ceil(avg / (self.target * self.container_concurrency) - 1e-9)
        desired = max(desired, 0)
        have = instances + starting
        if desired > have:
            return PolicyDecision(create=desired - have)
        if desired < have:
            return PolicyDecision(retire=min(have - desired, idle))
        return PolicyDecision()

    def keepalive(self, t):
        return math.inf  # teardown is driven by on_tick retire decisions


@dataclasses.dataclass
class HybridHistogramPolicy(Policy):
    """Beyond-paper: Shahrad'20 hybrid histogram keepalive.

    Tracks the function's idle-time distribution; keeps instances warm for the
    99th percentile of observed idle times (within [min_s, max_s]).  Behaves
    like a short keepalive for chatty functions and avoids wasting memory on
    rarely-invoked ones.
    """
    min_s: float = 30.0
    max_s: float = 1800.0
    quantile: float = 0.99
    container_concurrency: int = 1
    synchronous: bool = True

    def __post_init__(self):
        Policy.__init__(self)
        self._idle_samples: deque[float] = deque(maxlen=256)
        self._last_arrival: Optional[float] = None

    def on_arrival(self, t, idle, busy_slots, starting, queued):
        if self._last_arrival is not None:
            self._idle_samples.append(t - self._last_arrival)
        self._last_arrival = t
        if idle == 0 and busy_slots == 0:
            return PolicyDecision(create=1)
        return PolicyDecision()

    def keepalive(self, t):
        if not self._idle_samples:
            return self.min_s
        q = float(np.quantile(np.asarray(self._idle_samples), self.quantile))
        return float(np.clip(q * 1.1, self.min_s, self.max_s))


# how far ahead the spot-aware policy insures against preemption: warm
# headroom covers the expected instance loss over roughly one node
# provision cycle (rebuilding evicted capacity takes provision_s ≫ cold
# start).  Shared by the oracle twin below and the traced
# ``policy_api.SpotAwareFamily`` so both engines compute identical headroom.
SPOT_HEADROOM_HORIZON_S = 120.0


@dataclasses.dataclass
class SpotAwarePolicy(SyncKeepalivePolicy):
    """Sync keepalive scaling that over-provisions warm headroom against
    spot preemption: each reconcile tick tops idle capacity up to the
    expected instance loss rate (instances x spot_fraction x hazard) over
    the headroom horizon, so an eviction lands on pre-warmed spares
    instead of a cold-start storm.  ``spot_fraction``/``hazard_per_hour``
    mirror the fleet tier actually purchased (the policy insures exactly
    the capacity at risk)."""
    spot_fraction: float = 0.0
    hazard_per_hour: float = 0.0

    def on_tick(self, t, concurrency, instances, starting, idle):
        target = int(round(instances * self.spot_fraction
                           * self.hazard_per_hour / 3600.0
                           * SPOT_HEADROOM_HORIZON_S))
        extra = max(target - idle - starting, 0)
        if extra > 0:
            return PolicyDecision(create=extra)
        return PolicyDecision()


# ---------------------------------------------------------------------------
# learned keepalive: the gradient-searched policy family
# ---------------------------------------------------------------------------
#
# A tiny MLP maps a function's observed arrival rate to its keepalive — the
# smooth, parameterized generalization of the hybrid histogram's rate->warmth
# heuristic.  The NETWORK lives here (numpy by default, jnp when the fluid
# simulator passes ``xp=jax.numpy``) so the oracle twin below and the traced
# ``repro.core.policy_api.LearnedKeepaliveFamily`` evaluate literally the
# same arithmetic; ``repro.opt.learned`` trains ``theta`` by ``jax.grad``
# through the chunked scan.

#: keepalive output range (log-interpolated by the network's sigmoid head)
LEARNED_KA_MIN_S = 20.0
LEARNED_KA_MAX_S = 1800.0
#: arrival-rate feature normalization: z = (ln lam - _F_MU) / _F_SD
_F_MU, _F_SD = -4.6, 3.0
_LEARNED_HIDDEN = 4


def init_theta(seed: int = 0) -> dict:
    """Deterministic init with a ZERO output layer: the untrained network
    emits exactly keepalive=600 s for every rate (the paper's default
    ladder point), so at init the learned family is bit-identical to a
    plain sync keepalive on BOTH engines and passes the parity gate before
    any training.  ``w2=0`` also zeroes the first-step gradient into
    ``w1``/``b1`` (standard zero-init-head trick); ``w2`` moves first and
    unfreezes them."""
    rng = np.random.default_rng(seed)
    h = _LEARNED_HIDDEN
    span = math.log(LEARNED_KA_MAX_S / LEARNED_KA_MIN_S)
    s0 = math.log(600.0 / LEARNED_KA_MIN_S) / span       # target sigmoid out
    return {
        "w1": (0.3 * rng.standard_normal(h)).astype(np.float32),
        "b1": np.zeros(h, np.float32),
        "w2": np.zeros(h, np.float32),
        "b2": np.float32(math.log(s0 / (1.0 - s0))),
    }


def learned_keepalive(theta, lam, xp=np):
    """Per-function keepalive from the arrival rate ``lam`` (scalar or (F,)).

    ka = KA_MIN * (KA_MAX/KA_MIN) ** sigmoid(MLP(z)),  z = (ln lam - mu)/sd

    ``xp`` selects the array namespace: numpy for the oracle / control plane,
    ``jax.numpy`` for the traced scan — one formula, two engines.
    """
    lam = xp.maximum(xp.asarray(lam, xp.float32), 1e-9)
    z = (xp.log(lam) - _F_MU) / _F_SD
    h = xp.tanh(z[..., None] * theta["w1"] + theta["b1"])
    u = h @ theta["w2"] + theta["b2"]
    s = 1.0 / (1.0 + xp.exp(-u))
    log_span = xp.log(LEARNED_KA_MAX_S / LEARNED_KA_MIN_S)
    return LEARNED_KA_MIN_S * xp.exp(s * log_span)


@dataclasses.dataclass
class LearnedKeepalivePolicy(Policy):
    """Oracle twin of the learned family: sync creation path, keepalive from
    the SAME network over the function's observed arrival rate.

    The rate estimate is arrivals-so-far over elapsed time with a one-minute
    prior window, which converges to the stationary mean the fluid engine
    feeds the network (``lam0``); the measurement window starts at T/2, so
    the early-estimate transient is excluded from parity metrics.
    """
    theta: Optional[dict] = None
    container_concurrency: int = 1
    synchronous: bool = True

    def __post_init__(self):
        Policy.__init__(self)
        if self.theta is None:
            self.theta = init_theta()
        self._arrivals = 0
        self._last_t = 0.0

    def _rate(self) -> float:
        return max(self._arrivals, 1) / max(self._last_t, 60.0)

    def on_arrival(self, t, idle, busy_slots, starting, queued):
        self._arrivals += 1
        self._last_t = max(self._last_t, t)
        if idle == 0 and busy_slots == 0:
            return PolicyDecision(create=1)
        return PolicyDecision()

    def keepalive(self, t):
        self._last_t = max(self._last_t, t)
        return float(learned_keepalive(self.theta, self._rate()))


def make_policy(name: str, **kw) -> Policy:
    return {
        "sync": SyncKeepalivePolicy,
        "async": AsyncConcurrencyPolicy,
        "hybrid": HybridHistogramPolicy,
        "learned": LearnedKeepalivePolicy,
        "spot_aware": SpotAwarePolicy,
    }[name](**kw)

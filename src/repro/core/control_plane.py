"""The REAL control plane: router + queue + autoscaler reconciler.

This is the production-style implementation of the same policy objects used
by the simulators — any ``repro.core.policy_api`` family lowers to them via
``PolicySpec.factory()``, so a policy registered once (including the
gradient-learned keepalive) drives the oracle, the traced scan, AND this
control plane.  Workers are pluggable (paper §3.4's KWOK methodology):

* ``SimWorkerBackend``  — virtual-clock workers (instance creation latency,
  per-request service times); the control plane logic is real, the workers
  are simulated.  This scales the control plane to thousands of instances.
* ``JaxWorkerBackend``  — real ``ModelReplica``s running actual JAX model
  decode steps on the local device(s); cold start = real init + compile.

The control plane is tick-driven and clock-agnostic: pass wall-clock now for
real serving, virtual now for simulation.

Two-level autoscaling: pass a ``repro.fleet.FleetManager`` and live
instances are capped by current node capacity — creates beyond capacity are
deferred (never dropped) while placement pressure scales the node fleet up,
and billable node-seconds are metered for the cost model.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from collections import deque
from typing import Optional, Protocol

from repro.core.policies import Policy
from repro.serving.engine import ModelReplica, ServeRequest


class WorkerBackend(Protocol):
    def create_instance(self, fn: int, now: float) -> int: ...
    def poll_ready(self, now: float) -> list[int]: ...
    def dispatch(self, iid: int, req: ServeRequest, now: float) -> None: ...
    def poll_completions(self, now: float) -> list[tuple[int, ServeRequest]]: ...
    def teardown(self, iid: int, now: float) -> None: ...
    def memory_bytes(self, iid: int) -> int: ...


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


class SimWorkerBackend:
    """KWOK-style simulated workers under a virtual clock."""

    def __init__(self, cold_start_s: float = 1.0, instance_mem_bytes: int = 256 << 20,
                 service_time: Optional[dict] = None, default_service_s: float = 0.5):
        self._iid = itertools.count()
        self._ready_at: dict[int, float] = {}
        self._ready: set[int] = set()
        self._running: list[tuple[float, int, ServeRequest]] = []
        self.cold_start_s = cold_start_s
        self.mem = instance_mem_bytes
        self.service_time = service_time or {}
        self.default_service_s = default_service_s
        self.creations = 0
        self.teardowns = 0

    def create_instance(self, fn, now):
        iid = next(self._iid)
        self._ready_at[iid] = now + self.cold_start_s
        self.creations += 1
        return iid

    def poll_ready(self, now):
        out = [i for i, t in self._ready_at.items() if t <= now]
        for i in out:
            del self._ready_at[i]
            self._ready.add(i)
        return out

    def dispatch(self, iid, req, now):
        dur = self.service_time.get(req.fn, self.default_service_s)
        self._running.append((now + dur, iid, req))

    def poll_completions(self, now):
        done = [(i, r) for t, i, r in self._running if t <= now]
        self._running = [(t, i, r) for t, i, r in self._running if t > now]
        for _, r in done:
            r.done_t = now
        return done

    def teardown(self, iid, now):
        self._ready.discard(iid)
        self._ready_at.pop(iid, None)
        self.teardowns += 1

    def memory_bytes(self, iid):
        return self.mem


class JaxWorkerBackend:
    """Real replicas running real models (cold start = init + compile)."""

    def __init__(self, cfg, *, max_slots: int = 4, max_seq: int = 128):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self._iid = itertools.count()
        self.replicas: dict[int, ModelReplica] = {}
        self._fresh: list[int] = []
        self.creations = 0
        self.teardowns = 0
        self.cold_start_times: list[float] = []

    def create_instance(self, fn, now):
        iid = next(self._iid)
        rep = ModelReplica(self.cfg, max_slots=self.max_slots, max_seq=self.max_seq,
                           seed=iid)
        self.replicas[iid] = rep
        self._fresh.append(iid)
        self.creations += 1
        self.cold_start_times.append(rep.cold_start_s)
        return iid

    def poll_ready(self, now):
        out, self._fresh = self._fresh, []
        return out

    def dispatch(self, iid, req, now):
        assert self.replicas[iid].add(req, now)

    def poll_completions(self, now):
        done = []
        for iid, rep in self.replicas.items():
            for r in rep.step(now):
                done.append((iid, r))
        return done

    def teardown(self, iid, now):
        self.replicas.pop(iid, None)
        self.teardowns += 1

    def memory_bytes(self, iid):
        rep = self.replicas.get(iid)
        return rep.memory_bytes() if rep else 0


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Inst:
    iid: int
    fn: int
    state: str = "starting"        # starting | up
    in_flight: int = 0
    idle_since: float = math.nan


class ControlPlane:
    def __init__(self, backend: WorkerBackend, policy_factory, num_functions: int,
                 tick_s: float = 0.5, fleet=None, obs=None):
        self.backend = backend
        self.tick_s = tick_s
        self.fleet = fleet             # Optional[repro.fleet.FleetManager]
        self.obs = obs                 # Optional[repro.obs.SpanRecorder]
        self.policies: list[Policy] = [policy_factory(f) for f in range(num_functions)]
        self.queues: list[deque] = [deque() for _ in range(num_functions)]
        self.instances: dict[int, _Inst] = {}
        self.by_fn: list[list[_Inst]] = [[] for _ in range(num_functions)]
        self.completed: list[ServeRequest] = []
        self._deferred_creates: deque = deque()
        self._last_tick = -math.inf
        # span bookkeeping: req.rid -> [request sid, queue sid, execute sid]
        # (-1 = closed/absent), iid -> open instance_create sid
        self._rspans: dict = {}
        self._cspans: dict[int, int] = {}
        self._rtid = itertools.count()

    # -- helpers ------------------------------------------------------------------

    def _idle(self, fn):
        return [i for i in self.by_fn[fn] if i.state == "up" and i.in_flight == 0]

    def _busy_free_slots(self, fn):
        """Spare request slots on instances already serving traffic."""
        cc = self.policies[fn].container_concurrency
        return sum(cc - i.in_flight for i in self.by_fn[fn]
                   if i.state == "up" and 0 < i.in_flight < cc)

    def _free_slot_inst(self, fn):
        cc = self.policies[fn].container_concurrency
        for i in self.by_fn[fn]:
            if i.state == "up" and i.in_flight < cc:
                return i
        return None

    def _create(self, fn, now):
        if self.fleet is not None and not self.fleet.can_create(len(self.instances)):
            # at node capacity: defer (retried each tick once the fleet has
            # scaled up) rather than over-committing the backend; clamp to
            # real queued demand so level-based policies re-issuing creates
            # every tick can't stack duplicate deferrals
            if self._deferred_creates.count(fn) < max(1, len(self.queues[fn])):
                self._deferred_creates.append(fn)
            return
        iid = self.backend.create_instance(fn, now)
        inst = _Inst(iid, fn)
        self.instances[iid] = inst
        self.by_fn[fn].append(inst)
        if self.obs:
            self._cspans[iid] = self.obs.begin(
                "instance_create", "instance", now, pid="instances",
                tid=iid, fn=fn)

    def _teardown(self, inst, now):
        self.backend.teardown(inst.iid, now)
        self.instances.pop(inst.iid, None)
        self.by_fn[inst.fn].remove(inst)
        if self.obs:
            sid = self._cspans.pop(inst.iid, -1)
            if sid >= 0:
                self.obs.end(sid, now, aborted=True)
            self.obs.instant("teardown", "instance", now, pid="instances",
                             tid=inst.iid, fn=inst.fn)

    def _dispatch(self, inst, req: ServeRequest, now: float):
        inst.in_flight += 1
        self.backend.dispatch(inst.iid, req, now)
        if self.obs and req.rid in self._rspans:
            sp = self._rspans[req.rid]
            if sp[1] >= 0:
                self.obs.end(sp[1], now)
                sp[1] = -1
            sp[2] = self.obs.begin(
                "execute", "request", now, pid="requests",
                tid=self.obs.spans[sp[0]].tid, parent=sp[0], fn=req.fn,
                cold=req.cold, instance=inst.iid)

    # -- API ------------------------------------------------------------------------

    def submit(self, req: ServeRequest, now: float):
        fn = req.fn
        pol = self.policies[fn]
        if self.obs:
            sid = self.obs.begin("request", "request", now, pid="requests",
                                 tid=next(self._rtid), fn=fn)
            self._rspans[req.rid] = [sid, -1, -1]
        starting = sum(1 for i in self.by_fn[fn] if i.state == "starting")
        dec = pol.on_arrival(now, len(self._idle(fn)), self._busy_free_slots(fn),
                             starting, len(self.queues[fn]))
        for _ in range(dec.create):
            self._create(fn, now)
        inst = self._free_slot_inst(fn)
        if inst is not None:
            self._dispatch(inst, req, now)
        else:
            req.cold = True
            if self.obs and req.rid in self._rspans:
                sp = self._rspans[req.rid]
                sp[1] = self.obs.begin(
                    "queue", "request", now, pid="requests",
                    tid=self.obs.spans[sp[0]].tid, parent=sp[0], fn=fn)
            self.queues[fn].append(req)

    def tick(self, now: float):
        # 0. node fleet: advance provisioning, reconcile capacity, then retry
        #    creates that were deferred at the old capacity
        if self.fleet is not None:
            self.fleet.tick(now, len(self.instances))
            deferred, self._deferred_creates = self._deferred_creates, deque()
            for fn in deferred:
                self._create(fn, now)
        # 1. newly ready instances
        for iid in self.backend.poll_ready(now):
            inst = self.instances.get(iid)
            if inst is None:
                continue
            inst.state = "up"
            inst.idle_since = now
            if self.obs:
                sid = self._cspans.pop(iid, -1)
                if sid >= 0:
                    self.obs.end(sid, now)
        # 2. completions free slots
        for iid, req in self.backend.poll_completions(now):
            self.completed.append(req)
            inst = self.instances.get(iid)
            if inst is not None:
                inst.in_flight = max(0, inst.in_flight - 1)
                if inst.in_flight == 0:
                    inst.idle_since = now
            if self.obs:
                sp = self._rspans.pop(req.rid, None)
                if sp is not None:
                    if sp[2] >= 0:
                        self.obs.end(sp[2], now)
                    self.obs.end(sp[0], now)
        # 3. drain queues into free slots
        for fn, q in enumerate(self.queues):
            while q:
                inst = self._free_slot_inst(fn)
                if inst is None:
                    break
                self._dispatch(inst, q.popleft(), now)
        # 4. policy reconciliation + keepalive expiry
        for fn, pol in enumerate(self.policies):
            conc = sum(i.in_flight for i in self.by_fn[fn]) + len(self.queues[fn])
            starting = sum(1 for i in self.by_fn[fn] if i.state == "starting")
            up = sum(1 for i in self.by_fn[fn] if i.state == "up")
            idle = self._idle(fn)
            dec = pol.on_tick(now, conc, up, starting, len(idle))
            for _ in range(dec.create):
                self._create(fn, now)
            for inst in sorted(idle, key=lambda i: i.idle_since)[:dec.retire]:
                self._teardown(inst, now)
            ka = pol.keepalive(now)
            if not math.isinf(ka):
                for inst in list(self._idle(fn)):
                    if now - inst.idle_since > ka \
                            and pol.on_idle_expired(now, now - inst.idle_since):
                        self._teardown(inst, now)
        self._last_tick = now

    # -- observability -----------------------------------------------------------------

    def snapshot(self) -> dict:
        total_mem = sum(self.backend.memory_bytes(i) for i in self.instances)
        busy_mem = sum(self.backend.memory_bytes(iid)
                       for iid, inst in self.instances.items() if inst.in_flight > 0)
        snap = {
            "instances": len(self.instances),
            "starting": sum(1 for i in self.instances.values() if i.state == "starting"),
            "queued": sum(len(q) for q in self.queues),
            "deferred_creates": len(self._deferred_creates),
            "memory_bytes": total_mem,
            "busy_memory_bytes": busy_mem,
        }
        if self.fleet is not None:
            snap["fleet"] = self.fleet.snapshot()
        return snap

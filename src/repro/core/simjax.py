"""Vectorized cluster simulator: the whole fleet as one ``jax.lax.scan``.

This is the KWOK analogue (paper §3.4): the *policy math is identical* to the
real control plane (same window average / utilization target / keepalive
semantics), while workers are simulated — so experiments scale to thousands
of functions and hundreds of nodes in seconds, jit-compiled.

Two-level autoscaling: when a ``JaxFleet`` is passed, the node fleet joins
the scan carry — a scalar node count, a provisioning pipeline (provision
latency ≫ cold start), and a scale-down cooldown timer — mirroring
``repro.fleet.UtilizationFleetPolicy`` + ``NodeFleet`` branchlessly.
Instance creation is then capped by node capacity (capped creates stay
queued and re-request, the fluid analogue of placement-failure deferral),
and unplaceable demand feeds the node reconciler, so placement pressure
scales the fleet up instead of dropping requests.  When the policy family
declares the spot axes (``spot_fraction`` / ``hazard_per_hour`` — read off
the policy params like ``cc``), the fleet splits across an on-demand and a
spot tier and a traced hazard flux evicts spot capacity each tick: warm
instances on reclaimed nodes die, in-flight work outliving the reclaim
notice re-queues, and the spot share bills separately — the fluid twin of
``repro.fleet.spot``.

Numeric policy and fleet parameters are *traced*, not compile-time
constants, so ``repro.fleet.sweep`` can ``vmap`` thousands of policy
configurations through one compiled scan (the fast path behind the Fig. 8 /
Fig. 10 trade-off frontiers).  Only structural sizes (window buffer,
cold-start/provision pipeline depths, the policy FAMILY name) are static.

Policies dispatch through ``repro.core.policy_api``: the scan asks the
registered family for one pure ``decide(params, PolicyObs) -> JaxDecision``
call per tick, with ``params`` a traced PYTREE ({axis: leaf}) rather than a
fixed four-knob vector — a learned policy's weight pytree batches exactly
like a keepalive scalar.  Family metadata (synchronous tails, async cold
factor, window-buffer use) replaces the per-kind special cases that used to
be duplicated here and in ``repro.opt``.

Approximations vs the discrete-event oracle (validated in tests):
* fluid service: completions per tick = in_service * dt / mean_dur_f
  (memoryless service), fractional instances allowed; dispatch credits
  within-tick slot turnover (the oracle hands requests to instances the
  moment they free);
* keepalive expiry as a renewal-matched flux: rate lam/(e^{lam*ka}-1) per
  idle instance reproduces the oracle's continuous-idleness timer in
  expectation for Poisson gaps (1/ka as lam->0, ~never for chatty fns);
* per-tick queue-delay estimator (backlog position / drain rate + residual
  cold-start wait) applies only to the arrivals NOT served warm that tick;
  per-function p99 slowdown comes from a (delay histogram x lognormal
  duration) mixture with a finite-sample percentile correction, matching
  the oracle's per-request empirical percentile;
* scale-down removes (cooldown-gated) idle node capacity instantly; the
  oracle drains the emptiest nodes first, so the residual drain time is
  small (parity-tested within 15%, see tests/test_scenarios.py).

State is (F,)-vectorized; policies are branchless jnp.  dt = 1s.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.eventsim import SimConfig
from repro.core.policy_api import (HYBRID_MIN_KA_S, PolicyObs,  # noqa: F401
                                   get_family)
from repro.core.trace import Trace, gap_statistics, rate_matrix
from repro.obs.telemetry import TELEM_ATTR, TELEM_SERIES, assemble_telemetry


@dataclasses.dataclass(frozen=True)
class JaxPolicy:
    """One traced policy configuration: a registered FAMILY plus its params.

    ``family`` names a ``repro.core.policy_api`` registry entry ("sync",
    "async", "hybrid", "learned", ...); the legacy integer ``kind`` is kept
    as an alias (0/1/2/3) and either selector may be given.  ``params()``
    lowers the declared axes to the traced params PYTREE the scan consumes
    — every leaf (scalar knob or weight array) is a sweepable/learnable
    batch axis.  Only ``family`` and ``window_s`` (the window buffer depth)
    are structural.  Knob values are validated against the family's
    declared bounds at construction: a NaN or out-of-range keepalive fails
    HERE, not at the end of a scan."""
    kind: int = -1
    keepalive_s: float = 600.0
    window_s: float = 60.0
    target: float = 0.7
    cc: int = 1
    prewarm_s: float = 0.0
    family: str = ""
    theta: Any = None          # learnable pytree (learned family)
    extra: Any = None          # {axis: value} for axes beyond these fields

    def __post_init__(self):
        if not self.family:
            if self.kind < 0:
                raise ValueError("JaxPolicy needs a family name or a "
                                 "legacy kind")
            object.__setattr__(self, "family", get_family(self.kind).name)
        fam = get_family(self.family)      # raises KeyError on unknown names
        if fam.kind is not None:
            object.__setattr__(self, "kind", fam.kind)
        for nm in ("keepalive_s", "window_s", "target", "cc", "prewarm_s"):
            if not np.isfinite(float(getattr(self, nm))):
                raise ValueError(f"JaxPolicy.{nm} is not finite: "
                                 f"{getattr(self, nm)!r}")
        if self.window_s <= 0:
            raise ValueError(f"JaxPolicy.window_s must be > 0, got "
                             f"{self.window_s!r}")
        fam.validate(self.params())

    def params(self) -> dict:
        """The traced params pytree ({axis name: leaf})."""
        return get_family(self.family).init_params(self)


@dataclasses.dataclass(frozen=True)
class JaxFleet:
    """Node-fleet layer parameters (mirrors UtilizationFleetPolicy +
    NodeFleet).  ``provision_s`` is structural (pipeline depth, static);
    the rest are traced and sweepable.  ``reclaim_notice_s`` is the spot
    tier's eviction warning (repro.fleet.spot); it only acts when the
    policy family declares the spot axes (spot_fraction / hazard_per_hour
    — the engine reads them off the policy params, like ``cc``)."""
    node_memory_mb: float = 192_000.0
    provision_s: float = 60.0
    min_nodes: float = 1.0
    max_nodes: float = 64.0
    util_target: float = 0.7
    warm_frac: float = 0.25
    cooldown_s: float = 120.0
    reclaim_notice_s: float = 120.0

    def params(self) -> np.ndarray:
        """The traced parameter vector (see _PFLEET indices)."""
        return np.asarray([self.min_nodes, self.max_nodes, self.util_target,
                           self.warm_frac, self.cooldown_s,
                           self.node_memory_mb, self.reclaim_notice_s],
                          np.float32)


# traced fleet parameter vector layout (policy params are a pytree now —
# see repro.core.policy_api; the fleet layer keeps its fixed vector)
_PFLEET = ("min_nodes", "max_nodes", "util_target", "warm_frac",
           "cooldown_s", "node_memory_mb", "reclaim_notice_s")


def _init_state(f, cold_ticks, wbuf, prov_ticks, init_nodes):
    # the four trailing leaves are the spot tier (node count, provisioning
    # pipeline, instance mass resident on spot capacity, evicted-warm
    # deficit); they stay identically zero unless the policy family
    # declares the spot axes
    return (jnp.zeros(f), jnp.zeros(f), jnp.zeros(f),
            jnp.zeros((f, cold_ticks)), jnp.zeros((f, wbuf)), jnp.asarray(0),
            init_nodes * jnp.ones(()), jnp.zeros(prov_ticks), jnp.zeros(()),
            jnp.zeros(()), jnp.zeros(prov_ticks), jnp.zeros(f),
            jnp.zeros(f))


def _make_step(arrivals, dur, mem, billed_w, lam0, gaps, gap_tab, pol, fleet,
               cpu_consts,
               static_nodes, *, family: str, dt: float, cold_ticks: int,
               wbuf: int, prov_ticks: int, has_fleet: bool,
               telem: bool = False, weights=None):
    """One simulated tick, shared by the full-history scan (`_sim_impl`) and
    the chunked-summary scan (`_chunk_impl`) so the policy math exists once.

    ``lam0`` is the (F,) long-run mean arrival rate per function, the
    input to the renewal-matched keepalive expiry (see
    ``policy_api.renewal_expiry_rate``).  A windowed estimate would adapt
    to regime changes, but its per-arrival spikes are huge relative to
    sparse functions' rates and bias the (convex) expiry rate exactly while
    an instance is alive; the stationary mean is exact for the
    Poisson-renewal model (trace parity holds within a few percent for
    Poisson gaps; strongly bursty gap distributions under SHORT keepalives
    under-expire somewhat — see EXPERIMENTS.md).

    All of ``pol`` (a params PYTREE — scalar knobs or weight arrays) is
    traced, so the frontier engine can vmap over any leaf; only ``family``
    (the registry key) selects the compiled decide branch.

    ``weights`` is the (F,) super-function multiplicity from the clustering
    preprocessor (repro.scenarios.cluster): each function's PER-FUNCTION
    dynamics are those of one representative member, while every
    cross-function coupling and metric sum — node capacity pressure, CPU
    churn, the scalar accumulators — is linear in per-function
    contributions and therefore weighted by the member count.  This is
    exact when members are identical (they evolve identically in the fluid
    limit).  ``weights=None`` emits LITERALLY the unweighted ops, keeping
    the bit-for-bit baseline.
    """
    f = dur.shape[0]
    fam = get_family(family)
    ccf = pol["cc"]
    ws = (lambda v: v) if weights is None else (lambda v: v * weights)
    # the engine reads the spot axes off the policy params exactly like
    # ``cc``: a family that never declares them runs the original
    # single-tier fleet math (the spot carries stay identically zero)
    has_spot = has_fleet and "spot_fraction" in pol

    def step(state, tick):
        (inst, in_service, queue, starting, win, wcur,
         nodes, pipe, cool, nodes_spot, pipe_spot, spot_inst,
         evict_deficit) = state
        arr = arrivals[tick].astype(jnp.float32)

        if has_fleet:
            # provisioning completes
            nodes = nodes + pipe[0]
            pipe = jnp.concatenate([pipe[1:], jnp.zeros((1,))])
            if has_spot:
                nodes_spot = nodes_spot + pipe_spot[0]
                pipe_spot = jnp.concatenate([pipe_spot[1:], jnp.zeros((1,))])

        # instances finishing cold start
        ready = starting[:, 0]
        inst = inst + ready
        starting = jnp.concatenate([starting[:, 1:], jnp.zeros((f, 1))], axis=1)

        # dispatch + fluid service.  Dispatch capacity credits the slot
        # turnover expected WITHIN this tick (the oracle hands a request to
        # an instance the moment it frees, not at tick boundaries); the
        # momentary in_service overshoot is removed by the completions flux.
        slots = inst * ccf
        turnover = jnp.minimum(in_service * dt / dur, in_service)
        free = jnp.maximum(slots - in_service, 0.0)
        dispatch = jnp.minimum(queue + arr, free + turnover)
        # FIFO: backlog dispatches first; whatever of THIS tick's arrivals
        # doesn't fit waits (cold start / queue) — the delay estimate below
        # applies only to this delayed share, warm hits see ~zero wait
        arr_delayed = arr - jnp.maximum(dispatch - queue, 0.0)
        in_service = in_service + dispatch
        queue = queue + arr - dispatch
        completions = jnp.minimum(in_service * dt / dur, in_service)
        in_service = in_service - completions

        # Busy memory sample: expected busy-instance count time-averaged
        # over the tick.  Completed work was present for min(dur, dt) of the
        # tick, survivors for all of it — in steady state this recovers the
        # continuous-time E[#busy] = lambda*dur exactly in both the dur<dt
        # and dur>dt regimes.  A ceil here would charge a full instance to
        # every fractional in-service tail and overcount busy memory 10x+
        # on sparse functions.  The policy-facing idle count below stays
        # integral (ceil) — the oracle can only retire instances with ZERO
        # in-flight requests at the tick instant.
        served_avg = in_service + completions * jnp.minimum(dur / dt, 1.0)
        # cc > 1 packing: the oracle charges a partially-occupied instance's
        # memory as FULLY busy, so expected busy instances is ~ceil(B/cc)
        # under its first-free (packing) dispatch, not B/cc slot-utilization.
        # The smooth analogue B/cc + (1-1/cc)(1-e^-B) is exact at cc=1 and
        # reproduces the one-partial-instance bin for sparse load; remaining
        # cc>1 gaps are documented in EXPERIMENTS.md (frontier envelope).
        packed = served_avg / ccf + (1.0 - 1.0 / ccf) * -jnp.expm1(-served_avg)
        busy_inst = jnp.minimum(inst, packed)
        # two idle views: the EXPECTED idle mass (fractional — drives the
        # sync expiry flux; a ceil would pin idle to zero for as long as any
        # exponential in-service tail persists, i.e. forever for dur > dt)
        # and the INTEGRAL idle count (drives the async retire cap — the
        # oracle only retires instances with zero in-flight requests)
        idle_frac = jnp.maximum(inst - jnp.minimum(inst, in_service / ccf), 0.0)
        idle = jnp.maximum(inst - jnp.minimum(inst, jnp.ceil(in_service / ccf)),
                           0.0)
        # window concurrency is the end-of-tick snapshot (in-flight +
        # backlog), mirroring what the oracle's reconcile tick observes
        concurrency = in_service + queue

        # ---- instance-level policy: registry dispatch ----
        win_ = win.at[:, wcur % wbuf].set(concurrency)
        n_valid = jnp.minimum(wcur + 1, wbuf).astype(jnp.float32)
        avg = win_.sum(axis=1) / n_valid

        pending = starting.sum(axis=1)
        if has_fleet:
            # queued demand not already covered by in-flight cold starts
            # re-requests creation — capacity-capped creates retry here
            demand = jnp.maximum(queue - pending * ccf, 0.0)
        else:
            demand = jnp.maximum(arr - (free + pending), 0.0)
        obs = PolicyObs(arr=arr, queue=queue, inst=inst, pending=pending,
                        idle=idle, idle_frac=idle_frac, free=free, avg=avg,
                        demand=demand, lam=lam0, gap_p99=gaps,
                        alive_tab=gap_tab[0], tail_tab=gap_tab[1], dt=dt)
        dec = fam.decide(pol, obs)
        create, retire = dec.create, dec.retire

        inst = inst - retire

        # ---- node-fleet layer ----
        if has_fleet:
            min_n, max_n, util_t, warm_f, cool_s, node_mem = (
                fleet[0], fleet[1], fleet[2], fleet[3], fleet[4], fleet[5])

            # spot eviction flux: each UP spot node is reclaimed at the
            # hazard rate; warm instances on reclaimed capacity die (the
            # fleet spreads instances uniformly, so the instance loss is
            # the evicted capacity fraction).  In-flight work whose
            # memoryless remaining service outlives the reclaim notice
            # re-queues (the rest completes while the node drains, as the
            # oracle lets it).  The oracle recreates each killed warm
            # instance on its function's NEXT ARRIVAL — a cold start — so
            # the killed mass parks in ``evict_deficit`` and drains back
            # into creation at the arrival rate: the eviction-driven
            # cold-start storm.  The evicted node bills through its notice
            # window.
            if has_spot:
                notice = fleet[6]
                h_tick = -jnp.expm1(-(pol["hazard_per_hour"] / 3600.0) * dt)
                evict = nodes_spot * h_tick
                # the mass at risk is what actually RESIDES on spot
                # capacity (``spot_inst``): evicted spot nodes are young —
                # mean lifetime 1/hazard — so they only hold instances
                # placed since they booted, not a uniform 1/nodes share of
                # the fleet.  Each spot node evicts with probability
                # h_tick, taking its resident share with it.
                spot_inst = spot_inst \
                    * jnp.clip(1.0 - retire / jnp.maximum(inst + retire,
                                                          1e-9), 0.0, 1.0)
                spot_inst = jnp.minimum(spot_inst, inst)
                killed = spot_inst * h_tick
                spot_inst = spot_inst - killed
                inst = inst - killed
                # in-flight work rides the same resident share; whatever
                # outlives the reclaim notice re-queues
                evict_frac = killed / jnp.maximum(inst + killed, 1e-9)
                requeue = in_service * evict_frac * jnp.exp(-notice / dur)
                in_service = in_service - requeue
                queue = queue + requeue
                # a sync arrival recreates a killed instance iff it finds
                # no free slot: conditioned on one whole instance missing,
                # the surviving free capacity is (inst + deficit - 1 +
                # pending - busy slots).  The blocked-arrival probability
                # falls geometrically per surviving free slot (each spare
                # is busy with odds a/(1+a) at offered load a, the
                # coincidence that birthed it in the first place), so even
                # a killed EXCESS instance regenerates at the next
                # concurrency peak within the keepalive — exactly how the
                # oracle's per-arrival create maintains its equilibrium.
                pool = evict_deficit + killed
                free_cond = jnp.maximum(
                    inst + pool - 1.0 + pending - in_service / ccf, 0.0)
                a = in_service / ccf
                p_need = (a / (1.0 + a)) ** free_cond
                drain = pool * -jnp.expm1(-lam0 * dt)
                rec = drain * p_need
                evict_deficit = pool - rec
                # sync semantics: every arrival queued DURING the recreate's
                # cold start also creates (one sandbox per concurrent
                # request), so each recreate overshoots by ~lam x cold —
                # excess instances that then idle a full keepalive
                evict_rec = rec * (1.0 + lam0 * cold_ticks * dt)
                create = create + evict_rec
                nodes_spot = nodes_spot - evict
                evict_bill = evict * notice / dt
            else:
                killed = jnp.zeros(f)
                evict_bill = jnp.zeros(())

            capacity_mb = (nodes + nodes_spot) * node_mem
            committed = ws((inst + starting.sum(axis=1)) * mem).sum()
            free_mb = jnp.maximum(capacity_mb - committed, 0.0)
            req_mb = ws(create * mem).sum()
            scale = jnp.minimum(1.0, free_mb / jnp.maximum(req_mb, 1e-9))
            create = create * scale
            starting = starting.at[:, cold_ticks - 1].add(create)
            if has_spot:
                # round-robin first-fit walks the node list and takes the
                # first node with space — uniform by NODE COUNT while
                # nodes have room (free-capacity weighting would cascade
                # recreated mass straight back onto young spot nodes)
                cap_share = nodes_spot / jnp.maximum(nodes + nodes_spot,
                                                     1e-9)
                spot_inst = spot_inst + create * cap_share

            # reconcile: used memory plus unplaceable pressure -> desired
            # nodes, split across tiers at the policy's spot fraction
            used = ws((inst + starting.sum(axis=1)) * mem).sum()
            pressure = jnp.maximum(req_mb * (1.0 - scale), 0.0)
            needed = jnp.ceil((used + pressure) / (util_t * node_mem) - 1e-9)
            warm = jnp.ceil(warm_f * jnp.maximum(needed, 1.0) - 1e-9)
            desired_n = jnp.clip(needed + warm, min_n, max_n)
            desired_spot = jnp.round(desired_n * pol["spot_fraction"]) \
                if has_spot else jnp.zeros(())
            desired_od = desired_n - desired_spot
            have_od = nodes + pipe.sum()
            have_spot = nodes_spot + pipe_spot.sum()
            up = jnp.maximum(desired_od - have_od, 0.0)
            pipe = pipe.at[prov_ticks - 1].add(up)
            up_spot = jnp.maximum(desired_spot - have_spot, 0.0)
            pipe_spot = pipe_spot.at[prov_ticks - 1].add(up_spot)
            down_want = jnp.maximum(have_od - desired_od, 0.0)
            down_want_spot = jnp.maximum(have_spot - desired_spot, 0.0)
            max_down = jnp.maximum(nodes + nodes_spot
                                   - jnp.ceil(used / node_mem), 0.0)
            # each tier can only terminate its own UP nodes (down_want
            # counts un-cancellable pipeline nodes, and max_down spans
            # both tiers, so without the per-tier clamp a drained tier
            # could be driven negative)
            down_spot = jnp.where(cool <= 0.0,
                                  jnp.minimum(jnp.minimum(down_want_spot,
                                                          max_down),
                                              nodes_spot), 0.0)
            down = jnp.where(cool <= 0.0,
                             jnp.minimum(jnp.minimum(down_want,
                                                     max_down - down_spot),
                                         nodes), 0.0)
            nodes = nodes - down
            nodes_spot = nodes_spot - down_spot
            down_all = down + down_spot
            cool = jnp.where(down_all > 0.0, jnp.ceil(cool_s / dt),
                             jnp.maximum(cool - 1.0, 0.0))
            nodes_billed = nodes + nodes_spot + pipe.sum() + pipe_spot.sum() \
                + evict_bill
            spot_billed = nodes_spot + pipe_spot.sum() + evict_bill
        else:
            starting = starting.at[:, cold_ticks - 1].add(create)
            nodes_billed = jnp.asarray(static_nodes, jnp.float32)
            spot_billed = jnp.zeros(())

        # queue-delay estimator for THIS tick's arrivals: drain with the
        # capacity that will exist once in-flight creations finish, plus the
        # residual cold-start wait if capacity is still materializing.
        pending = starting.sum(axis=1)
        future_slots = (inst + pending) * ccf
        drain = jnp.maximum(future_slots / dur, 1e-6)
        # async arrivals additionally wait for the reconcile tick that
        # notices them before their instance even starts (the family's
        # cold_factor; sync creates on the arrival path, so its wait is the
        # cold start alone); a pre-warming family hides up to cold_hide
        # seconds of the cold start (the sandbox was requested that early),
        # paid for below in standing pre-warmed memory
        prewarm_hide = dec.cold_hide
        cold_full = jnp.maximum(
            fam.cold_factor * cold_ticks * dt - prewarm_hide, 0.0)
        cold_wait = jnp.where(pending > 0, cold_full,
                              jnp.where(future_slots < 0.5,
                                        jnp.maximum(2.0 * cold_ticks * dt
                                                    - prewarm_hide, 0.0),
                                        0.0))
        # a delayed arrival waits behind the backlog ahead of it — its own
        # cohort sits half in front, half behind on average
        queue_pos = jnp.maximum(queue - 0.5 * arr_delayed, 0.0)
        delay = queue_pos / drain + cold_wait

        (c_cw, c_cm, c_tw, c_tm, c_rq, c_idle, c_wfloor_node, c_mfloor) = cpu_consts
        # eviction-drained instances tear down gracefully during the notice
        # window, so they cost teardown CPU like a policy retire
        teard = ws(retire).sum() + ws(killed).sum() if has_spot \
            else ws(retire).sum()
        create_sum = ws(create).sum()
        cpu_worker = create_sum * c_cw + teard * c_tw \
            + ws(idle).sum() * c_idle * dt + c_wfloor_node * nodes_billed * dt
        cpu_master = create_sum * c_cm + teard * c_tm \
            + ws(dispatch).sum() * c_rq + c_mfloor * dt
        useful = ws(completions * dur).sum()

        # total allocated memory counts still-starting sandboxes, as the
        # oracle's per-tick sample does; the hybrid additionally holds each
        # new sandbox warm for its prewarm_s lead — a standing mass of
        # (creations/s x prewarm_s) pre-warmed instances in steady state
        prewarm_mass = ws(create * mem).sum() * prewarm_hide / dt
        # billed GB-s this tick: completions weighted by each function's
        # EXPECTED billed duration x configured GB (repro.fleet.billing) —
        # the fluid twin of the oracle's exact per-record rounding
        ys = (delay, arr, arr_delayed, ws(inst).sum(),
              ws((inst + pending) * mem).sum() + prewarm_mass,
              ws(busy_inst * mem).sum(),
              create_sum, cpu_worker, cpu_master, useful, nodes_billed,
              ws(completions).sum(), spot_billed,
              ws(completions * billed_w).sum())
        if telem:
            # in-scan telemetry (repro.obs): ys[14] is the per-tick series
            # vector (TELEM_SERIES order), ys[15] the attribution vector
            # (TELEM_ATTR order).  The eviction-storm share of this tick's
            # creation is the (capacity-scaled) recreate wave the hazard
            # triggered; everything else is ordinary churn, idle keepalive
            # is priced directly, and creation+eviction+idle subtracted
            # from cpu_worker+cpu_master leaves exactly the floors+dispatch
            # residual (master_control) — the exact-sum the attribution
            # ledger checks.
            if has_spot:
                ev_create = ws(evict_rec * scale).sum()
                ev_kill = ws(killed).sum()
            else:
                ev_create = jnp.zeros(())
                ev_kill = jnp.zeros(())
            # create-side CPU only: graceful-teardown CPU stays in the
            # master_control residual on BOTH engines (the oracle does the
            # same — see eventsim._teardown)
            cpu_creation = (create_sum - ev_create) * (c_cw + c_cm)
            cpu_evict = ev_create * (c_cw + c_cm)
            mem_pipe = ws(pending * mem).sum() + prewarm_mass
            tser = jnp.stack([
                ws(inst).sum(), ws(busy_inst).sum(), ws(queue).sum(),
                create_sum, ev_kill, ys[4], ys[5], mem_pipe, nodes_billed,
                spot_billed, cpu_worker, cpu_master])
            tattr = jnp.stack([cpu_creation, cpu_evict,
                               ws(idle).sum() * c_idle * dt, mem_pipe,
                               ev_kill, ev_create])
            ys = ys + (tser, tattr)
        return (inst, in_service, queue, starting, win_, wcur + 1,
                nodes, pipe, cool, nodes_spot, pipe_spot, spot_inst,
                evict_deficit), ys

    return step


def _sim_impl(arrivals, dur, mem, billed_w, lam0, gaps, gap_tab, pol, fleet,
              cpu_consts,
              static_nodes, *, family: str, n_ticks: int, dt: float,
              cold_ticks: int, wbuf: int, prov_ticks: int, has_fleet: bool):
    step = _make_step(arrivals, dur, mem, billed_w, lam0, gaps, gap_tab,
                      pol, fleet, cpu_consts,
                      static_nodes, family=family, dt=dt,
                      cold_ticks=cold_ticks, wbuf=wbuf, prov_ticks=prov_ticks,
                      has_fleet=has_fleet)
    init_nodes = fleet[0] if has_fleet else jnp.asarray(static_nodes, jnp.float32)
    init = _init_state(dur.shape[0], cold_ticks, wbuf, prov_ticks, init_nodes)
    _, ys = jax.lax.scan(step, init, jnp.arange(n_ticks))
    return ys


_simulate = partial(jax.jit, static_argnames=(
    "family", "n_ticks", "dt", "cold_ticks", "wbuf", "prov_ticks",
    "has_fleet"))(_sim_impl)


@dataclasses.dataclass
class JaxSimResult:
    delay: np.ndarray      # (T, F) per-tick queue delay estimate
    arrivals: np.ndarray   # (T, F)
    arr_delayed: np.ndarray  # (T, F) arrivals NOT served warm this tick
    instances: np.ndarray  # (T,)
    mem_total: np.ndarray  # (T,)
    mem_busy: np.ndarray   # (T,)
    creations: np.ndarray  # (T,)
    cpu_worker: np.ndarray
    cpu_master: np.ndarray
    useful: np.ndarray
    nodes: np.ndarray      # (T,) billable node count (static fleet: constant)
    completions: np.ndarray  # (T,) fluid request completions
    spot_nodes: np.ndarray  # (T,) billable SPOT share of nodes (0 w/o spot)
    billed_gb_s: np.ndarray  # (T,) billed GB-s (repro.fleet.billing weights)
    dt: float
    dur: np.ndarray        # (F,)
    fleet: Optional[JaxFleet] = None
    # per-request duration distribution (for the slowdown mixture); falls
    # back to a near-degenerate lognormal at the mean when absent
    dur_median: Optional[np.ndarray] = None   # (F,)
    dur_sigma: Optional[np.ndarray] = None    # (F,)
    warm_latency_s: float = 0.008
    # sync policies produce iid per-request cold-start tails (finite-sample
    # percentile correction applies); async tails are backlog episodes
    sync_tail: bool = True


_YS_NAMES = ["delay", "arrivals", "arr_delayed", "instances", "mem_total",
             "mem_busy", "creations", "cpu_worker", "cpu_master", "useful",
             "nodes", "completions", "spot_nodes", "billed_gb_s"]


def _prep_static(trace: Trace, policy: JaxPolicy, sim: SimConfig, dt: float):
    """Everything ``_sim_impl`` needs except the (T, F) arrivals matrix."""
    dur_mean = trace.profile.dur_median * np.exp(trace.profile.dur_sigma ** 2 / 2)
    dur = jnp.asarray(np.maximum(dur_mean, dt * 0.25), jnp.float32)
    mem = jnp.asarray(trace.profile.memory_mb + sim.instance_overhead_mb, jnp.float32)
    cold_ticks = max(1, int(round(sim.cold_start_s / dt)))
    wbuf = max(1, int(round(policy.window_s / dt))) \
        if get_family(policy.family).uses_window else 1
    cpu_consts = (sim.cpu_create_worker_s, sim.cpu_create_master_s,
                  sim.cpu_teardown_worker_s, sim.cpu_teardown_master_s,
                  sim.cpu_request_s, sim.cpu_idle_per_s,
                  sim.cpu_worker_floor_per_node_s,
                  sim.cpu_master_floor_per_s)
    return dur, mem, cold_ticks, wbuf, cpu_consts


def _prep(trace: Trace, policy: JaxPolicy, sim: SimConfig, dt: float):
    arr = jnp.asarray(rate_matrix(trace, dt))
    dur, mem, cold_ticks, wbuf, cpu_consts = _prep_static(trace, policy, sim, dt)
    return arr, dur, mem, cold_ticks, wbuf, cpu_consts


def _billed_weights(trace: Trace, billing) -> jnp.ndarray:
    """(F,) expected billed GB-s per completion under a billing profile
    (default: the ``ideal`` profile — no rounding, so the weight is just
    E[duration] x configured GB).  Imported lazily: ``repro.core`` stays
    free of a hard ``repro.fleet`` dependency."""
    from repro.fleet.billing import get_profile
    prof = get_profile(billing if billing is not None else "ideal")
    return jnp.asarray(prof.billed_weights(trace.profile), jnp.float32)


def simulate(trace: Trace, policy: JaxPolicy, sim: SimConfig = SimConfig(),
             dt: float = 1.0, num_nodes: int = 8,
             fleet: Optional[JaxFleet] = None, billing=None) -> JaxSimResult:
    arr, dur, mem, cold_ticks, wbuf, cpu_consts = _prep(trace, policy, sim, dt)
    billed_w = _billed_weights(trace, billing)
    has_fleet = fleet is not None
    prov_ticks = max(1, int(round((fleet.provision_s if has_fleet else 0.0) / dt)))
    pol = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), policy.params())
    fl = jnp.asarray(fleet.params() if has_fleet else np.zeros(len(_PFLEET)),
                     jnp.float32)
    lam0 = jnp.asarray(np.asarray(arr).mean(axis=0) / dt, jnp.float32)
    gq, alive_tab, tail_tab = gap_statistics(trace)
    gaps = jnp.asarray(gq, jnp.float32)
    gap_tab = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                           (alive_tab, tail_tab))
    ys = _simulate(arr, dur, mem, billed_w, lam0, gaps, gap_tab, pol, fl,
                   cpu_consts, float(num_nodes),
                   family=policy.family, n_ticks=arr.shape[0], dt=dt,
                   cold_ticks=cold_ticks, wbuf=wbuf, prov_ticks=prov_ticks,
                   has_fleet=has_fleet)
    vals = {n: np.asarray(v) for n, v in zip(_YS_NAMES, ys)}
    return JaxSimResult(dt=dt, dur=np.asarray(dur), fleet=fleet,
                        dur_median=np.asarray(trace.profile.dur_median),
                        dur_sigma=np.asarray(trace.profile.dur_sigma),
                        warm_latency_s=sim.warm_latency_s,
                        sync_tail=get_family(policy.family).synchronous_tail,
                        **vals)


def summarize(res: JaxSimResult, warmup_frac: float = 0.5,
              nbins: int = 256) -> dict:
    t0 = int(len(res.instances) * warmup_frac)
    sl = slice(t0, None)
    # arrival-weighted per-function delay histogram -> p99 of the
    # per-request slowdown mixture (same estimator as the chunked path):
    # warm-served arrivals land in the zero-delay bin, delayed arrivals
    # carry the tick's delay estimate
    delays, weights = res.delay[sl], res.arrivals[sl]
    delayed = res.arr_delayed[sl]
    f = delays.shape[1]
    edges = _delay_edges(nbins)
    b = np.clip(np.searchsorted(edges, delays, side="right"), 0, nbins - 1)
    hist = np.zeros((f, nbins))
    fn_idx = np.broadcast_to(np.arange(f), delays.shape)
    np.add.at(hist, (fn_idx, b), delayed)
    hist[:, 0] += (weights - delayed).sum(axis=0)
    med = res.dur_median if res.dur_median is not None else np.asarray(res.dur)
    sig = res.dur_sigma if res.dur_sigma is not None else np.zeros(f)
    # delegate to the chunked path's row builder so every metric formula
    # exists exactly once (the "memory-bounded twin" contract)
    sums = np.asarray([res.instances[sl].sum(), res.mem_total[sl].sum(),
                       res.mem_busy[sl].sum(), res.creations[sl].sum(),
                       res.cpu_worker[sl].sum(), res.cpu_master[sl].sum(),
                       res.useful[sl].sum(), res.nodes[sl].sum(),
                       res.completions[sl].sum(), res.spot_nodes[sl].sum(),
                       res.billed_gb_s[sl].sum()])
    return _acc_summary(hist, weights.sum(axis=0), sums,
                        len(res.instances) - t0, edges, med, sig,
                        res.warm_latency_s, res.dt, iid_tail=res.sync_tail)


# ---------------------------------------------------------------------------
# chunked scan: production scale without per-tick histories
# ---------------------------------------------------------------------------
#
# ``simulate`` materializes two (T, F) arrays plus nine (T,) series — fine for
# a 400-function / 80-minute trace, ruinous for the 2000-function Fig. 9
# replay and for vmapped sweeps (P x T x F).  The chunked path runs the SAME
# ``_make_step`` tick function, but the scan emits nothing per tick: summary
# statistics (per-function arrival-weighted delay histograms + scalar sums)
# live in the scan carry, the time axis is segmented into fixed-size chunks,
# and the carry buffers are donated between chunk calls, so peak device
# memory is O(F * BINS + chunk * F) regardless of trace length.

# scalar per-tick series accumulated post-warmup (order matches ys[3:];
# ys[0:3] are the per-function delay / arrivals / delayed-arrivals vectors)
_ACC_NAMES = ("instances", "mem_total", "mem_busy", "creations", "cpu_worker",
              "cpu_master", "useful", "nodes", "completions", "spot_nodes",
              "billed_gb_s")


def _delay_edges(nbins: int) -> np.ndarray:
    """Log-spaced histogram bin edges over 1 ms .. ~28 h of queueing delay.
    ~1.075x per bin at nbins=256, so histogram p99s land within a few
    percent of the exact per-tick percentile."""
    return np.logspace(-3, 5, nbins - 1, dtype=np.float32)


def _bin_reps(edges: np.ndarray) -> np.ndarray:
    """Representative delay per histogram bin: 0 below the first edge,
    geometric midpoints inside, the top edge above."""
    return np.concatenate([[0.0], np.sqrt(edges[:-1] * edges[1:]),
                           [float(edges[-1])]])


def _phi(z: np.ndarray) -> np.ndarray:
    """Standard normal CDF (Abramowitz–Stegun 7.1.26; |err| < 1.5e-7),
    vectorized — scipy is not a dependency of this repo."""
    z = np.asarray(z, np.float64)
    t = 1.0 / (1.0 + 0.3275911 * np.abs(z) / np.sqrt(2.0))
    poly = t * (0.254829592 + t * (-0.284496736 + t * (1.421413741
                + t * (-1.453152027 + t * 1.061405429))))
    erf = 1.0 - poly * np.exp(-0.5 * z * z)
    return 0.5 * (1.0 + np.sign(z) * erf)


# per-request service times are clipped lognormals (see trace.synthesize)
_DUR_FLOOR, _DUR_CAP = 0.02, 30.0


def _slowdown_geomean(hist, arrtot, edges, dur_median, dur_sigma, warm,
                      min_requests: int = 5, q: float = 0.99,
                      iid_tail: bool = True, fn_weights=None) -> float:
    """Geomean over functions of the q-quantile of per-request slowdown.

    The oracle computes p99 of (wait + service) / dur_i per REQUEST, where
    dur_i is that request's own lognormal service time — so its slowdown
    tail is driven by (long wait, short request) coincidences.  Dividing a
    single p99 delay by the MEAN duration (the naive fluid estimator)
    ignores that dispersion and can undershoot by 3-4x on bursty traces.
    Here slowdown is the mixture S = 1 + (W + warm) / D with W the
    arrival-weighted delay histogram and D an independent clipped
    lognormal:  P(S <= s) = sum_b p_b * P(D >= (w_b + warm)/(s - 1)),
    solved for the q-quantile by bisection, vectorized over functions.

    ``fn_weights`` is the super-function multiplicity (clustered traces):
    the geomean weighs each representative by its member count, and the
    finite-sample correction uses the PER-MEMBER request count (arrtot
    holds the weighted bucket total) — matching what each member would
    report unclustered.  Planet-sized histograms (>= ~4M cells) route
    through the jitted float32 bisection (`_slowdown_geomean_jax`); the
    2000-function fig9 replay and below keep the float64 numpy path
    bit-for-bit."""
    if np.asarray(hist).size >= _JAX_SOLVER_MIN_CELLS:
        return _slowdown_geomean_jax(hist, arrtot, edges, dur_median,
                                     dur_sigma, warm, min_requests, q,
                                     iid_tail, fn_weights)
    n_eff = np.asarray(arrtot, np.float64)
    if fn_weights is not None:
        n_eff = n_eff / np.maximum(np.asarray(fn_weights, np.float64), 1e-12)
    keep = n_eff >= min_requests
    if not keep.any():
        return float("nan")
    h = np.asarray(hist)[keep]
    p = h / h.sum(axis=1, keepdims=True)
    w = _bin_reps(edges)[None, :] + warm                      # (F', B)
    log_med = np.log(np.maximum(dur_median[keep], 1e-9))[:, None]
    sig = np.maximum(dur_sigma[keep], 1e-6)[:, None]
    # Finite-sample correction: the oracle reports np.percentile(q) over a
    # function's n observed requests, whose expectation is the POPULATION
    # quantile at roughly (q*(n-1)+1)/(n+1) — e.g. ~0.94 for n=20.  Solving
    # the mixture at the raw q would systematically overshoot the oracle on
    # sparsely-invoked functions, where the empirical p99 rarely reaches
    # the (long-wait, short-request) joint tail.
    # The correction assumes tail events are roughly independent across a
    # function's requests — true for sync cold starts (each arrival is
    # independently warm or cold), NOT for async backlog episodes, where
    # one burst delays a correlated block of requests and the empirical
    # percentile does reach the population tail (iid_tail=False -> raw q).
    n = n_eff[keep]
    q_eff = (q * (n - 1.0) + 1.0) / (n + 1.0) if iid_tail \
        else np.full(len(n), q)
    lo = np.full(h.shape[0], 1.0)
    hi = np.full(h.shape[0], 1.0 + w.max() / _DUR_FLOOR + 1.0)
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        x = w / np.maximum(mid - 1.0, 1e-12)[:, None]
        sf = np.where(x <= _DUR_FLOOR, 1.0,
                      np.where(x >= _DUR_CAP, 0.0,
                               1.0 - _phi((np.log(np.maximum(x, 1e-300))
                                           - log_med) / sig)))
        ok = (p * sf).sum(axis=1) >= q_eff
        hi = np.where(ok, mid, hi)
        lo = np.where(ok, lo, mid)
    logs = np.log(np.maximum(0.5 * (lo + hi), 1.0))
    if fn_weights is None:
        return float(np.exp(np.mean(logs)))
    return float(np.exp(np.average(
        logs, weights=np.asarray(fn_weights, np.float64)[keep])))


#: histogram cell count at which the slowdown bisection switches from the
#: float64 numpy solver to the jitted float32 one — chosen above the
#: 2000-function fig9 replay (2000 x 256 = 512k cells stays numpy, keeping
#: checked-in baselines bitwise) and below fig9_planet (100k x 256 = 25.6M)
_JAX_SOLVER_MIN_CELLS = 1 << 22


def _phi_jax(z):
    """float32 jnp port of `_phi` (A&S 7.1.26 normal CDF)."""
    t = 1.0 / (1.0 + 0.3275911 * jnp.abs(z) / np.sqrt(2.0).astype(np.float32))
    poly = t * (0.254829592 + t * (-0.284496736 + t * (1.421413741
                + t * (-1.453152027 + t * 1.061405429))))
    erf = 1.0 - poly * jnp.exp(-0.5 * z * z)
    return 0.5 * (1.0 + jnp.sign(z) * erf)


@partial(jax.jit, static_argnames=("iters",))
def _bisect_slowdown(p, wrow, q_eff, log_med, sig, hi0, iters=60):
    lo = jnp.ones(p.shape[0], jnp.float32)
    hi = jnp.full(p.shape[0], 1.0, jnp.float32) * hi0

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        x = wrow[None, :] / jnp.maximum(mid - 1.0, 1e-12)[:, None]
        z = (jnp.log(jnp.maximum(x, 1e-30)) - log_med) / sig
        sf = jnp.where(x <= _DUR_FLOOR, 1.0,
                       jnp.where(x >= _DUR_CAP, 0.0, 1.0 - _phi_jax(z)))
        ok = (p * sf).sum(axis=1) >= q_eff
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def _slowdown_geomean_jax(hist, arrtot, edges, dur_median, dur_sigma, warm,
                          min_requests, q, iid_tail, fn_weights) -> float:
    """Planet-scale twin of the numpy bisection: same mixture, float32 on
    device, one fused fori_loop — 100k x 256 histograms solve in ~1 s where
    the 60-pass float64 numpy loop takes tens of seconds.  The float32
    interval bottoms out around 1e-7 relative, far below the ~7% histogram
    bin width that dominates the estimator's resolution."""
    n_eff = np.asarray(arrtot, np.float64)
    w_np = None if fn_weights is None else np.asarray(fn_weights, np.float64)
    if w_np is not None:
        n_eff = n_eff / np.maximum(w_np, 1e-12)
    keep = n_eff >= min_requests
    if not keep.any():
        return float("nan")
    h = np.asarray(hist, np.float32)[keep]
    p = jnp.asarray(h) / jnp.maximum(
        jnp.asarray(h).sum(axis=1, keepdims=True), 1e-30)
    wrow = jnp.asarray(_bin_reps(edges) + warm, jnp.float32)
    log_med = jnp.asarray(
        np.log(np.maximum(np.asarray(dur_median)[keep], 1e-9)),
        jnp.float32)[:, None]
    sig = jnp.asarray(np.maximum(np.asarray(dur_sigma)[keep], 1e-6),
                      jnp.float32)[:, None]
    n = n_eff[keep]
    q_np = (q * (n - 1.0) + 1.0) / (n + 1.0) if iid_tail \
        else np.full(len(n), q)
    hi0 = np.float32(1.0 + (float(_bin_reps(edges)[-1]) + warm)
                     / _DUR_FLOOR + 1.0)
    s = np.asarray(_bisect_slowdown(p, wrow, jnp.asarray(q_np, jnp.float32),
                                    log_med, sig, hi0), np.float64)
    logs = np.log(np.maximum(s, 1.0))
    if w_np is None:
        return float(np.exp(np.mean(logs)))
    return float(np.exp(np.average(logs, weights=w_np[keep])))


def _chunk_impl(state, arr_chunk, lam0, gaps, gap_tab, dur, mem, billed_w,
                pol, fleet,
                cpu_consts, static_nodes, edges, tick0, *, warm_tick: int,
                total_ticks: int, family: str, dt: float,
                cold_ticks: int, wbuf: int, prov_ticks: int, has_fleet: bool,
                telem_slots: int = 0, weights=None):
    """Advance the simulation by one time chunk; return the carried state and
    this chunk's summary-statistic partials (host accumulates across chunks).
    Ticks at global index < warm_tick (warmup) or >= total_ticks (padding of
    the final chunk) advance state but are excluded from the statistics.

    ``telem_slots > 0`` (static) adds the bounded in-scan telemetry buffers
    (repro.obs): per-slot sums of the TELEM_SERIES vector over the WHOLE run
    (each slot covers ~total_ticks/telem_slots consecutive ticks — constant
    memory in trace length) plus the measurement-window TELEM_ATTR sums.
    With telemetry off the carry and the emitted ops are LITERALLY the
    pre-telemetry ones (the bit-for-bit guarantee the tests pin)."""
    f = arr_chunk.shape[1]
    nbins = edges.shape[0] + 1
    telem = telem_slots > 0
    step = _make_step(arr_chunk, dur, mem, billed_w, lam0, gaps, gap_tab,
                      pol, fleet,
                      cpu_consts, static_nodes, family=family, dt=dt,
                      cold_ticks=cold_ticks, wbuf=wbuf, prov_ticks=prov_ticks,
                      has_fleet=has_fleet, telem=telem, weights=weights)

    def acc_step(carry, i):
        st, hist, arrtot, sums, n = carry[:5]
        st, ys = step(st, i)
        delay, arr, arr_delayed = ys[0], ys[1], ys[2]
        if weights is not None:
            # super-function multiplicity: the histogram counts REQUESTS,
            # so the representative's arrivals weigh in once per member
            arr, arr_delayed = arr * weights, arr_delayed * weights
        g = tick0 + i
        m = ((g >= warm_tick) & (g < total_ticks)).astype(jnp.float32)
        b = jnp.clip(jnp.searchsorted(edges, delay, side="right"), 0, nbins - 1)
        hist = hist.at[jnp.arange(f), b].add(arr_delayed * m)
        hist = hist.at[:, 0].add((arr - arr_delayed) * m)
        out = (st, hist, arrtot + arr * m,
               sums + m * jnp.stack(ys[3:3 + len(_ACC_NAMES)]), n + m)
        if telem:
            tser, tcnt, tattr = carry[5:]
            slot = jnp.clip(g * telem_slots // total_ticks, 0,
                            telem_slots - 1)
            mt = (g < total_ticks).astype(jnp.float32)   # timeline: warmup in
            out = out + (tser.at[slot].add(ys[14] * mt),
                         tcnt.at[slot].add(mt),
                         tattr + ys[15] * m)             # attribution: not
        return out, None

    init = (state, jnp.zeros((f, nbins)), jnp.zeros(f),
            jnp.zeros(len(_ACC_NAMES)), jnp.zeros(()))
    if telem:
        init = init + (jnp.zeros((telem_slots, len(TELEM_SERIES))),
                       jnp.zeros(telem_slots), jnp.zeros(len(TELEM_ATTR)))
    carry, _ = jax.lax.scan(acc_step, init, jnp.arange(arr_chunk.shape[0]))
    return carry[0], carry[1:]


def _acc_summary(hist, arrtot, sums, n, edges, dur_median, dur_sigma, warm,
                 dt, iid_tail: bool = True, fn_weights=None) -> dict:
    """Build the ``summarize``-compatible metric row from chunk partials."""
    geo = _slowdown_geomean(hist, arrtot, edges, dur_median, dur_sigma, warm,
                            iid_tail=iid_tail, fn_weights=fn_weights)
    s = dict(zip(_ACC_NAMES, sums))
    n = max(float(n), 1e-9)
    window = n * dt
    useful = max(s["useful"], 1e-9)
    w, m = s["cpu_worker"], s["cpu_master"]
    return {
        "slowdown_geomean_p99": geo,
        "normalized_memory": float(s["mem_total"] / max(s["mem_busy"], 1e-9)),
        "creation_rate": float(s["creations"] / window),
        "cpu_overhead": float((w + m) / useful),
        "worker_share": float(w / max(w + m, 1e-9)),
        "instances_mean": float(s["instances"] / n),
        "nodes_mean": float(s["nodes"] / n),
        "node_seconds": float(s["nodes"] * dt),
        "spot_nodes_mean": float(s["spot_nodes"] / n),
        "spot_node_seconds": float(s["spot_nodes"] * dt),
        "completed": float(s["completions"]),
        "cpu_useful_s": float(s["useful"]),
        "cpu_worker_s": float(w),
        "cpu_master_s": float(m),
        "mem_total_mean": float(s["mem_total"] / n),
        "mem_busy_mean": float(s["mem_busy"] / n),
        "billed_gb_s": float(s["billed_gb_s"]),
        "ticks_measured": float(n),
    }


def _chunk_batch_impl(state, arr_chunk, lam0, gaps, gap_tab, dur, mem,
                      billed_w, pols, fleets,
                      cpu_consts, static_nodes, edges, tick0, *,
                      warm_tick: int, total_ticks: int, family: str, dt: float,
                      cold_ticks: int, wbuf: int, prov_ticks: int,
                      has_fleet: bool, telem_slots: int = 0, weights=None):
    """One time chunk for a whole batch of parameter points (vmap over the
    point axis of state/lam0/pols/fleets; ``pols`` is a STACKED params
    pytree — every leaf, scalar knob or weight array, carries a leading
    point axis)."""
    def one(st, l0, p, fl):
        return _chunk_impl(st, arr_chunk, l0, gaps, gap_tab, dur, mem,
                           billed_w, p, fl,
                           cpu_consts,
                           static_nodes, edges, tick0, warm_tick=warm_tick,
                           total_ticks=total_ticks, family=family, dt=dt,
                           cold_ticks=cold_ticks, wbuf=wbuf,
                           prov_ticks=prov_ticks, has_fleet=has_fleet,
                           telem_slots=telem_slots, weights=weights)
    return jax.vmap(one)(state, lam0, pols, fleets)


# module-level jit so repeated simulate_chunked / sweep calls with the same
# shapes and static config hit the compile cache (a per-call jit(vmap(...))
# closure would retrace every invocation); tick0 is a traced scalar, so the
# host chunk loop reuses one executable across chunks
_chunk_batch = partial(jax.jit, static_argnames=(
    "warm_tick", "total_ticks", "family", "dt", "cold_ticks", "wbuf",
    "prov_ticks", "has_fleet", "telem_slots"),
    donate_argnums=(0,))(_chunk_batch_impl)


# ---------------------------------------------------------------------------
# device-sharded dispatch (planet scale)
# ---------------------------------------------------------------------------
#
# The function axis is embarrassingly parallel: per-function state never
# couples across functions EXCEPT through a handful of scalar reductions
# (node capacity pressure, CPU floors, the metric sums).  ``shard_map``
# splits every per-function input and carry leaf over a 1-D "functions"
# mesh, each device runs the full chunk scan on its function slice with
# per-function histograms device-local, and ONE psum per chunk restores the
# global scalar sums.  The replicated floor terms (master CPU floor, the
# static node count) are pre-divided by the device count so the psum of the
# local sums reconstructs them exactly — division by 1.0 is a bitwise
# identity and the 1-device mesh is bit-for-bit the unsharded scan (tested),
# while powers of two divide exactly.
#
# The fleet layer reduces over functions INSIDE every tick (capacity
# scaling feeds back into per-function creates), which would need a psum
# per tick, not per chunk — so fleet runs shard the POINT axis instead
# (``_chunked_summaries`` places the vmapped batch over a "points" mesh and
# lets GSPMD partition the existing ``_chunk_batch``), which also batches
# frontier candidates as grid-points x devices in one compiled dispatch.

def _largest_divisor(n: int, cap: int) -> int:
    """Largest d <= cap with n % d == 0 (device_put refuses uneven shards)."""
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def _chunk_batch_fnshard_impl(state, arr_chunk, lam0, gaps, gap_tab, dur,
                              mem, billed_w, pols, fleets, edges, tick0,
                              weights, *, mesh, cpu_consts, static_nodes,
                              warm_tick: int, total_ticks: int, family: str,
                              dt: float, cold_ticks: int, wbuf: int,
                              prov_ticks: int, telem_slots: int = 0):
    """Function-sharded twin of ``_chunk_batch_impl`` (no-fleet only; the
    caller pads F to a multiple of the mesh size with inert zero-rate
    functions).  Per-function outputs (histogram, arrival totals) stay
    device-local; the scalar sums and telemetry vectors psum once per chunk."""
    ndev = mesh.shape["functions"]
    # replicated per-tick floors: each shard carries 1/ndev of the master
    # CPU floor and the static node count so the cross-device sum of local
    # accumulators reconstructs the global ones (exact for ndev a power of
    # two; ndev=1 divides by 1.0, a bitwise identity).  The worker floor
    # multiplies the already-divided node count and needs no split.
    consts_local = cpu_consts[:-1] + (cpu_consts[-1] / ndev,)
    nodes_local = static_nodes / ndev
    telem = telem_slots > 0

    def body(st, a, l0, g, gt, du, me, bw, pl, fl, ed, t0, wt):
        st, out = _chunk_batch_impl(
            st, a, l0, g, gt, du, me, bw, pl, fl, consts_local, nodes_local,
            ed, t0, warm_tick=warm_tick, total_ticks=total_ticks,
            family=family, dt=dt, cold_ticks=cold_ticks, wbuf=wbuf,
            prov_ticks=prov_ticks, has_fleet=False, telem_slots=telem_slots,
            weights=wt)
        red = (out[0], out[1], jax.lax.psum(out[2], "functions"), out[3])
        if telem:
            red = red + (jax.lax.psum(out[4], "functions"), out[5],
                         jax.lax.psum(out[6], "functions"))
        return st, red

    fP = P(None, "functions")      # leading point axis, functions sharded
    rep = P()
    st_specs = (fP, fP, fP, fP, fP, rep, rep, rep, rep, rep, rep, fP, fP)
    f1 = P("functions")
    w_spec = rep if weights is None else f1
    in_specs = (st_specs, fP, fP, f1, f1, f1, f1, f1, rep, rep, rep, rep,
                w_spec)
    out_stats = (fP, fP, rep, rep)
    if telem:
        out_stats = out_stats + (rep, rep, rep)
    sharded = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=(st_specs, out_stats), check_rep=False)
    return sharded(state, arr_chunk, lam0, gaps, gap_tab, dur, mem, billed_w,
                   pols, fleets, edges, tick0, weights)


_chunk_batch_fnshard = partial(jax.jit, static_argnames=(
    "mesh", "cpu_consts", "static_nodes", "warm_tick", "total_ticks",
    "family", "dt", "cold_ticks", "wbuf", "prov_ticks", "telem_slots"),
    donate_argnums=(0,))(_chunk_batch_fnshard_impl)


def stack_params(param_trees: "list[dict]") -> dict:
    """Stack per-point params pytrees into one batched pytree: every leaf
    (scalar knob or weight array) gains a leading point axis — the batch
    axes ``_chunk_batch_impl`` vmaps over."""
    return jax.tree.map(
        lambda *leaves: np.stack([np.asarray(lf, np.float32)
                                  for lf in leaves]), *param_trees)


def _chunked_summaries(trace, policy: JaxPolicy, pols: dict,
                       fleets: np.ndarray, *, sim: SimConfig, dt: float,
                       num_nodes: float, provision_s: float, has_fleet: bool,
                       chunk_ticks: int, warmup_frac: float,
                       nbins: int, telemetry: int = 0,
                       billing=None, devices: int = 0) -> list[dict]:
    """Run a batch of policy/fleet parameter points through the chunked scan
    (vmapped over points, host loop over time chunks, carry donated) and
    return one ``summarize``-style dict per point.  ``pols`` is a stacked
    params pytree (see ``stack_params``); ``policy`` supplies the family
    and the structural knobs.

    ``devices > 0`` shards the dispatch over a 1-D mesh of that many local
    devices (repro.distributed.sharding.device_mesh).  No-fleet runs shard
    the FUNCTION axis via ``shard_map`` (F is padded to a mesh multiple
    with inert zero-rate functions, trimmed from the results); fleet runs
    couple functions through per-tick capacity reductions, so they shard
    the POINT axis instead — the largest divisor of the point count that
    fits the device budget, falling back to the unsharded dispatch when the
    batch cannot split.  ``devices=0`` is the legacy single-device path."""
    arr_np = np.asarray(rate_matrix(trace, dt))
    n_ticks, f = arr_np.shape
    dur, mem, cold_ticks, wbuf, cpu_consts = _prep_static(trace, policy, sim, dt)
    billed_w = _billed_weights(trace, billing)
    dur_median = np.asarray(trace.profile.dur_median)
    dur_sigma = np.asarray(trace.profile.dur_sigma)
    weights_np = getattr(trace, "weights", None)
    prov_ticks = max(1, int(round(provision_s / dt)))
    edges = _delay_edges(nbins)
    warm_tick = int(n_ticks * warmup_frac)
    chunk_ticks = max(1, min(chunk_ticks, n_ticks))
    n_points = fleets.shape[0]

    lam_np = arr_np.mean(axis=0) / dt
    gq, alive_tab, tail_tab = gap_statistics(trace)

    devices = int(devices)
    fn_mesh = pt_sharding = None
    f_orig = f
    if devices > 0 and not has_fleet:
        from repro.distributed.sharding import device_mesh
        fn_mesh = device_mesh(devices, "functions")
        pad = (-f) % devices
        if pad:
            # inert padding functions: zero arrivals -> zero instances,
            # creations, memory and histogram mass (trimmed below anyway)
            arr_np = np.concatenate(
                [arr_np, np.zeros((n_ticks, pad), arr_np.dtype)], axis=1)
            dur = jnp.concatenate([dur, jnp.ones(pad, dur.dtype)])
            mem = jnp.concatenate([mem, jnp.zeros(pad, mem.dtype)])
            billed_w = jnp.concatenate([billed_w,
                                        jnp.zeros(pad, billed_w.dtype)])
            lam_np = np.concatenate([lam_np, np.zeros(pad)])
            gq = np.concatenate([gq, np.full(pad, trace.duration_s)])
            # never-observed-gap convention: alive = ka, tail = 1
            from repro.core.trace import KA_GRID
            alive_tab = np.concatenate(
                [alive_tab, np.broadcast_to(KA_GRID, (pad, len(KA_GRID)))])
            tail_tab = np.concatenate([tail_tab, np.ones((pad, len(KA_GRID)))])
            if weights_np is not None:
                weights_np = np.concatenate([weights_np, np.zeros(pad)])
            f += pad
    elif devices > 0 and has_fleet:
        d = _largest_divisor(n_points, devices)
        if d > 1:
            from repro.distributed.sharding import device_mesh
            pt_sharding = NamedSharding(device_mesh(d, "points"), P("points"))

    lam_eff = jnp.broadcast_to(jnp.asarray(lam_np, jnp.float32),
                               (n_points, f))
    gaps = jnp.asarray(gq, jnp.float32)
    gap_tab = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                           (alive_tab, tail_tab))
    edges_j = jnp.asarray(edges)
    weights_j = None if weights_np is None \
        else jnp.asarray(weights_np, jnp.float32)

    def init_point(fl):
        init_nodes = fl[0] if has_fleet else jnp.asarray(float(num_nodes))
        return _init_state(f, cold_ticks, wbuf, prov_ticks, init_nodes)

    state = jax.vmap(init_point)(jnp.asarray(fleets, jnp.float32))
    pols_j = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), pols)
    fleets_j = jnp.asarray(fleets, jnp.float32)
    if pt_sharding is not None:
        # point-axis sharding: place the vmapped batch over the mesh and
        # let GSPMD partition the existing compiled dispatch — frontier
        # candidates run as grid-points x devices in one call
        state = jax.device_put(state, pt_sharding)
        lam_eff = jax.device_put(lam_eff, pt_sharding)
        pols_j = jax.device_put(pols_j, pt_sharding)
        fleets_j = jax.device_put(fleets_j, pt_sharding)

    hist = np.zeros((n_points, f, nbins))
    arrtot = np.zeros((n_points, f))
    sums = np.zeros((n_points, len(_ACC_NAMES)))
    n = np.zeros(n_points)
    telemetry = int(telemetry)
    tser = np.zeros((n_points, max(telemetry, 1), len(TELEM_SERIES)))
    tcnt = np.zeros((n_points, max(telemetry, 1)))
    tattr = np.zeros((n_points, len(TELEM_ATTR)))
    for t0 in range(0, n_ticks, chunk_ticks):
        a = arr_np[t0:t0 + chunk_ticks]
        if a.shape[0] < chunk_ticks:        # pad the tail chunk; the padded
            a = np.concatenate(             # ticks are masked out of the stats
                [a, np.zeros((chunk_ticks - a.shape[0], f), a.dtype)])
        if fn_mesh is not None:
            state, out = _chunk_batch_fnshard(
                state, jnp.asarray(a), lam_eff, gaps, gap_tab, dur, mem,
                billed_w, pols_j, fleets_j, edges_j,
                jnp.asarray(t0, jnp.int32), weights_j, mesh=fn_mesh,
                cpu_consts=cpu_consts, static_nodes=float(num_nodes),
                warm_tick=warm_tick, total_ticks=n_ticks,
                family=policy.family, dt=dt, cold_ticks=cold_ticks,
                wbuf=wbuf, prov_ticks=prov_ticks, telem_slots=telemetry)
        else:
            state, out = _chunk_batch(
                state, jnp.asarray(a), lam_eff, gaps, gap_tab, dur, mem,
                billed_w, pols_j, fleets_j,
                cpu_consts, float(num_nodes), edges_j,
                jnp.asarray(t0, jnp.int32), warm_tick=warm_tick,
                total_ticks=n_ticks, family=policy.family, dt=dt,
                cold_ticks=cold_ticks, wbuf=wbuf, prov_ticks=prov_ticks,
                has_fleet=has_fleet, telem_slots=telemetry,
                weights=weights_j)
        hist += np.asarray(out[0])
        arrtot += np.asarray(out[1])
        sums += np.asarray(out[2])
        n += np.asarray(out[3])
        if telemetry:
            tser += np.asarray(out[4])
            tcnt += np.asarray(out[5])
            tattr += np.asarray(out[6])
    iid = get_family(policy.family).synchronous_tail
    fw = None if weights_np is None else np.asarray(weights_np)[:f_orig]
    rows = [_acc_summary(hist[i, :f_orig], arrtot[i, :f_orig], sums[i], n[i],
                         edges, dur_median, dur_sigma, sim.warm_latency_s,
                         dt, iid_tail=iid, fn_weights=fw)
            for i in range(n_points)]
    if telemetry:
        for i, row in enumerate(rows):
            row["telemetry"] = assemble_telemetry(tser[i], tcnt[i], tattr[i],
                                                  n_ticks, dt)
    return rows


def simulate_chunked(trace, policy: JaxPolicy, sim: SimConfig = SimConfig(),
                     dt: float = 1.0, num_nodes: int = 8,
                     fleet: Optional[JaxFleet] = None, chunk_ticks: int = 512,
                     warmup_frac: float = 0.5, nbins: int = 256,
                     *, spec=None) -> dict:
    """Memory-bounded twin of ``summarize(simulate(...))``: same step math,
    same metric keys, but summary statistics are accumulated inside a
    segmented scan so arbitrarily long / wide traces (the 2000-function
    Fig. 9 replay, fig9_planet's 100k functions, and beyond) never
    materialize (T, F) histories.  ``trace`` may be an event-level
    ``Trace`` or a pre-binned ``RateTrace`` (optionally clustered into
    weighted super-functions).

    ``spec`` (a ``repro.core.runspec.RunSpec``) carries the run knobs this
    engine consumes: ``telemetry`` slots, the ``billing`` profile, and
    ``devices`` for the sharded dispatch (function axis here; see
    ``_chunked_summaries``).  It is the only way to pass them — the loose
    ``telemetry=`` / ``billing=`` shim kwargs were removed.

    ``telemetry=S`` (static, default off) rides S downsampled per-tick
    series slots plus attribution sums in the scan carry — constant memory —
    and attaches the assembled ``telemetry`` dict (repro.obs.telemetry) to
    the returned row.  ``telemetry=0`` compiles the exact pre-telemetry
    program: results are bit-for-bit identical to a build without this
    feature.

    ``spec.billing`` (a ``repro.fleet.billing`` profile or name, default
    ``ideal``) selects the billed-duration expectation the scan's
    ``billed_gb_s`` accumulates — the ONLY knob it touches; every other
    metric is independent of the profile."""
    from repro.core.runspec import RunSpec
    spec = spec if spec is not None else RunSpec()
    if not isinstance(spec, RunSpec):
        raise TypeError("simulate_chunked() spec= must be a RunSpec, got "
                        f"{type(spec).__name__}")
    has_fleet = fleet is not None
    pols = stack_params([policy.params()])
    fleets = np.asarray([fleet.params() if has_fleet
                         else np.zeros(len(_PFLEET))], np.float32)
    return _chunked_summaries(
        trace, policy, pols, fleets, sim=sim, dt=dt, num_nodes=num_nodes,
        provision_s=fleet.provision_s if has_fleet else 0.0,
        has_fleet=has_fleet, chunk_ticks=chunk_ticks,
        warmup_frac=warmup_frac, nbins=nbins, telemetry=spec.telemetry,
        billing=spec.billing, devices=spec.devices)[0]

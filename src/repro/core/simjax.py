"""Vectorized cluster simulator: the whole fleet as one ``jax.lax.scan``.

This is the KWOK analogue (paper §3.4): the *policy math is identical* to the
real control plane (same window average / utilization target / keepalive
semantics), while workers are simulated — so experiments scale to thousands
of functions and hundreds of nodes in seconds, jit-compiled.

Two-level autoscaling: when a ``JaxFleet`` is passed, the node fleet joins
the scan carry — a scalar node count, a provisioning pipeline (provision
latency ≫ cold start), and a scale-down cooldown timer — mirroring
``repro.fleet.UtilizationFleetPolicy`` + ``NodeFleet`` branchlessly.
Instance creation is then capped by node capacity (capped creates stay
queued and re-request, the fluid analogue of placement-failure deferral),
and unplaceable demand feeds the node reconciler, so placement pressure
scales the fleet up instead of dropping requests.

Numeric policy and fleet parameters are *traced*, not compile-time
constants, so ``repro.fleet.sweep`` can ``vmap`` thousands of policy
configurations through one compiled scan (the fast path behind the Fig. 8 /
Fig. 10 trade-off frontiers).  Only structural sizes (window buffer,
cold-start/provision pipeline depths, policy kind) are static.

Approximations vs the discrete-event oracle (validated in tests):
* fluid service: completions per tick = in_service * dt / mean_dur_f
  (memoryless service), fractional instances allowed;
* keepalive expiry as a flux: idle * dt / keepalive (steady-state cohort
  equivalent) instead of per-instance timers;
* per-tick queue-delay estimator (queue / drain rate) stands in for exact
  per-request latency; p99 is taken over arrival-weighted tick samples;
* scale-down removes (cooldown-gated) idle node capacity instantly; the
  oracle drains the emptiest nodes first, so the residual drain time is
  small (parity-tested within 15%).

State is (F,)-vectorized; policies are branchless jnp.  dt = 1s.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eventsim import SimConfig
from repro.core.trace import Trace, rate_matrix


@dataclasses.dataclass(frozen=True)
class JaxPolicy:
    """Branchless policy parameters; kind: 0=sync keepalive, 1=async window."""
    kind: int
    keepalive_s: float = 600.0
    window_s: float = 60.0
    target: float = 0.7
    cc: int = 1


@dataclasses.dataclass(frozen=True)
class JaxFleet:
    """Node-fleet layer parameters (mirrors UtilizationFleetPolicy +
    NodeFleet).  ``provision_s`` is structural (pipeline depth, static);
    the rest are traced and sweepable."""
    node_memory_mb: float = 192_000.0
    provision_s: float = 60.0
    min_nodes: float = 1.0
    max_nodes: float = 64.0
    util_target: float = 0.7
    warm_frac: float = 0.25
    cooldown_s: float = 120.0

    def params(self) -> np.ndarray:
        """The traced parameter vector (see _PFLEET indices)."""
        return np.asarray([self.min_nodes, self.max_nodes, self.util_target,
                           self.warm_frac, self.cooldown_s,
                           self.node_memory_mb], np.float32)


# traced parameter vector layouts
_PPOL = ("keepalive_s", "target")
_PFLEET = ("min_nodes", "max_nodes", "util_target", "warm_frac",
           "cooldown_s", "node_memory_mb")


def _sim_impl(arrivals, dur, mem, pol, fleet, cpu_consts, static_nodes,
              *, kind: int, cc: int, n_ticks: int, dt: float, cold_ticks: int,
              wbuf: int, prov_ticks: int, has_fleet: bool):
    f = dur.shape[0]
    ccf = float(cc)
    keepalive_s, target = pol[0], pol[1]

    def step(state, tick):
        inst, in_service, queue, starting, win, wcur, nodes, pipe, cool = state
        arr = arrivals[tick].astype(jnp.float32)

        if has_fleet:
            # provisioning completes
            nodes = nodes + pipe[0]
            pipe = jnp.concatenate([pipe[1:], jnp.zeros((1,))])

        # instances finishing cold start
        ready = starting[:, 0]
        inst = inst + ready
        starting = jnp.concatenate([starting[:, 1:], jnp.zeros((f, 1))], axis=1)

        # dispatch + fluid service
        slots = inst * ccf
        free = jnp.maximum(slots - in_service, 0.0)
        dispatch = jnp.minimum(queue + arr, free)
        in_service = in_service + dispatch
        queue = queue + arr - dispatch
        completions = jnp.minimum(in_service * dt / dur, in_service)
        in_service = in_service - completions

        busy_inst = jnp.minimum(inst, jnp.ceil(in_service / ccf))
        idle = jnp.maximum(inst - busy_inst, 0.0)
        concurrency = in_service + queue

        # ---- instance-level policy ----
        win_ = win.at[:, wcur % wbuf].set(concurrency)
        n_valid = jnp.minimum(wcur + 1, wbuf).astype(jnp.float32)
        avg = win_.sum(axis=1) / n_valid

        pending = starting.sum(axis=1)
        if kind == 1:          # async: reconcile to desired
            desired = jnp.ceil(avg / (target * ccf) - 1e-9)
            have = inst + pending
            create = jnp.maximum(desired - have, 0.0)
            retire = jnp.minimum(jnp.maximum(have - desired, 0.0), idle)
        else:                  # sync: create per unserveable arrival, expire flux
            if has_fleet:
                # queued demand not already covered by in-flight cold starts
                # re-requests creation — capacity-capped creates retry here
                unserved = jnp.maximum(queue - pending * ccf, 0.0)
            else:
                unserved = jnp.maximum(arr - (free + pending), 0.0)
            create = unserved
            retire = idle * dt / keepalive_s

        inst = inst - retire

        # ---- node-fleet layer ----
        if has_fleet:
            min_n, max_n, util_t, warm_f, cool_s, node_mem = (
                fleet[0], fleet[1], fleet[2], fleet[3], fleet[4], fleet[5])
            capacity_mb = nodes * node_mem
            committed = ((inst + starting.sum(axis=1)) * mem).sum()
            free_mb = jnp.maximum(capacity_mb - committed, 0.0)
            req_mb = (create * mem).sum()
            scale = jnp.minimum(1.0, free_mb / jnp.maximum(req_mb, 1e-9))
            create = create * scale
            starting = starting.at[:, cold_ticks - 1].add(create)

            # reconcile: used memory plus unplaceable pressure -> desired nodes
            used = ((inst + starting.sum(axis=1)) * mem).sum()
            pressure = jnp.maximum(req_mb * (1.0 - scale), 0.0)
            needed = jnp.ceil((used + pressure) / (util_t * node_mem) - 1e-9)
            warm = jnp.ceil(warm_f * jnp.maximum(needed, 1.0) - 1e-9)
            desired_n = jnp.clip(needed + warm, min_n, max_n)
            have_n = nodes + pipe.sum()
            up = jnp.maximum(desired_n - have_n, 0.0)
            pipe = pipe.at[prov_ticks - 1].add(up)
            down_want = jnp.maximum(have_n - desired_n, 0.0)
            max_down = jnp.maximum(nodes - jnp.ceil(used / node_mem), 0.0)
            down = jnp.where(cool <= 0.0, jnp.minimum(down_want, max_down), 0.0)
            nodes = nodes - down
            cool = jnp.where(down > 0.0, jnp.ceil(cool_s / dt),
                             jnp.maximum(cool - 1.0, 0.0))
            nodes_billed = nodes + pipe.sum()
        else:
            starting = starting.at[:, cold_ticks - 1].add(create)
            nodes_billed = jnp.asarray(static_nodes, jnp.float32)

        # queue-delay estimator for THIS tick's arrivals: drain with the
        # capacity that will exist once in-flight creations finish, plus the
        # residual cold-start wait if capacity is still materializing.
        pending = starting.sum(axis=1)
        future_slots = (inst + pending) * ccf
        drain = jnp.maximum(future_slots / dur, 1e-6)
        cold_wait = jnp.where(future_slots < 0.5, 2.0 * cold_ticks * dt,
                              jnp.where((queue > 0) & (pending > 0),
                                        0.5 * cold_ticks * dt, 0.0))
        delay = queue / drain + cold_wait

        (c_cw, c_cm, c_tw, c_tm, c_rq, c_idle, c_wfloor_node, c_mfloor) = cpu_consts
        cpu_worker = create.sum() * c_cw + retire.sum() * c_tw \
            + idle.sum() * c_idle * dt + c_wfloor_node * nodes_billed * dt
        cpu_master = create.sum() * c_cm + retire.sum() * c_tm \
            + dispatch.sum() * c_rq + c_mfloor * dt
        useful = (completions * dur).sum()

        ys = (delay, arr, inst.sum(), (inst * mem).sum(), (busy_inst * mem).sum(),
              create.sum(), cpu_worker, cpu_master, useful, nodes_billed,
              completions.sum())
        return (inst, in_service, queue, starting, win_, wcur + 1,
                nodes, pipe, cool), ys

    init_nodes = fleet[0] if has_fleet else jnp.asarray(static_nodes, jnp.float32)
    init = (jnp.zeros(f), jnp.zeros(f), jnp.zeros(f),
            jnp.zeros((f, cold_ticks)), jnp.zeros((f, wbuf)), jnp.asarray(0),
            init_nodes * jnp.ones(()), jnp.zeros(prov_ticks), jnp.zeros(()))
    _, ys = jax.lax.scan(step, init, jnp.arange(n_ticks))
    return ys


_simulate = partial(jax.jit, static_argnames=(
    "kind", "cc", "n_ticks", "dt", "cold_ticks", "wbuf", "prov_ticks",
    "has_fleet"))(_sim_impl)


@dataclasses.dataclass
class JaxSimResult:
    delay: np.ndarray      # (T, F) per-tick queue delay estimate
    arrivals: np.ndarray   # (T, F)
    instances: np.ndarray  # (T,)
    mem_total: np.ndarray  # (T,)
    mem_busy: np.ndarray   # (T,)
    creations: np.ndarray  # (T,)
    cpu_worker: np.ndarray
    cpu_master: np.ndarray
    useful: np.ndarray
    nodes: np.ndarray      # (T,) billable node count (static fleet: constant)
    completions: np.ndarray  # (T,) fluid request completions
    dt: float
    dur: np.ndarray        # (F,)
    fleet: Optional[JaxFleet] = None


_YS_NAMES = ["delay", "arrivals", "instances", "mem_total", "mem_busy",
             "creations", "cpu_worker", "cpu_master", "useful", "nodes",
             "completions"]


def _prep(trace: Trace, policy: JaxPolicy, sim: SimConfig, dt: float):
    arr = jnp.asarray(rate_matrix(trace, dt))
    dur_mean = trace.profile.dur_median * np.exp(trace.profile.dur_sigma ** 2 / 2)
    dur = jnp.asarray(np.maximum(dur_mean, dt * 0.25), jnp.float32)
    mem = jnp.asarray(trace.profile.memory_mb + sim.instance_overhead_mb, jnp.float32)
    cold_ticks = max(1, int(round(sim.cold_start_s / dt)))
    wbuf = max(1, int(round(policy.window_s / dt))) if policy.kind == 1 else 1
    cpu_consts = (sim.cpu_create_worker_s, sim.cpu_create_master_s,
                  sim.cpu_teardown_worker_s, sim.cpu_teardown_master_s,
                  sim.cpu_request_s, sim.cpu_idle_per_s,
                  sim.cpu_worker_floor_per_node_s,
                  sim.cpu_master_floor_per_s)
    return arr, dur, mem, cold_ticks, wbuf, cpu_consts


def simulate(trace: Trace, policy: JaxPolicy, sim: SimConfig = SimConfig(),
             dt: float = 1.0, num_nodes: int = 8,
             fleet: Optional[JaxFleet] = None) -> JaxSimResult:
    arr, dur, mem, cold_ticks, wbuf, cpu_consts = _prep(trace, policy, sim, dt)
    has_fleet = fleet is not None
    prov_ticks = max(1, int(round((fleet.provision_s if has_fleet else 0.0) / dt)))
    pol = jnp.asarray([policy.keepalive_s, policy.target], jnp.float32)
    fl = jnp.asarray(fleet.params() if has_fleet else np.zeros(len(_PFLEET)),
                     jnp.float32)
    ys = _simulate(arr, dur, mem, pol, fl, cpu_consts, float(num_nodes),
                   kind=policy.kind, cc=policy.cc, n_ticks=arr.shape[0], dt=dt,
                   cold_ticks=cold_ticks, wbuf=wbuf, prov_ticks=prov_ticks,
                   has_fleet=has_fleet)
    vals = {n: np.asarray(v) for n, v in zip(_YS_NAMES, ys)}
    return JaxSimResult(dt=dt, dur=np.asarray(dur), fleet=fleet, **vals)


def summarize(res: JaxSimResult, warmup_frac: float = 0.5) -> dict:
    t0 = int(len(res.instances) * warmup_frac)
    sl = slice(t0, None)
    # arrival-weighted per-function p99 of (1 + delay/dur + warm overhead)
    delays, weights = res.delay[sl], res.arrivals[sl]
    slows = []
    for fidx in range(delays.shape[1]):
        w = weights[:, fidx]
        if w.sum() < 5:
            continue
        d = np.repeat(delays[:, fidx], w.astype(int))
        if len(d) == 0:
            continue
        p99 = np.percentile(d, 99)
        slows.append(max(1.0, 1.0 + p99 / res.dur[fidx]))
    geo = float(np.exp(np.mean(np.log(slows)))) if slows else float("nan")
    window = (len(res.instances) - t0) * res.dt
    useful = max(res.useful[sl].sum(), 1e-9)
    w = res.cpu_worker[sl].sum()
    m = res.cpu_master[sl].sum()
    out = {
        "slowdown_geomean_p99": geo,
        "normalized_memory": float(res.mem_total[sl].mean()
                                   / max(res.mem_busy[sl].mean(), 1e-9)),
        "creation_rate": float(res.creations[sl].sum() / window),
        "cpu_overhead": float((w + m) / useful),
        "worker_share": float(w / max(w + m, 1e-9)),
        "instances_mean": float(res.instances[sl].mean()),
        "nodes_mean": float(res.nodes[sl].mean()),
        "node_seconds": float(res.nodes[sl].sum() * res.dt),
        "completed": float(res.completions[sl].sum()),
        "cpu_worker_s": float(w),
        "cpu_master_s": float(m),
    }
    return out

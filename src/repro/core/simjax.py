"""Vectorized cluster simulator: the whole fleet as one ``jax.lax.scan``.

This is the KWOK analogue (paper §3.4): the *policy math is identical* to the
real control plane (same window average / utilization target / keepalive
semantics), while workers are simulated — so experiments scale to thousands
of functions and hundreds of nodes in seconds, jit-compiled.

Approximations vs the discrete-event oracle (validated in tests):
* fluid service: completions per tick = in_service * dt / mean_dur_f
  (memoryless service), fractional instances allowed;
* keepalive expiry as a flux: idle * dt / keepalive (steady-state cohort
  equivalent) instead of per-instance timers;
* per-tick queue-delay estimator (queue / drain rate) stands in for exact
  per-request latency; p99 is taken over arrival-weighted tick samples.

State is (F,)-vectorized; policies are branchless jnp.  dt = 1s.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eventsim import SimConfig
from repro.core.trace import Trace, rate_matrix


@dataclasses.dataclass(frozen=True)
class JaxPolicy:
    """Branchless policy parameters; kind: 0=sync keepalive, 1=async window."""
    kind: int
    keepalive_s: float = 600.0
    window_s: float = 60.0
    target: float = 0.7
    cc: int = 1


@partial(jax.jit, static_argnames=("policy", "n_ticks", "dt", "cold_ticks", "wbuf"))
def _simulate(arrivals, dur, mem, policy: JaxPolicy, n_ticks: int, dt: float,
              cold_ticks: int, wbuf: int, cpu_consts):
    f = dur.shape[0]
    cc = float(policy.cc)

    def step(state, tick):
        inst, in_service, queue, starting, win, wcur = state
        arr = arrivals[tick].astype(jnp.float32)

        # instances finishing cold start
        ready = starting[:, 0]
        inst = inst + ready
        starting = jnp.concatenate([starting[:, 1:], jnp.zeros((f, 1))], axis=1)

        # dispatch + fluid service
        slots = inst * cc
        free = jnp.maximum(slots - in_service, 0.0)
        dispatch = jnp.minimum(queue + arr, free)
        in_service = in_service + dispatch
        queue = queue + arr - dispatch
        completions = jnp.minimum(in_service * dt / dur, in_service)
        in_service = in_service - completions

        busy_inst = jnp.minimum(inst, jnp.ceil(in_service / cc))
        idle = jnp.maximum(inst - busy_inst, 0.0)
        concurrency = in_service + queue

        # ---- policy ----
        win = win.at[:, wcur % wbuf].set(concurrency)
        n_valid = jnp.minimum(wcur + 1, wbuf).astype(jnp.float32)
        avg = win.sum(axis=1) / n_valid

        if policy.kind == 1:   # async: reconcile to desired
            desired = jnp.ceil(avg / (policy.target * cc) - 1e-9)
            have = inst + starting.sum(axis=1)
            create = jnp.maximum(desired - have, 0.0)
            retire = jnp.minimum(jnp.maximum(have - desired, 0.0), idle)
        else:                  # sync: create per unserveable arrival, expire flux
            unserved = jnp.maximum(arr - (free + starting.sum(axis=1)), 0.0)
            create = unserved
            retire = idle * dt / policy.keepalive_s

        inst = inst - retire
        starting = starting.at[:, cold_ticks - 1].add(create)

        # queue-delay estimator for THIS tick's arrivals: drain with the
        # capacity that will exist once in-flight creations finish, plus the
        # residual cold-start wait if capacity is still materializing.
        pending = starting.sum(axis=1)
        future_slots = (inst + pending) * cc
        drain = jnp.maximum(future_slots / dur, 1e-6)
        cold_wait = jnp.where(future_slots < 0.5, 2.0 * cold_ticks * dt,
                              jnp.where((queue > 0) & (pending > 0),
                                        0.5 * cold_ticks * dt, 0.0))
        delay = queue / drain + cold_wait

        (c_cw, c_cm, c_tw, c_tm, c_rq, c_idle, c_wfloor, c_mfloor) = cpu_consts
        cpu_worker = create.sum() * c_cw + retire.sum() * c_tw \
            + idle.sum() * c_idle * dt + c_wfloor * dt
        cpu_master = create.sum() * c_cm + retire.sum() * c_tm \
            + dispatch.sum() * c_rq + c_mfloor * dt
        useful = (completions * dur).sum()

        ys = (delay, arr, inst.sum(), (inst * mem).sum(), (busy_inst * mem).sum(),
              create.sum(), cpu_worker, cpu_master, useful)
        return (inst, in_service, queue, starting, win, wcur + 1), ys

    init = (jnp.zeros(f), jnp.zeros(f), jnp.zeros(f),
            jnp.zeros((f, cold_ticks)), jnp.zeros((f, wbuf)), jnp.asarray(0))
    _, ys = jax.lax.scan(step, init, jnp.arange(n_ticks))
    return ys


@dataclasses.dataclass
class JaxSimResult:
    delay: np.ndarray      # (T, F) per-tick queue delay estimate
    arrivals: np.ndarray   # (T, F)
    instances: np.ndarray  # (T,)
    mem_total: np.ndarray  # (T,)
    mem_busy: np.ndarray   # (T,)
    creations: np.ndarray  # (T,)
    cpu_worker: np.ndarray
    cpu_master: np.ndarray
    useful: np.ndarray
    dt: float
    dur: np.ndarray        # (F,)


def simulate(trace: Trace, policy: JaxPolicy, sim: SimConfig = SimConfig(),
             dt: float = 1.0, num_nodes: int = 8) -> JaxSimResult:
    arr = jnp.asarray(rate_matrix(trace, dt))
    dur_mean = trace.profile.dur_median * np.exp(trace.profile.dur_sigma ** 2 / 2)
    dur = jnp.asarray(np.maximum(dur_mean, dt * 0.25), jnp.float32)
    mem = jnp.asarray(trace.profile.memory_mb + sim.instance_overhead_mb, jnp.float32)
    cold_ticks = max(1, int(round(sim.cold_start_s / dt)))
    wbuf = max(1, int(round(policy.window_s / dt))) if policy.kind == 1 else 1
    cpu_consts = (sim.cpu_create_worker_s, sim.cpu_create_master_s,
                  sim.cpu_teardown_worker_s, sim.cpu_teardown_master_s,
                  sim.cpu_request_s, sim.cpu_idle_per_s,
                  sim.cpu_worker_floor_per_node_s * num_nodes,
                  sim.cpu_master_floor_per_s)
    ys = _simulate(arr, dur, mem, policy, arr.shape[0], dt, cold_ticks, wbuf,
                   cpu_consts)
    names = ["delay", "arrivals", "instances", "mem_total", "mem_busy",
             "creations", "cpu_worker", "cpu_master", "useful"]
    vals = {n: np.asarray(v) for n, v in zip(names, ys)}
    return JaxSimResult(dt=dt, dur=np.asarray(dur), **vals)


def summarize(res: JaxSimResult, warmup_frac: float = 0.5) -> dict:
    t0 = int(len(res.instances) * warmup_frac)
    sl = slice(t0, None)
    # arrival-weighted per-function p99 of (1 + delay/dur + warm overhead)
    delays, weights = res.delay[sl], res.arrivals[sl]
    slows = []
    for fidx in range(delays.shape[1]):
        w = weights[:, fidx]
        if w.sum() < 5:
            continue
        d = np.repeat(delays[:, fidx], w.astype(int))
        if len(d) == 0:
            continue
        p99 = np.percentile(d, 99)
        slows.append(max(1.0, 1.0 + p99 / res.dur[fidx]))
    geo = float(np.exp(np.mean(np.log(slows)))) if slows else float("nan")
    window = (len(res.instances) - t0) * res.dt
    useful = max(res.useful[sl].sum(), 1e-9)
    w = res.cpu_worker[sl].sum()
    m = res.cpu_master[sl].sum()
    return {
        "slowdown_geomean_p99": geo,
        "normalized_memory": float(res.mem_total[sl].mean()
                                   / max(res.mem_busy[sl].mean(), 1e-9)),
        "creation_rate": float(res.creations[sl].sum() / window),
        "cpu_overhead": float((w + m) / useful),
        "worker_share": float(w / max(w + m, 1e-9)),
        "instances_mean": float(res.instances[sl].mean()),
    }

"""Discrete-event cluster simulator — the oracle for the paper's experiments.

Replays a Trace against a Cluster under a Policy (per function), modelling:
(policies are lowered from ``repro.core.policy_api`` family registrations
via ``PolicySpec.factory()`` — the oracle leg every registered policy
family, hand-written or gradient-learned, must hold the parity band on)
  instance lifecycle (cold start, busy/idle, keepalive expiry, teardown),
  container concurrency slots, request queueing (sync buffers per new
  instance, async queues until any instance frees), node failures with
  re-queued requests, straggler nodes, and the CPU/memory accounting behind
  the paper's four metrics.

Two-level autoscaling: pass a ``repro.fleet.NodeFleet`` and the node list
itself becomes elastic — nodes are provisioned (latency ≫ cold start),
drained before termination (in-flight work finishes first), and billed by
the second for the cost model in ``repro.fleet.costs``.  A placement
failure then *defers* the instance creation and feeds the fleet reconciler
as scale-up pressure, instead of dropping the request.  A
``repro.fleet.spot.SpotNodeFleet`` adds preemptible capacity: the market
announces reclaims, the node drains through its notice window, and the
``node_evict`` event force-kills whatever is still running — its in-flight
requests re-queue and recreate capacity (the eviction cold-start storm).

CPU overhead model (calibrated against the paper's Fig. 5/6 in
EXPERIMENTS.md):  churn dominates — a create+teardown pair costs ~8 CPU-s
(80% on the worker: sandbox setup, CNI, queue-proxy, probes; 20% master),
plus a small per-request data-plane cost, a per-idle-instance keepalive cost
(probes/metrics), and a constant control-plane floor.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.cluster import DRAINING, GONE, UP, Cluster
from repro.core.policies import Policy
from repro.core.trace import Trace


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cold_start_s: float = 1.0            # Knative-like; 0.3 approximates Lambda
    cold_start_jitter: float = 0.15
    warm_latency_s: float = 0.008        # data-plane hop on every dispatch
    teardown_s: float = 0.2
    tick_s: float = 2.0
    # CPU accounting (cpu-seconds)
    cpu_create_worker_s: float = 5.2
    cpu_create_master_s: float = 1.3
    cpu_teardown_worker_s: float = 1.2
    cpu_teardown_master_s: float = 0.3
    cpu_request_s: float = 0.02          # activator/queue-proxy per request
    cpu_idle_per_s: float = 0.002        # probes+metrics per warm instance
    cpu_master_floor_per_s: float = 1.5  # apiserver/controllers/prometheus
    cpu_worker_floor_per_node_s: float = 0.3   # kubelet/containerd/node-exporter
    num_worker_nodes_hint: int = 8
    instance_overhead_mb: float = 10.0   # per-sandbox memory overhead
    seed: int = 0
    warmup_s: Optional[float] = None     # measurement starts here (default T/2)


@dataclasses.dataclass
class RequestRecord:
    fn: int
    arrival: float
    start: float = math.nan
    end: float = math.nan
    dur: float = 0.0
    cold: bool = False
    requeued: int = 0
    # span bookkeeping (repro.obs): ids of this request's open request /
    # queue / execute spans; -1 when tracing is off or the span is closed
    sid: int = dataclasses.field(default=-1, repr=False, compare=False)
    qsid: int = dataclasses.field(default=-1, repr=False, compare=False)
    xsid: int = dataclasses.field(default=-1, repr=False, compare=False)


class _Instance:
    __slots__ = ("iid", "fn", "node", "cc", "in_flight", "state", "idle_since",
                 "expire_version", "memory_mb", "csid")

    def __init__(self, iid, fn, node, cc, memory_mb):
        self.iid, self.fn, self.node, self.cc = iid, fn, node, cc
        self.in_flight = 0
        self.state = "starting"            # starting | up | dead
        self.idle_since = math.nan
        self.expire_version = 0
        self.memory_mb = memory_mb
        self.csid = -1                     # open instance_create span id


class _FnState:
    __slots__ = ("instances", "queue", "starting", "policy")

    def __init__(self, policy: Policy):
        self.instances: list[_Instance] = []
        self.queue: deque = deque()
        self.starting = 0
        self.policy = policy

    @property
    def idle_count(self):
        return sum(1 for i in self.instances if i.state == "up" and i.in_flight == 0)

    @property
    def busy_free_slots(self):
        """Spare slots on instances that are already serving traffic — the
        ``busy_slots`` argument of ``Policy.on_arrival``.  Instances on
        draining nodes take no new dispatches, so their slots don't count."""
        return sum(i.cc - i.in_flight for i in self.instances
                   if i.state == "up" and i.in_flight > 0
                   and i.node.state == UP)

    @property
    def concurrency(self):
        return sum(i.in_flight for i in self.instances) + len(self.queue)


@dataclasses.dataclass
class SimResult:
    records: list[RequestRecord]
    creations: int
    teardowns: int
    cpu_useful_s: float
    cpu_worker_overhead_s: float
    cpu_master_overhead_s: float
    mem_samples_total_mb: np.ndarray
    mem_samples_busy_mb: np.ndarray
    sample_times: np.ndarray
    measure_window_s: float
    dropped: int = 0
    # node-fleet accounting (zero / static when no fleet is attached)
    node_seconds: float = 0.0
    node_samples: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    node_provisions: int = 0
    node_terminations: int = 0
    nodes_hint: int = 0
    # spot-tier accounting (zero for an on-demand-only fleet)
    spot_node_seconds: float = 0.0
    node_evictions: int = 0
    # overhead attribution (repro.obs.ledger): the measured-window CPU
    # split into creation churn / eviction storms / keepalive idle (the
    # control-plane remainder is the residual), plus the still-starting
    # memory samples behind the pipeline share of normalized memory
    mem_samples_starting_mb: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    cpu_churn_creation_s: float = 0.0
    cpu_evict_storm_s: float = 0.0
    cpu_keepalive_idle_s: float = 0.0

    def billed_duration_totals(self, granularity_s: float = 0.0,
                               min_billed_s: float = 0.0):
        """Per-request billed-duration totals over the measured window's
        ``records``: each recorded duration is rounded UP to the billing
        granularity and censored at the minimum billed duration EXACTLY
        (no expectation) — the oracle-side input ``repro.fleet.billing``
        meters GB-s against.  Returns ``(fn_ids, billed_seconds)``
        aggregated per function; identity rounding when both knobs are 0.
        (``records`` already covers only the measured window, so these
        totals align with ``len(records)`` completions.)"""
        if not self.records:
            return np.zeros(0, np.int64), np.zeros(0)
        fn = np.asarray([r.fn for r in self.records], np.int64)
        d = np.asarray([r.dur for r in self.records], np.float64)
        if granularity_s > 0.0:
            # the 1e-9 guard keeps exact multiples of the granularity
            # from rounding up an extra step through d/g float noise
            d = np.ceil(d / granularity_s - 1e-9) * granularity_s
        if min_billed_s > 0.0:
            d = np.maximum(d, min_billed_s)
        uniq = np.unique(fn)
        tot = np.bincount(fn, weights=d)
        return uniq, tot[uniq]


class EventSim:
    def __init__(self, trace: Trace, cluster: Cluster, policy_factory: Callable[[int], Policy],
                 cfg: SimConfig = SimConfig(),
                 failures: Optional[list[tuple[float, int]]] = None,
                 fleet=None, obs=None):
        self.trace = trace
        self.cluster = cluster
        self.cfg = cfg
        self.fleet = fleet                 # Optional[repro.fleet.NodeFleet]
        self.obs = obs                     # Optional[repro.obs.SpanRecorder]
        self.rng = np.random.default_rng(cfg.seed)
        self.fns = [_FnState(policy_factory(f)) for f in range(trace.num_functions)]
        self.failures = sorted(failures or [])
        self._events: list = []
        self._counter = itertools.count()
        self._iid = itertools.count()
        self._rid = itertools.count()      # request span track ids
        # deferred creations per function, clamped to real queued demand so
        # level-based policies re-issuing creates every tick can't stack
        # duplicate deferrals (and duplicate scale-up pressure)
        self._pending_creates: dict[int, int] = {}
        self.records: list[RequestRecord] = []
        self.creations = 0
        self.teardowns = 0
        self.cpu_useful = 0.0
        self.cpu_worker = 0.0
        self.cpu_master = 0.0
        self.mem_total: list[float] = []
        self.mem_busy: list[float] = []
        self.mem_start: list[float] = []
        self.sample_t: list[float] = []
        self.node_samples: list[int] = []
        self.node_seconds = 0.0
        self.dropped = 0
        # overhead attribution (repro.obs.ledger): measured-window CPU by
        # cause; ``_evict_debt`` counts eviction-killed instances per
        # function whose recreate (the next cold start) belongs to the
        # eviction storm, not to ordinary creation churn — the discrete
        # twin of the fluid engine's ``evict_deficit`` carry
        self.att_create = 0.0
        self.att_evict = 0.0
        self.att_idle = 0.0
        self._evict_debt: dict[int, int] = {}
        self._drain_sids: dict[int, int] = {}   # node_id -> open drain span
        self._measure_from = cfg.warmup_s if cfg.warmup_s is not None \
            else trace.duration_s / 2

    # -- event machinery -----------------------------------------------------------

    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._events, (t, next(self._counter), kind, payload))

    def run(self) -> SimResult:
        cfg = self.cfg
        for t, fn, dur in zip(self.trace.t, self.trace.fn, self.trace.dur):
            rec = RequestRecord(int(fn), float(t), dur=float(dur))
            self._push(float(t), "arrival", rec)
        for t in np.arange(0, self.trace.duration_s, cfg.tick_s):
            self._push(float(t), "tick")
        for t, node in self.failures:
            self._push(t, "fail", node)
        end_t = self.trace.duration_s
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if t > end_t and kind in ("tick",):
                continue
            getattr(self, f"_on_{kind}")(t, payload)
        if self.obs:
            # requests still queued / instances still starting when the
            # trace ends close here, tagged ``truncated``
            self.obs.finish(end_t)
        fl = self.fleet
        return SimResult(
            self.records, self.creations, self.teardowns, self.cpu_useful,
            self.cpu_worker, self.cpu_master,
            np.asarray(self.mem_total), np.asarray(self.mem_busy),
            np.asarray(self.sample_t), end_t - self._measure_from, self.dropped,
            node_seconds=self.node_seconds,
            node_samples=np.asarray(self.node_samples),
            node_provisions=fl.provisions if fl else 0,
            node_terminations=fl.terminations if fl else 0,
            nodes_hint=sum(1 for n in self.cluster.nodes if n.billable),
            spot_node_seconds=fl.spot_node_seconds if fl else 0.0,
            node_evictions=fl.evictions if fl else 0,
            mem_samples_starting_mb=np.asarray(self.mem_start),
            cpu_churn_creation_s=self.att_create,
            cpu_evict_storm_s=self.att_evict,
            cpu_keepalive_idle_s=self.att_idle)

    def _measuring(self, t) -> bool:
        return t >= self._measure_from

    def _node_evicting(self, node) -> bool:
        """Is this node under an announced (not yet enforced) spot reclaim?
        Teardowns on announced nodes belong to the eviction storm."""
        return self.fleet is not None \
            and node.node_id in getattr(self.fleet, "announced_ids", ())

    # -- instance lifecycle ----------------------------------------------------------

    def _create_instance(self, t: float, fn: int):
        fs = self.fns[fn]
        mem = self.trace.profile.memory_mb[fn] + self.cfg.instance_overhead_mb
        node = self.cluster.place(mem)
        if node is None:
            if self.fleet is not None:
                # placement failure -> the create is deferred and retried when
                # capacity appears (scale-up pressure is metered per tick from
                # the deferred level), not dropped
                demand = max(1, len(fs.queue))
                self._pending_creates[fn] = min(
                    self._pending_creates.get(fn, 0) + 1, demand)
            else:
                self.dropped += 1          # static cluster full: creation fails
            return
        inst = _Instance(next(self._iid), fn, node, fs.policy.container_concurrency, mem)
        fs.instances.append(inst)
        fs.starting += 1
        # an eviction-killed instance's recreate is eviction-storm CPU, not
        # ordinary churn: each kill registers one debt unit, drained by the
        # next create (the fluid twin drains ``evict_deficit`` identically)
        evict_recreate = self._evict_debt.get(fn, 0) > 0
        if evict_recreate:
            self._evict_debt[fn] -= 1
            if self._evict_debt[fn] <= 0:
                del self._evict_debt[fn]
        if self._measuring(t):
            self.creations += 1
            self.cpu_worker += self.cfg.cpu_create_worker_s
            self.cpu_master += self.cfg.cpu_create_master_s
            cpu = self.cfg.cpu_create_worker_s + self.cfg.cpu_create_master_s
            if evict_recreate:
                self.att_evict += cpu
            else:
                self.att_create += cpu
        delay = self.cfg.cold_start_s * (1 + self.cfg.cold_start_jitter * self.rng.uniform(-1, 1))
        delay *= inst.node.slowdown
        if self.obs:
            inst.csid = self.obs.begin(
                "instance_create", "instance", t, pid="instances",
                tid=inst.iid, fn=fn, node=node.node_id,
                evict_recreate=evict_recreate)
        self._push(t + delay, "ready", inst)

    def _teardown(self, t: float, inst: _Instance, reason: str = "keepalive"):
        if inst.state == "dead":
            return
        if inst.state == "starting":
            self.fns[inst.fn].starting -= 1
        inst.state = "dead"
        fs = self.fns[inst.fn]
        if inst in fs.instances:
            fs.instances.remove(inst)
        self.cluster.release(inst.node, inst.memory_mb)
        evicting = self._node_evicting(inst.node)
        if self._measuring(t):
            self.teardowns += 1
            # graceful-teardown CPU stays in the master_control residual of
            # the attribution ledger (it is control-plane/kubelet work, and
            # the engines disagree on WHEN idle mass sheds around the
            # measurement boundary — pairing it with creation would
            # concentrate that timing skew in one component)
            self.cpu_worker += self.cfg.cpu_teardown_worker_s
            self.cpu_master += self.cfg.cpu_teardown_master_s
        if self.obs:
            if inst.csid >= 0:
                self.obs.end(inst.csid, t, aborted=True)
                inst.csid = -1
            self.obs.emit("teardown", "instance", t,
                          t + self.cfg.teardown_s, pid="instances",
                          tid=inst.iid, fn=inst.fn,
                          reason="evict_notice" if evicting else reason)

    def _schedule_expire(self, t: float, inst: _Instance):
        fs = self.fns[inst.fn]
        ka = fs.policy.keepalive(t)
        if math.isinf(ka):
            return
        inst.expire_version += 1
        self._push(t + ka, "expire", (inst, inst.expire_version))

    # -- dispatch ----------------------------------------------------------------------

    def _free_inst(self, fs: _FnState) -> Optional[_Instance]:
        return next((i for i in fs.instances
                     if i.state == "up" and i.in_flight < i.cc
                     and i.node.state == UP), None)

    def _dispatch(self, t: float, inst: _Instance, rec: RequestRecord):
        rec.start = t + self.cfg.warm_latency_s
        inst.in_flight += 1
        inst.idle_since = math.nan
        service = rec.dur * inst.node.slowdown + self.cfg.warm_latency_s
        self._push(t + service, "done", (inst, rec))
        if self._measuring(t):
            self.cpu_master += self.cfg.cpu_request_s
        if self.obs and rec.sid >= 0:
            if rec.qsid >= 0:
                self.obs.end(rec.qsid, rec.start)
                rec.qsid = -1
            rec.xsid = self.obs.begin(
                "execute", "request", rec.start, pid="requests",
                tid=self.obs.spans[rec.sid].tid, parent=rec.sid,
                fn=rec.fn, cold=rec.cold, instance=inst.iid)

    def _drain_queue(self, t: float, fs: _FnState):
        while fs.queue:
            inst = self._free_inst(fs)
            if inst is None:
                return
            self._dispatch(t, inst, fs.queue.popleft())

    # -- event handlers ----------------------------------------------------------------

    def _on_arrival(self, t: float, rec: RequestRecord):
        fs = self.fns[rec.fn]
        if self.obs:
            rec.sid = self.obs.begin("request", "request", t, pid="requests",
                                     tid=next(self._rid), fn=rec.fn)
        decision = fs.policy.on_arrival(
            t, fs.idle_count, fs.busy_free_slots, fs.starting, len(fs.queue))
        for _ in range(decision.create):
            self._create_instance(t, rec.fn)
        inst = self._free_inst(fs)
        if inst is not None:
            self._dispatch(t, inst, rec)
        else:
            rec.cold = True
            if self.obs and rec.sid >= 0:
                rec.qsid = self.obs.begin(
                    "queue", "request", t, pid="requests",
                    tid=self.obs.spans[rec.sid].tid, parent=rec.sid,
                    fn=rec.fn)
            fs.queue.append(rec)

    def _on_ready(self, t: float, inst: _Instance):
        if inst.state == "dead":
            return
        fs = self.fns[inst.fn]
        inst.state = "up"
        fs.starting -= 1
        inst.idle_since = t
        if self.obs and inst.csid >= 0:
            self.obs.end(inst.csid, t)
            inst.csid = -1
        self._drain_queue(t, fs)
        if inst.in_flight == 0:
            if inst.node.state == DRAINING:
                self._teardown(t, inst, reason="node_drain")
            else:
                self._schedule_expire(t, inst)

    def _on_done(self, t: float, payload):
        inst, rec = payload
        rec.end = t
        if self.obs and rec.sid >= 0:
            if rec.xsid >= 0:
                self.obs.end(rec.xsid, t)
                rec.xsid = -1
            self.obs.end(rec.sid, t, requeued=rec.requeued)
            rec.sid = -1
        if self._measuring(rec.arrival) and not math.isnan(rec.start):
            self.cpu_useful += rec.dur
        if self._measuring(rec.arrival):
            self.records.append(rec)
        if inst.state == "dead":
            return
        inst.in_flight -= 1
        fs = self.fns[inst.fn]
        self._drain_queue(t, fs)
        if inst.in_flight == 0 and inst.state == "up":
            if inst.node.state == DRAINING:
                self._teardown(t, inst, reason="node_drain")
            else:
                inst.idle_since = t
                self._schedule_expire(t, inst)

    def _on_expire(self, t: float, payload):
        inst, version = payload
        if inst.state != "up" or inst.in_flight > 0 or inst.expire_version != version:
            return
        idle_for = t - inst.idle_since
        if self.fns[inst.fn].policy.on_idle_expired(t, idle_for):
            self._teardown(t, inst)

    def _retry_pending_creates(self, t: float):
        pend, self._pending_creates = self._pending_creates, {}
        for fn, count in pend.items():
            for _ in range(count):
                self._create_instance(t, fn)

    def _pending_pressure_mb(self) -> float:
        return sum(count * (self.trace.profile.memory_mb[fn]
                            + self.cfg.instance_overhead_mb)
                   for fn, count in self._pending_creates.items())

    def _on_node_ready(self, t: float, node):
        if self.fleet is None or not node.alive:
            return
        self.fleet.node_ready(node)
        self._retry_pending_creates(t)
        for fs in self.fns:
            self._drain_queue(t, fs)

    def _on_tick(self, t: float, _):
        total_mb = busy_mb = start_mb = 0.0
        n_idle = 0
        for fidx, fs in enumerate(self.fns):
            dec = fs.policy.on_tick(t, fs.concurrency,
                                    len(fs.instances) - fs.starting,
                                    fs.starting, fs.idle_count)
            for _ in range(dec.create):
                self._create_instance(t, fidx)
            if dec.retire:
                idles = sorted((i for i in fs.instances
                                if i.state == "up" and i.in_flight == 0),
                               key=lambda i: i.idle_since)
                for inst in idles[:dec.retire]:
                    self._teardown(t, inst, reason="retire")
            for i in fs.instances:
                total_mb += i.memory_mb
                if i.in_flight > 0:
                    busy_mb += i.memory_mb
                elif i.state == "up":
                    n_idle += 1
                elif i.state == "starting":
                    start_mb += i.memory_mb
        if self.fleet is not None:
            self._fleet_tick(t)
        if self._measuring(t):
            alive_nodes = self.cluster.billable_count
            self.cpu_worker += (n_idle * self.cfg.cpu_idle_per_s
                                + alive_nodes * self.cfg.cpu_worker_floor_per_node_s
                                ) * self.cfg.tick_s
            self.cpu_master += self.cfg.cpu_master_floor_per_s * self.cfg.tick_s
            self.att_idle += n_idle * self.cfg.cpu_idle_per_s * self.cfg.tick_s
            self.mem_total.append(total_mb)
            self.mem_busy.append(busy_mb)
            self.mem_start.append(start_mb)
            self.sample_t.append(t)

    def _fleet_tick(self, t: float):
        fleet = self.fleet
        # retry deferrals against existing capacity first; what still cannot
        # place is this tick's scale-up pressure
        if self._pending_creates:
            self._retry_pending_creates(t)
        fleet.note_pressure(self._pending_pressure_mb())
        provisioned, draining = fleet.reconcile(t, self.cluster)
        for node in provisioned:
            if self.obs:
                self.obs.emit("node_provision", "node", t,
                              t + fleet.node_type.provision_s, pid="nodes",
                              tid=node.node_id, spot=node.spot)
            self._push(t + fleet.node_type.provision_s, "node_ready", node)
        if draining:
            if self.obs:
                for node in draining:
                    if node.node_id not in self._drain_sids:
                        self._drain_sids[node.node_id] = self.obs.begin(
                            "node_drain", "node", t, pid="nodes",
                            tid=node.node_id,
                            evict=self._node_evicting(node))
            # idle and still-starting instances on a draining node are torn
            # down now (busy ones finish via _on_done); demand they were
            # covering re-registers as a deferred create so it lands on a
            # kept node
            drain_set = set(id(n) for n in draining)
            for fidx, fs in enumerate(self.fns):
                for inst in [i for i in fs.instances
                             if id(i.node) in drain_set and i.in_flight == 0
                             and i.state in ("up", "starting")]:
                    was_starting = inst.state == "starting"
                    if self._node_evicting(inst.node):
                        # an evicted warm/starting instance's replacement
                        # cold start is eviction-storm work
                        self._evict_debt[fidx] = \
                            self._evict_debt.get(fidx, 0) + 1
                    self._teardown(t, inst, reason="scale_down")
                    if was_starting and fs.queue:
                        self._pending_creates[fidx] = min(
                            self._pending_creates.get(fidx, 0) + 1,
                            len(fs.queue))
        # spot preemptions announced this tick: the node is already
        # draining (idle instances torn down above); whatever is still
        # busy at the notice deadline is force-evicted
        for node, deadline in fleet.pop_evictions():
            self._push(deadline, "node_evict", node)
        for node in (fleet.maybe_reclaim(self.cluster) or ()):
            sid = self._drain_sids.pop(node.node_id, -1)
            if self.obs and sid >= 0:
                self.obs.end(sid, t, reclaimed=True)
        if self._measuring(t):
            billed = fleet.bill(self.cluster, self.cfg.tick_s)
            self.node_seconds += billed * self.cfg.tick_s
            self.node_samples.append(billed)

    def _kill_node_instances(self, t: float, node, evict: bool = False):
        """Mark every instance on ``node`` dead (abrupt death: teardowns
        counted, no graceful-teardown CPU) — shared by node failures and
        forced spot evictions.  An eviction registers one unit of
        ``_evict_debt`` per kill so the replacement cold start is
        attributed to the storm."""
        for fs in self.fns:
            dead = [i for i in fs.instances if i.node is node]
            for inst in dead:
                if inst.state == "starting":
                    fs.starting -= 1
                inst.state = "dead"
                fs.instances.remove(inst)
                if evict:
                    self._evict_debt[inst.fn] = \
                        self._evict_debt.get(inst.fn, 0) + 1
                if self._measuring(t):
                    self.teardowns += 1
                if self.obs:
                    if inst.csid >= 0:
                        self.obs.end(inst.csid, t, aborted=True)
                        inst.csid = -1
                    self.obs.instant(
                        "instance_evicted" if evict else "instance_failed",
                        "instance", t, pid="instances", tid=inst.iid,
                        fn=inst.fn)

    def _requeue_inflight(self, t: float, node):
        """Re-queue the in-flight requests of ``node``'s dead instances
        (their pending 'done' events are dropped); scanning the event heap
        is O(E) but failures/evictions are rare events."""
        new_events = []
        for ev in self._events:
            tt, c, kind, payload = ev
            if kind == "done" and payload[0].node is node \
                    and payload[0].state == "dead":
                rec = payload[1]
                rec.requeued += 1
                if self.obs and rec.sid >= 0:
                    if rec.xsid >= 0:
                        self.obs.end(rec.xsid, t, evicted=True)
                        rec.xsid = -1
                    rec.qsid = self.obs.begin(
                        "queue", "request", t, pid="requests",
                        tid=self.obs.spans[rec.sid].tid, parent=rec.sid,
                        fn=rec.fn, requeue=rec.requeued)
                fs = self.fns[rec.fn]
                dec = fs.policy.on_arrival(t, fs.idle_count,
                                           fs.busy_free_slots, fs.starting,
                                           len(fs.queue))
                for _ in range(dec.create):
                    self._create_instance(t, rec.fn)
                fs.queue.append(rec)
            else:
                new_events.append(ev)
        heapq.heapify(new_events)
        self._events = new_events

    def _on_node_evict(self, t: float, node):
        """The reclaim notice expired: the provider takes the spot node
        back.  Instances still on it die abruptly; their in-flight
        requests re-queue and re-trigger creation — the eviction-driven
        cold-start storm."""
        fleet = self.fleet
        if fleet is None or not node.alive or node.state == GONE:
            return                      # drained empty and reclaimed already
        self._kill_node_instances(t, node, evict=True)
        self._requeue_inflight(t, node)
        fleet.force_evict(node, self.cluster)
        if self.obs:
            sid = self._drain_sids.pop(node.node_id, -1)
            if sid >= 0:
                self.obs.end(sid, t, evicted=True)
            self.obs.instant("node_evict", "node", t, pid="nodes",
                             tid=node.node_id)
        for fs in self.fns:
            self._drain_queue(t, fs)

    def _on_fail(self, t: float, node_id: int):
        node = self.cluster.fail_node(node_id)
        self._kill_node_instances(t, node)
        self._requeue_inflight(t, node)
        for fs in self.fns:
            self._drain_queue(t, fs)

"""Discrete-event cluster simulator — the oracle for the paper's experiments.

Replays a Trace against a Cluster under a Policy (per function), modelling:
  instance lifecycle (cold start, busy/idle, keepalive expiry, teardown),
  container concurrency slots, request queueing (sync buffers per new
  instance, async queues until any instance frees), node failures with
  re-queued requests, straggler nodes, and the CPU/memory accounting behind
  the paper's four metrics.

CPU overhead model (calibrated against the paper's Fig. 5/6 in
EXPERIMENTS.md):  churn dominates — a create+teardown pair costs ~8 CPU-s
(80% on the worker: sandbox setup, CNI, queue-proxy, probes; 20% master),
plus a small per-request data-plane cost, a per-idle-instance keepalive cost
(probes/metrics), and a constant control-plane floor.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.cluster import Cluster
from repro.core.policies import Policy
from repro.core.trace import Trace


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cold_start_s: float = 1.0            # Knative-like; 0.3 approximates Lambda
    cold_start_jitter: float = 0.15
    warm_latency_s: float = 0.008        # data-plane hop on every dispatch
    teardown_s: float = 0.2
    tick_s: float = 2.0
    # CPU accounting (cpu-seconds)
    cpu_create_worker_s: float = 5.2
    cpu_create_master_s: float = 1.3
    cpu_teardown_worker_s: float = 1.2
    cpu_teardown_master_s: float = 0.3
    cpu_request_s: float = 0.02          # activator/queue-proxy per request
    cpu_idle_per_s: float = 0.002        # probes+metrics per warm instance
    cpu_master_floor_per_s: float = 1.5  # apiserver/controllers/prometheus
    cpu_worker_floor_per_node_s: float = 0.3   # kubelet/containerd/node-exporter
    num_worker_nodes_hint: int = 8
    instance_overhead_mb: float = 10.0   # per-sandbox memory overhead
    seed: int = 0
    warmup_s: Optional[float] = None     # measurement starts here (default T/2)


@dataclasses.dataclass
class RequestRecord:
    fn: int
    arrival: float
    start: float = math.nan
    end: float = math.nan
    dur: float = 0.0
    cold: bool = False
    requeued: int = 0


class _Instance:
    __slots__ = ("iid", "fn", "node", "cc", "in_flight", "state", "idle_since",
                 "expire_version", "memory_mb")

    def __init__(self, iid, fn, node, cc, memory_mb):
        self.iid, self.fn, self.node, self.cc = iid, fn, node, cc
        self.in_flight = 0
        self.state = "starting"            # starting | up | dead
        self.idle_since = math.nan
        self.expire_version = 0
        self.memory_mb = memory_mb


class _FnState:
    __slots__ = ("instances", "queue", "starting", "policy")

    def __init__(self, policy: Policy):
        self.instances: list[_Instance] = []
        self.queue: deque = deque()
        self.starting = 0
        self.policy = policy

    @property
    def idle_count(self):
        return sum(1 for i in self.instances if i.state == "up" and i.in_flight == 0)

    @property
    def free_slots(self):
        return sum(i.cc - i.in_flight for i in self.instances if i.state == "up")

    @property
    def concurrency(self):
        return sum(i.in_flight for i in self.instances) + len(self.queue)


@dataclasses.dataclass
class SimResult:
    records: list[RequestRecord]
    creations: int
    teardowns: int
    cpu_useful_s: float
    cpu_worker_overhead_s: float
    cpu_master_overhead_s: float
    mem_samples_total_mb: np.ndarray
    mem_samples_busy_mb: np.ndarray
    sample_times: np.ndarray
    measure_window_s: float
    dropped: int = 0


class EventSim:
    def __init__(self, trace: Trace, cluster: Cluster, policy_factory: Callable[[int], Policy],
                 cfg: SimConfig = SimConfig(),
                 failures: Optional[list[tuple[float, int]]] = None):
        self.trace = trace
        self.cluster = cluster
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.fns = [_FnState(policy_factory(f)) for f in range(trace.num_functions)]
        self.failures = sorted(failures or [])
        self._events: list = []
        self._counter = itertools.count()
        self._iid = itertools.count()
        self.records: list[RequestRecord] = []
        self.creations = 0
        self.teardowns = 0
        self.cpu_useful = 0.0
        self.cpu_worker = 0.0
        self.cpu_master = 0.0
        self.mem_total: list[float] = []
        self.mem_busy: list[float] = []
        self.sample_t: list[float] = []
        self.dropped = 0
        self._measure_from = cfg.warmup_s if cfg.warmup_s is not None \
            else trace.duration_s / 2

    # -- event machinery -----------------------------------------------------------

    def _push(self, t: float, kind: str, payload=None):
        heapq.heappush(self._events, (t, next(self._counter), kind, payload))

    def run(self) -> SimResult:
        cfg = self.cfg
        for t, fn, dur in zip(self.trace.t, self.trace.fn, self.trace.dur):
            rec = RequestRecord(int(fn), float(t), dur=float(dur))
            self._push(float(t), "arrival", rec)
        for t in np.arange(0, self.trace.duration_s, cfg.tick_s):
            self._push(float(t), "tick")
        for t, node in self.failures:
            self._push(t, "fail", node)
        end_t = self.trace.duration_s
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            if t > end_t and kind in ("tick",):
                continue
            getattr(self, f"_on_{kind}")(t, payload)
        return SimResult(
            self.records, self.creations, self.teardowns, self.cpu_useful,
            self.cpu_worker, self.cpu_master,
            np.asarray(self.mem_total), np.asarray(self.mem_busy),
            np.asarray(self.sample_t), end_t - self._measure_from, self.dropped)

    def _measuring(self, t) -> bool:
        return t >= self._measure_from

    # -- instance lifecycle ----------------------------------------------------------

    def _create_instance(self, t: float, fn: int):
        fs = self.fns[fn]
        mem = self.trace.profile.memory_mb[fn] + self.cfg.instance_overhead_mb
        node = self.cluster.place(mem)
        if node is None:
            self.dropped += 1          # cluster full: creation fails
            return
        inst = _Instance(next(self._iid), fn, node, fs.policy.container_concurrency, mem)
        fs.instances.append(inst)
        fs.starting += 1
        if self._measuring(t):
            self.creations += 1
            self.cpu_worker += self.cfg.cpu_create_worker_s
            self.cpu_master += self.cfg.cpu_create_master_s
        delay = self.cfg.cold_start_s * (1 + self.cfg.cold_start_jitter * self.rng.uniform(-1, 1))
        delay *= inst.node.slowdown
        self._push(t + delay, "ready", inst)

    def _teardown(self, t: float, inst: _Instance):
        if inst.state == "dead":
            return
        inst.state = "dead"
        fs = self.fns[inst.fn]
        if inst in fs.instances:
            fs.instances.remove(inst)
        self.cluster.release(inst.node, inst.memory_mb)
        if self._measuring(t):
            self.teardowns += 1
            self.cpu_worker += self.cfg.cpu_teardown_worker_s
            self.cpu_master += self.cfg.cpu_teardown_master_s

    def _schedule_expire(self, t: float, inst: _Instance):
        fs = self.fns[inst.fn]
        ka = fs.policy.keepalive(t)
        if math.isinf(ka):
            return
        inst.expire_version += 1
        self._push(t + ka, "expire", (inst, inst.expire_version))

    # -- dispatch ----------------------------------------------------------------------

    def _dispatch(self, t: float, inst: _Instance, rec: RequestRecord):
        rec.start = t + self.cfg.warm_latency_s
        inst.in_flight += 1
        inst.idle_since = math.nan
        service = rec.dur * inst.node.slowdown + self.cfg.warm_latency_s
        self._push(t + service, "done", (inst, rec))
        if self._measuring(t):
            self.cpu_master += self.cfg.cpu_request_s

    def _drain_queue(self, t: float, fs: _FnState):
        while fs.queue:
            inst = next((i for i in fs.instances
                         if i.state == "up" and i.in_flight < i.cc), None)
            if inst is None:
                return
            self._dispatch(t, inst, fs.queue.popleft())

    # -- event handlers ----------------------------------------------------------------

    def _on_arrival(self, t: float, rec: RequestRecord):
        fs = self.fns[rec.fn]
        decision = fs.policy.on_arrival(
            t, fs.idle_count, fs.free_slots - fs.idle_count * 0, fs.starting,
            len(fs.queue))
        for _ in range(decision.create):
            self._create_instance(t, rec.fn)
        inst = next((i for i in fs.instances
                     if i.state == "up" and i.in_flight < i.cc), None)
        if inst is not None:
            self._dispatch(t, inst, rec)
        else:
            rec.cold = True
            fs.queue.append(rec)

    def _on_ready(self, t: float, inst: _Instance):
        if inst.state == "dead":
            return
        fs = self.fns[inst.fn]
        inst.state = "up"
        fs.starting -= 1
        inst.idle_since = t
        self._drain_queue(t, fs)
        if inst.in_flight == 0:
            self._schedule_expire(t, inst)

    def _on_done(self, t: float, payload):
        inst, rec = payload
        rec.end = t
        if self._measuring(rec.arrival) and not math.isnan(rec.start):
            self.cpu_useful += rec.dur
        if self._measuring(rec.arrival):
            self.records.append(rec)
        if inst.state == "dead":
            return
        inst.in_flight -= 1
        fs = self.fns[inst.fn]
        self._drain_queue(t, fs)
        if inst.in_flight == 0 and inst.state == "up":
            inst.idle_since = t
            self._schedule_expire(t, inst)

    def _on_expire(self, t: float, payload):
        inst, version = payload
        if inst.state != "up" or inst.in_flight > 0 or inst.expire_version != version:
            return
        idle_for = t - inst.idle_since
        if self.fns[inst.fn].policy.on_idle_expired(t, idle_for):
            self._teardown(t, inst)

    def _on_tick(self, t: float, _):
        total_mb = busy_mb = 0.0
        n_idle = 0
        for fs in self.fns:
            conc = fs.concurrency
            dec = fs.policy.on_tick(t, conc, len(fs.instances) - fs.starting,
                                    fs.starting, fs.idle_count)
            fn = fs.instances[0].fn if fs.instances else None
            for _ in range(dec.create):
                fidx = self.fns.index(fs) if fn is None else fn
                self._create_instance(t, fidx)
            if dec.retire:
                idles = sorted((i for i in fs.instances
                                if i.state == "up" and i.in_flight == 0),
                               key=lambda i: i.idle_since)
                for inst in idles[:dec.retire]:
                    self._teardown(t, inst)
            for i in fs.instances:
                total_mb += i.memory_mb
                if i.in_flight > 0:
                    busy_mb += i.memory_mb
                elif i.state == "up":
                    n_idle += 1
        if self._measuring(t):
            alive_nodes = sum(1 for n in self.cluster.nodes if n.alive)
            self.cpu_worker += (n_idle * self.cfg.cpu_idle_per_s
                                + alive_nodes * self.cfg.cpu_worker_floor_per_node_s
                                ) * self.cfg.tick_s
            self.cpu_master += self.cfg.cpu_master_floor_per_s * self.cfg.tick_s
            self.mem_total.append(total_mb)
            self.mem_busy.append(busy_mb)
            self.sample_t.append(t)

    def _on_fail(self, t: float, node_id: int):
        node = self.cluster.fail_node(node_id)
        for fs in self.fns:
            dead = [i for i in fs.instances if i.node is node]
            for inst in dead:
                inst.state = "dead"
                fs.instances.remove(inst)
                if self._measuring(t):
                    self.teardowns += 1
        # in-flight requests on the dead node are re-queued when their 'done'
        # fires: mark via node.alive in _on_done? simpler: scan outstanding
        # events is O(E); instead requeue at fail time:
        new_events = []
        for ev in self._events:
            tt, c, kind, payload = ev
            if kind == "done" and payload[0].node is node and payload[0].state == "dead":
                rec = payload[1]
                rec.requeued += 1
                fs = self.fns[rec.fn]
                dec = fs.policy.on_arrival(t, fs.idle_count, 0, fs.starting,
                                           len(fs.queue))
                for _ in range(dec.create):
                    self._create_instance(t, rec.fn)
                fs.queue.append(rec)
            else:
                new_events.append(ev)
        heapq.heapify(new_events)
        self._events = new_events
        for fs in self.fns:
            self._drain_queue(t, fs)

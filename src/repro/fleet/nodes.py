"""Node lifecycle management under a fleet policy (the EventSim-side manager).

``NodeFleet`` owns the elastic half of a ``Cluster``: it provisions nodes
(with a provision latency an order of magnitude above a container cold
start), drains before terminating (in-flight instances finish; the node is
reclaimed only once empty), gates scale-down behind a cooldown, and meters
billable node-seconds for the cost model.

The simulator drives it:

* ``reconcile(t, cluster)``     — once per tick; returns nodes that just
  entered ``provisioning`` (the caller schedules their ready events) and
  nodes that just started draining (the caller tears down their idle
  instances).
* ``note_pressure(mb)``         — a placement just failed for ``mb``; the
  next reconcile counts that memory as demand, so placement failures turn
  into node scale-up rather than request drops.
* ``node_ready(node)``          — provision latency elapsed.
* ``maybe_reclaim(cluster)``    — terminate any empty draining node.
* ``bill(tick_s)``              — accumulate node-seconds while measuring.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.cluster import (DRAINING, PROVISIONING, UP, Cluster, Node)
from repro.fleet.policies import FleetPolicy, UtilizationFleetPolicy


@dataclasses.dataclass(frozen=True)
class NodeType:
    """A purchasable node shape (see EXPERIMENTS.md for the pricing table)."""
    name: str = "standard-48"
    memory_mb: float = 192_000.0
    vcpus: float = 48.0
    price_per_hour: float = 1.88       # on-demand $/node-hour
    provision_s: float = 60.0          # boot + join + image pull >> cold start


class NodeFleet:
    def __init__(self, policy: FleetPolicy | None = None,
                 node_type: NodeType = NodeType(),
                 cooldown_s: float = 120.0):
        self.policy = policy or UtilizationFleetPolicy()
        self.node_type = node_type
        self.cooldown_s = cooldown_s
        # scale-down cooldown PER decision source: a policy that exposes
        # ``last_source`` (e.g. a reactive trigger name on a convergence
        # policy) gets its own clock, so two triggers with different
        # cooldowns never suppress each other; plain policies all key on
        # None and behave exactly as the old single-fleet timer did
        self._cooldown_until: dict = {}
        self._pressure_mb = 0.0
        self.provisions = 0
        self.terminations = 0
        self.node_seconds = 0.0
        # spot-tier accounting: an on-demand-only fleet never touches these;
        # repro.fleet.spot.SpotNodeFleet drives them (the simulators read
        # them unconditionally, so they live on the base class)
        self.evictions = 0
        self.spot_node_seconds = 0.0
        # node_ids whose drain is a market reclaim in progress (announced
        # but not yet enforced) — teardowns there are eviction-storm work,
        # not ordinary churn (repro.obs.ledger reads this via the sim)
        self.announced_ids: set[int] = set()

    # -- demand signals ---------------------------------------------------------

    def note_pressure(self, memory_mb: float) -> None:
        self._pressure_mb += memory_mb

    # -- reconciliation ---------------------------------------------------------

    def reconcile(self, t: float, cluster: Cluster) -> tuple[list[Node], list[Node]]:
        # demand = memory on the capacity we keep (up + provisioning) plus
        # unplaceable pressure; draining nodes are exiting, so their load
        # must not re-inflate desired capacity (it finishes or recreates on
        # kept nodes, where it is counted)
        have_nodes = cluster.nodes_in(UP, PROVISIONING)
        used = sum(n.used_mb for n in have_nodes) + self._pressure_mb
        self._pressure_mb = 0.0
        have = len(have_nodes)
        desired = self.policy.desired(t, used, self.node_type.memory_mb, have)

        provisioned: list[Node] = []
        draining: list[Node] = []
        if desired > have:
            provisioned = self._provision(cluster, desired - have)
            self.provisions += len(provisioned)
        else:
            key = getattr(self.policy, "last_source", None)
            if desired < have \
                    and t >= self._cooldown_until.get(key, -math.inf):
                # drain the emptiest up-nodes first so reclamation is fast
                up = sorted(cluster.nodes_in(UP), key=lambda n: n.used_mb)
                for node in up[:have - desired]:
                    cluster.start_drain(node)
                    draining.append(node)
                if draining:
                    cool = getattr(self.policy, "last_cooldown_s", None)
                    self._cooldown_until[key] = t + (cool if cool is not None
                                                     else self.cooldown_s)
        return provisioned, draining

    def _provision(self, cluster: Cluster, count: int) -> list[Node]:
        """Buy ``count`` nodes; the spot subclass overrides this to split
        the purchase across capacity tiers."""
        return [cluster.add_node(self.node_type.memory_mb)
                for _ in range(count)]

    def pop_evictions(self) -> list[tuple[Node, float]]:
        """(node, force-termination deadline) pairs announced since the
        last call — the reclaim notices the simulator must schedule.  An
        on-demand fleet never announces any."""
        return []

    def node_ready(self, node: Node) -> None:
        if node.state == PROVISIONING and node.alive:
            node.state = UP

    def maybe_reclaim(self, cluster: Cluster) -> list[Node]:
        """Terminate draining nodes whose instances have all finished."""
        done = [n for n in cluster.nodes_in(DRAINING) if n.used_mb <= 1e-9]
        for node in done:
            cluster.terminate(node)
            self.announced_ids.discard(node.node_id)
        self.terminations += len(done)
        return done

    # -- billing -----------------------------------------------------------------

    def bill(self, cluster: Cluster, dt_s: float) -> int:
        n = cluster.billable_count
        self.node_seconds += n * dt_s
        return n

"""Dollar-cost accounting: node-hours + control-plane CPU -> $/1M requests.

The paper's metrics (CPU churn overhead, memory over-allocation, creation
rate) are resource-denominated; operators optimize dollars ("Understanding
Cost Dynamics of Serverless Computing", PAPERS.md).  This module converts a
simulation's resource totals into a bill:

* worker fleet:   billable node-seconds x the node type's $/hour,
* control plane:  master CPU-seconds x a managed-vCPU rate (apiserver,
  autoscaler, activator — billed per-vCPU like a managed control plane),
* attribution:    the share of the worker bill burned by churn
  (create/teardown CPU) and by idle keepalive memory, so the headline
  "cost of keeping warm" is a dollar figure.

Pricing defaults are in the table in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

from repro.fleet.nodes import NodeType


@dataclasses.dataclass(frozen=True)
class PriceBook:
    master_vcpu_per_hour: float = 0.048   # managed control-plane vCPU $/h
    # spot-tier discount: 0.65 -> SPOT node-hours bill at 35% of on-demand.
    # Applied ONLY to the ``spot_node_seconds`` share of the fleet — a
    # mixed fleet bills each tier at its own rate (it used to be applied
    # fleet-wide, which overstated the savings of any partial-spot fleet).
    spot_discount: float = 0.0


@dataclasses.dataclass
class CostReport:
    node_hours: float
    node_cost: float                 # worker fleet bill
    master_cpu_hours: float
    master_cost: float               # control-plane bill
    churn_cost: float                # share of node bill spent creating/tearing down
    idle_cost: float                 # share of node bill holding idle-warm instances
    total_cost: float
    completed: int
    cost_per_million: float          # $ / 1M completed requests

    def row(self) -> dict:
        return dataclasses.asdict(self)


def cost_report(*, node_seconds: float, cpu_worker_overhead_s: float,
                cpu_master_overhead_s: float, idle_node_share: float,
                completed: int, node_type: NodeType = NodeType(),
                prices: PriceBook = PriceBook(),
                spot_node_seconds: float = 0.0) -> CostReport:
    """``idle_node_share``: fraction of fleet capacity held by idle-warm
    instances (e.g. ``(mem_total - mem_busy) / fleet capacity`` averaged
    over the measurement window).  ``spot_node_seconds`` is the share of
    ``node_seconds`` billed on the spot tier (at ``1 - spot_discount`` of
    the on-demand rate); billing is per tier, never fleet-wide."""
    node_hours = node_seconds / 3600.0
    od_rate = node_type.price_per_hour
    spot_rate = od_rate * (1.0 - prices.spot_discount)
    spot_hours = min(max(spot_node_seconds, 0.0), node_seconds) / 3600.0
    node_cost = (node_hours - spot_hours) * od_rate + spot_hours * spot_rate

    # churn CPU runs on the workers: price it at the per-vCPU slice of the
    # fleet's BLENDED rate (a mixed fleet churns on both tiers).
    blended_rate = node_cost / node_hours if node_hours > 0.0 else od_rate
    churn_cost = (cpu_worker_overhead_s / 3600.0) \
        * (blended_rate / node_type.vcpus)
    idle_cost = node_cost * max(0.0, min(1.0, idle_node_share))

    master_cpu_hours = cpu_master_overhead_s / 3600.0
    master_cost = master_cpu_hours * prices.master_vcpu_per_hour

    total = node_cost + master_cost
    # a window that completed nothing has no meaningful unit cost: report
    # NaN — labeled, like the ``dropped`` column in ``Metrics.row()`` —
    # instead of a real-looking $/1M figure divided by a phantom request
    per_million = total / completed * 1e6 if completed > 0 else float("nan")
    return CostReport(node_hours, node_cost, master_cpu_hours, master_cost,
                      churn_cost, idle_cost, total, completed, per_million)


def cost_from_sim(result, node_type: NodeType = NodeType(),
                  prices: PriceBook = PriceBook()) -> CostReport:
    """Bill an ``EventSim`` result (fleet-enabled or static-cluster)."""
    node_seconds = result.node_seconds
    if node_seconds <= 0.0 and len(result.sample_times):
        # static cluster: every configured node bills for the whole window
        node_seconds = result.measure_window_s * max(result.nodes_hint, 1)
    cap_mb = max(node_seconds / max(result.measure_window_s, 1e-9), 1e-9) \
        * node_type.memory_mb
    idle_mb = 0.0
    if len(result.mem_samples_total_mb):
        idle_mb = float(result.mem_samples_total_mb.mean()
                        - result.mem_samples_busy_mb.mean())
    return cost_report(
        node_seconds=node_seconds,
        cpu_worker_overhead_s=result.cpu_worker_overhead_s,
        cpu_master_overhead_s=result.cpu_master_overhead_s,
        idle_node_share=idle_mb / cap_mb,
        completed=len(result.records),
        node_type=node_type, prices=prices,
        spot_node_seconds=result.spot_node_seconds)

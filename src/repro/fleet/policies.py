"""Node-fleet scaling policies (the otter/node-fleet layer of the stack).

These decide *how many worker nodes* the cluster should run, one level below
the per-function instance policies in ``repro.core.policies``.  Three
families, mirroring rackerlabs/otter's policy taxonomy:

* ``UtilizationFleetPolicy`` — a reconciler: keep memory utilization of the
  fleet at a target, plus a warm-node pool for cold-start headroom.  This is
  the policy mirrored branchlessly inside the ``lax.scan`` simulator, so it
  is the one used for oracle/vectorized parity.
* ``ThresholdFleetPolicy``  — otter-style step policy: when utilization
  crosses a high/low watermark, add/remove a fixed ``change`` of nodes,
  gated by a per-policy cooldown.
* ``ScheduleFleetPolicy``   — otter's scheduled scaling: a piecewise-constant
  desired capacity over time (e.g. business-hours up, nights down).

All desired sizes are clamped to ``[min_nodes, max_nodes]`` (otter's
min/maxEntities).  Scale-*down* cooldown and draining are enforced by the
fleet manager, not here; ``ThresholdFleetPolicy`` additionally carries its
own trigger cooldown like otter's per-policy cooldown.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class FleetPolicy:
    """Base: a fixed-size fleet (desired == min_nodes == max_nodes)."""
    min_nodes: int = 1
    max_nodes: int = 64

    def clamp(self, n: float) -> int:
        return int(min(max(math.ceil(n - 1e-9), self.min_nodes), self.max_nodes))

    def desired(self, t: float, used_mb: float, node_memory_mb: float,
                nodes_now: int) -> int:
        return self.clamp(self.min_nodes)


@dataclasses.dataclass
class UtilizationFleetPolicy(FleetPolicy):
    """Reconcile node count so used memory sits at ``util_target`` of
    capacity, then add a warm pool of ``ceil(warm_frac * needed)`` spare
    nodes so placement bursts land on already-provisioned capacity."""
    util_target: float = 0.7
    warm_frac: float = 0.25

    def desired(self, t, used_mb, node_memory_mb, nodes_now):
        needed = math.ceil(used_mb / (self.util_target * node_memory_mb) - 1e-9)
        warm = math.ceil(self.warm_frac * max(needed, 1) - 1e-9)
        return self.clamp(needed + warm)


@dataclasses.dataclass
class ThresholdFleetPolicy(FleetPolicy):
    """Otter-style watermark policy: utilization above ``high`` adds
    ``change`` nodes, below ``low`` removes ``change``, at most once per
    ``cooldown_s`` (the per-policy cooldown in otter's schema)."""
    high: float = 0.8
    low: float = 0.3
    change: int = 1
    cooldown_s: float = 120.0
    _last_fired: float = dataclasses.field(default=-math.inf, repr=False)

    def desired(self, t, used_mb, node_memory_mb, nodes_now):
        if t - self._last_fired < self.cooldown_s:
            return self.clamp(nodes_now)
        util = used_mb / max(nodes_now * node_memory_mb, 1e-9)
        if util > self.high:
            self._last_fired = t
            return self.clamp(nodes_now + self.change)
        if util < self.low and nodes_now > self.min_nodes:
            self._last_fired = t
            return self.clamp(nodes_now - self.change)
        return self.clamp(nodes_now)


@dataclasses.dataclass
class ScheduleFleetPolicy(FleetPolicy):
    """Piecewise-constant desired capacity: ``entries`` is a sorted list of
    (start_time_s, desired_nodes); the last entry at or before ``t`` wins."""
    entries: tuple = ((0.0, 1),)

    def desired(self, t, used_mb, node_memory_mb, nodes_now):
        want = self.entries[0][1]
        for start, n in self.entries:
            if start <= t:
                want = n
            else:
                break
        # never scale below what current usage occupies
        floor = math.ceil(used_mb / node_memory_mb - 1e-9)
        return self.clamp(max(want, floor))

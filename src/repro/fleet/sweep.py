"""vmapped policy-parameter sweeps over the chunked lax.scan simulator.

The oracle explores a trade-off frontier (Fig. 8 / Fig. 10) by re-running a
discrete-event simulation per configuration — minutes per point.  Here the
whole grid runs as ONE jit-compiled ``vmap`` over the traced policy/fleet
parameter vectors of ``repro.core.simjax``: every (keepalive x warm-pool x
node-cap x target) combination shares a single compiled scan, so a
hundred-point frontier costs about as much as one simulation.

The sweep rides the *chunked* scan (``simjax._chunked_summaries``): summary
statistics accumulate inside the scan carry instead of materializing a
(points x ticks x functions) history, so grids stay cheap even on the
2000-function production-scale traces.

    rows = sweep(trace, JaxPolicy(kind=0), JaxFleet(),
                 grid={"keepalive_s": [60, 300, 600],
                       "warm_frac": [0.0, 0.25, 0.5],
                       "max_nodes": [8, 16]})

Each row carries the swept parameters, the standard summary metrics, and
the dollar bill (cost_per_million) from ``repro.fleet.costs``.
"""

from __future__ import annotations

import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.eventsim import SimConfig
from repro.core.simjax import (_PFLEET, _PPOL, JaxFleet, JaxPolicy,
                               _chunked_summaries)
from repro.core.trace import Trace
from repro.fleet.costs import PriceBook, cost_report
from repro.fleet.nodes import NodeType

SWEEPABLE = set(_PPOL) | set(_PFLEET)


def grid_points(grid: dict) -> list[dict]:
    """Cartesian product of a {param: values} grid, as one dict per point."""
    keys = list(grid)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(grid[k] for k in keys))]


def sweep(trace: Trace, policy: JaxPolicy, fleet: JaxFleet,
          grid: Optional[dict] = None, points: Optional[Sequence[dict]] = None,
          sim: SimConfig = SimConfig(), dt: float = 1.0,
          node_type: Optional[NodeType] = None,
          prices: PriceBook = PriceBook(),
          warmup_frac: float = 0.5, chunk_ticks: int = 512) -> list[dict]:
    """Run every parameter point through one vmapped chunked scan; return one
    row per point: {params..., metrics..., cost fields...}."""
    pts = list(points) if points is not None else grid_points(grid or {})
    if not pts:
        pts = [{}]
    unknown = {k for p in pts for k in p} - SWEEPABLE
    if unknown:
        raise ValueError(f"unsweepable params {sorted(unknown)}; "
                         f"traced params are {sorted(SWEEPABLE)}")

    base_pol = np.asarray([policy.keepalive_s, policy.target], np.float32)
    base_fleet = fleet.params()
    pols = np.tile(base_pol, (len(pts), 1))
    fleets = np.tile(base_fleet, (len(pts), 1))
    for i, p in enumerate(pts):
        for k, v in p.items():
            if k in _PPOL:
                pols[i, _PPOL.index(k)] = v
            else:
                fleets[i, _PFLEET.index(k)] = v

    summaries = _chunked_summaries(
        trace, policy, pols, fleets, sim=sim, dt=dt, num_nodes=0,
        provision_s=fleet.provision_s, has_fleet=True,
        chunk_ticks=chunk_ticks, warmup_frac=warmup_frac, nbins=256)

    if node_type is None:
        # derive a shape from the fleet's node size at the default $/GB-hour
        base = NodeType()
        ratio = fleet.node_memory_mb / base.memory_mb
        node_type = NodeType(memory_mb=fleet.node_memory_mb,
                             vcpus=base.vcpus * ratio,
                             price_per_hour=base.price_per_hour * ratio,
                             provision_s=fleet.provision_s)
    nt = node_type
    rows = []
    for i, p in enumerate(pts):
        s = summaries[i]
        node_mem = fleets[i, _PFLEET.index("node_memory_mb")]
        if node_mem != nt.memory_mb:
            # sweeping node size: scale price and vCPUs linearly ($/GB-hour
            # held constant) so cost rows stay comparable across shapes
            ratio = node_mem / nt.memory_mb
            nt_i = NodeType(name=nt.name, memory_mb=float(node_mem),
                            vcpus=nt.vcpus * ratio,
                            price_per_hour=nt.price_per_hour * ratio,
                            provision_s=nt.provision_s)
        else:
            nt_i = nt
        cap_mb = max(s["nodes_mean"] * node_mem, 1e-9)
        idle_mb = s["mem_total_mean"] - s["mem_busy_mean"]
        cost = cost_report(
            node_seconds=s["node_seconds"],
            cpu_worker_overhead_s=s["cpu_worker_s"],
            cpu_master_overhead_s=s["cpu_master_s"],
            idle_node_share=idle_mb / cap_mb,
            completed=int(s["completed"]),
            node_type=nt_i, prices=prices)
        rows.append({**p, **s, **cost.row()})
    return rows


def pareto_front(rows: list[dict], x: str = "cost_per_million",
                 y: str = "slowdown_geomean_p99") -> list[dict]:
    """Non-dominated subset (minimize both axes), sorted by x."""
    out = [r for r in rows
           if not any(o[x] <= r[x] and o[y] <= r[y]
                      and (o[x] < r[x] or o[y] < r[y]) for o in rows)]
    return sorted(out, key=lambda r: r[x])

"""vmapped policy-parameter sweeps over the chunked lax.scan simulator.

The oracle explores a trade-off frontier (Fig. 8 / Fig. 10) by re-running a
discrete-event simulation per configuration — minutes per point.  Here the
whole grid runs as ONE jit-compiled ``vmap`` over the traced policy/fleet
parameter vectors of ``repro.core.simjax``: every (keepalive x warm-pool x
node-cap x target) combination shares a single compiled scan, so a
hundred-point frontier costs about as much as one simulation.

    rows = sweep(trace, JaxPolicy(kind=0), JaxFleet(),
                 grid={"keepalive_s": [60, 300, 600],
                       "warm_frac": [0.0, 0.25, 0.5],
                       "max_nodes": [8, 16]})

Each row carries the swept parameters, the standard summary metrics, and
the dollar bill (cost_per_million) from ``repro.fleet.billing`` — pass
``billing="aws_lambda"`` / ``"gcr"`` to bill the whole grid through a
provider-calibrated profile (default: the ``ideal`` profile, bitwise the
old ``repro.fleet.costs`` math).

This module is the stable fleet-facing surface; the machinery itself lives
in ``repro.opt`` (``opt.search.evaluate_points`` generalizes it so EVERY
policy axis a registered ``repro.core.policy_api`` family declares
sweepable — keepalive, utilization target, container concurrency, pre-warm
lead, and whatever future families declare — is a traced batch axis, which
is what the frontier engine sweeps).  ``grid_points`` / ``pareto_front`` /
``SWEEPABLE`` live at their canonical homes in ``repro.opt``; the lazy
deprecation re-exports that used to resolve here were removed.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.core.eventsim import SimConfig
from repro.core.simjax import JaxFleet, JaxPolicy
from repro.core.trace import Trace
from repro.fleet.billing import BillingProfile
from repro.fleet.nodes import NodeType
from repro.opt.search import evaluate_points


def sweep(trace: Trace, policy: JaxPolicy, fleet: JaxFleet,
          grid: Optional[dict] = None, points: Optional[Sequence[dict]] = None,
          sim: SimConfig = SimConfig(), dt: float = 1.0,
          node_type: Optional[NodeType] = None,
          billing: Union[str, BillingProfile, None] = None,
          warmup_frac: float = 0.5, chunk_ticks: int = 512,
          devices: int = 0) -> list[dict]:
    """Run every parameter point through one vmapped chunked scan; return one
    row per point: {params..., metrics..., cost fields...}."""
    from repro.opt.space import grid_points as _grid_points
    pts = list(points) if points is not None else _grid_points(grid or {})
    return evaluate_points(trace, policy, fleet, pts, sim=sim, dt=dt,
                           node_type=node_type, billing=billing,
                           warmup_frac=warmup_frac, chunk_ticks=chunk_ticks,
                           devices=devices)

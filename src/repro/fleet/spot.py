"""Spot / preemptible capacity tiers: the market that evicts your warm pool.

The paper prices the cost of keeping warm in resources; operators cut the
*dollar* bill with spot (preemptible) nodes at a 60-70% discount — but spot
capacity can be reclaimed by the provider with a short notice window, and
every reclaim is a forced eviction of warm instances whose demand comes
back as a cold-start storm ("Understanding Cost Dynamics of Serverless
Computing" / "Demystifying Serverless Costs on Public Platforms",
PAPERS.md).  This module is the discrete (oracle) half of that model:

* ``CapacityTier``  — a purchasing tier for a ``NodeType``: price
  multiplier vs on-demand, a Poisson preemption hazard (reclaims per
  node-hour), and the provider's reclaim-notice window.  Tiers live in a
  small registry so CLIs can list them and fail friendly on unknown names.
* ``SpotMarket``    — the seeded hazard process: per reconcile tick, each
  UP spot node is preempted with probability ``1 - exp(-hazard * dt)``.
  Deterministic given its seed (the parity/property tests pin this).
* ``SpotNodeFleet`` — ``NodeFleet`` with tier-split provisioning (a
  ``spot_fraction`` of the fleet is bought on the spot tier), market-driven
  evictions (an announced node drains immediately and is force-terminated
  at the notice deadline — ``repro.core.eventsim`` re-queues its in-flight
  work as scale-up pressure), and per-tier billing
  (``spot_node_seconds`` ⊂ ``node_seconds``) so ``repro.fleet.costs`` can
  bill mixed fleets correctly.

The fluid twin lives in ``repro.core.simjax`` (a traced hazard/eviction
flux in the chunked scan, driven by the ``spot_aware`` policy family's
``spot_fraction``/``hazard_per_hour`` axes); oracle-vs-fluid parity under
the ``spot_storm`` scenario is pinned in ``tests/test_spot.py``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.cluster import PROVISIONING, UP, Cluster, Node
from repro.fleet.nodes import NodeFleet, NodeType
from repro.fleet.policies import FleetPolicy


@dataclasses.dataclass(frozen=True)
class CapacityTier:
    """One purchasing tier of a node shape (see EXPERIMENTS.md, "Spot
    capacity tiers").  ``price_multiplier`` scales ``NodeType.
    price_per_hour``; ``hazard_per_hour`` is the Poisson reclaim rate per
    node; ``reclaim_notice_s`` is the provider's eviction warning."""
    name: str
    price_multiplier: float = 1.0
    hazard_per_hour: float = 0.0
    reclaim_notice_s: float = 0.0

    @property
    def discount(self) -> float:
        """The ``PriceBook.spot_discount`` equivalent (0.65 -> pay 35%)."""
        return 1.0 - self.price_multiplier


_TIERS: dict[str, CapacityTier] = {}


def register_tier(tier: CapacityTier) -> CapacityTier:
    if not tier.name:
        raise ValueError("capacity tier needs a name")
    if tier.name in _TIERS:
        raise ValueError(f"duplicate capacity tier {tier.name!r}")
    _TIERS[tier.name] = tier
    return tier


def get_tier(name: str) -> CapacityTier:
    try:
        return _TIERS[name]
    except KeyError:
        raise KeyError(f"unknown capacity tier {name!r}; "
                       f"registered: {sorted(_TIERS)}") from None


def list_tiers() -> list[str]:
    return sorted(_TIERS)


# On-demand is hazardless by definition.  The spot defaults follow the
# published reclaim statistics the calibration section of EXPERIMENTS.md
# cites: a ~65% discount, single-digit reclaims per node-hour under pool
# pressure (an accelerated rate — calm pools reclaim orders of magnitude
# less often; simulations compress the pressured regime), and a
# two-minute warning (the AWS/GCE notice).
ON_DEMAND = register_tier(CapacityTier("on_demand"))
SPOT_DEFAULT = register_tier(CapacityTier(
    "spot", price_multiplier=0.35, hazard_per_hour=8.0,
    reclaim_notice_s=120.0))


class SpotMarket:
    """Seeded Bernoulli thinning of the tier's Poisson preemption process.

    Each poll covers the interval since the previous one; every candidate
    node is reclaimed independently with ``1 - exp(-hazard * dt)`` — the
    exact discretization of the hazard the fluid twin integrates, so the
    two engines agree in expectation.  Identical seeds replay identical
    eviction schedules against identical node sequences."""

    def __init__(self, tier: CapacityTier = SPOT_DEFAULT, seed: int = 0):
        self.tier = tier
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._last_poll: Optional[float] = None

    def preempted(self, t: float, nodes: list[Node]) -> list[Node]:
        dt = 0.0 if self._last_poll is None else max(t - self._last_poll, 0.0)
        self._last_poll = t
        if dt <= 0.0 or self.tier.hazard_per_hour <= 0.0 or not nodes:
            return []
        p = -math.expm1(-self.tier.hazard_per_hour / 3600.0 * dt)
        return [n for n in nodes if self.rng.uniform() < p]


class SpotNodeFleet(NodeFleet):
    """A ``NodeFleet`` buying a ``spot_fraction`` of its capacity on the
    spot tier.  Provisioning keeps the UP+PROVISIONING mix at the target
    fraction; the market preempts UP spot nodes (the announced node starts
    draining at once — no new placements — and the simulator force-evicts
    whatever is still running at the notice deadline); billing meters the
    spot tier separately so the bill can discount only spot node-hours."""

    def __init__(self, policy: FleetPolicy | None = None,
                 node_type: NodeType = NodeType(),
                 cooldown_s: float = 120.0,
                 spot_fraction: float = 0.0,
                 market: Optional[SpotMarket] = None):
        super().__init__(policy, node_type=node_type, cooldown_s=cooldown_s)
        if not 0.0 <= spot_fraction <= 1.0:
            raise ValueError(f"spot_fraction must be in [0, 1], got "
                             f"{spot_fraction!r}")
        self.spot_fraction = spot_fraction
        self.market = market or SpotMarket()
        self._evict_deadlines: list[tuple[Node, float]] = []

    # -- tier-split provisioning -------------------------------------------

    def _provision(self, cluster: Cluster, count: int) -> list[Node]:
        have = cluster.nodes_in(UP, PROVISIONING)
        n_spot = sum(1 for n in have if n.spot)
        target = int(round(self.spot_fraction * (len(have) + count)))
        add_spot = min(max(target - n_spot, 0), count)
        out = []
        for i in range(count):
            node = cluster.add_node(self.node_type.memory_mb)
            node.spot = i < add_spot
            out.append(node)
        return out

    # -- market-driven evictions -------------------------------------------

    def reconcile(self, t: float, cluster: Cluster):
        provisioned, draining = super().reconcile(t, cluster)
        announced = self.market.preempted(
            t, [n for n in cluster.nodes_in(UP) if n.spot])
        for node in announced:
            cluster.start_drain(node)
            self.evictions += 1
            self.announced_ids.add(node.node_id)
            self._evict_deadlines.append(
                (node, t + self.market.tier.reclaim_notice_s))
        return provisioned, draining + announced

    def pop_evictions(self) -> list[tuple[Node, float]]:
        out, self._evict_deadlines = self._evict_deadlines, []
        return out

    def force_evict(self, node: Node, cluster: Cluster) -> None:
        """The reclaim notice ran out: the provider takes the node back,
        whatever is still running on it (the simulator has already
        re-queued the in-flight work)."""
        if node.alive:
            cluster.terminate(node)
        self.announced_ids.discard(node.node_id)

    # -- per-tier billing ---------------------------------------------------

    def bill(self, cluster: Cluster, dt_s: float) -> int:
        n = super().bill(cluster, dt_s)
        self.spot_node_seconds += sum(
            1 for nd in cluster.nodes if nd.billable and nd.spot) * dt_s
        return n

"""FleetManager: node capacity for the REAL control plane.

The control plane's workers are backend objects (simulated or real JAX
replicas), not bin-packed ``Cluster`` nodes, so capacity is expressed as
*instance slots*: each node hosts ``instances_per_node`` live instances.
The manager

* caps instance creation at current node capacity (``can_create``) — a
  denied create is deferred by the control plane, not dropped,
* scales up when creates are denied or utilization exceeds the policy's
  target (placement pressure feeds the same policy math as the simulators),
* scales down behind a cooldown, never below what live instances occupy,
* meters billable node-seconds under whatever clock the control plane runs
  (virtual or wall), for the same ``repro.fleet.costs`` bill.

Spot capacity (``spot_fraction`` + a ``repro.fleet.spot.SpotMarket``): the
manager buys that share of its nodes on the spot tier, the market preempts
them (capacity vanishes immediately at this layer — backend instances are
not node-bound, so an eviction surfaces as denied creates / placement
pressure rather than killed work), scale-down sheds the spot tier first,
and spot node-seconds are metered separately for per-tier billing.
"""

from __future__ import annotations

import math
from typing import Optional

from repro.fleet.nodes import NodeType
from repro.fleet.policies import FleetPolicy, UtilizationFleetPolicy
from repro.fleet.spot import SpotMarket


class FleetManager:
    def __init__(self, policy: FleetPolicy | None = None,
                 node_type: NodeType = NodeType(),
                 instances_per_node: int = 8,
                 cooldown_s: float = 120.0,
                 initial_nodes: int = 1,
                 spot_fraction: float = 0.0,
                 market: Optional[SpotMarket] = None):
        self.policy = policy or UtilizationFleetPolicy()
        self.node_type = node_type
        self.instances_per_node = instances_per_node
        self.cooldown_s = cooldown_s
        self.nodes_up = max(initial_nodes, self.policy.min_nodes)
        # (ready time, is_spot) per provisioning node
        self._pipeline: list[tuple[float, bool]] = []
        # per-decision-source scale-down cooldown (see NodeFleet): policies
        # exposing ``last_source`` get one clock per trigger; plain
        # policies key on None — identical to the old single timer
        self._cooldown_until: dict = {}
        self._pressure = 0                    # denied creates since last tick
        self._last_bill_t: float | None = None
        self.provisions = 0
        self.terminations = 0
        self.node_seconds = 0.0
        if not 0.0 <= spot_fraction <= 1.0:
            raise ValueError(f"spot_fraction must be in [0, 1], got "
                             f"{spot_fraction!r}")
        self.spot_fraction = spot_fraction
        self.market = market if market is not None \
            else (SpotMarket() if spot_fraction > 0.0 else None)
        self.nodes_up_spot = 0
        self.spot_node_seconds = 0.0
        self.evictions = 0

    # -- capacity ----------------------------------------------------------------

    @property
    def nodes_total(self) -> int:
        return self.nodes_up + len(self._pipeline)

    def capacity(self) -> int:
        return self.nodes_up * self.instances_per_node

    def can_create(self, live_instances: int) -> bool:
        if live_instances < self.capacity():
            return True
        self._pressure += 1
        return False

    # -- reconciliation ----------------------------------------------------------

    @property
    def _spot_total(self) -> int:
        return self.nodes_up_spot + sum(1 for _, sp in self._pipeline if sp)

    def tick(self, now: float, live_instances: int) -> None:
        # billing first, under the pre-tick fleet size
        if self._last_bill_t is not None:
            dt = max(0.0, now - self._last_bill_t)
            self.node_seconds += self.nodes_total * dt
            self.spot_node_seconds += self._spot_total * dt
        self._last_bill_t = now

        ready = [(t, sp) for t, sp in self._pipeline if t <= now]
        if ready:
            self._pipeline = [(t, sp) for t, sp in self._pipeline if t > now]
            self.nodes_up += len(ready)
            self.nodes_up_spot += sum(1 for _, sp in ready if sp)

        # spot preemptions: capacity vanishes now (instances are backend
        # objects, not node-bound — the shortage surfaces as denied
        # creates feeding placement pressure below).  Poll the market even
        # with zero spot nodes up: a skipped poll leaves _last_poll stale,
        # and the next one would apply the whole gap's hazard to a fresh
        # node.
        if self.market is not None:
            gone = len(self.market.preempted(
                now, list(range(self.nodes_up_spot))))
            if gone:
                self.nodes_up -= gone
                self.nodes_up_spot -= gone
                self.evictions += gone

        # express instance slots in the policy's memory units so the same
        # FleetPolicy drives simulators and the real control plane alike
        per_inst_mb = self.node_type.memory_mb / self.instances_per_node
        used_mb = (live_instances + self._pressure) * per_inst_mb
        self._pressure = 0
        desired = self.policy.desired(now, used_mb, self.node_type.memory_mb,
                                      self.nodes_total)
        if desired > self.nodes_total:
            for _ in range(desired - self.nodes_total):
                want_spot = int(round(self.spot_fraction
                                      * (self.nodes_total + 1)))
                is_spot = self._spot_total < want_spot
                self._pipeline.append((now + self.node_type.provision_s,
                                       is_spot))
                self.provisions += 1
        elif desired < self.nodes_total:
            key = getattr(self.policy, "last_source", None)
            if now >= self._cooldown_until.get(key, -math.inf):
                floor = math.ceil(live_instances / self.instances_per_node)
                down = min(self.nodes_total - desired,
                           max(self.nodes_up - floor, 0))
                if down > 0:
                    self.nodes_up -= down
                    # shed the preemptible tier first: it is the flexible
                    # share
                    shed_spot = min(down, self.nodes_up_spot)
                    self.nodes_up_spot -= shed_spot
                    self.terminations += down
                    cool = getattr(self.policy, "last_cooldown_s", None)
                    self._cooldown_until[key] = now + (
                        cool if cool is not None else self.cooldown_s)

    def snapshot(self) -> dict:
        return {
            "nodes_up": self.nodes_up,
            "nodes_provisioning": len(self._pipeline),
            "capacity_instances": self.capacity(),
            "node_seconds": self.node_seconds,
            "provisions": self.provisions,
            "terminations": self.terminations,
            "nodes_up_spot": self.nodes_up_spot,
            "spot_node_seconds": self.spot_node_seconds,
            "evictions": self.evictions,
        }

"""FleetManager: node capacity for the REAL control plane.

The control plane's workers are backend objects (simulated or real JAX
replicas), not bin-packed ``Cluster`` nodes, so capacity is expressed as
*instance slots*: each node hosts ``instances_per_node`` live instances.
The manager

* caps instance creation at current node capacity (``can_create``) — a
  denied create is deferred by the control plane, not dropped,
* scales up when creates are denied or utilization exceeds the policy's
  target (placement pressure feeds the same policy math as the simulators),
* scales down behind a cooldown, never below what live instances occupy,
* meters billable node-seconds under whatever clock the control plane runs
  (virtual or wall), for the same ``repro.fleet.costs`` bill.
"""

from __future__ import annotations

import math

from repro.fleet.nodes import NodeType
from repro.fleet.policies import FleetPolicy, UtilizationFleetPolicy


class FleetManager:
    def __init__(self, policy: FleetPolicy | None = None,
                 node_type: NodeType = NodeType(),
                 instances_per_node: int = 8,
                 cooldown_s: float = 120.0,
                 initial_nodes: int = 1):
        self.policy = policy or UtilizationFleetPolicy()
        self.node_type = node_type
        self.instances_per_node = instances_per_node
        self.cooldown_s = cooldown_s
        self.nodes_up = max(initial_nodes, self.policy.min_nodes)
        self._pipeline: list[float] = []      # ready times of provisioning nodes
        self._cooldown_until = -math.inf
        self._pressure = 0                    # denied creates since last tick
        self._last_bill_t: float | None = None
        self.provisions = 0
        self.terminations = 0
        self.node_seconds = 0.0

    # -- capacity ----------------------------------------------------------------

    @property
    def nodes_total(self) -> int:
        return self.nodes_up + len(self._pipeline)

    def capacity(self) -> int:
        return self.nodes_up * self.instances_per_node

    def can_create(self, live_instances: int) -> bool:
        if live_instances < self.capacity():
            return True
        self._pressure += 1
        return False

    # -- reconciliation ----------------------------------------------------------

    def tick(self, now: float, live_instances: int) -> None:
        # billing first, under the pre-tick fleet size
        if self._last_bill_t is not None:
            self.node_seconds += self.nodes_total * max(0.0, now - self._last_bill_t)
        self._last_bill_t = now

        ready = [t for t in self._pipeline if t <= now]
        if ready:
            self._pipeline = [t for t in self._pipeline if t > now]
            self.nodes_up += len(ready)

        # express instance slots in the policy's memory units so the same
        # FleetPolicy drives simulators and the real control plane alike
        per_inst_mb = self.node_type.memory_mb / self.instances_per_node
        used_mb = (live_instances + self._pressure) * per_inst_mb
        self._pressure = 0
        desired = self.policy.desired(now, used_mb, self.node_type.memory_mb,
                                      self.nodes_total)
        if desired > self.nodes_total:
            for _ in range(desired - self.nodes_total):
                self._pipeline.append(now + self.node_type.provision_s)
                self.provisions += 1
        elif desired < self.nodes_total and now >= self._cooldown_until:
            floor = math.ceil(live_instances / self.instances_per_node)
            down = min(self.nodes_total - desired, max(self.nodes_up - floor, 0))
            if down > 0:
                self.nodes_up -= down
                self.terminations += down
                self._cooldown_until = now + self.cooldown_s

    def snapshot(self) -> dict:
        return {
            "nodes_up": self.nodes_up,
            "nodes_provisioning": len(self._pipeline),
            "capacity_instances": self.capacity(),
            "node_seconds": self.node_seconds,
            "provisions": self.provisions,
            "terminations": self.terminations,
        }

"""Provider-calibrated billing engine: what a platform actually charges.

``repro.fleet.costs`` bills the IDEAL model — per-second node-hours plus a
managed control-plane rate.  Real serverless bills diverge sharply from
that ("Demystifying Serverless Costs on Public Platforms", "Understanding
Cost Dynamics of Serverless Computing", PAPERS.md): durations are rounded
UP to a billing granularity and censored at a minimum billed duration,
every request pays a flat fee, compute is metered in GB-seconds of BILLED
(not actual) duration, keeping capacity warm is a separate
provisioned-concurrency tier, and CPU share scales with configured memory
so under-provisioned functions run (and bill) longer.

A ``BillingProfile`` captures all of that as data.  Four are registered:

* ``ideal``           — bit-for-bit the ``PriceBook`` math in ``costs.py``
                        (all provider-side rates are exactly 0.0, the
                        node-hour weight exactly 1.0, so every added term
                        is a float-identity ``+ 0.0`` / ``* 1.0``);
* ``aws_lambda``      — AWS Lambda, x86 / us-east-1 public prices;
* ``gcr``             — Google Cloud Run, request billing, tier-1 region;
* ``azure_functions`` — Azure Functions Consumption plan (100 ms minimum
                        bill + per-execution fee).

Both engines bill through one profile: the discrete-event oracle rounds
each request's recorded duration exactly (``billed_seconds`` over
``SimResult.records``), while the fluid scan accumulates the ANALYTIC
expectation of the rounded/min-censored duration under the trace's clipped
lognormal mixture (``expected_billed_seconds``) — the same
quantile-midpoint construction the slowdown mixture uses, so the two
engines' billed totals agree to sampling error (parity-gated ≤15%).

Rates are documented against the public pricing pages in EXPERIMENTS.md
("Billing").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Union

import numpy as np

from repro.fleet.costs import CostReport, PriceBook, cost_report
from repro.fleet.nodes import NodeType

# per-request service times are clipped lognormals — the same clip window
# ``trace.synthesize`` samples from and ``simjax``'s slowdown mixture
# integrates over (keep the three in sync)
_DUR_FLOOR, _DUR_CAP = 0.02, 30.0

# quantile-midpoint grid for the analytic billed-duration expectation;
# 4096 midpoints put the Riemann error well under the rounding granularity
_QUANTILE_GRID = 4096


def _norm_ppf(q: np.ndarray) -> np.ndarray:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |rel err| < 1.15e-9), vectorized — scipy is not a dependency here,
    mirroring ``simjax._phi`` on the forward side."""
    q = np.asarray(q, np.float64)
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    lo, hi = 0.02425, 1.0 - 0.02425
    out = np.empty_like(q)
    m = q < lo
    if m.any():
        u = np.sqrt(-2.0 * np.log(q[m]))
        out[m] = (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                  * u + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u
                                  + d[3]) * u + 1.0)
    m = q > hi
    if m.any():
        u = np.sqrt(-2.0 * np.log(1.0 - q[m]))
        out[m] = -(((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                   * u + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u
                                   + d[3]) * u + 1.0)
    m = (q >= lo) & (q <= hi)
    if m.any():
        u = q[m] - 0.5
        r = u * u
        out[m] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
                  * r + a[5]) * u / (((((b[0] * r + b[1]) * r + b[2]) * r
                                       + b[3]) * r + b[4]) * r + 1.0)
    return out


@dataclasses.dataclass
class BillReport(CostReport):
    """A ``CostReport`` extended with the provider-side components.  Under
    the ``ideal`` profile every extension is exactly 0.0 and the inherited
    fields are bitwise the ``cost_report`` values (the regression the
    billing tests pin)."""
    billing: str = "ideal"
    request_cost: float = 0.0        # per-request fee x completed
    duration_cost: float = 0.0       # per-GB-s rate x billed GB-s
    warm_pool_cost: float = 0.0      # provisioned-concurrency / idle tier
    billed_gb_s: float = 0.0         # metered GB-s of BILLED duration
    warm_gb_s: float = 0.0           # idle-warm GB-s held over the window


@dataclasses.dataclass(frozen=True)
class BillingProfile:
    """One provider's billing semantics as data.

    The node-denominated side (``node_hour_weight`` x the ``PriceBook``
    math) and the request-denominated side (rounding + minimum + fees +
    GB-s metering + warm-pool tier) coexist so ``ideal`` (weight 1, all
    provider rates 0) and pure-serverless profiles (weight 0) are the two
    ends of one parameterization, not separate code paths.
    """
    name: str = "ideal"
    description: str = "per-second node-hours (the pre-billing cost model)"
    # --- node-denominated (infrastructure) side ------------------------
    node_hour_weight: float = 1.0    # share of the node-hour bill charged
    master_vcpu_per_hour: float = 0.048
    spot_discount: float = 0.0
    # --- request-denominated (provider) side ---------------------------
    rounding_s: float = 0.0          # billed duration rounds UP to this
    min_billed_s: float = 0.0        # minimum billed duration (censoring)
    per_request: float = 0.0         # $ / request
    per_gb_s: float = 0.0            # $ / GB-s of billed duration
    warm_gb_s_rate: float = 0.0      # $ / GB-s of idle-warm capacity
    # --- cpu-throttle term (fluid duration model) ----------------------
    # memory granting a full CPU share; functions configured below it run
    # (and bill) up to ``throttle_cap`` x longer.  0 disables the term.
    throttle_full_mb: float = 0.0
    throttle_cap: float = 2.0

    # -- conversions ----------------------------------------------------

    def prices(self) -> PriceBook:
        """The node-tier subset, for delegating to ``costs.cost_report``."""
        return PriceBook(master_vcpu_per_hour=self.master_vcpu_per_hour,
                         spot_discount=self.spot_discount)

    def with_spot_discount(self, discount: float) -> "BillingProfile":
        """This profile re-specced to a capacity tier's discount (the
        billing analogue of ``runner.apply_tier``'s PriceBook edit)."""
        return dataclasses.replace(self, spot_discount=float(discount))

    # -- duration billing -----------------------------------------------

    def billed_seconds(self, dur) -> np.ndarray:
        """Exact billed duration per request: round UP to ``rounding_s``,
        then censor at ``min_billed_s``.  Identity under ``ideal``."""
        d = np.asarray(dur, np.float64)
        if self.rounding_s > 0.0:
            # the 1e-9 guard keeps exact multiples of the granularity from
            # rounding up one extra step through d/g float noise
            d = np.ceil(d / self.rounding_s - 1e-9) * self.rounding_s
        if self.min_billed_s > 0.0:
            d = np.maximum(d, self.min_billed_s)
        return d

    def expected_billed_seconds(self, dur_median, dur_sigma,
                                n: int = _QUANTILE_GRID) -> np.ndarray:
        """Per-function E[billed(D)] for D ~ clipped LogNormal(log median,
        sigma) — the analytic twin of averaging ``billed_seconds`` over a
        sampled trace, evaluated on a quantile-midpoint grid (exact as
        n -> inf; at n=4096 the gap to the exact integral is far below the
        fluid-vs-oracle sampling noise)."""
        med = np.atleast_1d(np.asarray(dur_median, np.float64))
        sig = np.atleast_1d(np.asarray(dur_sigma, np.float64))
        z = _norm_ppf((np.arange(n) + 0.5) / n)
        d = np.exp(np.log(med)[:, None] + sig[:, None] * z[None, :])
        d = np.clip(d, _DUR_FLOOR, _DUR_CAP)
        return self.billed_seconds(d).mean(axis=1)

    def billed_weights(self, profile) -> np.ndarray:
        """(F,) expected billed GB-s PER COMPLETION for a trace's
        ``FunctionProfile`` — the weight the fluid scan multiplies into its
        per-tick completions vector.  GB is the function's CONFIGURED
        memory (what the provider meters), not the +overhead sandbox size
        both engines use for capacity accounting."""
        e = self.expected_billed_seconds(profile.dur_median,
                                         profile.dur_sigma)
        return e * np.asarray(profile.memory_mb, np.float64) / 1024.0

    # -- cpu throttle ---------------------------------------------------

    def throttle_factor(self, memory_mb) -> np.ndarray:
        """Duration inflation for memory-throttled CPU: full share at
        ``throttle_full_mb``, proportional below, clamped at
        ``throttle_cap`` (burst credits and the fact that measured
        durations already embed partial throttling bound the stretch)."""
        mem = np.asarray(memory_mb, np.float64)
        if self.throttle_full_mb <= 0.0:
            return np.ones_like(mem)
        return np.clip(self.throttle_full_mb / np.maximum(mem, 1.0),
                       1.0, self.throttle_cap)

    # -- the bill -------------------------------------------------------

    def bill(self, *, node_seconds: float, cpu_worker_overhead_s: float,
             cpu_master_overhead_s: float, idle_node_share: float,
             completed: int, node_type: NodeType = NodeType(),
             spot_node_seconds: float = 0.0, billed_gb_s: float = 0.0,
             warm_gb_s: float = 0.0) -> BillReport:
        """The full bill.  The node-denominated fields delegate to
        ``costs.cost_report`` (the math exists once) scaled by
        ``node_hour_weight``; the provider terms add on top.  Under
        ``ideal`` the result is bitwise ``cost_report``'s (x*1.0 and
        x+0.0 are IEEE identities for the non-negative values here)."""
        base = cost_report(
            node_seconds=node_seconds,
            cpu_worker_overhead_s=cpu_worker_overhead_s,
            cpu_master_overhead_s=cpu_master_overhead_s,
            idle_node_share=idle_node_share, completed=completed,
            node_type=node_type, prices=self.prices(),
            spot_node_seconds=spot_node_seconds)
        w = self.node_hour_weight
        node_cost = base.node_cost * w
        churn_cost = base.churn_cost * w
        idle_cost = base.idle_cost * w
        request_cost = self.per_request * completed
        duration_cost = self.per_gb_s * billed_gb_s
        warm_pool_cost = self.warm_gb_s_rate * warm_gb_s
        total = node_cost + base.master_cost + request_cost \
            + duration_cost + warm_pool_cost
        per_million = total / completed * 1e6 if completed > 0 \
            else float("nan")
        return BillReport(
            node_hours=base.node_hours, node_cost=node_cost,
            master_cpu_hours=base.master_cpu_hours,
            master_cost=base.master_cost, churn_cost=churn_cost,
            idle_cost=idle_cost, total_cost=total, completed=completed,
            cost_per_million=per_million, billing=self.name,
            request_cost=request_cost, duration_cost=duration_cost,
            warm_pool_cost=warm_pool_cost, billed_gb_s=billed_gb_s,
            warm_gb_s=warm_gb_s)


# ---------------------------------------------------------------------------
# engine adapters: one profile, two engines
# ---------------------------------------------------------------------------


def apply_throttle(trace, profile: BillingProfile):
    """The trace as the provider's throttled CPU actually runs it: per-
    request durations AND the per-function duration model stretch by the
    same factor, so the oracle (which replays ``trace.dur``) and the fluid
    scan (which derives service rates and the slowdown/billing mixtures
    from ``profile.dur_median/dur_sigma``) see one consistent workload.
    Returns the trace unchanged (same object) when the profile has no
    throttle term — the ``ideal`` bit-for-bit guarantee."""
    f = profile.throttle_factor(trace.profile.memory_mb)
    if not np.any(f > 1.0):
        return trace
    prof = dataclasses.replace(
        trace.profile,
        dur_median=np.minimum(trace.profile.dur_median * f, _DUR_CAP))
    if not hasattr(trace, "dur"):
        # rate-based trace (repro.core.trace.RateTrace): no per-request
        # events, the duration model IS the workload's duration state
        return dataclasses.replace(trace, profile=prof)
    return dataclasses.replace(
        trace, dur=np.minimum(trace.dur * f[trace.fn], _DUR_CAP),
        profile=prof)


def bill_sim(result, trace, profile: BillingProfile,
             node_type: NodeType = NodeType()) -> BillReport:
    """Bill an ``EventSim`` result through a profile: node accounting as
    ``costs.cost_from_sim``, plus EXACT per-request billed GB-s (each
    recorded duration rounded/censored individually — no expectation) and
    the measured idle-warm GB-s for the provisioned/warm tier."""
    node_seconds = result.node_seconds
    if node_seconds <= 0.0 and len(result.sample_times):
        node_seconds = result.measure_window_s * max(result.nodes_hint, 1)
    cap_mb = max(node_seconds / max(result.measure_window_s, 1e-9), 1e-9) \
        * node_type.memory_mb
    idle_mb = 0.0
    if len(result.mem_samples_total_mb):
        idle_mb = float(result.mem_samples_total_mb.mean()
                        - result.mem_samples_busy_mb.mean())
    fn_s, billed_s = result.billed_duration_totals(
        granularity_s=profile.rounding_s, min_billed_s=profile.min_billed_s)
    mem_gb = np.asarray(trace.profile.memory_mb, np.float64)[fn_s] / 1024.0
    billed_gb_s = float((billed_s * mem_gb).sum())
    warm_gb_s = max(idle_mb, 0.0) * result.measure_window_s / 1024.0
    return profile.bill(
        node_seconds=node_seconds,
        cpu_worker_overhead_s=result.cpu_worker_overhead_s,
        cpu_master_overhead_s=result.cpu_master_overhead_s,
        idle_node_share=idle_mb / cap_mb,
        completed=len(result.records), node_type=node_type,
        spot_node_seconds=result.spot_node_seconds,
        billed_gb_s=billed_gb_s, warm_gb_s=warm_gb_s)


def bill_summary(summary: dict, profile: BillingProfile,
                 node_type: NodeType = NodeType(), dt: float = 1.0,
                 cap_mb: float = 0.0) -> BillReport:
    """Bill a ``simulate_chunked`` summary row through a profile.  The scan
    accumulated ``billed_gb_s`` with this profile's expectation weights;
    the warm-pool GB-s is the measured idle mass held over the window —
    the same (mem_total - mem_busy) basis the oracle side bills."""
    window = summary["ticks_measured"] * dt
    if cap_mb <= 0.0:
        cap_mb = max(summary["nodes_mean"] * node_type.memory_mb, 1e-9)
    idle_mb = summary["mem_total_mean"] - summary["mem_busy_mean"]
    return profile.bill(
        node_seconds=summary["node_seconds"],
        cpu_worker_overhead_s=summary["cpu_worker_s"],
        cpu_master_overhead_s=summary["cpu_master_s"],
        idle_node_share=idle_mb / cap_mb,
        completed=int(summary["completed"]), node_type=node_type,
        spot_node_seconds=summary["spot_node_seconds"],
        billed_gb_s=summary.get("billed_gb_s", 0.0),
        warm_gb_s=max(idle_mb, 0.0) * window / 1024.0)


# ---------------------------------------------------------------------------
# the profile registry (mirrors repro.fleet.spot's tier registry)
# ---------------------------------------------------------------------------

_PROFILES: dict[str, BillingProfile] = {}


def register_profile(profile: BillingProfile) -> BillingProfile:
    if profile.name in _PROFILES:
        raise ValueError(f"duplicate billing profile {profile.name!r}")
    _PROFILES[profile.name] = profile
    return profile


def get_profile(name: Union[str, BillingProfile]) -> BillingProfile:
    if isinstance(name, BillingProfile):
        return name
    try:
        return _PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown billing profile {name!r}; "
                       f"registered: {sorted(_PROFILES)}") from None


def list_profiles() -> list[str]:
    return sorted(_PROFILES)


def resolve_profile(billing, default: "BillingProfile" = None
                    ) -> "BillingProfile":
    """Resolve a billing spec against a context default (typically the
    scenario's own profile): ``None`` -> the default; a NAME -> the
    registered profile inheriting the default's spot discount (the tier is
    workload state, not provider semantics); a profile OBJECT ->
    verbatim."""
    default = default if default is not None else IDEAL
    if billing is None:
        return default
    prof = get_profile(billing)
    if isinstance(billing, str):
        prof = prof.with_spot_discount(default.spot_discount)
    return prof


IDEAL = register_profile(BillingProfile())

# AWS Lambda, x86 / us-east-1 (aws.amazon.com/lambda/pricing, 2025):
# $0.20 / 1M requests, $0.0000166667 / GB-s billed at 1 ms granularity
# (duration rounds up to the nearest ms; the 1 ms is also the minimum),
# provisioned concurrency at $0.0000041667 / GB-s, and CPU share
# proportional to memory with a full vCPU at 1769 MB.  The throttle cap
# is calibrated at 1.5x, well under the raw memory ratio: measured trace
# durations already embed partial provider throttling (plus burst
# credits), so the full proportional stretch would double-count — and the
# 0.25x oracle-vs-fluid billed-cost parity band (<=15% on every
# registered scenario, pinned in tests/test_billing.py) bounds how far
# the workload may be stretched before the fluid idle-mass model drifts.
AWS_LAMBDA = register_profile(BillingProfile(
    name="aws_lambda",
    description="AWS Lambda x86 us-east-1: per-request + per-GB-s at 1 ms "
                "granularity, provisioned-concurrency warm tier, "
                "memory-proportional CPU",
    node_hour_weight=0.0, master_vcpu_per_hour=0.0,
    rounding_s=0.001, min_billed_s=0.001,
    per_request=2.0e-7, per_gb_s=1.66667e-5,
    warm_gb_s_rate=4.1667e-6,
    throttle_full_mb=1769.0, throttle_cap=1.5))

# Google Cloud Run, request-based billing, tier-1 region
# (cloud.google.com/run/pricing, 2025): $0.40 / 1M requests; CPU
# $0.000024 / vCPU-s + memory $0.0000025 / GiB-s, folded at the default
# 1-vCPU-per-GiB shape into one $/GB-s rate; durations round UP to the
# nearest 100 ms (which is therefore also the minimum bill); idle
# min-instances bill CPU at a reduced rate — folded into the warm tier.
# Cloud Run grants whole vCPUs regardless of memory: no throttle term.
GCR = register_profile(BillingProfile(
    name="gcr",
    description="Google Cloud Run tier-1: per-request + folded "
                "CPU+memory $/GB-s at 100 ms round-up, idle min-instance "
                "warm tier, whole-vCPU (no throttle)",
    node_hour_weight=0.0, master_vcpu_per_hour=0.0,
    rounding_s=0.1, min_billed_s=0.1,
    per_request=4.0e-7, per_gb_s=2.65e-5,
    warm_gb_s_rate=5.0e-6))

# Azure Functions, Consumption plan (azure.microsoft.com/pricing/details/
# functions, 2025): $0.20 / 1M executions; $0.000016 / GB-s of observed
# duration, rounded UP to the nearest 1 ms with a 100 ms minimum per
# execution — the most aggressive minimum-billing censoring of the three
# providers, so short functions over-bill hardest here.  Memory is rounded
# to the nearest 128 MB by the platform; we bill the configured MB
# directly (the rounding is second-order next to the 100 ms floor).  The
# Consumption plan has no provisioned-concurrency tier (that's Premium)
# and the host grants a full core per sandbox: no warm rate, no throttle.
AZURE_FUNCTIONS = register_profile(BillingProfile(
    name="azure_functions",
    description="Azure Functions Consumption: per-execution + $/GB-s at "
                "1 ms round-up with a 100 ms minimum bill, no warm tier, "
                "full-core host (no throttle)",
    node_hour_weight=0.0, master_vcpu_per_hour=0.0,
    rounding_s=0.001, min_billed_s=0.1,
    per_request=2.0e-7, per_gb_s=1.6e-5))


def _require_float_identities() -> None:
    """The ideal-profile bitwise guarantee rests on x*1.0 == x and
    x+0.0 == x for finite non-negative x; both are IEEE-754 exact.  This
    module-import assertion documents (and enforces) the assumption."""
    x = 0.1 + 0.2
    assert x * 1.0 == x and x + 0.0 == x
    assert math.isnan(float("nan"))


_require_float_identities()

# Two-level autoscaling: the node-fleet layer under the per-function
# instance policies — node lifecycle + fleet policies + dollar-cost
# accounting + the provider-calibrated billing engine + the control-plane
# capacity manager + the vmapped policy-parameter sweep over the lax.scan
# simulator + the spot capacity tiers (preemption hazards, reclaim
# notices, per-tier billing).
from repro.fleet.billing import (  # noqa: F401
    AWS_LAMBDA,
    GCR,
    IDEAL,
    BillingProfile,
    BillReport,
    apply_throttle,
    bill_sim,
    bill_summary,
    get_profile,
    list_profiles,
    register_profile,
    resolve_profile,
)
from repro.fleet.costs import CostReport, PriceBook, cost_from_sim, cost_report  # noqa: F401
from repro.fleet.manager import FleetManager  # noqa: F401
from repro.fleet.nodes import NodeFleet, NodeType  # noqa: F401
from repro.fleet.policies import (  # noqa: F401
    FleetPolicy,
    ScheduleFleetPolicy,
    ThresholdFleetPolicy,
    UtilizationFleetPolicy,
)
from repro.fleet.spot import (  # noqa: F401
    CapacityTier,
    SpotMarket,
    SpotNodeFleet,
    get_tier,
    list_tiers,
    register_tier,
)

"""Logical-axis sharding: one place that maps model-level axis names onto
physical mesh axes.

Models annotate tensors with *logical* axes ("batch", "heads", "ffn", ...).
The table below maps those onto whatever physical mesh is active.  The same
model code therefore runs on a single CPU device (no mesh -> no-op), the
single-pod 16x16 mesh, and the multi-pod 2x16x16 mesh.

Design notes
------------
* ``batch`` maps to ("pod", "data"): data parallelism spans pods so only
  gradient/metric all-reduces cross the slow DCN links.
* ``heads``/``kv_heads``/``ffn``/``experts``/``vocab`` map to "model"
  (tensor/expert parallelism stays inside a pod on fast ICI).
* A mesh may lack some axes (e.g. no "pod" on the single-pod mesh); unknown
  axes are silently dropped from the spec, which is exactly the semantics we
  want for elastic meshes.
"""

from __future__ import annotations

import threading
from typing import Any, Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axis (or tuple of axes)
LOGICAL_RULES: dict[str, Union[str, tuple[str, ...], None]] = {
    "batch": ("pod", "data"),
    "expert_batch": ("pod", "data"),   # token dim inside MoE dispatch
    "seq": None,                        # sequence kept unsharded by default
    "seq_sp": "data",                   # sequence-parallel variant (opt-in)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_ffn": None,
    "vocab": "model",
    "kv_lora": None,
    # decode KV-cache sequence dim: sharded over "model" for MQA/low-kv-head
    # archs (split-K decode: each model shard scores a context slice, XLA
    # combines the softmax with small all-reduces).  Only consulted when the
    # kv-head dim cannot shard (see attention.cache_specs).
    "kv_seq": "model",
    "state": None,
    "conv": None,
    "layers": None,
    "frames": None,
    "patches": None,
    "opt_state": ("data",),             # extra ZeRO-1 axis for optimizer moments
    "fsdp": ("data",),                  # FSDP/ZeRO-3 parameter axis
    # simulator axes (repro.core.simjax): the chunked scan shard_maps its
    # per-tick step over a 1-D "functions" mesh (per-function state and
    # histograms device-local, one psum at chunk boundaries), and the
    # frontier batches grid points over a 1-D "points" mesh
    "functions": "functions",
    "points": "points",
}

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    """Install *mesh* as the ambient mesh used by :func:`shard`."""
    _state.mesh = mesh


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


class use_mesh:
    """Context manager installing an ambient mesh."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh
        self._prev: Optional[Mesh] = None

    def __enter__(self):
        self._prev = current_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self._prev)
        return False


def _resolve(axis: Optional[str], mesh_axes: Sequence[str]):
    """Map one logical axis name to mesh axes present on the current mesh.

    Tuple rules stay tuples even when only one physical axis survives
    (e.g. ``("pod", "data")`` on a pod-less mesh resolves to ``("data",)``,
    not ``"data"``): PartitionSpec treats the two forms as distinct entries,
    and collapsing would make a spec's shape depend on which mesh is active.
    String rules resolve to the bare axis name.
    """
    if axis is None:
        return None
    rule = LOGICAL_RULES.get(axis, None)
    if rule is None:
        return None
    if isinstance(rule, str):
        return rule if rule in mesh_axes else None
    present = tuple(a for a in rule if a in mesh_axes)
    return present or None


def logical_to_spec(logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> P:
    """Translate a tuple of logical axis names into a PartitionSpec."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    mesh_axes = tuple(mesh.axis_names)
    return P(*[_resolve(a, mesh_axes) for a in logical])


def sharding_for(logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical, mesh))


def device_mesh(devices: int, axis: str) -> Mesh:
    """1-D mesh over the first ``devices`` local devices, named ``axis``.

    The simulator's sharded dispatch uses this for its "functions" /
    "points" meshes; on CPU hosts pair it with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    avail = jax.devices()
    if devices < 1:
        raise ValueError(f"device_mesh needs >= 1 device, got {devices}")
    if devices > len(avail):
        raise ValueError(
            f"device_mesh({devices}, {axis!r}): only {len(avail)} local "
            f"device(s) visible — on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={devices}")
    return Mesh(np.asarray(avail[:devices]), (axis,))


def sanitize_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide evenly (e.g. batch=1 decode,
    odd vocab sizes): sharding degrades gracefully instead of erroring."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if size and shape[i] % size == 0 else None)
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint expressed in logical axes (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = sanitize_spec(logical_to_spec(logical, mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def mesh_axis_size(logical_axis: str, mesh: Optional[Mesh] = None) -> int:
    """Product of physical mesh axis sizes a logical axis maps onto (1 if unmapped)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return 1
    rule = LOGICAL_RULES.get(logical_axis)
    if rule is None:
        return 1
    if isinstance(rule, str):
        rule = (rule,)
    size = 1
    for a in rule:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size


def div_axis(logical_axis: Optional[str], dim_size: int) -> Optional[str]:
    """Use *logical_axis* only if dim_size divides evenly on the current mesh."""
    if logical_axis is None:
        return None
    n = mesh_axis_size(logical_axis)
    if n <= 1 or dim_size % n != 0:
        return None
    return logical_axis


def spec_tree_to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    """Convert a pytree of logical-axis tuples into NamedShardings."""

    def conv(leaf):
        if leaf is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, logical_to_spec(leaf, mesh))

    return jax.tree.map(conv, spec_tree, is_leaf=lambda l: l is None or isinstance(l, tuple))


def fsdp_specs(spec_tree: Any, shape_tree: Any, min_dim: int = 1024) -> Any:
    """ZeRO-3/FSDP: additionally shard each large weight over the data axis
    on its first free, evenly-divisible dimension.  GSPMD then all-gathers
    the shard inside the (scanned) layer and reduce-scatters its gradient —
    the standard FSDP collective schedule, for free.
    """
    n = mesh_axis_size("fsdp")
    is_spec = lambda l: l is None or isinstance(l, tuple)

    def free(ax):  # dim is free if its logical axis maps to no mesh axis
        return ax is None or mesh_axis_size(ax) <= 1

    def f(spec, sd):
        shape = sd.shape
        if spec is None:
            spec = (None,) * len(shape)
        if n <= 1 or len(shape) < 2:
            return spec
        out = list(spec)
        for i, (ax, dim) in enumerate(zip(spec, shape)):
            if free(ax) and dim >= min_dim and dim % n == 0:
                out[i] = "fsdp"
                break
        return tuple(out)

    return jax.tree.map(f, spec_tree, shape_tree, is_leaf=is_spec)

from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES,
    current_mesh,
    logical_to_spec,
    set_mesh,
    shard,
    sharding_for,
    spec_tree_to_shardings,
)

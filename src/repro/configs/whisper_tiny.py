"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865.
Encoder-decoder; conv frontend is a STUB (input_specs() provides precomputed
frame embeddings, 1500 frames).  Decode shapes apply to the text decoder
mechanically (see DESIGN.md §Arch-applicability). [arXiv:2212.04356]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        num_layers=4,                # decoder layers
        num_encoder_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51_865,
        head_dim=64,
        encoder_seq=1500,
        source="arXiv:2212.04356; unverified",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_seq=16, remat="none",
    )


register("whisper-tiny", full, smoke)

"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.
Parallel attention + Mamba heads in every layer; ssm_state=16; 128 meta
tokens; full attention only in layers {0, 15, 31}, sliding window elsewhere.
[arXiv:2411.13676; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32_001,
        head_dim=64,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        sliding_window=1024,
        full_attn_layers=(0, 15, 31),
        num_meta_tokens=128,
        source="arXiv:2411.13676; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, ssm_state=8, sliding_window=16,
        full_attn_layers=(0,), num_meta_tokens=8, remat="none",
    )


register("hymba-1.5b", full, smoke)

"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Llama-3-70B-class text backbone; InternViT frontend is a STUB: input_specs()
provides 256 pre-projected patch embeddings per image at d_model.
[arXiv:2404.16821; unverified]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128_256,
        head_dim=128,
        num_patches=256,
        rope_theta=500_000.0,
        source="arXiv:2404.16821; unverified",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, num_patches=8, remat="none",
    )


register("internvl2-76b", full, smoke)

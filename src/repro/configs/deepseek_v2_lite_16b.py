"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H vocab=102400.
MLA attention (kv_lora=512, qk_nope=128, qk_rope=64, v_head=128);
MoE 64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer dense.

NOTE: the assignment line says "MoE 64e top-6" while its comment mentions
"160 routed" (the HF checkpoint uses 64 routed for v2-lite at 16B is actually
64; the 160-expert figure belongs to full V2).  We follow the primary spec
field: 64 routed, top-6. [arXiv:2405.04434; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,            # unused under MLA (heads share latent KV)
        d_ff=11264,
        vocab_size=102_400,
        head_dim=128,
        use_mla=True,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        first_dense_layers=1,
        source="arXiv:2405.04434; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=512, num_experts=8, top_k=2, moe_d_ff=32,
        num_shared_experts=1, first_dense_layers=1, kv_lora_rank=32,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16, remat="none",
    )


register("deepseek-v2-lite-16b", full, smoke)

"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
RWKV-6 "Finch": data-dependent decay, head_dim=64 (40 heads).
[arXiv:2404.05892; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,               # d_model / rwkv_head_dim
        num_kv_heads=40,
        d_ff=8960,
        vocab_size=65_536,
        head_dim=64,
        rwkv_head_dim=64,
        source="arXiv:2404.05892; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        rwkv_head_dim=16, d_ff=128, vocab_size=512, remat="none",
    )


register("rwkv6-3b", full, smoke)

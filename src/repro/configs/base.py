"""Config system: one dataclass describes every architecture in the zoo.

Family selects the model implementation in ``repro.models``:
  dense   - decoder-only transformer (GQA/sliding-window/softcap variants)
  moe     - dense attention (or MLA) + mixture-of-experts FFN
  ssm     - RWKV6 (attention-free)
  hybrid  - Hymba (parallel attention + SSM heads)
  encdec  - Whisper (encoder-decoder, stub audio frontend)
  vlm     - InternVL2 (stub vision frontend + decoder LM)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads

    # -- attention variants ------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int = 4096       # window for "L" layers
    # layer pattern, repeated over depth: "G"=global attn, "L"=local/sliding.
    attn_pattern: str = "G"
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    tie_embeddings: bool = False

    # -- MLA (deepseek-v2) ---------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0             # routed experts (0 = dense FFN)
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_dense_layers: int = 0      # leading dense layers (deepseek)
    capacity_factor: float = 1.25
    moe_impl: str = "dispatch"       # dispatch (GShard einsum) | ragged (sort)
    aux_loss_coef: float = 0.01

    # -- SSM / RWKV / hybrid ---------------------------------------------------
    ssm_state: int = 16              # mamba d_state (hymba)
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # hybrid: indices of full-attention layers (others sliding window)
    full_attn_layers: tuple[int, ...] = ()
    num_meta_tokens: int = 0

    # -- enc-dec / multimodal ---------------------------------------------------
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # frames (whisper) / patches (internvl)
    num_patches: int = 0

    # -- numerics / execution ---------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # dtype of materialized attention score blocks in the jnp path.  fp32 is
    # the safe default; bf16 halves score HBM traffic at ~1e-2 softmax
    # precision (the Pallas kernel keeps fp32 accumulation in VMEM for free).
    attn_scores_dtype: str = "float32"
    remat: str = "full"              # none | full | dots
    attn_impl: str = "ref"           # ref | pallas | pallas_interpret
    scan_layers: bool = True
    norm_eps: float = 1e-6

    source: str = ""                 # provenance note

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter counts (for roofline MODEL_FLOPS) -------------------
    def param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    return _SMOKE[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


# Which (arch, shape) cells are exercised. long_500k only for archs with
# sub-quadratic/local attention (see DESIGN.md §Arch-applicability).
LONG_CTX_ARCHS = {"gemma3-4b", "gemma2-27b", "rwkv6-3b", "hymba-1.5b"}


def cells(arch: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CTX_ARCHS:
        out.append("long_500k")
    return out

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    get_smoke_config,
    list_archs,
    register,
)

# Import arch modules for registration side effects.
from repro.configs import (  # noqa: F401
    gemma3_4b,
    granite_34b,
    minitron_8b,
    gemma2_27b,
    deepseek_moe_16b,
    deepseek_v2_lite_16b,
    internvl2_76b,
    rwkv6_3b,
    hymba_1_5b,
    whisper_tiny,
)

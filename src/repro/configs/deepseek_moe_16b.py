"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) vocab=102400.
Fine-grained MoE: 2 shared + 64 routed experts, top-6, expert d_ff=1408.
First layer dense (d_ff chosen to match active MoE compute: (6+2)*1408).
[arXiv:2401.06066; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=11264,                 # dense first-layer FFN = (top_k+shared)*1408
        vocab_size=102_400,
        head_dim=128,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        first_dense_layers=1,
        source="arXiv:2401.06066; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=512, num_experts=8, top_k=2, moe_d_ff=32,
        num_shared_experts=1, first_dense_layers=1, remat="none",
    )


register("deepseek-moe-16b", full, smoke)

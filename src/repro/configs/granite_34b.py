"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
Llama-style code model. [arXiv:2405.04324; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        num_layers=88,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24576,
        vocab_size=49_152,
        head_dim=128,
        attn_pattern="G",
        source="arXiv:2405.04324; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, remat="none",
    )


register("granite-34b", full, smoke)

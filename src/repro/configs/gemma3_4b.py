"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
5:1 local:global attention pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        d_ff=10240,
        vocab_size=262_144,
        head_dim=256,
        attn_pattern="LLLLLG",      # 5 local : 1 global
        sliding_window=1024,
        rope_theta=1_000_000.0,
        qk_norm=True,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt (scaled); unverified",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=16, remat="none",
    )


register("gemma3-4b", full, smoke)

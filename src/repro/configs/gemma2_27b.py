"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Local+global alternating attention, logit softcaps. [arXiv:2408.00118; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        d_ff=36864,
        vocab_size=256_000,
        head_dim=128,
        attn_pattern="LG",          # alternating local/global
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        tie_embeddings=True,
        source="arXiv:2408.00118; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=16, remat="none",
    )


register("gemma2-27b", full, smoke)

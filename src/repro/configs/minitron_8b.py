"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Pruned Nemotron. [arXiv:2407.14679; hf]
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=256_000,
        head_dim=128,
        attn_pattern="G",
        source="arXiv:2407.14679; hf",
    )


def smoke() -> ModelConfig:
    return full().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, remat="none",
    )


register("minitron-8b", full, smoke)

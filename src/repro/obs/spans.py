"""Request-lifecycle and node-lifecycle spans for the discrete-event oracle.

A ``Span`` is one timed interval on a named track: a request's queue wait,
an instance's cold start, a node's provision/drain window.  ``SpanRecorder``
collects them with near-zero cost when disabled (the instrumented code
guards every call behind ``if rec:``, and a disabled recorder is falsy), and
exports the collected tree as Chrome-trace / Perfetto JSON
(``chrome_trace``): load ``trace.json`` at https://ui.perfetto.dev or
chrome://tracing.

Span trees are real trees — each span carries a ``parent`` span id — so
``validate`` can check structural invariants (every span closed,
non-negative duration, children nested inside their parent) independent of
the track layout the viewer shows.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

# nesting tolerance: the oracle timestamps children at event granularity,
# so a child may start/end within float rounding of its parent's bounds
_EPS = 1e-6


@dataclasses.dataclass
class Span:
    sid: int
    name: str
    cat: str                    # request | instance | node
    t0: float
    t1: Optional[float]         # None while open
    pid: str                    # process track ("requests", "instances", ...)
    tid: int                    # thread track within the process
    parent: Optional[int]       # parent span id (the tree edge)
    args: dict

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else float("nan")


class SpanRecorder:
    """Collects spans; a disabled recorder is falsy so instrumented code
    pays one truthiness check per site (``if rec: rec.begin(...)``)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.spans: list[Span] = []
        self._open: dict[int, Span] = {}
        self._next = 0

    def __bool__(self) -> bool:
        return self.enabled

    def begin(self, name: str, cat: str, t: float, *, pid: str, tid: int,
              parent: Optional[int] = None, **args) -> int:
        sid = self._next
        self._next += 1
        sp = Span(sid, name, cat, float(t), None, pid, int(tid), parent, args)
        self.spans.append(sp)
        self._open[sid] = sp
        return sid

    def end(self, sid: int, t: float, **args) -> None:
        sp = self._open.pop(sid, None)
        if sp is None:
            return                       # already closed (or never opened)
        sp.t1 = float(t)
        if args:
            sp.args.update(args)

    def emit(self, name: str, cat: str, t0: float, t1: float, *, pid: str,
             tid: int, parent: Optional[int] = None, **args) -> int:
        sid = self.begin(name, cat, t0, pid=pid, tid=tid, parent=parent,
                         **args)
        self.end(sid, t1)
        return sid

    def instant(self, name: str, cat: str, t: float, *, pid: str, tid: int,
                **args) -> None:
        # represented as a zero-duration span; chrome_trace exports "i"
        sid = self.emit(name, cat, t, t, pid=pid, tid=tid, **args)
        self.spans[sid].args["_instant"] = True

    def finish(self, t: float) -> int:
        """Close every still-open span at ``t`` (end of run), tagging it
        ``truncated`` — a request still queued when the trace ends, an
        instance still starting.  Returns how many were closed."""
        n = len(self._open)
        for sid in list(self._open):
            self.end(sid, t, truncated=True)
        return n

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object ({"traceEvents": [...]}):
        "X" complete events (timestamps in microseconds), one Perfetto
        process per ``pid`` string, named via metadata events."""
        pids: dict[str, int] = {}
        events = []
        for sp in self.spans:
            pid = pids.setdefault(sp.pid, len(pids) + 1)
            args = {k: v for k, v in sp.args.items() if k != "_instant"}
            base = {"name": sp.name, "cat": sp.cat, "pid": pid,
                    "tid": sp.tid, "ts": sp.t0 * 1e6, "args": args}
            if sp.args.get("_instant"):
                events.append({**base, "ph": "i", "s": "t"})
            else:
                t1 = sp.t1 if sp.t1 is not None else sp.t0
                events.append({**base, "ph": "X",
                               "dur": max(t1 - sp.t0, 0.0) * 1e6})
        meta = [{"name": "process_name", "ph": "M", "pid": i, "tid": 0,
                 "args": {"name": name}} for name, i in pids.items()]
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms"}

    def write_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)


def validate(rec: SpanRecorder) -> list[str]:
    """Structural invariants of the span tree; returns problem strings
    (empty = well-formed): every span closed, durations non-negative,
    children nested inside their parent's interval."""
    problems = []
    by_id = {sp.sid: sp for sp in rec.spans}
    for sp in rec.spans:
        if sp.t1 is None:
            problems.append(f"span {sp.sid} ({sp.name}) never closed")
            continue
        if sp.t1 < sp.t0 - _EPS:
            problems.append(f"span {sp.sid} ({sp.name}) negative duration "
                            f"{sp.t1 - sp.t0:.6g}")
        if sp.parent is not None:
            par = by_id.get(sp.parent)
            if par is None:
                problems.append(f"span {sp.sid} ({sp.name}) dangling parent "
                                f"{sp.parent}")
            elif par.t1 is not None and (sp.t0 < par.t0 - _EPS
                                         or sp.t1 > par.t1 + _EPS):
                problems.append(
                    f"span {sp.sid} ({sp.name}) [{sp.t0:.6g},{sp.t1:.6g}] "
                    f"outside parent {par.sid} ({par.name}) "
                    f"[{par.t0:.6g},{par.t1:.6g}]")
    return problems

"""The churn-overhead attribution ledger (both engines).

The paper's headline metrics are ratios — ``cpu_overhead`` = system CPU /
useful CPU, ``normalized_memory`` = allocated / busy memory — and this
module decomposes each into WHERE the overhead goes:

* CPU:    creation (cold-start churn: the create-side sandbox/CNI/probe
            cost of ordinary scale-up)
          / eviction_storm (spot reclaims: the recreate wave killed warm
            instances trigger)
          / keepalive_idle (probes+metrics on warm-idle instances)
          / master_control (control-plane floors + per-request data plane
            + graceful-teardown work — computed as the residual, so the
            four components sum to the aggregate EXACTLY, by construction;
            teardown CPU lives here on BOTH engines because the engines
            agree on creation flux, the parity-banded metric, but not on
            when idle mass sheds around the measurement boundary)
* memory: busy / warm_idle / pipeline (still-starting sandboxes +
          pre-warmed mass), warm_idle the residual.

Both engines feed the same ``OverheadLedger``: the oracle from its
attribution counters (``SimResult.cpu_churn_creation_s`` etc.), the fluid
engine from the in-scan telemetry sums (``simulate_chunked(...,
telemetry=...)``).  ``ledger_parity`` judges each component's
oracle-vs-fluid gap against the aggregate's magnitude — the same <=15% bar
the aggregate parity band uses — so attribution that disagrees between
engines surfaces as a bug, not a footnote.
"""

from __future__ import annotations

import dataclasses
import math

CPU_COMPONENTS = ("creation", "eviction_storm", "keepalive_idle",
                  "master_control")
MEM_COMPONENTS = ("busy", "warm_idle", "pipeline")


@dataclasses.dataclass
class OverheadLedger:
    """One engine's overhead decomposition over the measurement window.
    CPU components are cpu-seconds; memory components are mean MB."""
    engine: str
    cpu_useful_s: float
    cpu_creation_s: float
    cpu_eviction_s: float
    cpu_keepalive_s: float
    cpu_control_s: float               # residual: floors + per-request CPU
    mem_busy_mb: float
    mem_warm_idle_mb: float            # residual
    mem_pipeline_mb: float

    # -- aggregates ----------------------------------------------------------

    @property
    def cpu_total_s(self) -> float:
        return (self.cpu_creation_s + self.cpu_eviction_s
                + self.cpu_keepalive_s + self.cpu_control_s)

    @property
    def cpu_overhead(self) -> float:
        return self.cpu_total_s / max(self.cpu_useful_s, 1e-9)

    @property
    def mem_total_mb(self) -> float:
        return (self.mem_busy_mb + self.mem_warm_idle_mb
                + self.mem_pipeline_mb)

    @property
    def normalized_memory(self) -> float:
        return self.mem_total_mb / max(self.mem_busy_mb, 1e-9)

    # -- component views -----------------------------------------------------

    def cpu_components(self) -> dict:
        """Each component as a share of USEFUL cpu (the same normalization
        as ``cpu_overhead`` — the four values sum to it)."""
        u = max(self.cpu_useful_s, 1e-9)
        return {"creation": self.cpu_creation_s / u,
                "eviction_storm": self.cpu_eviction_s / u,
                "keepalive_idle": self.cpu_keepalive_s / u,
                "master_control": self.cpu_control_s / u}

    def mem_components(self) -> dict:
        """Each component as a multiple of BUSY memory (the same
        normalization as ``normalized_memory`` — the three sum to it)."""
        b = max(self.mem_busy_mb, 1e-9)
        return {"busy": self.mem_busy_mb / b,
                "warm_idle": self.mem_warm_idle_mb / b,
                "pipeline": self.mem_pipeline_mb / b}

    def row(self) -> dict:
        return {"engine": self.engine, "cpu_useful_s": self.cpu_useful_s,
                "cpu_overhead": self.cpu_overhead,
                "normalized_memory": self.normalized_memory,
                **{f"cpu_{k}": v for k, v in self.cpu_components().items()},
                **{f"mem_{k}": v for k, v in self.mem_components().items()}}


def ledger_from_eventsim(result) -> OverheadLedger:
    """Build the ledger from the oracle's attribution counters (a
    ``repro.core.eventsim.SimResult``)."""
    total = result.cpu_worker_overhead_s + result.cpu_master_overhead_s
    creation = result.cpu_churn_creation_s
    evict = result.cpu_evict_storm_s
    idle = result.cpu_keepalive_idle_s
    mem_total = (float(result.mem_samples_total_mb.mean())
                 if len(result.mem_samples_total_mb) else 0.0)
    mem_busy = (float(result.mem_samples_busy_mb.mean())
                if len(result.mem_samples_busy_mb) else 0.0)
    pipe = (float(result.mem_samples_starting_mb.mean())
            if len(result.mem_samples_starting_mb) else 0.0)
    return OverheadLedger(
        engine="eventsim",
        cpu_useful_s=result.cpu_useful_s,
        cpu_creation_s=creation, cpu_eviction_s=evict,
        cpu_keepalive_s=idle,
        cpu_control_s=total - creation - evict - idle,
        mem_busy_mb=mem_busy, mem_pipeline_mb=pipe,
        mem_warm_idle_mb=mem_total - mem_busy - pipe)


def ledger_from_chunked(summary: dict) -> OverheadLedger:
    """Build the ledger from a ``simulate_chunked(..., telemetry=N)`` row
    (its ``telemetry.attribution`` sums cover the measurement window)."""
    telem = summary.get("telemetry")
    if not telem or "attribution" not in telem:
        raise ValueError("summary carries no telemetry attribution; run "
                         "simulate_chunked(..., telemetry=N) with N > 0")
    att = telem["attribution"]
    total = summary["cpu_worker_s"] + summary["cpu_master_s"]
    creation = att["cpu_creation_s"]
    evict = att["cpu_eviction_s"]
    idle = att["cpu_keepalive_s"]
    ticks = max(summary["ticks_measured"], 1e-9)
    pipe = att["mem_pipeline_mb_ticks"] / ticks
    mem_total = summary["mem_total_mean"]
    mem_busy = summary["mem_busy_mean"]
    return OverheadLedger(
        engine="simjax",
        cpu_useful_s=summary["cpu_useful_s"],
        cpu_creation_s=creation, cpu_eviction_s=evict,
        cpu_keepalive_s=idle,
        cpu_control_s=total - creation - evict - idle,
        mem_busy_mb=mem_busy, mem_pipeline_mb=pipe,
        mem_warm_idle_mb=mem_total - mem_busy - pipe)


def check_ledger(led: OverheadLedger, tol: float = 1e-6) -> list[str]:
    """Attribution-sum consistency: components must sum to the aggregates
    within ``tol`` (relative), every value finite, residuals non-negative
    (a negative residual means a component double-counted overhead it does
    not own).  Returns problem strings; empty = consistent."""
    problems = []
    vals = dataclasses.asdict(led)
    for k, v in vals.items():
        if k != "engine" and not math.isfinite(v):
            problems.append(f"{led.engine}: {k} non-finite ({v})")
    cpu_sum = sum(led.cpu_components().values())
    if abs(cpu_sum - led.cpu_overhead) > tol * max(led.cpu_overhead, 1.0):
        problems.append(f"{led.engine}: cpu components sum {cpu_sum:.9g} != "
                        f"cpu_overhead {led.cpu_overhead:.9g}")
    mem_sum = sum(led.mem_components().values())
    if abs(mem_sum - led.normalized_memory) \
            > tol * max(led.normalized_memory, 1.0):
        problems.append(f"{led.engine}: mem components sum {mem_sum:.9g} != "
                        f"normalized_memory {led.normalized_memory:.9g}")
    slack = tol * max(led.cpu_total_s, 1.0)
    for k in ("cpu_creation_s", "cpu_eviction_s", "cpu_keepalive_s",
              "cpu_control_s"):
        if vals[k] < -slack:
            problems.append(f"{led.engine}: {k} negative ({vals[k]:.6g})")
    mslack = tol * max(led.mem_total_mb, 1.0)
    for k in ("mem_busy_mb", "mem_warm_idle_mb", "mem_pipeline_mb"):
        if vals[k] < -mslack:
            problems.append(f"{led.engine}: {k} negative ({vals[k]:.6g})")
    return problems


def ledger_parity(a: OverheadLedger, b: OverheadLedger) -> dict:
    """Per-component oracle-vs-fluid gaps.

    Components are shares of the aggregate's own denominator (useful CPU /
    busy memory), so the gap divides the share difference by the AGGREGATE
    (max over engines), floored at 1: a gap of 0.15 means the engines
    disagree on that component by 15% of the aggregate overhead — or, for
    a lean scenario whose overhead is below its useful work, by 15% of
    USEFUL CPU itself.  The floor keeps the bar meaningful where the
    aggregate ratio is small: without it, a cpu_overhead of 0.25 would
    amplify a 4-cpu-points disagreement (out of every 100 useful cpu-s)
    into a 16% "failure" even though both engines agree the component is
    tiny."""
    out = {}
    ca, cb = a.cpu_components(), b.cpu_components()
    cpu_ref = max(a.cpu_overhead, b.cpu_overhead, 1.0)
    for k in CPU_COMPONENTS:
        out[f"cpu_{k}"] = abs(ca[k] - cb[k]) / cpu_ref
    ma, mb = a.mem_components(), b.mem_components()
    mem_ref = max(a.normalized_memory, b.normalized_memory, 1.0)
    for k in MEM_COMPONENTS:
        out[f"mem_{k}"] = abs(ma[k] - mb[k]) / mem_ref
    return out


def attribution_table(ledgers: list[OverheadLedger]) -> str:
    """The human-readable summary table the trace CLI prints: one line per
    component, one column per engine, plus the parity gap when both engines
    are present."""
    by = {led.engine: led for led in ledgers}
    gaps = (ledger_parity(by["eventsim"], by["simjax"])
            if {"eventsim", "simjax"} <= set(by) else {})
    cols = [led.engine for led in ledgers]
    lines = [f"{'component':24s} " + " ".join(f"{c:>10s}" for c in cols)
             + ("        gap" if gaps else "")]
    rows = [("cpu_overhead", [led.cpu_overhead for led in ledgers], None)]
    for k in CPU_COMPONENTS:
        rows.append((f"  cpu.{k}",
                     [led.cpu_components()[k] for led in ledgers],
                     gaps.get(f"cpu_{k}")))
    rows.append(("normalized_memory",
                 [led.normalized_memory for led in ledgers], None))
    for k in MEM_COMPONENTS:
        rows.append((f"  mem.{k}",
                     [led.mem_components()[k] for led in ledgers],
                     gaps.get(f"mem_{k}")))
    for name, vals, gap in rows:
        line = f"{name:24s} " + " ".join(f"{v:10.4f}" for v in vals)
        if gap is not None:
            line += f"  {gap:9.3f}"
        lines.append(line)
    return "\n".join(lines)

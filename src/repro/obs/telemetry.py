"""In-scan telemetry schema + host-side assembly and export.

The chunked ``lax.scan`` cannot emit per-tick histories at fig9 scale
(that is the point of the chunked path), so telemetry rides the scan carry
as a BOUNDED downsampled buffer: ``telemetry=S`` slots, each accumulating
sum+tick-count for ~``total_ticks/S`` consecutive ticks, plus a vector of
measurement-window attribution sums — constant memory in trace length.
``repro.core.simjax`` owns the in-scan side; this module pins the schema
(series order = the ``jnp.stack`` order in ``_make_step``) and turns the
accumulated buffers into timeline CSVs.

``RunTelemetry`` is the host-side event log the opt layer hooks feed
(per-round hypervolume, spot-check demotions, training-loss series): a
flat append-only list of {"event": kind, ...} records, exportable as JSON.
"""

from __future__ import annotations

import csv
import json

import numpy as np

# per-slot downsampled series, in the exact order the scan stacks them
# (repro.core.simjax._make_step, telem branch)
TELEM_SERIES = ("instances", "busy_instances", "queue_depth", "creations",
                "evictions", "mem_total_mb", "mem_busy_mb",
                "mem_pipeline_mb", "nodes", "spot_nodes", "cpu_worker_s",
                "cpu_master_s")

# measurement-window attribution sums, in scan stack order
TELEM_ATTR = ("cpu_creation_s", "cpu_eviction_s", "cpu_keepalive_s",
              "mem_pipeline_mb_ticks", "evict_kills", "evict_recreates")


def assemble_telemetry(series_sums: np.ndarray, slot_ticks: np.ndarray,
                       attr_sums: np.ndarray, total_ticks: int,
                       dt: float) -> dict:
    """Host-side assembly of the scan's telemetry buffers into the
    ``telemetry`` dict a ``simulate_chunked`` row carries:
    ``series_sums`` is (S, len(TELEM_SERIES)) per-slot sums, ``slot_ticks``
    the (S,) tick counts, ``attr_sums`` the (len(TELEM_ATTR),) sums."""
    series_sums = np.asarray(series_sums, np.float64)
    slot_ticks = np.asarray(slot_ticks, np.float64)
    slots = len(slot_ticks)
    denom = np.maximum(slot_ticks, 1e-9)[:, None]
    means = series_sums / denom
    centers = (np.arange(slots) + 0.5) * (total_ticks / slots) * dt
    return {
        "slots": slots,
        "dt": dt,
        "t": centers,
        "ticks_per_slot": slot_ticks,
        "series": {name: means[:, i] for i, name in enumerate(TELEM_SERIES)},
        "attribution": {name: float(attr_sums[i])
                        for i, name in enumerate(TELEM_ATTR)},
    }


def write_timeline_csv(telemetry: dict, path: str) -> None:
    """One row per slot: slot-center time, ticks covered, then every
    downsampled series (per-tick means over the slot)."""
    names = [n for n in TELEM_SERIES if n in telemetry["series"]]
    with open(path, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["t_s", "ticks"] + names)
        t = telemetry["t"]
        ticks = telemetry["ticks_per_slot"]
        for i in range(telemetry["slots"]):
            w.writerow([f"{t[i]:.6g}", f"{ticks[i]:g}"]
                       + [f"{telemetry['series'][n][i]:.6g}" for n in names])


def write_oracle_timeline_csv(result, path: str) -> None:
    """The oracle's per-tick samples as a timeline CSV (same spirit as the
    fluid one; the oracle samples only inside the measurement window)."""
    t = np.asarray(result.sample_times)
    total = np.asarray(result.mem_samples_total_mb)
    busy = np.asarray(result.mem_samples_busy_mb)
    start = np.asarray(result.mem_samples_starting_mb)
    nodes = np.asarray(result.node_samples)
    with open(path, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["t_s", "mem_total_mb", "mem_busy_mb", "mem_starting_mb",
                    "nodes"])
        for i in range(len(t)):
            w.writerow([f"{t[i]:.6g}", f"{total[i]:.6g}", f"{busy[i]:.6g}",
                        f"{start[i]:.6g}" if i < len(start) else "0",
                        f"{nodes[i]:g}" if i < len(nodes) else ""])


class RunTelemetry:
    """Append-only event log for long-running host loops (frontier search,
    oracle spot-checks, policy training).  Always truthy; callers guard
    with ``if telemetry:`` against the default ``None``."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, kind: str, **fields) -> None:
        self.events.append({"event": kind, **fields})

    def series(self, kind: str, field: str) -> list:
        return [e[field] for e in self.events
                if e["event"] == kind and field in e]

    def to_json(self) -> dict:
        return {"events": self.events}

    def write_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, default=float)

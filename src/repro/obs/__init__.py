"""repro.obs — unified observability across both simulation engines.

The paper's §3.6 metric suite is four aggregate scalars; this package
explains them.  Every span the oracle emits and every telemetry series the
chunked scan records maps onto exactly one §3.6 metric:

Oracle spans (``SpanRecorder``; Chrome-trace/Perfetto export):

* ``request`` (arrival -> completion), child ``queue`` (arrival -> first
  dispatch) and ``execute`` (dispatch -> done) — the per-request slowdown
  whose per-function p99 geomean is §3.6's *end-to-end performance*
  ((end - arrival) / pure duration); a re-queued request shows one
  ``execute`` per attempt, evicted attempts tagged ``evicted``.
* ``instance_create`` (placement -> ready) and ``teardown`` (tagged with
  its reason: keepalive / retire / drain / evict) — their rate over the
  measurement window is §3.6's *instance creation rate*, and each
  create/teardown pair carries the CPU cost behind *normalized CPU
  overhead*.
* ``node_provision`` / ``node_drain`` / ``node_evict`` — the two-level
  fleet's capacity timeline, the node-hours input of the dollar-cost
  model (beyond-paper, ``repro.fleet.costs``).

Fluid telemetry series (``simulate_chunked(..., telemetry=S)``; bounded
downsampled per-tick means, constant memory):

* ``instances`` / ``busy_instances`` / ``mem_total_mb`` / ``mem_busy_mb``
  / ``mem_pipeline_mb`` — the allocated-vs-busy mass whose time-averaged
  ratio is §3.6's *normalized memory usage*.
* ``creations`` / ``evictions`` — the churn flux behind *instance
  creation rate* (evictions split out the spot-storm share).
* ``cpu_worker_s`` / ``cpu_master_s`` — the per-tick overhead series
  behind *normalized CPU overhead* and its ~80/20 worker/master split.
* ``queue_depth`` / ``nodes`` / ``spot_nodes`` — the queueing and
  capacity context the other series are read against.

The attribution ledger (``OverheadLedger``) then decomposes
*cpu_overhead* into creation / eviction_storm / keepalive_idle /
master_control and *normalized_memory* into busy / warm_idle / pipeline,
from BOTH engines, with a component-level parity check — see
``repro.obs.ledger`` and the ``python -m repro.launch.trace`` CLI.
"""

from repro.obs.ledger import (CPU_COMPONENTS, MEM_COMPONENTS, OverheadLedger,
                              attribution_table, check_ledger,
                              ledger_from_chunked, ledger_from_eventsim,
                              ledger_parity)
from repro.obs.spans import Span, SpanRecorder, validate
from repro.obs.telemetry import (TELEM_ATTR, TELEM_SERIES, RunTelemetry,
                                 assemble_telemetry,
                                 write_oracle_timeline_csv,
                                 write_timeline_csv)

__all__ = [
    "Span", "SpanRecorder", "validate",
    "OverheadLedger", "ledger_from_eventsim", "ledger_from_chunked",
    "ledger_parity", "check_ledger", "attribution_table",
    "CPU_COMPONENTS", "MEM_COMPONENTS",
    "TELEM_SERIES", "TELEM_ATTR", "RunTelemetry", "assemble_telemetry",
    "write_timeline_csv", "write_oracle_timeline_csv",
]

"""Run-compressed layer stacks.

Layer patterns like gemma3's "LLLLLG" (5 sliding-window : 1 global) or
gemma2's alternating "LG" mean consecutive layers are not homogeneous.  We
compress the per-layer (window, kind) sequence into *runs*, where each run is
``count`` repetitions of a ``unit`` of one or more sub-layers:

* homogeneous stretches -> unit of length 1, scanned over ``count`` layers;
* periodic patterns -> unit = one period (e.g. (L, G)), scanned over the
  number of periods — gemma2's 46 alternating layers become ONE scan of 23
  blocks instead of 46 inline layers (HLO size O(1) in depth, ~15x faster
  XLA compile);
* singleton runs are applied inline.

Decode threads a per-run cache (a list per sub-layer) through the same
structure; sliding-window sub-layers get ring caches sized to the window,
which is what makes 500k-context decode fit in HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

LayerSig = tuple[Optional[int], str]     # (window, kind)


@dataclasses.dataclass(frozen=True)
class Run:
    count: int                  # scan length (number of unit repetitions)
    unit: tuple[LayerSig, ...]  # sub-layers applied per repetition

    @property
    def n_layers(self) -> int:
        return self.count * len(self.unit)


def layer_windows(cfg: ModelConfig) -> list[Optional[int]]:
    if cfg.family in ("ssm",):
        return [None] * cfg.num_layers
    if cfg.family == "hybrid":
        return [None if i in cfg.full_attn_layers else cfg.sliding_window
                for i in range(cfg.num_layers)]
    pat = cfg.attn_pattern or "G"
    out = []
    for i in range(cfg.num_layers):
        c = pat[i % len(pat)]
        out.append(None if c == "G" else cfg.sliding_window)
    return out


def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.num_experts > 0:
        return ["dense" if i < cfg.first_dense_layers else "moe"
                for i in range(cfg.num_layers)]
    return ["dense"] * cfg.num_layers


def _compress_homogeneous(sigs: list[LayerSig]) -> list[Run]:
    runs: list[Run] = []
    for s in sigs:
        if runs and runs[-1].unit == (s,):
            runs[-1] = Run(runs[-1].count + 1, (s,))
        else:
            runs.append(Run(1, (s,)))
    return runs


def compute_runs(cfg: ModelConfig) -> list[Run]:
    sigs = list(zip(layer_windows(cfg), layer_kinds(cfg)))
    n = len(sigs)
    if not cfg.scan_layers:
        # unrolled: singleton runs -> per-layer (donatable, in-place-updatable)
        # caches; the production choice for decode, where restacking a
        # scan-carried cache would rewrite the whole cache every token.
        return [Run(1, (s,)) for s in sigs]
    # periodic block compression (layer i sig depends only on i % p)
    pat = cfg.attn_pattern or "G"
    p = len(pat)
    if p > 1 and cfg.family not in ("hybrid", "ssm"):
        # layers [0, full*p) form identical blocks iff kinds are uniform there
        full = n // p
        if full >= 2 and all(sigs[i] == sigs[i % p] for i in range(full * p)):
            runs = [Run(full, tuple(sigs[:p]))]
            runs += _compress_homogeneous(sigs[full * p:])
            return runs
    return _compress_homogeneous(sigs)


# ---------------------------------------------------------------------------
# params: each run is a list (one entry per unit sub-layer) of stacked trees
# ---------------------------------------------------------------------------


def init_runs(cfg: ModelConfig, key, layer_init: Callable) -> list[Any]:
    """layer_init(cfg, key, kind) -> layer params pytree."""
    out = []
    for i, run in enumerate(compute_runs(cfg)):
        rk = jax.random.fold_in(key, i)
        if run.count == 1:
            out.append([layer_init(cfg, jax.random.fold_in(rk, j), kind)
                        for j, (_, kind) in enumerate(run.unit)])
        else:
            def unit_init(k, _run=run):
                return [layer_init(cfg, jax.random.fold_in(k, j), kind)
                        for j, (_, kind) in enumerate(_run.unit)]
            out.append(jax.vmap(unit_init)(jax.random.split(rk, run.count)))
    return out


def _add_layer_axis(tree):
    return jax.tree.map(lambda spec: ("layers", *spec), tree,
                        is_leaf=lambda l: isinstance(l, tuple))


def run_specs(cfg: ModelConfig, layer_specs: Callable) -> list[Any]:
    out = []
    for run in compute_runs(cfg):
        s = [layer_specs(cfg, kind) for (_, kind) in run.unit]
        if run.count > 1:
            s = _add_layer_axis(s)
        out.append(s)
    return out


def _maybe_remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def apply_runs(cfg: ModelConfig, run_params: list, x, layer_apply: Callable,
               *, remat: bool = False, **kw):
    """layer_apply(cfg, p, x, window=..., kind=..., **kw) -> x."""
    for run, plist in zip(compute_runs(cfg), run_params):
        def body(pl_list, xl, _run=run):
            for (w, k), pl in zip(_run.unit, pl_list):
                xl = layer_apply(cfg, pl, xl, window=w, kind=k, **kw)
            return xl
        if run.count == 1:
            x = (_maybe_remat(cfg, body) if remat else body)(plist, x)
        else:
            def scan_body(carry, pl, _body=body):
                return _body(pl, carry), None
            if remat:
                scan_body = _maybe_remat(cfg, scan_body)
            x, _ = jax.lax.scan(scan_body, x, plist)
    return x


def apply_runs_aux(cfg: ModelConfig, run_params: list, x, layer_apply: Callable,
                   *, remat: bool = False, **kw):
    """Like apply_runs but layer_apply returns (x, aux_scalar); auxes summed."""
    aux = jnp.zeros((), jnp.float32)
    for run, plist in zip(compute_runs(cfg), run_params):
        def body(pl_list, xl, _run=run):
            a_sum = jnp.zeros((), jnp.float32)
            for (w, k), pl in zip(_run.unit, pl_list):
                xl, a = layer_apply(cfg, pl, xl, window=w, kind=k, **kw)
                a_sum = a_sum + a
            return xl, a_sum
        if run.count == 1:
            fn = _maybe_remat(cfg, body) if remat else body
            x, a = fn(plist, x)
            aux = aux + a
        else:
            def scan_body(carry, pl, _body=body):
                xl, acc = carry
                xl, a = _body(pl, xl)
                return (xl, acc + a), None
            if remat:
                scan_body = _maybe_remat(cfg, scan_body)
            (x, aux), _ = jax.lax.scan(scan_body, (x, aux), plist)
    return x, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int,
                 layer_cache_shape: Callable) -> list[Any]:
    """layer_cache_shape(cfg, kind, window, batch, seq_len) -> SDS tree."""
    out = []
    for run in compute_runs(cfg):
        s = [layer_cache_shape(cfg, kind, w, batch, seq_len) for (w, kind) in run.unit]
        if run.count > 1:
            s = jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct((run.count, *sd.shape), sd.dtype), s)
        out.append(s)
    return out


def cache_run_specs(cfg: ModelConfig, layer_cache_specs: Callable) -> list[Any]:
    out = []
    for run in compute_runs(cfg):
        s = [layer_cache_specs(cfg, kind) for (_, kind) in run.unit]
        if run.count > 1:
            s = _add_layer_axis(s)
        out.append(s)
    return out


def prefill_runs(cfg: ModelConfig, run_params: list, caches: list, x,
                 layer_prefill: Callable, **kw):
    """layer_prefill(cfg, p, cache, x, window=..., kind=..., **kw)
    -> (x, new_cache).  Full-sequence forward from position 0."""
    new_caches = []
    for run, plist, clist in zip(compute_runs(cfg), run_params, caches):
        def body(pl_list, cl_list, xl, _run=run):
            new_cl = []
            for (w, k), pl, cl in zip(_run.unit, pl_list, cl_list):
                xl, c2 = layer_prefill(cfg, pl, cl, xl, window=w, kind=k, **kw)
                new_cl.append(c2)
            return xl, new_cl
        if run.count == 1:
            x, c2 = body(plist, clist, x)
        else:
            def scan_body(carry, pc, _body=body):
                pl, cl = pc
                xl, c2 = _body(pl, cl, carry)
                return xl, c2
            x, c2 = jax.lax.scan(scan_body, x, (plist, clist))
        new_caches.append(c2)
    return x, new_caches


def decode_runs(cfg: ModelConfig, run_params: list, caches: list, x, pos,
                layer_decode: Callable, **kw):
    """layer_decode(cfg, p, cache, x, pos, window=..., kind=..., **kw)
    -> (x, new_cache)."""
    new_caches = []
    for run, plist, clist in zip(compute_runs(cfg), run_params, caches):
        def body(pl_list, cl_list, xl, _run=run):
            new_cl = []
            for (w, k), pl, cl in zip(_run.unit, pl_list, cl_list):
                xl, c2 = layer_decode(cfg, pl, cl, xl, pos, window=w, kind=k, **kw)
                new_cl.append(c2)
            return xl, new_cl
        if run.count == 1:
            x, c2 = body(plist, clist, x)
        else:
            def scan_body(carry, pc, _body=body):
                pl, cl = pc
                xl, c2 = _body(pl, cl, carry)
                return xl, c2
            x, c2 = jax.lax.scan(scan_body, x, (plist, clist))
        new_caches.append(c2)
    return x, new_caches

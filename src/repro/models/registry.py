"""Uniform model API: dispatch by cfg.family.

Every family module implements:
  init_params(cfg, key) / param_specs(cfg)
  forward(cfg, params, batch) -> (logits, aux)
  loss_fn(cfg, params, batch) -> (loss, aux)
  cache_shapes(cfg, batch, seq_len) / cache_specs(cfg) / init_cache(...)
  decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.configs.base import ModelConfig


def family_module(cfg: ModelConfig):
    from repro.models import hymba, moe, rwkv6, transformer, whisper
    return {
        "dense": transformer,
        "vlm": transformer,
        "moe": moe,
        "ssm": rwkv6,
        "hybrid": hymba,
        "encdec": whisper,
    }[cfg.family]


def init_params(cfg: ModelConfig, key):
    return family_module(cfg).init_params(cfg, key)


def param_specs(cfg: ModelConfig):
    return family_module(cfg).param_specs(cfg)


def forward(cfg: ModelConfig, params, batch):
    return family_module(cfg).forward(cfg, params, batch)


def loss_fn(cfg: ModelConfig, params, batch):
    return family_module(cfg).loss_fn(cfg, params, batch)


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    return family_module(cfg).cache_shapes(cfg, batch, seq_len)


def cache_specs(cfg: ModelConfig):
    return family_module(cfg).cache_specs(cfg)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return family_module(cfg).init_cache(cfg, batch, seq_len)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    return family_module(cfg).decode_step(cfg, params, cache, tokens, pos)


def prefill(cfg: ModelConfig, params, cache, batch):
    """Batched prefill from position 0: (logits, filled cache)."""
    return family_module(cfg).prefill(cfg, params, cache, batch)


# ---------------------------------------------------------------------------
# parameter accounting (for roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _param_count_cached(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = _param_count_cached(cfg)
    if not active_only or cfg.num_experts == 0:
        return total
    n_moe_layers = cfg.num_layers - cfg.first_dense_layers
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe_layers * (cfg.num_experts - cfg.top_k) * per_expert
    return total - inactive

"""Hymba: every layer runs an attention head-group and a Mamba (selective
SSM) head-group IN PARALLEL on the same normed input; their normalized
outputs are averaged (learnable per-branch scale), then a SwiGLU FFN.

Full attention only in ``cfg.full_attn_layers`` (3 layers), sliding window
elsewhere; 128 learnable meta tokens are prepended to the sequence.
[arXiv:2411.13676]
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention, head, layers, stack


def _dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = -(-cfg.d_model // 16)
    return di, dt_rank, cfg.ssm_state, cfg.ssm_conv


# ---------------------------------------------------------------------------
# mamba branch
# ---------------------------------------------------------------------------


def mamba_init(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    di, dt_rank, n, k = _dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * di, cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (k, di)) * 0.1).astype(cfg.pdtype),
        "conv_b": jnp.zeros((di,), cfg.pdtype),
        "x_proj": layers.dense_init(ks[2], di, dt_rank + 2 * n, cfg.pdtype),
        "dt_proj": layers.dense_init(ks[3], dt_rank, di, cfg.pdtype),
        "dt_bias": jnp.full((di,), -4.6, cfg.pdtype),   # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))).astype(cfg.pdtype),
        "D": jnp.ones((di,), cfg.pdtype),
        "out_proj": layers.dense_init(ks[4], di, d, cfg.pdtype),
    }


def mamba_specs(cfg: ModelConfig) -> dict:
    return {
        "in_proj": ("embed", "ffn"), "conv_w": (None, "ffn"), "conv_b": ("ffn",),
        "x_proj": ("ffn", None), "dt_proj": (None, "ffn"), "dt_bias": ("ffn",),
        "A_log": ("ffn", None), "D": ("ffn",), "out_proj": ("ffn", "embed"),
    }


def _conv1d(xin, w, b, conv_state=None):
    """Causal depthwise conv. xin: (B,S,di); w: (k,di).  If conv_state
    (B,k-1,di) is given it is the left context (decode)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xin.shape[0], k - 1, xin.shape[2]), xin.dtype)
    else:
        pad = conv_state.astype(xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)          # (B, S+k-1, di)
    out = sum(xp[:, i:i + xin.shape[1]] * w[i] for i in range(k))
    return out + b, xp[:, -(k - 1):]


def _ssm_params(cfg, p, xc):
    di, dt_rank, n, _ = _dims(cfg)
    xdb = jnp.einsum("bsd,de->bse", xc, p["x_proj"].astype(xc.dtype))
    dt_raw, b_, c_ = jnp.split(xdb, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_proj"].astype(xc.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))      # (di, N)
    return dt, a, b_.astype(jnp.float32), c_.astype(jnp.float32)


def selective_scan(dt, a, b_, c_, xc, d_skip, h0):
    """dt: (B,S,di) fp32; a: (di,N); b_/c_: (B,S,N); xc: (B,S,di).
    h: (B,di,N).  Returns (y (B,S,di) fp32, h)."""
    xf = xc.astype(jnp.float32)

    def step(h, ts):
        dt_t, b_t, c_t, x_t = ts
        da = jnp.exp(dt_t[..., None] * a)                      # (B,di,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b_, 1, 0),
          jnp.moveaxis(c_, 1, 0), jnp.moveaxis(xf, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * d_skip
    return y, h


def mamba_apply(cfg: ModelConfig, p, x, h0=None, conv_state=None):
    """x: (B,S,d) -> (y (B,S,d), (h, conv_state))."""
    di, dt_rank, n, k = _dims(cfg)
    b = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cfg.cdtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", None, "ffn")
    xc, conv_state = _conv1d(xin, p["conv_w"].astype(cfg.cdtype),
                             p["conv_b"].astype(cfg.cdtype), conv_state)
    xc = jax.nn.silu(xc)
    dt, a, b_, c_ = _ssm_params(cfg, p, xc)
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)
    y, h = selective_scan(dt, a, b_, c_, xc, p["D"].astype(jnp.float32), h0)
    y = y.astype(cfg.cdtype) * jax.nn.silu(z)
    y = shard(y, "batch", None, "ffn")
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cfg.cdtype)), (h, conv_state)


# ---------------------------------------------------------------------------
# fused layer
# ---------------------------------------------------------------------------


def layer_init(cfg: ModelConfig, key, kind: str) -> dict:
    ka, km, kf = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "attn": attention.init(cfg, ka),
        "mamba": mamba_init(cfg, km),
        "norm_attn": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "norm_ssm": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "mlp": layers.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.pdtype),
    }


def layer_specs(cfg: ModelConfig, kind: str) -> dict:
    return {
        "ln1": (None,), "attn": attention.specs(cfg), "mamba": mamba_specs(cfg),
        "norm_attn": (None,), "norm_ssm": (None,),
        "ln2": (None,), "mlp": layers.swiglu_specs(),
    }


def layer_apply(cfg: ModelConfig, p, x, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a = attention.apply(cfg, p["attn"], h, window=window)
    m, _ = mamba_apply(cfg, p["mamba"], h)
    fused = 0.5 * (layers.rmsnorm(a, p["norm_attn"], cfg.norm_eps)
                   + layers.rmsnorm(m, p["norm_ssm"], cfg.norm_eps))
    x = shard(x + fused, "batch", None, "embed")
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return shard(x, "batch", None, "embed")


def layer_cache_shape(cfg: ModelConfig, kind, window, batch, seq_len):
    di, dt_rank, n, k = _dims(cfg)
    c = attention.cache_shape(cfg, batch, seq_len + cfg.num_meta_tokens, window)
    c["ssm_h"] = jax.ShapeDtypeStruct((batch, di, n), jnp.float32)
    c["conv"] = jax.ShapeDtypeStruct((batch, k - 1, di), cfg.cdtype)
    return c


def layer_cache_specs(cfg: ModelConfig, kind):
    s = attention.cache_specs(cfg)
    s["ssm_h"] = ("batch", "ffn", None)
    s["conv"] = ("batch", None, "ffn")
    return s


def layer_decode(cfg: ModelConfig, p, cache, x, pos, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    attn_cache = {"k": cache["k"], "v": cache["v"]}
    a, attn_cache = attention.decode(cfg, p["attn"], attn_cache, h, pos, window=window)
    m, (ssm_h, conv) = mamba_apply(cfg, p["mamba"], h, h0=cache["ssm_h"],
                                   conv_state=cache["conv"])
    fused = 0.5 * (layers.rmsnorm(a, p["norm_attn"], cfg.norm_eps)
                   + layers.rmsnorm(m, p["norm_ssm"], cfg.norm_eps))
    x = x + fused
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return x, {"k": attn_cache["k"], "v": attn_cache["v"], "ssm_h": ssm_h, "conv": conv}


# -- model -------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    kh, kl, km = jax.random.split(key, 3)
    p = {"head": head.init(cfg, kh), "runs": stack.init_runs(cfg, kl, layer_init)}
    if cfg.num_meta_tokens:
        p["meta"] = (jax.random.normal(km, (cfg.num_meta_tokens, cfg.d_model))
                     * 0.02).astype(cfg.pdtype)
    return p


def param_specs(cfg: ModelConfig) -> dict:
    s = {"head": head.specs(cfg), "runs": stack.run_specs(cfg, layer_specs)}
    if cfg.num_meta_tokens:
        s["meta"] = (None, "embed")
    return s


def _hidden(cfg: ModelConfig, params, batch, remat=None):
    x = head.embed(cfg, params["head"], batch["tokens"])
    if cfg.num_meta_tokens:
        meta = jnp.broadcast_to(params["meta"].astype(cfg.cdtype),
                                (x.shape[0], cfg.num_meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
    remat = (cfg.remat != "none") if remat is None else remat
    x = stack.apply_runs(cfg, params["runs"], x, layer_apply, remat=remat)
    if cfg.num_meta_tokens:
        x = x[:, cfg.num_meta_tokens:]
    return x


def forward(cfg: ModelConfig, params, batch, *, remat=None):
    return head.logits(cfg, params["head"], _hidden(cfg, params, batch, remat)), {}


def loss_fn(cfg: ModelConfig, params, batch):
    x = _hidden(cfg, params, batch)
    return head.chunked_loss(cfg, params["head"], x, batch), {}


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    return stack.cache_shapes(cfg, batch, seq_len, layer_cache_shape)


def cache_specs(cfg: ModelConfig):
    return stack.cache_run_specs(cfg, layer_cache_specs)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq_len))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    x = head.embed(cfg, params["head"], tokens)
    # positions are offset by the meta-token prefix
    x, cache = stack.decode_runs(cfg, params["runs"], cache, x,
                                 pos + cfg.num_meta_tokens, layer_decode)
    return head.logits(cfg, params["head"], x), cache


def layer_prefill(cfg: ModelConfig, p, cache, x, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    attn_cache = {"k": cache["k"], "v": cache["v"]}
    a, attn_cache = attention.prefill(cfg, p["attn"], attn_cache, h, window=window)
    m, (ssm_h, conv) = mamba_apply(cfg, p["mamba"], h)
    fused = 0.5 * (layers.rmsnorm(a, p["norm_attn"], cfg.norm_eps)
                   + layers.rmsnorm(m, p["norm_ssm"], cfg.norm_eps))
    x = shard(x + fused, "batch", None, "embed")
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return shard(x, "batch", None, "embed"), {
        "k": attn_cache["k"], "v": attn_cache["v"], "ssm_h": ssm_h, "conv": conv}


def prefill(cfg: ModelConfig, params, cache, batch):
    """Prefill including the meta-token prefix (positions [0, M))."""
    x = head.embed(cfg, params["head"], batch["tokens"])
    if cfg.num_meta_tokens:
        meta = jnp.broadcast_to(params["meta"].astype(cfg.cdtype),
                                (x.shape[0], cfg.num_meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
    x, cache = stack.prefill_runs(cfg, params["runs"], cache, x, layer_prefill)
    if cfg.num_meta_tokens:
        x = x[:, cfg.num_meta_tokens:]
    return head.logits(cfg, params["head"], x), cache

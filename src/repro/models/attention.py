"""GQA attention block: init / train apply / decode against full or ring KV.

Cache layouts
-------------
* global layers: full cache  k,v: (B, T, K, D); new tokens written at ``pos``.
* local (sliding window) layers: ring cache k,v: (B, W, K, D); slot = pos % W.
  Slot s holds position p - ((p - s) mod W); unwritten slots map to negative
  positions and are masked.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import div_axis, shard
from repro.models import layers
from repro.models.layers import NEG_INF


def init(cfg: ModelConfig, key) -> dict:
    h, k_, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(kq, cfg.d_model, (h, d), cfg.pdtype),
        "wk": layers.dense_init(kk, cfg.d_model, (k_, d), cfg.pdtype),
        "wv": layers.dense_init(kv, cfg.d_model, (k_, d), cfg.pdtype),
        "wo": layers.dense_init(ko, h * d, cfg.d_model, cfg.pdtype).reshape(h, d, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((d,), cfg.pdtype)
        p["k_norm"] = jnp.zeros((d,), cfg.pdtype)
    return p


def specs(cfg: ModelConfig) -> dict:
    qh = div_axis("heads", cfg.num_heads)
    kh = div_axis("kv_heads", cfg.num_kv_heads)
    s = {
        "wq": ("embed", qh, None),
        "wk": ("embed", kh, None),
        "wv": ("embed", kh, None),
        "wo": (qh, None, "embed"),
    }
    if cfg.qk_norm:
        s["q_norm"] = (None,)
        s["k_norm"] = (None,)
    return s


def _project_qkv(cfg: ModelConfig, p, x, positions):
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    qh = div_axis("heads", cfg.num_heads)
    kh = div_axis("kv_heads", cfg.num_kv_heads)
    q = shard(q, "batch", None, qh, None)
    k = shard(k, "batch", None, kh, None)
    v = shard(v, "batch", None, kh, None)
    return q, k, v


def _attn_core(cfg: ModelConfig, q, k, v, *, causal: bool, window, q_offset=0):
    """Dispatch between the jnp reference path and the Pallas kernel."""
    if cfg.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention import flash_attention
        s, t = q.shape[1], k.shape[1]
        bq = min(512, s)
        while s % bq:
            bq -= 1
        bk = min(512, t)
        while t % bk:
            bk -= 1
        return flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_logit_softcap, q_offset=q_offset,
            block_q=bq, block_k=bk,
            interpret=(cfg.attn_impl == "pallas_interpret"))
    return layers.attention(q, k, v, causal=causal, window=window,
                            logit_softcap=cfg.attn_logit_softcap,
                            q_block=min(512, q.shape[1]),
                            score_dtype=jnp.dtype(cfg.attn_scores_dtype))


def apply(cfg: ModelConfig, p, x, *, window: Optional[int], positions=None,
          causal: bool = True) -> jax.Array:
    """Training / prefill path. x: (B, S, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = _attn_core(cfg, q, k, v, causal=causal, window=window)
    qh = div_axis("heads", cfg.num_heads)
    out = shard(out, "batch", None, qh, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def cache_shape(cfg: ModelConfig, batch: int, seq_len: int, window: Optional[int]):
    t = seq_len if window is None else min(window, seq_len)
    shp = (batch, t, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, cfg.cdtype),
            "v": jax.ShapeDtypeStruct(shp, cfg.cdtype)}


def cache_specs(cfg: ModelConfig):
    kh = div_axis("kv_heads", cfg.num_kv_heads)
    seq = None if kh is not None else "kv_seq"   # split-K only when heads can't shard
    return {"k": ("batch", seq, kh, None), "v": ("batch", seq, kh, None)}


def prefill(cfg: ModelConfig, p, cache: dict, x, *, window: Optional[int]):
    """Full-sequence forward from position 0 that also fills the KV cache.

    x: (B, S, d).  Full cache gets k/v at [0, S); ring caches get the last
    min(W, S) tokens scattered at position % W.
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = _attn_core(cfg, q, k, v, causal=True, window=window)
    t = cache["k"].shape[1]
    if window is None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k[:, :t], 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v[:, :t], 0, axis=1)
    else:
        w = min(t, s)
        tail_pos = jnp.arange(s - w, s)
        slots = tail_pos % t
        ck = cache["k"].at[:, slots].set(k[:, s - w:])
        cv = cache["v"].at[:, slots].set(v[:, s - w:])
    qh = div_axis("heads", cfg.num_heads)
    out = shard(out, "batch", None, qh, None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))
    return out, {"k": ck, "v": cv}


def decode(cfg: ModelConfig, p, cache: dict, x, pos, *, window: Optional[int]):
    """One-token decode. x: (B, 1, d); pos: (B,) int32. Returns (out, cache)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x, pos[:, None])
    t = cache["k"].shape[1]
    slot = pos if window is None else pos % t
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])

    if window is None and cfg.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.decode_attention import decode_attention
        bk = min(512, t)
        while t % bk:
            bk -= 1
        out = decode_attention(
            q, k, v, pos, softcap=cfg.attn_logit_softcap, block_k=bk,
            interpret=(cfg.attn_impl == "pallas_interpret")).astype(cfg.cdtype)
        qh = div_axis("heads", cfg.num_heads)
        out = shard(out, "batch", None, qh, None)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))
        return out, {"k": k, "v": v}

    key_idx = jnp.arange(t)
    if window is None:
        # full cache: positions are 0..t-1; mask future
        mask = key_idx[None, :] <= pos[:, None]
    else:
        # ring cache: slot s holds position p - ((p - s) mod W)
        kpos = pos[:, None] - ((pos[:, None] - key_idx[None, :]) % t)
        mask = kpos >= 0

    scores = layers._gqa_scores(q, k, cfg.attn_logit_softcap)  # (B,K,G,1,T)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = layers._gqa_out(probs, v).astype(cfg.cdtype)          # (B,1,H,D)
    qh = div_axis("heads", cfg.num_heads)
    out = shard(out, "batch", None, qh, None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))
    return out, {"k": k, "v": v}

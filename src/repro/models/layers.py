"""Shared model building blocks (pure JAX, functional, pytree params).

Everything here is written to be lowered at production scale:
* attention never materializes a full (S, T) score matrix — prefill uses a
  ``lax.scan`` over query blocks (flash-style, fp32 online accumulation),
  local layers additionally bound the key range to the sliding window;
* all activations carry logical sharding constraints (see
  ``repro.distributed.sharding``);
* layer stacks are scanned, so HLO size is O(1) in depth.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh, shard

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Fan-in scaled normal init; out_shape may be a tuple (fused heads)."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, *out_shape)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention (jnp reference path; the Pallas kernels in repro.kernels implement
# the same contract for TPU runtime)
# ---------------------------------------------------------------------------


def _gqa_scores(q, k, softcap_val, score_dtype=jnp.float32):
    # q: (B, qb, H, D) ; k: (B, T, K, D) ; H = K*G
    b, s, h, d = q.shape
    kheads = k.shape[2]
    g = h // kheads
    q = q.reshape(b, s, kheads, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=score_dtype)
    scores = scores / math.sqrt(d)
    return softcap(scores, softcap_val)  # (B, K, G, qb, T)


def _gqa_out(probs, v):
    # probs: (B, K, G, qb, T), v: (B, T, K, D) -> (B, qb, H, D)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    b, s, kh, g, d = out.shape
    return out.reshape(b, s, kh * g, d)


NEG_INF = -1e30


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    q_block: int = 512,
    q_offset: int = 0,
    score_dtype=jnp.float32,
) -> jax.Array:
    """Memory-bounded multi-head attention with GQA.

    q: (B, S, H, D); k, v: (B, T, K, D).  Returns (B, S, H, D) in q.dtype.
    ``q_offset`` is the absolute position of q[0] (for decode/chunked prefill).
    Scans over query blocks; local (windowed) layers slice the key range so
    compute is O(S*window) instead of O(S*T).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    dv = v.shape[-1]          # may differ from d (e.g. MLA: qk 192, v 128)
    out_dtype = q.dtype

    if s == 1:
        # decode fast-path: single query token, full-row softmax
        scores = _gqa_scores(q, k, logit_softcap, score_dtype)  # (B,K,G,1,T)
        pos = q_offset
        key_idx = jnp.arange(t)
        mask = key_idx <= pos if causal else jnp.ones((t,), bool)
        if window is not None:
            mask = mask & (key_idx > pos - window)
        scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(probs, v).astype(out_dtype)

    qb = min(q_block, s)
    while s % qb != 0:   # largest divisor of s <= q_block (trace-time)
        qb -= 1
    n_blocks = s // qb

    # local layers: restrict keys per q block to [blk_start - window, blk_end)
    key_span = t if window is None else min(t, qb + int(window))

    @jax.checkpoint  # flash-style backward: recompute per-block scores, never
    def body(_, blk):  # stack (n_blocks, ..., span) residuals in HBM

        qi = blk * qb
        qpos = q_offset + qi + jnp.arange(qb)
        if window is None:
            kstart = 0
        else:
            kstart = jnp.clip(qi + q_offset - window + 1, 0, t - key_span)
        kblk = jax.lax.dynamic_slice_in_dim(k, kstart, key_span, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(v, kstart, key_span, axis=1)
        qblk = jax.lax.dynamic_slice_in_dim(q, qi, qb, axis=1)
        scores = _gqa_scores(qblk, kblk, logit_softcap, score_dtype)  # (B,K,G,qb,span)
        kpos = kstart + jnp.arange(key_span)
        mask = jnp.ones((qb, key_span), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores.astype(score_dtype),
                           jnp.asarray(NEG_INF, score_dtype))
        probs = jax.nn.softmax(scores, axis=-1)
        return None, _gqa_out(probs, vblk).astype(out_dtype)

    if n_blocks == 1:
        _, out = body(None, jnp.asarray(0))
        return out
    _, outs = jax.lax.scan(body, None, jnp.arange(n_blocks))
    # outs: (n_blocks, B, qb, H, Dv) -> (B, S, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)
    return out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_specs():
    return {
        "wi_gate": ("embed", "ffn"),
        "wi_up": ("embed", "ffn"),
        "wo": ("ffn", "embed"),
    }


def swiglu_apply(p, x, cdtype):
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(cdtype))
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(cdtype))
    h = jax.nn.silu(gate) * up
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cdtype))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: Optional[jax.Array] = None):
    """Stable CE on (possibly vocab-sharded) logits: (B,S,V) vs (B,S) ids.

    The gold logit is extracted with a fused one-hot reduction instead of
    ``take_along_axis``: a gather along a sharded vocab axis makes GSPMD
    all-gather the full logits (catastrophic at 262k vocab); the one-hot
    multiply-reduce keeps partial sums local + one small all-reduce.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (targets[..., None] == jnp.arange(v, dtype=targets.dtype)).astype(jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()

"""RWKV-6 "Finch" (attention-free, data-dependent decay).

Train/prefill uses the chunked-parallel form (chunk=16): within a chunk the
recurrence is computed with matmuls (MXU-friendly); the state is carried
across chunks with a ``lax.scan``.  Exponent centering at the chunk midpoint
keeps everything in fp32 range (|logw| clipped to 8, chunk 16 -> exponents
bounded by +-64).  The Pallas kernel in ``repro.kernels.rwkv6_scan``
implements the same contract; ``ref.py`` cross-checks both against a naive
per-token scan.

wkv head state: S in (B, H, Dk, Dv);   S_t = diag(w_t) S_{t-1} + k_t^T v_t
                y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import head, layers, stack

LORA_MIX = 32
LORA_DECAY = 64
CHUNK = 16
LOGW_MIN = -8.0
LOGW_MAX = -1e-4


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def layer_init(cfg: ModelConfig, key, kind: str) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    h, dh = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    tm = {
        "mu_x": jnp.full((d,), 0.5, cfg.pdtype),
        "mu": jnp.full((5, d), 0.5, cfg.pdtype),
        "w1": layers.dense_init(ks[0], d, 5 * LORA_MIX, cfg.pdtype),
        "w2": (jax.random.normal(ks[1], (5, LORA_MIX, d)) * 0.01).astype(cfg.pdtype),
        "w0": jnp.linspace(-5.0, -3.0, d).astype(cfg.pdtype),
        "wa": layers.dense_init(ks[2], d, LORA_DECAY, cfg.pdtype),
        "wb": (jax.random.normal(ks[3], (LORA_DECAY, d)) * 0.01).astype(cfg.pdtype),
        "u": (jax.random.normal(ks[4], (h, dh)) * 0.1).astype(cfg.pdtype),
        "wr": layers.dense_init(ks[5], d, d, cfg.pdtype),
        "wk": layers.dense_init(ks[6], d, d, cfg.pdtype),
        "wv": layers.dense_init(ks[7], d, d, cfg.pdtype),
        "wg": layers.dense_init(ks[8], d, d, cfg.pdtype),
        "wo": layers.dense_init(ks[9], d, d, cfg.pdtype),
        "gn_scale": jnp.ones((d,), cfg.pdtype),
        "gn_bias": jnp.zeros((d,), cfg.pdtype),
    }
    cm = {
        "mu_k": jnp.full((d,), 0.5, cfg.pdtype),
        "mu_r": jnp.full((d,), 0.5, cfg.pdtype),
        "wk": layers.dense_init(ks[10], d, dff, cfg.pdtype),
        "wv": layers.dense_init(ks[11], dff, d, cfg.pdtype),
        "wr": layers.dense_init(jax.random.fold_in(key, 99), d, d, cfg.pdtype),
    }
    return {"ln1": jnp.zeros((d,), cfg.pdtype), "tm": tm,
            "ln2": jnp.zeros((d,), cfg.pdtype), "cm": cm}


def layer_specs(cfg: ModelConfig, kind: str) -> dict:
    # time-mix channels must stay head-aligned -> replicated over "model";
    # channel-mix FFN and embeddings carry the tensor parallelism.
    tm = {k: tuple([None] * n) for k, n in [
        ("mu_x", 1), ("mu", 2), ("w1", 2), ("w2", 3), ("w0", 1), ("wa", 2),
        ("wb", 2), ("u", 2), ("wr", 2), ("wk", 2), ("wv", 2), ("wg", 2),
        ("wo", 2), ("gn_scale", 1), ("gn_bias", 1)]}
    cm = {"mu_k": (None,), "mu_r": (None,),
          "wk": ("embed", "ffn"), "wv": ("ffn", "embed"), "wr": ("embed", None)}
    return {"ln1": (None,), "tm": tm, "ln2": (None,), "cm": cm}


# ---------------------------------------------------------------------------
# time mix
# ---------------------------------------------------------------------------


def _ddlerp(p, x, xprev):
    """Data-dependent token-shift mixing -> (x_w, x_k, x_v, x_r, x_g)."""
    sx = xprev - x
    xxx = x + sx * p["mu_x"].astype(x.dtype)
    t = jnp.tanh(jnp.einsum("bsd,df->bsf", xxx, p["w1"].astype(x.dtype)))
    t = t.reshape(*t.shape[:-1], 5, LORA_MIX)
    m = jnp.einsum("bsfr,frd->bsfd", t, p["w2"].astype(x.dtype))
    mixed = x[..., None, :] + sx[..., None, :] * (p["mu"].astype(x.dtype) + m)
    return [mixed[..., i, :] for i in range(5)]


def _rkvwg(cfg, p, x, xprev):
    xw, xk, xv, xr, xg = _ddlerp(p, x, xprev)
    cd = cfg.cdtype
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(cd))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(cd))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(cd))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(cd)))
    logw = -jnp.exp(p["w0"].astype(jnp.float32)
                    + jnp.einsum("bsd,de->bse", jnp.tanh(
                        jnp.einsum("bsd,df->bsf", xw, p["wa"].astype(cd))).astype(jnp.float32),
                        p["wb"].astype(jnp.float32)))
    logw = jnp.clip(logw, LOGW_MIN, LOGW_MAX)
    return r, k, v, g, logw


def _heads(x, h, dh):
    return x.reshape(*x.shape[:-1], h, dh)


def wkv_chunked(r, k, v, logw, u, state):
    """Chunked-parallel wkv.  r/k/v: (B,S,H,D) (compute dtype), logw fp32,
    u: (H,D), state: (B,H,Dk,Dv) fp32.  Returns (y (B,S,H,D) fp32, state)."""
    b, s, h, dh = r.shape
    c = CHUNK
    pad = (-s) % c
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=LOGW_MAX)
    n = (s + pad) // c

    def to_chunks(a):  # (B, S, H, D) -> (n, B, C, H, D)
        return jnp.moveaxis(a.reshape(b, n, c, h, dh), 1, 0)

    rc, kc, vc = map(to_chunks, (r.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32)))
    lw = to_chunks(logw)
    la = jnp.cumsum(lw, axis=2)                    # inclusive within chunk
    la_prev = la - lw
    mid = la[:, :, c // 2: c // 2 + 1]             # centering constant

    qq = rc * jnp.exp(la_prev - mid)
    kk = kc * jnp.exp(mid - la)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strict lower: s' < t

    def chunk_step(S, xs):
        rc_, kc_, vc_, la_, lap_, qq_, kk_ = xs
        scores = jnp.einsum("bthd,bshd->bhts", qq_, kk_)
        scores = jnp.where(mask[None, None], scores, 0.0)
        intra = jnp.einsum("bhts,bshd->bthd", scores, vc_)
        bonus = jnp.einsum("bthd,hd,bthd->bth", rc_, u, kc_)
        intra = intra + bonus[..., None] * vc_
        cross = jnp.einsum("bthd,bhdv->bthv", rc_ * jnp.exp(lap_), S)
        y = intra + cross
        w_all = jnp.exp(la_[:, -1])                # (B,H,D)
        kdec = kc_ * jnp.exp(la_[:, -1:] - la_)
        S = w_all[..., None] * S + jnp.einsum("bthd,bthv->bhdv", kdec, vc_)
        return S, y

    state, y = jax.lax.scan(chunk_step, state.astype(jnp.float32),
                            (rc, kc, vc, la, la_prev, qq, kk))
    y = jnp.moveaxis(y, 0, 1).reshape(b, n * c, h, dh)
    return y[:, :s], state


def wkv_step(r, k, v, logw, u, state):
    """Single-token recurrence. r/k/v: (B,H,D); state (B,H,Dk,Dv) fp32."""
    r, k, v = (a.astype(jnp.float32) for a in (r, k, v))
    kv = k[..., :, None] * v[..., None, :]                   # (B,H,Dk,Dv)
    y = jnp.einsum("bhd,bhdv->bhv", r, state + u[..., None] * kv)
    state = jnp.exp(logw)[..., None] * state + kv
    return y, state


def _group_norm(y, scale, bias, eps):
    """Per-head layernorm over D (GroupNorm(H)); y: (B,S,H,D) fp32."""
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, d = y.shape
    y = y.reshape(b, s, h * d)
    return y * scale.astype(jnp.float32) + bias.astype(jnp.float32)


def time_mix(cfg: ModelConfig, p, x, xprev, state):
    """x: (B,S,d); xprev: token-shifted x; state: (B,H,D,D) fp32."""
    h, dh = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    r, k, v, g, logw = _rkvwg(cfg, p, x, xprev)
    if cfg.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.rwkv6_scan import rwkv6_scan
        y, state = rwkv6_scan(_heads(r, h, dh), _heads(k, h, dh),
                              _heads(v, h, dh), _heads(logw, h, dh),
                              p["u"].astype(jnp.float32),
                              interpret=(cfg.attn_impl == "pallas_interpret"))
    else:
        y, state = wkv_chunked(_heads(r, h, dh), _heads(k, h, dh),
                               _heads(v, h, dh), _heads(logw, h, dh),
                               p["u"].astype(jnp.float32), state)
    y = _group_norm(y, p["gn_scale"], p["gn_bias"], cfg.norm_eps)
    y = y.astype(cfg.cdtype) * g
    return jnp.einsum("bsd,de->bse", y, p["wo"].astype(cfg.cdtype)), state


def channel_mix(cfg: ModelConfig, p, x, xprev):
    cd = cfg.cdtype
    xk = x + (xprev - x) * p["mu_k"].astype(x.dtype)
    xr = x + (xprev - x) * p["mu_r"].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(cd))
    kk = jnp.square(jax.nn.relu(kk))
    kk = shard(kk, "batch", None, "ffn")
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"].astype(cd))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(cd)))
    return rr * vv


def _tshift(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def layer_apply(cfg: ModelConfig, p, x, *, window, kind):
    h, dh = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    b = x.shape[0]
    state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xa = layers.layernorm(x, 1.0 + p["ln1"], jnp.zeros_like(p["ln1"]), cfg.norm_eps)
    y, _ = time_mix(cfg, p["tm"], xa, _tshift(xa), state0)
    x = shard(x + y, "batch", None, "embed")
    xb = layers.layernorm(x, 1.0 + p["ln2"], jnp.zeros_like(p["ln2"]), cfg.norm_eps)
    x = x + channel_mix(cfg, p["cm"], xb, _tshift(xb))
    return shard(x, "batch", None, "embed")


# -- decode ----------------------------------------------------------------------


def layer_cache_shape(cfg: ModelConfig, kind, window, batch, seq_len):
    h, dh = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {"S": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
            "tshift": jax.ShapeDtypeStruct((batch, cfg.d_model), cfg.cdtype),
            "cshift": jax.ShapeDtypeStruct((batch, cfg.d_model), cfg.cdtype)}


def layer_cache_specs(cfg: ModelConfig, kind):
    return {"S": ("batch", None, None, None), "tshift": ("batch", None),
            "cshift": ("batch", None)}


def layer_decode(cfg: ModelConfig, p, cache, x, pos, *, window, kind):
    h, dh = cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xa = layers.layernorm(x, 1.0 + p["ln1"], jnp.zeros_like(p["ln1"]), cfg.norm_eps)
    xprev = cache["tshift"][:, None, :]
    r, k, v, g, logw = _rkvwg(cfg, p["tm"], xa, xprev)
    y, S = wkv_step(_heads(r[:, 0], h, dh), _heads(k[:, 0], h, dh),
                    _heads(v[:, 0], h, dh), _heads(logw[:, 0], h, dh),
                    p["tm"]["u"].astype(jnp.float32), cache["S"])
    y = _group_norm(y[:, None], p["tm"]["gn_scale"], p["tm"]["gn_bias"], cfg.norm_eps)
    y = y.astype(cfg.cdtype) * g
    y = jnp.einsum("bsd,de->bse", y, p["tm"]["wo"].astype(cfg.cdtype))
    x = x + y
    xb = layers.layernorm(x, 1.0 + p["ln2"], jnp.zeros_like(p["ln2"]), cfg.norm_eps)
    cprev = cache["cshift"][:, None, :]
    x = x + channel_mix(cfg, p["cm"], xb, cprev)
    return x, {"S": S, "tshift": xa[:, 0], "cshift": xb[:, 0]}


# -- model --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    kh, kl = jax.random.split(key)
    return {"head": head.init(cfg, kh),
            "runs": stack.init_runs(cfg, kl, layer_init)}


def param_specs(cfg: ModelConfig) -> dict:
    return {"head": head.specs(cfg), "runs": stack.run_specs(cfg, layer_specs)}


def _hidden(cfg: ModelConfig, params, batch, remat=None):
    x = head.embed(cfg, params["head"], batch["tokens"])
    remat = (cfg.remat != "none") if remat is None else remat
    return stack.apply_runs(cfg, params["runs"], x, layer_apply, remat=remat)


def forward(cfg: ModelConfig, params, batch, *, remat=None):
    return head.logits(cfg, params["head"], _hidden(cfg, params, batch, remat)), {}


def loss_fn(cfg: ModelConfig, params, batch):
    x = _hidden(cfg, params, batch)
    return head.chunked_loss(cfg, params["head"], x, batch), {}


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    return stack.cache_shapes(cfg, batch, seq_len, layer_cache_shape)


def cache_specs(cfg: ModelConfig):
    return stack.cache_run_specs(cfg, layer_cache_specs)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq_len))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    x = head.embed(cfg, params["head"], tokens)
    x, cache = stack.decode_runs(cfg, params["runs"], cache, x, pos, layer_decode)
    return head.logits(cfg, params["head"], x), cache


def layer_prefill(cfg: ModelConfig, p, cache, x, *, window, kind):
    xa = layers.layernorm(x, 1.0 + p["ln1"], jnp.zeros_like(p["ln1"]), cfg.norm_eps)
    y, S = time_mix(cfg, p["tm"], xa, _tshift(xa), cache["S"])
    x = shard(x + y, "batch", None, "embed")
    xb = layers.layernorm(x, 1.0 + p["ln2"], jnp.zeros_like(p["ln2"]), cfg.norm_eps)
    x = x + channel_mix(cfg, p["cm"], xb, _tshift(xb))
    return shard(x, "batch", None, "embed"), {
        "S": S, "tshift": xa[:, -1], "cshift": xb[:, -1]}


def prefill(cfg: ModelConfig, params, cache, batch):
    x = head.embed(cfg, params["head"], batch["tokens"])
    x, cache = stack.prefill_runs(cfg, params["runs"], cache, x, layer_prefill)
    return head.logits(cfg, params["head"], x), cache

"""Multi-head Latent Attention (DeepSeek-V2).  Train path expands the latent
KV; decode uses the absorbed formulation so the cache holds only
(kv_lora_rank + qk_rope_head_dim) per token — the paper's serving win.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import div_axis, shard
from repro.models import layers
from repro.models.layers import NEG_INF


def _dims(cfg: ModelConfig):
    return cfg.num_heads, cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim


def init(cfg: ModelConfig, key) -> dict:
    h, r, dn, dr, dv = _dims(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "wdkv": layers.dense_init(k1, cfg.d_model, r + dr, cfg.pdtype),
        "kv_norm": jnp.zeros((r,), cfg.pdtype),
        "wq": layers.dense_init(k2, cfg.d_model, (h, dn + dr), cfg.pdtype),
        "wuk": layers.dense_init(k3, r, (h, dn), cfg.pdtype),
        "wuv": layers.dense_init(k4, r, (h, dv), cfg.pdtype),
        "wo": layers.dense_init(k5, h * dv, cfg.d_model, cfg.pdtype).reshape(h, dv, cfg.d_model),
    }


def specs(cfg: ModelConfig) -> dict:
    qh = div_axis("heads", cfg.num_heads)
    return {
        "wdkv": ("embed", "kv_lora"),
        "kv_norm": (None,),
        "wq": ("embed", qh, None),
        "wuk": ("kv_lora", qh, None),
        "wuv": ("kv_lora", qh, None),
        "wo": (qh, None, "embed"),
    }


def _latent(cfg: ModelConfig, p, x, positions):
    """-> ckv (B,S,r) normalized, k_rope (B,S,1,dr) roped."""
    h, r, dn, dr, dv = _dims(cfg)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(cfg.cdtype))
    ckv, k_rope = ckv_full[..., :r], ckv_full[..., r:]
    ckv = layers.rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = layers.apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)
    return ckv, k_rope


def _queries(cfg: ModelConfig, p, x, positions):
    h, r, dn, dr, dv = _dims(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.cdtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def apply(cfg: ModelConfig, p, x, *, positions=None) -> jax.Array:
    """Training/prefill path (expanded KV). x: (B,S,d)."""
    h, r, dn, dr, dv = _dims(cfg)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    ckv, k_rope = _latent(cfg, p, x, positions)
    q_nope, q_rope = _queries(cfg, p, x, positions)

    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"].astype(cfg.cdtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"].astype(cfg.cdtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    qh = div_axis("heads", cfg.num_heads)
    q = shard(q, "batch", None, qh, None)
    k = shard(k, "batch", None, qh, None)
    v = shard(v, "batch", None, qh, None)
    # pad v to q/k head_dim so the shared attention core can be reused
    out = layers.attention(q, k, v, causal=True, window=None,
                           q_block=min(512, s))
    out = shard(out, "batch", None, qh, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))


def prefill(cfg: ModelConfig, p, cache, x):
    """Full-sequence forward from position 0 filling the latent cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    ckv, k_rope = _latent(cfg, p, x, positions)
    out = apply(cfg, p, x)
    t = cache["ckv"].shape[1]
    c1 = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv[:, :t], 0, axis=1)
    c2 = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope[:, :t, 0], 0, axis=1)
    return out, {"ckv": c1, "krope": c2}


# -- decode (absorbed) ---------------------------------------------------------


def cache_shape(cfg: ModelConfig, batch: int, seq_len: int):
    h, r, dn, dr, dv = _dims(cfg)
    return {"ckv": jax.ShapeDtypeStruct((batch, seq_len, r), cfg.cdtype),
            "krope": jax.ShapeDtypeStruct((batch, seq_len, dr), cfg.cdtype)}


def cache_specs(cfg: ModelConfig):
    # the latent is a single shared "head" — split-K the context over model
    return {"ckv": ("batch", "kv_seq", None), "krope": ("batch", "kv_seq", None)}


def decode(cfg: ModelConfig, p, cache, x, pos):
    """x: (B,1,d); pos: (B,). Absorbed-MLA single-token attention."""
    h, r, dn, dr, dv = _dims(cfg)
    b = x.shape[0]
    ckv_new, krope_new = _latent(cfg, p, x, pos[:, None])
    bidx = jnp.arange(b)
    ckv = cache["ckv"].at[bidx, pos].set(ckv_new[:, 0])
    krope = cache["krope"].at[bidx, pos].set(krope_new[:, 0, 0])

    q_nope, q_rope = _queries(cfg, p, x, pos[:, None])
    # absorb W_uk:  q_nope . k_nope = (q_nope @ W_uk^T) . ckv
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"].astype(cfg.cdtype))
    scores = jnp.einsum("bshr,btr->bhst", q_lat, ckv, preferred_element_type=jnp.float32)
    scores += jnp.einsum("bshk,btk->bhst", q_rope, krope, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dn + dr)

    t = ckv.shape[1]
    mask = jnp.arange(t)[None, :] <= pos[:, None]          # (B, T)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, ckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", ctx.astype(cfg.cdtype), p["wuv"].astype(cfg.cdtype))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))
    return out, {"ckv": ckv, "krope": krope}

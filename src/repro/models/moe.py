"""Mixture-of-Experts family (DeepSeekMoE / DeepSeek-V2-Lite).

FFN: ``num_shared_experts`` dense shared experts + ``num_experts`` routed
fine-grained experts with top-k gating.  Two routed implementations:

* ``dispatch`` — GShard-style one-hot dispatch/combine einsums over capacity
  buffers.  The standard JAX formulation (MaxText-style); pays ~2x FLOPs in
  the dispatch einsums.  This is the BASELINE.
* ``ragged``  — sort-based: tokens are argsorted by expert id inside each
  group, scattered into (E, C, d) buffers, run through batched expert GEMMs
  and gathered back.  Same GEMM FLOPs, no dispatch-einsum FLOPs; the
  beyond-baseline optimization evaluated in EXPERIMENTS.md §Perf.

Attention is standard MHA, or MLA when cfg.use_mla (DeepSeek-V2-Lite).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import div_axis, shard
from repro.models import attention, head, layers, mla, stack

MOE_GROUP = 4096  # tokens per dispatch group


# ---------------------------------------------------------------------------
# routed experts
# ---------------------------------------------------------------------------


def moe_init(cfg: ModelConfig, key) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(k1, d, e, jnp.float32),
        "wi_gate": layers.dense_init(k2, d, (e, f), cfg.pdtype).transpose(1, 0, 2),
        "wi_up": layers.dense_init(k3, d, (e, f), cfg.pdtype).transpose(1, 0, 2),
        "wo": layers.dense_init(k4, f, (e, d), cfg.pdtype).transpose(1, 0, 2),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.swiglu_init(k5, d, cfg.num_shared_experts * f, cfg.pdtype)
    return p


def moe_specs(cfg: ModelConfig) -> dict:
    s = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "expert_ffn"),
        "wi_up": ("experts", "embed", "expert_ffn"),
        "wo": ("experts", "expert_ffn", "embed"),
    }
    if cfg.num_shared_experts:
        s["shared"] = layers.swiglu_specs()
    return s


def _route(cfg: ModelConfig, p, xg):
    """xg: (n, G, d) -> (probs (n,G,K), ids (n,G,K), aux scalar)."""
    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), p["router"])
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, ids = jax.lax.top_k(probs_full, cfg.top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch/GShard): E * mean_e(frac_tokens_e * mean_prob_e)
    e = cfg.num_experts
    assign = jax.nn.one_hot(ids, e, dtype=jnp.float32).sum(axis=2)   # (n,G,E)
    frac = assign.mean(axis=(0, 1)) / cfg.top_k
    mean_p = probs_full.mean(axis=(0, 1))
    aux = e * jnp.sum(frac * mean_p) * cfg.aux_loss_coef
    return probs, ids, aux


def _capacity(cfg: ModelConfig, g: int) -> int:
    c = int(g * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def _expert_ffn(cfg: ModelConfig, p, xe):
    """xe: (n, E, C, d) -> (n, E, C, d)."""
    cd = cfg.cdtype
    if cfg.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.moe_gemm import moe_expert_ffn
        n, e, c, d = xe.shape
        out = jax.vmap(lambda xg: moe_expert_ffn(
            xg, p["wi_gate"].astype(cd), p["wi_up"].astype(cd),
            p["wo"].astype(cd), block_c=min(128, c),
            interpret=(cfg.attn_impl == "pallas_interpret")))(xe)
        return out
    gate = jnp.einsum("necd,edf->necf", xe, p["wi_gate"].astype(cd))
    up = jnp.einsum("necd,edf->necf", xe, p["wi_up"].astype(cd))
    h = jax.nn.silu(gate) * up
    h = shard(h, "expert_batch", "experts", None, "expert_ffn")
    return jnp.einsum("necf,efd->necd", h, p["wo"].astype(cd))


def _moe_dispatch(cfg: ModelConfig, p, xg, probs, ids):
    """GShard one-hot dispatch. xg: (n,G,d)."""
    n, g, d = xg.shape
    e, c = cfg.num_experts, _capacity(cfg, g)
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)               # (n,G,K,E)
    assign = onehot.sum(axis=2)                                      # (n,G,E)
    pos = jnp.cumsum(assign, axis=1) - assign                        # (n,G,E)
    keep = (pos < c) * assign
    disp = keep[..., None] * jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)
    gates = (onehot * probs[..., None]).sum(axis=2)                  # (n,G,E)
    combine = disp * gates[..., None]                                # (n,G,E,C)
    disp = shard(disp.astype(cfg.cdtype), "expert_batch", None, "experts", None)
    xe = jnp.einsum("ngec,ngd->necd", disp, xg)                      # (n,E,C,d)
    xe = shard(xe, "expert_batch", "experts", None, None)
    ye = _expert_ffn(cfg, p, xe)
    out = jnp.einsum("ngec,necd->ngd", combine.astype(cfg.cdtype), ye)
    return out


def _moe_ragged(cfg: ModelConfig, p, xg, probs, ids):
    """Sort-based dispatch (no one-hot einsum FLOPs). xg: (n,G,d)."""
    n, g, d = xg.shape
    e, k, c = cfg.num_experts, cfg.top_k, _capacity(cfg, g)
    eid = ids.reshape(n, g * k)                                       # (n, GK)
    tok = jnp.repeat(jnp.arange(g)[None, :], n, 0).reshape(n, g, 1)
    tok = jnp.broadcast_to(tok, (n, g, k)).reshape(n, g * k)
    pw = probs.reshape(n, g * k)

    order = jnp.argsort(eid, axis=-1, stable=True)
    eid_s = jnp.take_along_axis(eid, order, -1)
    tok_s = jnp.take_along_axis(tok, order, -1)
    pw_s = jnp.take_along_axis(pw, order, -1)
    # rank within expert segment
    seg_start = jax.vmap(lambda a: jnp.searchsorted(a, jnp.arange(e), side="left"))(eid_s)
    starts = jnp.take_along_axis(seg_start, eid_s, -1)               # (n, GK)
    slot = jnp.arange(g * k)[None, :] - starts
    keep = slot < c
    slot = jnp.where(keep, slot, c - 1)

    gathered = jnp.take_along_axis(xg, tok_s[..., None], axis=1)     # (n,GK,d)
    xe = jnp.zeros((n, e, c, d), xg.dtype)
    nidx = jnp.arange(n)[:, None]
    xe = xe.at[nidx, eid_s, slot].set(
        jnp.where(keep[..., None], gathered, 0.0), mode="drop")
    xe = shard(xe, "expert_batch", "experts", None, None)
    ye = _expert_ffn(cfg, p, xe)                                     # (n,E,C,d)
    back = ye[nidx, eid_s, slot]                                      # (n,GK,d)
    back = back * (pw_s * keep)[..., None].astype(back.dtype)
    out = jnp.zeros_like(xg)
    out = out.at[nidx, tok_s].add(back)
    return out


def moe_ffn(cfg: ModelConfig, p, x):
    """x: (B,S,d) -> (out, aux)."""
    b, s, d = x.shape
    tokens = b * s
    g = min(MOE_GROUP, tokens)
    while tokens % g != 0:
        g -= 1
    xg = x.reshape(tokens // g, g, d)
    xg = shard(xg, "expert_batch", None, "embed")
    probs, ids, aux = _route(cfg, p, xg)
    impl = _moe_ragged if cfg.moe_impl == "ragged" else _moe_dispatch
    out = impl(cfg, p, xg, probs, ids)
    out = out.reshape(b, s, d)
    if cfg.num_shared_experts:
        out = out + layers.swiglu_apply(p["shared"], x, cfg.cdtype)
    return shard(out, "batch", None, "embed"), aux


# ---------------------------------------------------------------------------
# layers / model (mirrors transformer.py but with aux threading + MLA)
# ---------------------------------------------------------------------------


def layer_init(cfg: ModelConfig, key, kind: str) -> dict:
    ka, km = jax.random.split(key)
    p = {"ln1": jnp.zeros((cfg.d_model,), cfg.pdtype),
         "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype)}
    p["attn"] = mla.init(cfg, ka) if cfg.use_mla else attention.init(cfg, ka)
    if kind == "moe":
        p["moe"] = moe_init(cfg, km)
    else:
        p["mlp"] = layers.swiglu_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype)
    return p


def layer_specs(cfg: ModelConfig, kind: str) -> dict:
    s = {"ln1": (None,), "ln2": (None,)}
    s["attn"] = mla.specs(cfg) if cfg.use_mla else attention.specs(cfg)
    if kind == "moe":
        s["moe"] = moe_specs(cfg)
    else:
        s["mlp"] = layers.swiglu_specs()
    return s


def layer_apply(cfg: ModelConfig, p, x, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a = mla.apply(cfg, p["attn"], h)
    else:
        a = attention.apply(cfg, p["attn"], h, window=window)
    x = shard(x + a, "batch", None, "embed")
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        f, aux = moe_ffn(cfg, p["moe"], h)
    else:
        f, aux = layers.swiglu_apply(p["mlp"], h, cfg.cdtype), jnp.zeros((), jnp.float32)
    return shard(x + f, "batch", None, "embed"), aux


def layer_decode(cfg: ModelConfig, p, cache, x, pos, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, cache = mla.decode(cfg, p["attn"], cache, h, pos)
    else:
        a, cache = attention.decode(cfg, p["attn"], cache, h, pos, window=window)
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        f, _ = moe_ffn(cfg, p["moe"], h)
    else:
        f = layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return x + f, cache


def layer_cache_shape(cfg: ModelConfig, kind, window, batch, seq_len):
    if cfg.use_mla:
        return mla.cache_shape(cfg, batch, seq_len)
    return attention.cache_shape(cfg, batch, seq_len, window)


def layer_cache_specs(cfg: ModelConfig, kind):
    return mla.cache_specs(cfg) if cfg.use_mla else attention.cache_specs(cfg)


def init_params(cfg: ModelConfig, key) -> dict:
    kh, kl = jax.random.split(key)
    return {"head": head.init(cfg, kh),
            "runs": stack.init_runs(cfg, kl, layer_init)}


def param_specs(cfg: ModelConfig) -> dict:
    return {"head": head.specs(cfg),
            "runs": stack.run_specs(cfg, layer_specs)}


def _hidden(cfg: ModelConfig, params, batch, remat=None):
    x = head.embed(cfg, params["head"], batch["tokens"])
    remat = (cfg.remat != "none") if remat is None else remat
    return stack.apply_runs_aux(cfg, params["runs"], x, layer_apply, remat=remat)


def forward(cfg: ModelConfig, params, batch, *, remat=None):
    x, aux = _hidden(cfg, params, batch, remat)
    lgts = head.logits(cfg, params["head"], x)
    return lgts, {"moe_aux": aux}


def loss_fn(cfg: ModelConfig, params, batch):
    x, aux = _hidden(cfg, params, batch)
    loss = head.chunked_loss(cfg, params["head"], x, batch)
    return loss + aux, {"moe_aux": aux}


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    return stack.cache_shapes(cfg, batch, seq_len, layer_cache_shape)


def cache_specs(cfg: ModelConfig):
    return stack.cache_run_specs(cfg, layer_cache_specs)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq_len))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    x = head.embed(cfg, params["head"], tokens)
    x, cache = stack.decode_runs(cfg, params["runs"], cache, x, pos, layer_decode)
    lgts = head.logits(cfg, params["head"], x)
    return lgts, cache


def layer_prefill(cfg: ModelConfig, p, cache, x, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, cache = mla.prefill(cfg, p["attn"], cache, h)
    else:
        a, cache = attention.prefill(cfg, p["attn"], cache, h, window=window)
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        f, _ = moe_ffn(cfg, p["moe"], h)
    else:
        f = layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return shard(x + f, "batch", None, "embed"), cache


def prefill(cfg: ModelConfig, params, cache, batch):
    x = head.embed(cfg, params["head"], batch["tokens"])
    x, cache = stack.prefill_runs(cfg, params["runs"], cache, x, layer_prefill)
    lgts = head.logits(cfg, params["head"], x)
    return lgts, cache

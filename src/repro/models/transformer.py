"""Decoder-only transformer (families: dense, vlm).

vlm prepends ``num_patches`` precomputed patch embeddings (stub frontend per
assignment) to the token sequence; the LM head/loss cover token positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention, head, layers, stack


# -- per-layer ---------------------------------------------------------------


def layer_init(cfg: ModelConfig, key, kind: str) -> dict:
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "attn": attention.init(cfg, ka),
        "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "mlp": layers.swiglu_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype),
    }


def layer_specs(cfg: ModelConfig, kind: str) -> dict:
    return {
        "ln1": (None,),
        "attn": attention.specs(cfg),
        "ln2": (None,),
        "mlp": layers.swiglu_specs(),
    }


def layer_apply(cfg: ModelConfig, p, x, *, window, kind, positions=None):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attention.apply(cfg, p["attn"], h, window=window, positions=positions)
    x = shard(x, "batch", None, "embed")
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return shard(x, "batch", None, "embed")


def layer_decode(cfg: ModelConfig, p, cache, x, pos, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, cache = attention.decode(cfg, p["attn"], cache, h, pos, window=window)
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return x, cache


def layer_cache_shape(cfg: ModelConfig, kind, window, batch, seq_len):
    return attention.cache_shape(cfg, batch, seq_len, window)


def layer_cache_specs(cfg: ModelConfig, kind):
    return attention.cache_specs(cfg)


# -- model --------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    kh, kl = jax.random.split(key)
    return {"head": head.init(cfg, kh),
            "runs": stack.init_runs(cfg, kl, layer_init)}


def param_specs(cfg: ModelConfig) -> dict:
    return {"head": head.specs(cfg),
            "runs": stack.run_specs(cfg, layer_specs)}


def _embed_inputs(cfg: ModelConfig, params, batch):
    x = head.embed(cfg, params["head"], batch["tokens"])
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(cfg.cdtype)
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _hidden(cfg: ModelConfig, params, batch, remat=None):
    x = _embed_inputs(cfg, params, batch)
    remat = (cfg.remat != "none") if remat is None else remat
    x = stack.apply_runs(cfg, params["runs"], x, layer_apply, remat=remat)
    if cfg.family == "vlm":
        x = x[:, cfg.num_patches:]
    return x


def forward(cfg: ModelConfig, params, batch, *, remat=None):
    """-> (logits over token positions, aux dict)."""
    x = _hidden(cfg, params, batch, remat)
    lgts = head.logits(cfg, params["head"], x)
    return lgts, {}


def loss_fn(cfg: ModelConfig, params, batch):
    x = _hidden(cfg, params, batch)
    return head.chunked_loss(cfg, params["head"], x, batch), {}


# -- decode --------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    return stack.cache_shapes(cfg, batch, seq_len, layer_cache_shape)


def cache_specs(cfg: ModelConfig):
    return stack.cache_run_specs(cfg, layer_cache_specs)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq_len))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens: (B, 1); pos: (B,) absolute positions. -> (logits, cache)."""
    x = head.embed(cfg, params["head"], tokens)
    x, cache = stack.decode_runs(cfg, params["runs"], cache, x, pos, layer_decode)
    lgts = head.logits(cfg, params["head"], x)
    return lgts, cache


def layer_prefill(cfg: ModelConfig, p, cache, x, *, window, kind):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, cache = attention.prefill(cfg, p["attn"], cache, h, window=window)
    x = shard(x + a, "batch", None, "embed")
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + layers.swiglu_apply(p["mlp"], h, cfg.cdtype)
    return shard(x, "batch", None, "embed"), cache


def prefill(cfg: ModelConfig, params, cache, batch):
    """Batched prefill from position 0: forward + cache fill.
    For vlm, patch embeddings occupy positions [0, num_patches)."""
    x = _embed_inputs(cfg, params, batch)
    x, cache = stack.prefill_runs(cfg, params["runs"], cache, x, layer_prefill)
    if cfg.family == "vlm":
        x = x[:, cfg.num_patches:]
    lgts = head.logits(cfg, params["head"], x)
    return lgts, cache

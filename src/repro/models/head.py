"""Embedding / LM-head helpers shared by all families."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers


def init(cfg: ModelConfig, key) -> dict:
    ke, kh = jax.random.split(key)
    p = {"embed": layers.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.pdtype),
         "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.vocab_size, cfg.pdtype)
    return p


def specs(cfg: ModelConfig) -> dict:
    s = {"embed": ("vocab", "embed"), "final_norm": (None,)}
    if not cfg.tie_embeddings:
        s["lm_head"] = ("embed", "vocab")
    return s


def embed(cfg: ModelConfig, p, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = x * math.sqrt(cfg.d_model)
    return shard(x, "batch", None, "embed")


def logits(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    x = layers.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    out = jnp.einsum("bsd,dv->bsv", x, w.astype(cfg.cdtype))
    out = layers.softcap(out, cfg.final_logit_softcap)
    return shard(out, "batch", None, "vocab")


def loss_from_logits(lgts: jax.Array, batch: dict) -> jax.Array:
    return layers.cross_entropy(lgts, batch["targets"], batch.get("loss_mask"))


def chunked_loss(cfg: ModelConfig, p, x: jax.Array, batch: dict,
                 chunk: int = 512) -> jax.Array:
    """CE without ever materializing full-sequence logits.

    Scans the LM head over sequence chunks (checkpointed, so the backward
    recomputes each chunk's logits).  At 262k vocab the full fp32 logits for
    a 4k x 16 per-device slab are ~17 GB; chunked they are ~0.5 GB.
    """
    s = x.shape[1]
    targets, mask = batch["targets"], batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    x = layers.rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]

    cb = min(chunk, s)
    while s % cb != 0:
        cb -= 1
    n = s // cb

    @jax.checkpoint
    def body(carry, i):
        nll_sum, msum = carry
        xc = jax.lax.dynamic_slice_in_dim(x, i * cb, cb, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * cb, cb, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * cb, cb, axis=1)
        lg = jnp.einsum("bsd,dv->bsv", xc, w.astype(cfg.cdtype))
        lg = layers.softcap(lg, cfg.final_logit_softcap)
        lg = shard(lg, "batch", None, "vocab").astype(jnp.float32)
        v = lg.shape[-1]
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = (tc[..., None] == jnp.arange(v, dtype=tc.dtype)).astype(jnp.float32)
        gold = jnp.sum(lg * onehot, axis=-1)
        nll = (lse - gold) * mc
        return (nll_sum + nll.sum(), msum + mc.sum()), None

    if n == 1:
        (nll_sum, msum), _ = body((jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                                  jnp.asarray(0))
    else:
        (nll_sum, msum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(n))
    return nll_sum / jnp.maximum(msum, 1.0)

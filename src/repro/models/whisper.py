"""Whisper-style encoder-decoder (audio frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, frames, d) — per assignment).

Absolute sinusoidal positions (parameter-free, so cache/params are
sequence-length agnostic), bidirectional encoder, causal decoder with
cross-attention.  No RoPE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import div_axis, shard
from repro.models import head, layers
from repro.models.layers import NEG_INF


# -- small building blocks ----------------------------------------------------


def _attn_init(cfg, key, kv_dim=None):
    h, d = cfg.num_heads, cfg.head_dim
    kv_dim = kv_dim or cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(kq, cfg.d_model, (h, d), cfg.pdtype),
        "wk": layers.dense_init(kk, kv_dim, (h, d), cfg.pdtype),
        "wv": layers.dense_init(kv, kv_dim, (h, d), cfg.pdtype),
        "wo": layers.dense_init(ko, h * d, cfg.d_model, cfg.pdtype).reshape(h, d, cfg.d_model),
    }


def _attn_specs(cfg):
    qh = div_axis("heads", cfg.num_heads)
    return {"wq": ("embed", qh, None), "wk": ("embed", qh, None),
            "wv": ("embed", qh, None), "wo": (qh, None, "embed")}


def _mlp_init(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"w1": layers.dense_init(k1, cfg.d_model, cfg.d_ff, cfg.pdtype),
            "w2": layers.dense_init(k2, cfg.d_ff, cfg.d_model, cfg.pdtype)}


def _mlp_specs():
    return {"w1": ("embed", "ffn"), "w2": ("ffn", "embed")}


def _mlp(p, x, cd):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(cd)))
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(cd))


def _proj_qkv(cfg, p, xq, xkv):
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(cd))
    return q, k, v


def _attn(cfg, p, xq, xkv, *, causal):
    q, k, v = _proj_qkv(cfg, p, xq, xkv)
    out = layers.attention(q, k, v, causal=causal, window=None,
                           q_block=min(512, q.shape[1]))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))


# -- layers --------------------------------------------------------------------


def enc_layer_init(cfg, key):
    ka, km = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.pdtype), "attn": _attn_init(cfg, ka),
            "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype), "mlp": _mlp_init(cfg, km)}


def dec_layer_init(cfg, key):
    ka, kc, km = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.pdtype), "self": _attn_init(cfg, ka),
            "lnx": jnp.zeros((cfg.d_model,), cfg.pdtype), "cross": _attn_init(cfg, kc),
            "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype), "mlp": _mlp_init(cfg, km)}


def enc_layer_specs(cfg):
    return {"ln1": (None,), "attn": _attn_specs(cfg), "ln2": (None,), "mlp": _mlp_specs()}


def dec_layer_specs(cfg):
    return {"ln1": (None,), "self": _attn_specs(cfg), "lnx": (None,),
            "cross": _attn_specs(cfg), "ln2": (None,), "mlp": _mlp_specs()}


def _stack(n):
    def deco(f):
        return f
    return deco


def init_params(cfg: ModelConfig, key) -> dict:
    kh, ke, kd = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: enc_layer_init(cfg, k))(jax.random.split(ke, cfg.num_encoder_layers))
    dec = jax.vmap(lambda k: dec_layer_init(cfg, k))(jax.random.split(kd, cfg.num_layers))
    return {"head": head.init(cfg, kh), "enc": enc, "dec": dec,
            "enc_norm": jnp.zeros((cfg.d_model,), cfg.pdtype)}


def param_specs(cfg: ModelConfig) -> dict:
    add_l = lambda tree: jax.tree.map(lambda s: ("layers", *s), tree,
                                      is_leaf=lambda l: isinstance(l, tuple))
    return {"head": head.specs(cfg), "enc": add_l(enc_layer_specs(cfg)),
            "dec": add_l(dec_layer_specs(cfg)), "enc_norm": (None,)}


def encode(cfg: ModelConfig, params, enc_embeds):
    x = enc_embeds.astype(cfg.cdtype)
    x = x + layers.sinusoidal_pos(x.shape[1], cfg.d_model).astype(cfg.cdtype)
    x = shard(x, "batch", None, "embed")

    def body(xl, p):
        h = layers.rmsnorm(xl, p["ln1"], cfg.norm_eps)
        xl = xl + _attn(cfg, p["attn"], h, h, causal=False)
        h = layers.rmsnorm(xl, p["ln2"], cfg.norm_eps)
        return shard(xl + _mlp(p["mlp"], h, cfg.cdtype), "batch", None, "embed"), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _hidden(cfg: ModelConfig, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    tokens = batch["tokens"]
    x = head.embed(cfg, params["head"], tokens)
    x = x + layers.sinusoidal_pos(x.shape[1], cfg.d_model).astype(cfg.cdtype)

    def body(xl, p):
        h = layers.rmsnorm(xl, p["ln1"], cfg.norm_eps)
        xl = xl + _attn(cfg, p["self"], h, h, causal=True)
        h = layers.rmsnorm(xl, p["lnx"], cfg.norm_eps)
        xl = xl + _attn(cfg, p["cross"], h, enc_out, causal=False)
        h = layers.rmsnorm(xl, p["ln2"], cfg.norm_eps)
        return shard(xl + _mlp(p["mlp"], h, cfg.cdtype), "batch", None, "embed"), None

    body_fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    return x


def forward(cfg: ModelConfig, params, batch, *, remat=None):
    return head.logits(cfg, params["head"], _hidden(cfg, params, batch)), {}


def loss_fn(cfg: ModelConfig, params, batch):
    x = _hidden(cfg, params, batch)
    return head.chunked_loss(cfg, params["head"], x, batch), {}


# -- decode ----------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int):
    h, d = cfg.num_heads, cfg.head_dim
    L, f = cfg.num_layers, cfg.encoder_seq
    kv = lambda t: jax.ShapeDtypeStruct((L, batch, t, h, d), cfg.cdtype)
    return {"self_k": kv(seq_len), "self_v": kv(seq_len),
            "cross_k": kv(f), "cross_v": kv(f)}


def cache_specs(cfg: ModelConfig):
    qh = div_axis("heads", cfg.num_heads)
    s = ("layers", "batch", None, qh, None)
    return {"self_k": s, "self_v": s, "cross_k": s, "cross_v": s}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq_len))


def prefill_cross(cfg: ModelConfig, params, cache, enc_embeds):
    """Encode audio and fill the cross-attention KV cache."""
    enc_out = encode(cfg, params, enc_embeds)

    def body(_, p):
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"].astype(cfg.cdtype))
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"].astype(cfg.cdtype))
        return None, (ck, cv)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec"])
    return {**cache, "cross_k": ck, "cross_v": cv}


def prefill(cfg: ModelConfig, params, cache, batch):
    """Encode audio, fill cross KV, and prefill the decoder self-cache with
    the prompt tokens (positions [0, S))."""
    cache = prefill_cross(cfg, params, cache, batch["enc_embeds"])
    enc_k, enc_v = cache["cross_k"], cache["cross_v"]
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = head.embed(cfg, params["head"], tokens)
    x = x + layers.sinusoidal_pos(s, cfg.d_model).astype(cfg.cdtype)
    t = cache["self_k"].shape[2]

    def body(xl, xs):
        p, ck, cv = xs
        h = layers.rmsnorm(xl, p["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(cfg, p["self"], h, h)
        a = layers.attention(q, k, v, causal=True, window=None,
                             q_block=min(512, s))
        xl = xl + jnp.einsum("bshk,hkd->bsd", a, p["self"]["wo"].astype(cfg.cdtype))
        h = layers.rmsnorm(xl, p["lnx"], cfg.norm_eps)
        xl = xl + _attn_kv(cfg, p["cross"], h, ck, cv)
        h = layers.rmsnorm(xl, p["ln2"], cfg.norm_eps)
        sk = jnp.zeros((xl.shape[0], t, cfg.num_heads, cfg.head_dim), cfg.cdtype)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k[:, :t], 0, axis=1)
        sv = jnp.zeros_like(sk)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v[:, :t], 0, axis=1)
        return xl + _mlp(p["mlp"], h, cfg.cdtype), (sk, sv)

    x, (sk, sv) = jax.lax.scan(body, x, (params["dec"], enc_k, enc_v))
    lgts = head.logits(cfg, params["head"], x)
    return lgts, {**cache, "self_k": sk, "self_v": sv}


def _attn_kv(cfg, p, xq, k, v):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cfg.cdtype))
    out = layers.attention(q, k, v, causal=False, window=None,
                           q_block=min(512, q.shape[1]))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.cdtype))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens (B,1); pos (B,). Self-cache updated; cross-cache read-only."""
    b = tokens.shape[0]
    x = head.embed(cfg, params["head"], tokens)
    pe = layers.sinusoidal_pos(cache["self_k"].shape[2], cfg.d_model).astype(cfg.cdtype)
    x = x + pe[pos][:, None, :]
    bidx = jnp.arange(b)
    t = cache["self_k"].shape[2]
    key_mask = jnp.arange(t)[None, :] <= pos[:, None]

    def body(xl, xs):
        p, sk, sv, ck, cv = xs
        h = layers.rmsnorm(xl, p["ln1"], cfg.norm_eps)
        q, k_new, v_new = _proj_qkv(cfg, p["self"], h, h)
        sk = sk.at[bidx, pos].set(k_new[:, 0])
        sv = sv.at[bidx, pos].set(v_new[:, 0])
        scores = layers._gqa_scores(q, sk, None)
        scores = jnp.where(key_mask[:, None, None, None, :], scores, NEG_INF)
        a = layers._gqa_out(jax.nn.softmax(scores, axis=-1), sv).astype(cfg.cdtype)
        xl = xl + jnp.einsum("bshk,hkd->bsd", a, p["self"]["wo"].astype(cfg.cdtype))
        h = layers.rmsnorm(xl, p["lnx"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(cfg.cdtype))
        scores = layers._gqa_scores(q, ck, None)
        a = layers._gqa_out(jax.nn.softmax(scores, axis=-1), cv).astype(cfg.cdtype)
        xl = xl + jnp.einsum("bshk,hkd->bsd", a, p["cross"]["wo"].astype(cfg.cdtype))
        h = layers.rmsnorm(xl, p["ln2"], cfg.norm_eps)
        return xl + _mlp(p["mlp"], h, cfg.cdtype), (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    lgts = head.logits(cfg, params["head"], x)
    return lgts, {**cache, "self_k": sk, "self_v": sv}

"""AdamW from scratch with ZeRO-1-style sharded moments.

Moments are stored fp32 and — on top of the parameter's own tensor-parallel
sharding — sharded along the data axis on the first unsharded dimension that
divides evenly ("opt_state" logical axis).  Parameters stay replicated across
data; XLA inserts the dynamic-slice before the moment update and the
all-gather after the parameter delta, which is exactly the ZeRO-1 collective
schedule.  Gradient all-reduces happen in bf16 because parameters are cast to
the compute dtype at their use sites (the reduction attaches to the bf16
tensor's cotangent) — the framework's gradient-compression default.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import mesh_axis_size


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _moment_spec(param_spec: Optional[tuple], shape: tuple) -> Optional[tuple]:
    """Add 'opt_state' (data-axis) sharding on the first free, divisible dim."""
    if param_spec is None:
        param_spec = (None,) * len(shape)
    n = mesh_axis_size("opt_state")
    out = list(param_spec)
    # if the param is already FSDP-sharded over data, moments follow it as-is
    if n > 1 and "fsdp" not in param_spec and "opt_state" not in param_spec:
        for i, (ax, dim) in enumerate(zip(param_spec, shape)):
            if ax is None and dim % n == 0 and dim >= n:
                out[i] = "opt_state"
                break
    return tuple(out)


def opt_specs(param_spec_tree, param_shape_tree) -> dict:
    """Logical spec tree for the optimizer state (same structure as params)."""
    is_spec = lambda l: l is None or isinstance(l, tuple)
    mspec = jax.tree.map(
        lambda sp, sh: _moment_spec(sp, sh.shape),
        param_spec_tree, param_shape_tree, is_leaf=is_spec)
    return {"m": mspec, "v": mspec, "step": None}


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_update(cfg: AdamWConfig, grads, params, state):
    """-> (new_params, new_state, lr).  Decoupled weight decay; bias-corrected."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm

"""Step-atomic checkpointing (fault tolerance).

Layout:  <dir>/step_0000100/   arrays.npz-style per-leaf .npy + meta.json
Writes go to a tmp dir and are renamed into place (atomic on POSIX), so a
crash mid-save never corrupts the latest checkpoint.  ``restore_latest``
skips incomplete checkpoints.  ``keep`` bounds disk usage.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

_SENTINEL = "COMMITTED"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names = []
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"{name}.npy"), arr)
        names.append(name)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "leaves": names, "extra": extra or {}}, f)
    with open(os.path.join(tmp, _SENTINEL), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _complete(path: str) -> bool:
    return os.path.exists(os.path.join(path, _SENTINEL))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and _complete(os.path.join(ckpt_dir, d)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like):
    """Restore into the structure (and shardings, if any) of *tree_like*."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not _complete(path):
        raise FileNotFoundError(f"incomplete/missing checkpoint {path}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    arrays = {}
    for name in meta["leaves"]:
        arrays[name] = np.load(os.path.join(path, f"{name}.npy"))
    flat = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for pathk, leaf in flat[0]:
        name = "_".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in pathk)
        arr = arrays[name]
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            leaves.append(jax.device_put(arr, leaf.sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat[1], leaves), meta["extra"]


def restore_latest(ckpt_dir: str, tree_like):
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, extra = restore(ckpt_dir, step, tree_like)
    return step, tree, extra

from repro.training.optimizer import adamw_init, adamw_update, opt_specs  # noqa: F401
from repro.training.train_step import TrainConfig, make_train_step, train_step  # noqa: F401

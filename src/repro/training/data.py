"""Deterministic, restart-safe synthetic data pipeline.

Batches are a pure function of (seed, step): a restart at step k reproduces
the exact token stream without replaying the first k-1 steps.  Documents with
lognormal lengths are greedily packed into fixed-length rows (pad-free LM
training); the loss mask zeroes cross-document boundaries.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: float = 512.0


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Deterministic batch for *step* (numpy; callers device_put with the
    right sharding)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    b, s = cfg.global_batch, cfg.seq_len
    tokens = rng.integers(1, cfg.vocab_size, size=(b, s + 1), dtype=np.int32)
    # pack documents: sample boundaries, zero loss across them
    mask = np.ones((b, s), np.float32)
    sigma = 0.6
    mu = np.log(cfg.mean_doc_len) - sigma ** 2 / 2
    for i in range(b):
        t = 0
        while t < s:
            doc = max(16, int(rng.lognormal(mu, sigma)))
            end = min(t + doc, s)
            if end < s:
                tokens[i, end] = 0          # document separator
                mask[i, end] = 0.0
            t = end + 1
    return {"tokens": tokens[:, :-1],
            "targets": tokens[:, 1:],
            "loss_mask": mask}


def jax_batch_at(cfg: DataConfig, step: int, extras: dict | None = None) -> dict:
    out = {k: jnp.asarray(v) for k, v in batch_at(cfg, step).items()}
    if extras:
        out.update(extras)
    return out

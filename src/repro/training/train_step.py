"""The jitted training step: loss -> grad -> clip -> AdamW -> metrics."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    grad_clip: float = 1.0
    n_microbatches: int = 1   # gradient accumulation (bounds activation HBM)


def _grad_fn(cfg, params, batch):
    def loss_of(p):
        loss, aux = registry.loss_fn(cfg, p, batch)
        return loss, aux
    return jax.value_and_grad(loss_of, has_aux=True)(params)


def train_step(cfg: ModelConfig, tcfg: TrainConfig, params, opt_state, batch):
    """One optimizer step.  Pure function of (params, opt_state, batch).

    With n_microbatches > 1 the global batch is split along dim 0 and grads
    are accumulated in fp32 over a lax.scan — activation memory scales with
    the microbatch, and the accumulators inherit the parameters' (FSDP)
    sharding.
    """
    n = tcfg.n_microbatches
    if n <= 1:
        (loss, aux), grads = _grad_fn(cfg, params, batch)
    else:
        micro = jax.tree.map(
            lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)

        def micro_step(acc, mb):
            g_acc, l_acc = acc
            (l, _), g = _grad_fn(cfg, params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(
            micro_step, (g0, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n, grads)
        loss = loss / n
        aux = {}

    grads, gnorm = opt.clip_by_global_norm(grads, tcfg.grad_clip)
    params, opt_state, lr = opt.adamw_update(tcfg.adamw, grads, params, opt_state)
    metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm, "lr": lr}
    for k, v in aux.items():
        metrics[f"aux/{k}"] = jnp.asarray(v, jnp.float32)
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    tcfg = tcfg or TrainConfig()

    def step(params, opt_state, batch):
        return train_step(cfg, tcfg, params, opt_state, batch)

    return step

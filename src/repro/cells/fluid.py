"""Fluid multi-cell engine: a leading cell axis in the chunked scan.

Same step math as ``repro.core.simjax`` — LITERALLY the same: every tick
each cell advances through the shared ``_make_step`` tick function (vmapped
over the cell axis, with per-cell state / arrivals / fleet / gap
statistics) — wrapped by the cells layer:

* the ROUTER: incoming per-cell arrivals are redistributed through a
  (C, C) row-stochastic flux matrix (``repro.cells.traffic``) — spill
  overflow to warm siblings, dead-cell traffic to the failover
  distribution.  ``route_skew`` and ``spill_threshold`` ride the traced
  policy params, so they are sweepable batch axes like any other knob.
* FAILOVER: at the (static) failure tick the dying cell's queued and
  in-flight mass re-queues on survivors along the failover distribution;
  from then on its state is alive-masked to zero and its fleet bounds
  collapse to (0, 0), so the dead region bills nothing and contributes
  nothing to the metric sums.
* TRIGGERS: host-precomputed scheduled floors (a (T, C) matrix chunked
  like the arrival tensor) and in-carry reactive threshold floors are
  applied as traced per-cell fleet ``min_nodes`` INSIDE the step — the
  fluid lowering of ``ConvergenceFleetPolicy``.

Accumulation mirrors ``simjax._chunk_impl``: one (F, nbins) delay
histogram summed ACROSS cells (function ids share one id space, so a
function's slowdown mixes its per-cell delay mixtures — exactly how the
oracle's combined record set reads), the 11 scalar sums alive-masked and
cell-summed, the measured-tick counter ``n`` bumped ONCE per tick, plus
per-cell partial sums for the attribution detail (``cell_rows``).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simjax import (_ACC_NAMES, _PFLEET, _acc_summary,
                               _billed_weights, _delay_edges, _init_state,
                               _make_step, _prep_static, stack_params)
from repro.core.policy_api import get_family
from repro.core.trace import gap_statistics, rate_matrix

from repro.cells.topology import CellTopology
from repro.cells.traffic import failover_dist, flux_matrix, spill_fraction


def _cells_chunk_impl(state, arr_chunk, floor_chunk, lam0, gaps, alive_tab,
                      tail_tab, dur, mem, billed_w, pol, fleet, trig,
                      cpu_consts, static_nodes, edges, tick0, *,
                      warm_tick: int, total_ticks: int, family: str,
                      dt: float, cold_ticks: int, wbuf: int, prov_ticks: int,
                      has_fleet: bool, fail_cell: int, fail_tick: int,
                      route_skew_static: float, spill_static: float):
    """One time chunk of the C-cell simulation for ONE parameter point.

    ``state`` is ``(st, fr, ft, tcool)``: the per-cell simulator state
    pytree (every ``simjax._init_state`` leaf with a leading C axis) plus
    the reactive-trigger latches (held floor value / expiry tick / re-arm
    tick, each (C, K)).  ``arr_chunk`` is (T, C, F); ``floor_chunk`` the
    (T, C) scheduled-floor slice; ``trig`` the shared (K,) trigger
    constants (util_high, change, hold_ticks, cool_ticks) or None.
    """
    c_n, f = arr_chunk.shape[1], arr_chunk.shape[2]
    nbins = edges.shape[0] + 1
    has_reactive = trig is not None
    cells_ax = jnp.arange(c_n)
    rows_flat = jnp.tile(jnp.arange(f), c_n)
    # traced router knobs when the family declares them, topology statics
    # otherwise (a non-cells policy family can still run a topology)
    rs = pol["route_skew"] if "route_skew" in pol \
        else jnp.asarray(route_skew_static, jnp.float32)
    thr = pol["spill_threshold"] if "spill_threshold" in pol \
        else jnp.asarray(spill_static, jnp.float32)

    def alive_at(g):
        if fail_cell < 0:
            return jnp.ones(c_n), jnp.zeros(c_n)
        dead = (cells_ax == fail_cell)
        alive = 1.0 - (dead & (g >= fail_tick)).astype(jnp.float32)
        died = (dead & (g == fail_tick)).astype(jnp.float32)
        return alive, died

    def mask_state(st, alive):
        # dtype-preserving alive mask (the window cursor leaf is integer)
        return tuple(
            (x * alive.reshape((c_n,) + (1,) * (x.ndim - 1))).astype(x.dtype)
            for x in st)

    def one_cell(arr_row, st_c, fl_c, l0_c, gp_c, at_c, tt_c, sn_c):
        step = _make_step(arr_row[None, :], dur, mem, billed_w, l0_c, gp_c,
                          (at_c, tt_c), pol, fl_c, cpu_consts, sn_c,
                          family=family, dt=dt, cold_ticks=cold_ticks,
                          wbuf=wbuf, prov_ticks=prov_ticks,
                          has_fleet=has_fleet)
        return step(st_c, 0)

    def acc_step(carry, xs):
        st, fr, ft, tcool, hist, arrtot, sums, n, csums, cn = carry
        a_t, fsched_t, i = xs
        g = tick0 + i
        alive, died = alive_at(g)
        # failover: harvest the dying cell's backlog + in-flight mass
        # BEFORE masking and re-inject it on survivors as retry ARRIVALS
        # at the failure tick (the fluid twin of the oracle's retry
        # re-injection).  Arrival injection — not queue injection — is
        # load-bearing: the delay histogram only records mass that enters
        # through the arrival path, and the retry cohort's post-failover
        # delays are exactly what the oracle's survivor records carry.
        # (The cohort's pre-failure arrival entries stay in the histogram
        # — a forward-only scan cannot retract them the way the oracle
        # drops its ghost records — so the retried share is counted at
        # both its optimistic pre-fail and its true post-fail delay; the
        # measured parity band absorbs this.)
        moved = jnp.einsum("c,cf->f", died, st[1] + st[2])
        st = mask_state(st, alive)
        fail_d = failover_dist(alive, rs)
        # router from previous-tick state
        slots = st[0].sum(-1) * pol["cc"]
        free = jnp.maximum(slots - st[1].sum(-1), 0.0)
        s = spill_fraction(st[2].sum(-1), a_t.sum(-1), slots, thr) * alive
        routed = jnp.einsum("cd,cf->df",
                            flux_matrix(alive, s, free, fail_d), a_t) \
            + fail_d[:, None] * moved[None, :]
        # per-cell fleet bounds: scheduled + reactive floors raise
        # min_nodes; a dead cell's bounds collapse to (0, 0)
        if has_reactive:
            floor_r = jnp.where(g < ft, fr, 0.0).max(axis=1)
        else:
            floor_r = jnp.zeros(c_n)
        if has_fleet:
            min_eff = jnp.maximum(jnp.maximum(fleet[0], fsched_t),
                                  floor_r) * alive
            fleet_cells = jnp.concatenate(
                [min_eff[:, None], (fleet[1] * alive)[:, None],
                 jnp.broadcast_to(fleet[2:], (c_n, fleet.shape[0] - 2))],
                axis=1)
        else:
            fleet_cells = jnp.broadcast_to(fleet, (c_n, fleet.shape[0]))
        st, ys = jax.vmap(one_cell)(routed, st, fleet_cells, lam0, gaps,
                                    alive_tab, tail_tab, static_nodes)
        # reactive triggers read this tick's utilization; the raised floor
        # binds from the NEXT tick (a one-tick actuation lag, matching the
        # oracle's once-per-tick reconcile)
        if has_reactive and has_fleet:
            util_high, change, hold_ticks, cool_ticks = trig
            util = ys[4] / jnp.maximum(ys[10] * fleet[5], 1e-9)
            can = (util[:, None] >= util_high[None, :]) & (g >= tcool) \
                & (alive[:, None] > 0.0)
            fr = jnp.where(can, ys[10][:, None] + change[None, :], fr)
            ft = jnp.where(can, (g + hold_ticks[None, :]).astype(ft.dtype),
                           ft)
            tcool = jnp.where(can,
                              (g + cool_ticks[None, :]).astype(tcool.dtype),
                              tcool)
        # accumulate: histogram mass per (function), scalars alive-masked
        # and cell-summed, n bumped ONCE per tick (not per cell)
        m = ((g >= warm_tick) & (g < total_ticks)).astype(jnp.float32)
        delay, arr, arr_delayed = ys[0], ys[1], ys[2]
        wmask = m * alive[:, None]
        b = jnp.clip(jnp.searchsorted(edges, delay.reshape(-1),
                                      side="right"), 0, nbins - 1)
        hist = hist.at[rows_flat, b].add((arr_delayed * wmask).reshape(-1))
        hist = hist.at[:, 0].add(((arr - arr_delayed) * wmask).sum(0))
        arrtot = arrtot + (arr * wmask).sum(0)
        ysc = jnp.stack(ys[3:3 + len(_ACC_NAMES)]) * alive[None, :]
        return (st, fr, ft, tcool, hist, arrtot,
                sums + m * ysc.sum(-1), n + m, csums + m * ysc.T,
                cn + m * alive), None

    st, fr, ft, tcool = state
    init = (st, fr, ft, tcool, jnp.zeros((f, nbins)), jnp.zeros(f),
            jnp.zeros(len(_ACC_NAMES)), jnp.zeros(()),
            jnp.zeros((c_n, len(_ACC_NAMES))), jnp.zeros(c_n))
    xs = (arr_chunk, floor_chunk, jnp.arange(arr_chunk.shape[0]))
    carry, _ = jax.lax.scan(acc_step, init, xs)
    return carry[:4], carry[4:]


def _cells_chunk_batch_impl(state, arr_chunk, floor_chunk, lam0, gaps,
                            alive_tab, tail_tab, dur, mem, billed_w, pols,
                            fleets, trig, cpu_consts, static_nodes, edges,
                            tick0, **statics):
    """One time chunk for a batch of parameter points (vmap over the point
    axis of state/pols/fleets, every per-cell input shared)."""
    def one(st, p, fl):
        return _cells_chunk_impl(st, arr_chunk, floor_chunk, lam0, gaps,
                                 alive_tab, tail_tab, dur, mem, billed_w,
                                 p, fl, trig, cpu_consts, static_nodes,
                                 edges, tick0, **statics)
    return jax.vmap(one)(state, pols, fleets)


_cells_chunk_batch = partial(jax.jit, static_argnames=(
    "warm_tick", "total_ticks", "family", "dt", "cold_ticks", "wbuf",
    "prov_ticks", "has_fleet", "fail_cell", "fail_tick",
    "route_skew_static", "spill_static"),
    donate_argnums=(0,))(_cells_chunk_batch_impl)


def cells_chunked_summaries(traces, topo: CellTopology, policy, pols,
                            fleets, *, sim, dt: float, num_nodes: int,
                            provision_s: float, has_fleet: bool,
                            chunk_ticks: int, warmup_frac: float = 0.5,
                            nbins: int = 256, billing=None,
                            detail: Optional[dict] = None) -> list:
    """Run a batch of policy/fleet points through the C-cell chunked scan
    and return one ``summarize``-style row per point (the multi-cell twin
    of ``simjax._chunked_summaries``; same metric keys, cross-cell sums).

    ``traces`` is the per-cell partition from ``build_cell_traces`` (one
    ``Trace`` per cell over the SHARED function id space).  When ``detail``
    is a dict it receives ``cell_rows`` — point 0's per-cell attribution
    partials (node-seconds, churn CPU, completions per cell).
    """
    c_n = topo.cell_count
    if len(traces) != c_n:
        raise ValueError(f"got {len(traces)} cell traces for a "
                         f"{c_n}-cell topology")
    if (topo.scheduled or topo.reactive) and not has_fleet:
        raise ValueError("cell triggers drive the node fleet: the scenario "
                         "needs a fleet for scheduled/reactive triggers")
    mats = [np.asarray(rate_matrix(tr, dt)) for tr in traces]
    arr_np = np.stack(mats, axis=1)                     # (T, C, F)
    n_ticks, _, f = arr_np.shape
    duration_s = traces[0].duration_s
    dur, mem, cold_ticks, wbuf, cpu_consts = _prep_static(
        traces[0], policy, sim, dt)
    billed_w = _billed_weights(traces[0], billing)      # profile-wide
    dur_median = np.asarray(traces[0].profile.dur_median)
    dur_sigma = np.asarray(traces[0].profile.dur_sigma)
    prov_ticks = max(1, int(round(provision_s / dt)))
    edges = _delay_edges(nbins)
    edges_j = jnp.asarray(edges)
    warm_tick = int(n_ticks * warmup_frac)
    chunk_ticks = max(1, min(chunk_ticks, n_ticks))
    n_points = fleets.shape[0]

    lam0 = jnp.asarray(np.stack([m.mean(axis=0) / dt for m in mats]),
                       jnp.float32)                     # (C, F)
    gq_l, at_l, tt_l = zip(*(gap_statistics(tr) for tr in traces))
    gaps = jnp.asarray(np.stack(gq_l), jnp.float32)
    alive_tab = jnp.asarray(np.stack(at_l), jnp.float32)
    tail_tab = jnp.asarray(np.stack(tt_l), jnp.float32)

    ft_s = topo.fail_time(duration_s)
    fail_tick = -1 if ft_s is None else int(round(ft_s / dt))
    floor_np = topo.floor_schedule(n_ticks, dt, duration_s)   # (T, C)
    k = len(topo.reactive)
    trig = None
    if k:
        trig = (jnp.asarray([t.util_high for t in topo.reactive],
                            jnp.float32),
                jnp.asarray([t.change for t in topo.reactive], jnp.float32),
                jnp.asarray([max(1, round(t.hold_s / dt))
                             for t in topo.reactive], jnp.float32),
                jnp.asarray([max(1, round(t.cooldown_s / dt))
                             for t in topo.reactive], jnp.float32))
    static_nodes = jnp.asarray(topo.cell_nodes(num_nodes), jnp.float32)

    fleets_j = jnp.asarray(fleets, jnp.float32)
    pols_j = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), pols)

    def init_point(fl):
        def init_cell(sn):
            return _init_state(f, cold_ticks, wbuf, prov_ticks,
                               fl[0] if has_fleet else sn)
        return (jax.vmap(init_cell)(static_nodes), jnp.zeros((c_n, k)),
                jnp.zeros((c_n, k)), jnp.zeros((c_n, k)))

    state = jax.vmap(init_point)(fleets_j)
    hist = np.zeros((n_points, f, nbins))
    arrtot = np.zeros((n_points, f))
    sums = np.zeros((n_points, len(_ACC_NAMES)))
    n = np.zeros(n_points)
    csums = np.zeros((n_points, c_n, len(_ACC_NAMES)))
    cn = np.zeros((n_points, c_n))
    for t0 in range(0, n_ticks, chunk_ticks):
        a = arr_np[t0:t0 + chunk_ticks]
        fl_c = floor_np[t0:t0 + chunk_ticks]
        if a.shape[0] < chunk_ticks:        # pad the tail chunk (masked out)
            pad = chunk_ticks - a.shape[0]
            a = np.concatenate([a, np.zeros((pad, c_n, f), a.dtype)])
            fl_c = np.concatenate([fl_c, np.zeros((pad, c_n), fl_c.dtype)])
        state, out = _cells_chunk_batch(
            state, jnp.asarray(a), jnp.asarray(fl_c), lam0, gaps, alive_tab,
            tail_tab, dur, mem, billed_w, pols_j, fleets_j, trig, cpu_consts,
            static_nodes, edges_j, jnp.asarray(t0, jnp.int32),
            warm_tick=warm_tick, total_ticks=n_ticks, family=policy.family,
            dt=dt, cold_ticks=cold_ticks, wbuf=wbuf, prov_ticks=prov_ticks,
            has_fleet=has_fleet, fail_cell=int(topo.fail_cell),
            fail_tick=fail_tick, route_skew_static=float(topo.route_skew),
            spill_static=float(topo.spill_threshold))
        hist += np.asarray(out[0])
        arrtot += np.asarray(out[1])
        sums += np.asarray(out[2])
        n += np.asarray(out[3])
        csums += np.asarray(out[4])
        cn += np.asarray(out[5])
    iid = get_family(policy.family).synchronous_tail
    rows = [_acc_summary(hist[i], arrtot[i], sums[i], n[i], edges,
                         dur_median, dur_sigma, sim.warm_latency_s, dt,
                         iid_tail=iid)
            for i in range(n_points)]
    if detail is not None:
        detail["cell_rows"] = _cell_rows(csums[0], cn[0], dt)
    return rows


def _cell_rows(csums, cn, dt: float) -> list:
    """Per-cell attribution partials (point 0): where the node-seconds and
    churn CPU of a multi-region run actually accrue — the cells extension
    of the overhead-attribution ledger."""
    out = []
    for c in range(csums.shape[0]):
        s = dict(zip(_ACC_NAMES, csums[c]))
        ticks = max(float(cn[c]), 1e-9)
        out.append({
            "cell": c,
            "ticks_alive": float(cn[c]),
            "instances_mean": float(s["instances"] / ticks),
            "nodes_mean": float(s["nodes"] / ticks),
            "node_seconds": float(s["nodes"] * dt),
            "spot_node_seconds": float(s["spot_nodes"] * dt),
            "creations": float(s["creations"]),
            "completed": float(s["completions"]),
            "cpu_worker_s": float(s["cpu_worker"]),
            "cpu_master_s": float(s["cpu_master"]),
            "cpu_useful_s": float(s["useful"]),
            "billed_gb_s": float(s["billed_gb_s"]),
            "mem_total_mean": float(s["mem_total"] / ticks),
        })
    return out


def run_cells_fluid(sc, traces, sim, *, billing=None,
                    detail: Optional[dict] = None) -> dict:
    """Single-point fluid replay of a cells scenario (the runner's simjax
    leg).  Returns one ``simulate_chunked``-style metric row."""
    policy = sc.policy.to_jax()
    has_fleet = sc.fleet is not None
    pols = stack_params([policy.params()])
    fleets = np.asarray([sc.fleet.params() if has_fleet
                         else np.zeros(len(_PFLEET))], np.float32)
    return cells_chunked_summaries(
        traces, sc.cells, policy, pols, fleets, sim=sim, dt=sim.tick_s,
        num_nodes=sc.num_nodes,
        provision_s=sc.fleet.provision_s if has_fleet else 0.0,
        has_fleet=has_fleet, chunk_ticks=sc.chunk_ticks,
        billing=billing, detail=detail)[0]

"""Oracle multi-cell engine: per-cell ``EventSim`` replicas + failover.

Each cell runs the FULL discrete simulator on its partition of the trace —
its own ``Cluster``, its own node fleet reconciled by
``ConvergenceFleetPolicy`` (utilization + scheduled + reactive desired
state, feeding ``NodeFleet``'s per-source scale-down cooldowns), its own
seeded spot market when the policy declares the spot axes.  The cells
layer wires them together:

* FAILOVER — the failed cell's simulation is truncated at the failure
  time (``duration_s = t_fail``: ticks, sampling and billing stop there,
  while the event heap drains so every accepted request still resolves).
  Requests still in flight at ``t_fail`` are harvested as RETRIES — their
  records are dropped from the dead cell (and their useful CPU backed
  out, since the work re-executes) and they restart from scratch on
  survivors at ``t_fail``.  Post-failure arrivals of the dead partition
  redirect the same way.  Both redistribute along the seeded failover
  distribution (``repro.cells.traffic.failover_dist_np``) — the discrete
  twin of the fluid engine's dead-row flux.
* CORRELATED HAZARD — ``CorrelatedSpotMarket`` splits each cell's spot
  reclaim hazard into a SHARED storm process (one coin per poll time,
  common to all cells: when it fires, every polled spot node in every
  cell is reclaimed together — the cross-region capacity storm) and an
  independent per-node remainder, keeping the total per-node hazard equal
  to the configured rate so the mean-field (fluid) lowering is unchanged.

The per-cell ``SimResult``s are combined into one (record concatenation,
counter sums, zero-padded elementwise sample sums) so ``compute`` and
``bill_sim`` read a multi-region run exactly like a single-cluster one.

NOT modelled here: spill routing (the fluid router's overflow flux).  The
oracle routes by origin weight + failover only; parity scenarios run with
``spill_threshold = 0`` and EXPERIMENTS.md flags spill as fluid-only.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig, SimResult
from repro.core.metrics import compute
from repro.core.trace import Trace
from repro.fleet.billing import bill_sim
from repro.fleet.nodes import NodeFleet
from repro.fleet.spot import CapacityTier, SpotMarket, SpotNodeFleet

from repro.cells.topology import CellTopology
from repro.cells.traffic import failover_dist_np
from repro.cells.triggers import ConvergenceFleetPolicy

_FAILOVER_SALT = 0xFA110373
_STORM_SALT = 0x570A11ED


class SharedStorm:
    """The correlated component of a multi-cell spot hazard: one seeded
    coin per poll time, shared by every cell's market.  ``active`` is
    memoized on the poll time so all cells polling the same reconcile tick
    see the same storm decision."""

    def __init__(self, hazard_per_hour: float, corr: float, seed: int = 0):
        self.rate_s = corr * hazard_per_hour / 3600.0
        self.rng = np.random.default_rng(seed)
        self._events: dict = {}

    def active(self, t: float, dt: float) -> bool:
        key = round(float(t), 6)
        if key not in self._events:
            p = -math.expm1(-self.rate_s * dt)
            self._events[key] = bool(self.rng.uniform() < p)
        return self._events[key]


class CorrelatedSpotMarket(SpotMarket):
    """``SpotMarket`` with its hazard split ``corr`` shared / ``1 - corr``
    independent.  A shared-storm poll reclaims EVERY polled node (the
    fleet-wide eviction storm); otherwise each node faces the thinned
    private hazard.  Total per-node reclaim probability per interval stays
    ``1 - exp(-hazard * dt)`` to first order, so the fluid engine's
    mean-field eviction flux needs no change."""

    def __init__(self, tier: CapacityTier, seed: int = 0,
                 storm: Optional[SharedStorm] = None, corr: float = 0.0):
        super().__init__(tier, seed=seed)
        self.storm = storm
        self.corr = corr

    def preempted(self, t, nodes):
        dt = 0.0 if self._last_poll is None else max(t - self._last_poll, 0.0)
        self._last_poll = t
        if dt <= 0.0 or self.tier.hazard_per_hour <= 0.0 or not nodes:
            return []
        if self.storm is not None and self.storm.active(t, dt):
            return list(nodes)
        p = -math.expm1(-(1.0 - self.corr)
                        * self.tier.hazard_per_hour / 3600.0 * dt)
        return [n for n in nodes if self.rng.uniform() < p]


def _cell_fleet(jf, spec, topo: CellTopology, cell: int, duration_s: float,
                seed: int, storm: Optional[SharedStorm]) -> NodeFleet:
    """Lower the traced fleet parameters to one cell's oracle fleet — the
    cells variant of ``runner._oracle_fleet``, with the utilization policy
    replaced by the trigger-aware convergence reconciler."""
    from repro.scenarios.runner import _spot_knobs, oracle_node_type
    nt = oracle_node_type(jf)
    policy = ConvergenceFleetPolicy(
        min_nodes=int(jf.min_nodes), max_nodes=int(jf.max_nodes),
        util_target=jf.util_target, warm_frac=jf.warm_frac,
        schedule=topo.schedule_entries(cell, duration_s),
        reactive=topo.reactive)
    sf, hz = _spot_knobs(spec) if spec is not None else (0.0, 0.0)
    if sf > 0.0 or hz > 0.0:
        tier = CapacityTier("spot", hazard_per_hour=hz,
                            reclaim_notice_s=jf.reclaim_notice_s)
        if storm is not None and topo.hazard_corr > 0.0:
            market = CorrelatedSpotMarket(tier, seed=seed, storm=storm,
                                          corr=topo.hazard_corr)
        else:
            market = SpotMarket(tier, seed=seed)
        return SpotNodeFleet(policy, node_type=nt, cooldown_s=jf.cooldown_s,
                             spot_fraction=sf, market=market)
    return NodeFleet(policy, node_type=nt, cooldown_s=jf.cooldown_s)


def _run_cell(sc, trace: Trace, sim: SimConfig, topo: CellTopology,
              cell: int, duration_s: float, warmup_s: float,
              storm: Optional[SharedStorm]) -> SimResult:
    """One cell's EventSim pass.  ``duration_s`` is the GLOBAL horizon
    (schedule windows are fractions of it, even when this cell's trace is
    truncated); ``warmup_s`` pins the global measure-from so a truncated
    cell measures [warmup, t_fail) rather than half its own horizon."""
    cfg = dataclasses.replace(sim, warmup_s=warmup_s,
                              seed=sim.seed + 101 * cell)
    if sc.fleet is not None:
        cluster = Cluster(max(1, int(sc.fleet.min_nodes)),
                          node_memory_mb=sc.fleet.node_memory_mb)
        fleet = _cell_fleet(sc.fleet, sc.policy, topo, cell, duration_s,
                            seed=cfg.seed, storm=storm)
    else:
        cluster = Cluster(int(topo.cell_nodes(sc.num_nodes)[cell]))
        fleet = None
    return EventSim(trace, cluster, sc.policy.factory(), cfg,
                    fleet=fleet).run()


def _pad_sum(arrays) -> np.ndarray:
    arrays = [np.asarray(a, np.float64) for a in arrays]
    out = np.zeros(max(len(a) for a in arrays))
    for a in arrays:
        out[:len(a)] += a
    return out


def _combine(results: list, measure_window_s: float) -> SimResult:
    """Merge per-cell SimResults into one: records concatenate (shared
    function-id space), counters sum, sample series zero-pad to the
    longest cell and sum elementwise (a dead cell simply stops
    contributing after its last sample)."""
    longest = max(results, key=lambda r: len(r.sample_times))
    return SimResult(
        records=[r for res in results for r in res.records],
        creations=sum(r.creations for r in results),
        teardowns=sum(r.teardowns for r in results),
        cpu_useful_s=sum(r.cpu_useful_s for r in results),
        cpu_worker_overhead_s=sum(r.cpu_worker_overhead_s for r in results),
        cpu_master_overhead_s=sum(r.cpu_master_overhead_s for r in results),
        mem_samples_total_mb=_pad_sum([r.mem_samples_total_mb
                                       for r in results]),
        mem_samples_busy_mb=_pad_sum([r.mem_samples_busy_mb
                                      for r in results]),
        sample_times=np.asarray(longest.sample_times).copy(),
        measure_window_s=measure_window_s,
        dropped=sum(r.dropped for r in results),
        node_seconds=sum(r.node_seconds for r in results),
        node_samples=_pad_sum([r.node_samples for r in results]),
        node_provisions=sum(r.node_provisions for r in results),
        node_terminations=sum(r.node_terminations for r in results),
        nodes_hint=sum(r.nodes_hint for r in results),
        spot_node_seconds=sum(r.spot_node_seconds for r in results),
        node_evictions=sum(r.node_evictions for r in results),
        mem_samples_starting_mb=_pad_sum([r.mem_samples_starting_mb
                                          for r in results]),
        cpu_churn_creation_s=sum(r.cpu_churn_creation_s for r in results),
        cpu_evict_storm_s=sum(r.cpu_evict_storm_s for r in results),
        cpu_keepalive_idle_s=sum(r.cpu_keepalive_idle_s for r in results))


def run_cells_eventsim(sc, traces, sim: SimConfig, *,
                       detail: Optional[dict] = None,
                       billing=None) -> dict:
    """Run a cells scenario through per-cell EventSims and return one
    combined metric row (the multi-region twin of ``runner._run_eventsim``).

    ``traces`` is the per-cell partition from ``build_cell_traces``.  When
    ``detail`` is a dict it receives ``oracle_result`` (the combined
    ``SimResult``) and ``cell_results`` (the per-cell list, failback
    adjustments applied)."""
    topo = sc.cells
    c_n = topo.cell_count
    duration = float(traces[0].duration_s)
    warmup = sim.warmup_s if sim.warmup_s is not None else duration / 2.0
    t_fail = topo.fail_time(duration)
    extra = dict(sc.policy.extra or {})
    route_skew = float(extra.get("route_skew", topo.route_skew))

    storm = None
    if sc.fleet is not None and topo.hazard_corr > 0.0:
        from repro.scenarios.runner import _spot_knobs
        _, hz = _spot_knobs(sc.policy)
        if hz > 0.0:
            storm = SharedStorm(hz, topo.hazard_corr,
                                seed=sim.seed ^ _STORM_SALT)

    cell_traces = list(traces)
    results: list = [None] * c_n
    if t_fail is not None:
        fc = topo.fail_cell
        tr = traces[fc]
        pre = tr.t < t_fail
        dead_trace = Trace(tr.t[pre], tr.fn[pre].astype(np.int32),
                           tr.dur[pre], tr.profile, t_fail)
        res = _run_cell(sc, dead_trace, sim, topo, fc, duration, warmup,
                        storm)
        # in flight at t_fail: these completed only in the drain — the
        # region died under them, so they re-execute on survivors (their
        # useful CPU is backed out here and re-earned there)
        ghosts = [r for r in res.records if r.end > t_fail]
        results[fc] = dataclasses.replace(
            res, records=[r for r in res.records if r.end <= t_fail],
            cpu_useful_s=res.cpu_useful_s - sum(g.dur for g in ghosts))
        # redirect retries (restarting at t_fail) + the dead partition's
        # post-failure arrivals along the failover distribution
        alive = np.ones(c_n)
        alive[fc] = 0.0
        dist = failover_dist_np(alive, route_skew)
        rng = np.random.default_rng((sim.seed << 1) ^ _FAILOVER_SALT)
        post = ~pre
        r_t = np.concatenate([np.full(len(ghosts), t_fail), tr.t[post]])
        r_fn = np.concatenate([np.asarray([g.fn for g in ghosts], np.int64),
                               tr.fn[post]]).astype(np.int32)
        r_dur = np.concatenate([np.asarray([g.dur for g in ghosts]),
                                tr.dur[post]])
        assign = rng.choice(c_n, size=len(r_t), p=dist)
        for d in range(c_n):
            if d == fc:
                continue
            sel = assign == d
            base = traces[d]
            t2 = np.concatenate([base.t, r_t[sel]])
            order = np.argsort(t2, kind="stable")
            cell_traces[d] = Trace(
                t2[order],
                np.concatenate([base.fn, r_fn[sel]])[order].astype(np.int32),
                np.concatenate([base.dur, r_dur[sel]])[order],
                base.profile, duration)

    for c in range(c_n):
        if results[c] is None:
            results[c] = _run_cell(sc, cell_traces[c], sim, topo, c,
                                   duration, warmup, storm)

    combined = _combine(results, max(duration - warmup, 1e-9))
    if detail is not None:
        detail["oracle_result"] = combined
        detail["cell_results"] = results
    row = compute(combined).row()
    if billing is not None:
        from repro.scenarios.runner import _billing_node_type
        row.update(bill_sim(combined, traces[0], billing,
                            node_type=_billing_node_type(sc)).row())
    return row

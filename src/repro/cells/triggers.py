"""Desired-state convergence: the trigger-driven fleet policy of a cell.

``ConvergenceFleetPolicy`` is the oracle-side reconciler of the cells
trigger layer (otter-style): every tick it converges the cell's node count
toward the MAX of three desired-state sources —

* the utilization reconciler (bit-for-bit ``UtilizationFleetPolicy``
  arithmetic: ceil(used / (util_target * node_mem)) plus warm headroom),
* active *scheduled* floors (cron/at pre-provisioning windows, lowered
  from ``CellTopology.schedule_entries`` to absolute (start, end, floor)
  triples),
* held *reactive* floors (utilization-threshold triggers that latch
  ``nodes_now + change`` for ``hold_s`` and re-arm after ``cooldown_s``).

Whichever source binds is exported as ``last_source`` (with the trigger's
own ``last_cooldown_s`` when a reactive trigger binds), which
``repro.fleet.nodes.NodeFleet`` keys its per-source scale-down cooldown
clocks on — two triggers with different cooldowns never suppress each
other's scale-downs.

The fluid twin integrates the same three sources as traced per-cell fleet
floors inside the chunked scan (``repro.cells.fluid``); the parity tests
pin that both lowerings of one ``CellTopology`` agree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.fleet.policies import FleetPolicy

from repro.cells.topology import ReactiveTrigger


@dataclasses.dataclass
class ConvergenceFleetPolicy(FleetPolicy):
    util_target: float = 0.7
    warm_frac: float = 0.25
    #: absolute (start_s, end_s, floor) scheduled windows for THIS cell
    schedule: Tuple[Tuple[float, float, int], ...] = ()
    reactive: Tuple[ReactiveTrigger, ...] = ()
    #: which desired-state source bound last tick (None = utilization /
    #: schedule path) — the per-source scale-down cooldown key NodeFleet
    #: reads; a binding reactive trigger also exports its own cooldown
    last_source: Optional[str] = dataclasses.field(default=None, repr=False)
    last_cooldown_s: Optional[float] = dataclasses.field(default=None,
                                                         repr=False)
    # reactive trigger state: next allowed fire time and the held
    # (floor, expires_at) latch, both keyed by trigger name
    _rearm_at: dict = dataclasses.field(default_factory=dict, repr=False)
    _held: dict = dataclasses.field(default_factory=dict, repr=False)

    def desired(self, t: float, used_mb: float, node_memory_mb: float,
                nodes_now: int) -> int:
        # utilization reconciler: EXACTLY UtilizationFleetPolicy's math so
        # a trigger-free convergence policy is that policy bit-for-bit
        needed = math.ceil(used_mb / (self.util_target * node_memory_mb)
                           - 1e-9)
        warm = math.ceil(self.warm_frac * max(needed, 1) - 1e-9)
        want, source, cool = needed + warm, None, None
        for start_s, end_s, floor in self.schedule:
            if start_s <= t < end_s and floor > want:
                want, source, cool = floor, "schedule", None
        if self.reactive:
            util = used_mb / max(nodes_now * node_memory_mb, 1e-9)
            for trig in self.reactive:
                held = self._held.get(trig.name)
                if held is not None and t >= held[1]:
                    del self._held[trig.name]
                    held = None
                if util >= trig.util_high \
                        and t >= self._rearm_at.get(trig.name, -math.inf):
                    self._rearm_at[trig.name] = t + trig.cooldown_s
                    held = (nodes_now + trig.change, t + trig.hold_s)
                    self._held[trig.name] = held
                if held is not None and held[0] > want:
                    want, source, cool = held[0], trig.name, trig.cooldown_s
        # never scale below what current usage physically occupies
        want = max(want, math.ceil(used_mb / node_memory_mb - 1e-9))
        self.last_source, self.last_cooldown_s = source, cool
        return self.clamp(want)

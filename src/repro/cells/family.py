"""The ``cells`` policy family: spot-aware scaling + the routing axes.

``CellsFamily`` extends ``SpotAwareFamily`` the same way that family
extends plain sync-keepalive: the per-function scaling DECISION is
inherited unchanged (keepalive expiry + spot headroom), while the new axes
are ENGINE-level knobs the multi-region machinery reads —

* ``cell_count``      — how many regional cells the workload splits into
  (structural: the sweep dispatcher groups points by its rounded value and
  rebuilds the per-cell traces per group);
* ``spill_threshold`` — the router's queue-per-warm-slot overflow level
  (traced: a sweepable batch axis of the fluid scan);
* ``route_skew``      — the origin-weight / failover-preference skew
  (traced likewise).

Declaring them as sweepable axes is what puts cell topology on the
frontier grid: ``repro.opt.space.sweepable_knobs()`` derives its whitelist
from the live registry, so ``evaluate_scenario(..., points)`` accepts
``cell_count`` / ``spill_threshold`` / ``route_skew`` the moment this
module is imported — no search-space surgery.
"""

from __future__ import annotations

from repro.core.policy_api import AxisSpec, SpotAwareFamily, register_family


class CellsFamily(SpotAwareFamily):
    name = "cells"
    kind = None

    axes = SpotAwareFamily.axes + (
        AxisSpec("cell_count", 1.0, 16.0,
                 doc="number of regional cells (rounded; structural — the "
                     "sweep groups points by it)"),
        AxisSpec("spill_threshold", 0.0, 64.0,
                 doc="queued-per-warm-slot level above which overflow "
                     "spills to warm siblings; 0 disables"),
        AxisSpec("route_skew", 0.0, 4.0,
                 doc="origin-weight and failover-preference skew "
                     "(w_c ~ exp(-skew * c))"),
    )

    # decide() and oracle_factory() are inherited: the cell axes never
    # change the per-function scaling decision — the engines read them the
    # way they read ``cc`` and the spot axes.


register_family(CellsFamily())

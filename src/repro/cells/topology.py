"""Multi-region cell topology: the declarative spec behind ``repro.cells``.

A ``CellTopology`` describes N regional cells sharing one function
population: how incoming traffic is weighted across them (``route_skew``),
when overflow spills to warm siblings (``spill_threshold``), how the
diurnal phase is staggered around the globe (``phase_spread`` — the
follow-the-sun offset applied to ``TimeWarp`` transforms per cell), an
optional deterministic regional failure (``fail_cell`` dies at
``fail_frac`` of the run and its traffic storms the survivors), the
cross-cell spot-reclaim correlation (``hazard_corr``), and the otter-style
trigger layer — scheduled (cron/at) pre-provisioning windows and reactive
utilization thresholds — that a per-cell desired-state convergence policy
(``repro.cells.triggers.ConvergenceFleetPolicy``) reconciles.

Everything here is engine-neutral plain data: the discrete oracle
(``repro.cells.oracle``) and the traced fluid engine (``repro.cells
.fluid``) both lower from this one spec, so every cells scenario doubles
as an oracle-vs-fluid parity measurement, exactly like the single-cell
scenario family.

Positions (trigger windows, the failure time) are expressed as *fractions
of the trace duration* so the same topology survives
``Scenario.build_trace(scale=...)`` shrinking unchanged — the convention
``repro.scenarios.transforms`` set.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.core.trace import Trace

# NOTE: repro.scenarios.transforms is imported lazily inside the two
# functions that need it — the scenarios package imports this module (the
# Scenario.cells field and the registry), so a module-level import here
# would be circular whenever repro.cells loads first.

# seed salt for the arrival->cell partition (independent of the transform
# stream's 0x5CE7A110 salt so routing never aliases transform randomness)
_ROUTE_SALT = 0xCE115EED


@dataclasses.dataclass(frozen=True)
class ScheduledTrigger:
    """A cron/at pre-provisioning window: hold ``cell``'s node floor at
    ``floor`` while run-fraction t is in [start_frac, end_frac) — the
    follow-the-sun "warm the region before its morning" policy."""
    cell: int
    start_frac: float
    end_frac: float
    floor: int

    def __post_init__(self):
        if not 0.0 <= self.start_frac < self.end_frac <= 1.0:
            raise ValueError(
                f"scheduled trigger window [{self.start_frac}, "
                f"{self.end_frac}) must satisfy 0 <= start < end <= 1")
        if self.cell < 0 or self.floor < 0:
            raise ValueError("scheduled trigger needs cell >= 0, floor >= 0")


@dataclasses.dataclass(frozen=True)
class ReactiveTrigger:
    """A threshold trigger: when a cell's memory utilization crosses
    ``util_high``, raise its node floor by ``change`` above the current
    count, hold it for ``hold_s``, and refuse to re-fire for
    ``cooldown_s`` (per trigger, per cell — the per-source cooldown split
    in ``repro.fleet.nodes`` keys scale-down clocks on the trigger name)."""
    name: str
    util_high: float
    change: int
    hold_s: float = 120.0
    cooldown_s: float = 120.0

    def __post_init__(self):
        if not self.name:
            raise ValueError("reactive trigger needs a name")
        if not 0.0 < self.util_high <= 10.0:
            raise ValueError(f"util_high must be in (0, 10], got "
                             f"{self.util_high!r}")
        if self.change < 0 or self.hold_s < 0 or self.cooldown_s < 0:
            raise ValueError("reactive trigger needs change/hold_s/"
                             "cooldown_s >= 0")


@dataclasses.dataclass(frozen=True)
class CellTopology:
    """N cells behind a weighted/spill router, plus failover + triggers."""
    cell_count: int = 1
    #: origin-weight skew: cell c receives a share proportional to
    #: exp(-route_skew * c).  0 = uniform.  The SAME skew orders failover
    #: and spill preference (surviving low-index cells absorb more).
    route_skew: float = 0.0
    #: queue-per-warm-slot level above which a cell's overflow arrivals
    #: spill to warm siblings (fluid router; 0 disables spill)
    spill_threshold: float = 0.0
    #: follow-the-sun: cell c's TimeWarp transforms are phase-shifted by
    #: 2*pi * phase_spread * c / cell_count (0 = all cells in phase)
    phase_spread: float = 0.0
    #: deterministic regional failure: fail_cell dies at fail_frac of the
    #: run (its queued + in-flight work re-queues on survivors, its later
    #: traffic redirects).  fail_cell < 0 disables.
    fail_cell: int = -1
    fail_frac: float = 0.6
    #: cross-cell spot-reclaim correlation in [0, 1]: this share of each
    #: cell's hazard comes from one shared storm process (all cells'
    #: markets reclaim together), the rest stays independent
    hazard_corr: float = 0.0
    scheduled: Tuple[ScheduledTrigger, ...] = ()
    reactive: Tuple[ReactiveTrigger, ...] = ()

    def __post_init__(self):
        if self.cell_count < 1:
            raise ValueError(f"cell_count must be >= 1, got "
                             f"{self.cell_count!r}")
        if self.route_skew < 0 or self.spill_threshold < 0:
            raise ValueError("route_skew / spill_threshold must be >= 0")
        if not 0.0 <= self.hazard_corr <= 1.0:
            raise ValueError(f"hazard_corr must be in [0, 1], got "
                             f"{self.hazard_corr!r}")
        if self.fail_cell >= self.cell_count:
            raise ValueError(f"fail_cell {self.fail_cell} out of range for "
                             f"{self.cell_count} cells")
        if self.fail_cell >= 0 and not 0.0 < self.fail_frac < 1.0:
            raise ValueError(f"fail_frac must be in (0, 1), got "
                             f"{self.fail_frac!r}")
        for tr in self.scheduled:
            if tr.cell >= self.cell_count:
                raise ValueError(f"scheduled trigger targets cell {tr.cell} "
                                 f"but there are {self.cell_count} cells")

    # -- derived routing data ----------------------------------------------

    def weights(self) -> np.ndarray:
        """(C,) normalized origin weights, w_c proportional to
        exp(-route_skew * c)."""
        w = np.exp(-self.route_skew * np.arange(self.cell_count, dtype=np.float64))
        return w / w.sum()

    @property
    def is_trivial(self) -> bool:
        """A topology the plain single-cell engines reproduce bit-for-bit:
        one cell, no failure, no triggers, no storm correlation.  The
        runner and sweep dispatchers use this to keep ``cells=None``
        behavior byte-identical for degenerate topologies."""
        return (self.cell_count == 1 and self.fail_cell < 0
                and not self.scheduled and not self.reactive
                and self.hazard_corr == 0.0)

    def fail_time(self, duration_s: float) -> Optional[float]:
        if self.fail_cell < 0:
            return None
        return self.fail_frac * duration_s

    def cell_nodes(self, num_nodes: int) -> np.ndarray:
        """(C,) static per-cell node counts for no-fleet scenarios: the
        scenario's ``num_nodes`` split by origin weight, at least 1 each."""
        return np.maximum(
            1, np.round(self.weights() * num_nodes)).astype(np.int64)

    # -- trigger lowering --------------------------------------------------

    def schedule_entries(self, cell: int, duration_s: float) -> tuple:
        """Absolute (start_s, end_s, floor) windows for one cell — the
        ``ConvergenceFleetPolicy.schedule`` input on the oracle side."""
        return tuple((tr.start_frac * duration_s, tr.end_frac * duration_s,
                      tr.floor)
                     for tr in self.scheduled if tr.cell == cell)

    def floor_schedule(self, n_ticks: int, dt: float,
                       duration_s: float) -> np.ndarray:
        """(T, C) float32 scheduled node floors per tick — the fluid
        engine's host-precomputed twin of ``schedule_entries`` (overlapping
        windows take the max floor; zero where no window is active)."""
        out = np.zeros((n_ticks, self.cell_count), np.float32)
        if not self.scheduled:
            return out
        t = (np.arange(n_ticks) + 0.5) * dt
        for tr in self.scheduled:
            live = (t >= tr.start_frac * duration_s) \
                & (t < tr.end_frac * duration_s)
            out[live, tr.cell] = np.maximum(out[live, tr.cell], tr.floor)
        return out


def _phase_shifted(tf, topo: CellTopology, cell: int):
    """Per-cell transform variant: TimeWarp gains the follow-the-sun phase
    offset; every other transform is shared verbatim."""
    from repro.scenarios.transforms import TimeWarp
    if topo.phase_spread != 0.0 and isinstance(tf, TimeWarp):
        shift = 2.0 * math.pi * topo.phase_spread * cell / topo.cell_count
        return dataclasses.replace(tf, phase=tf.phase + shift)
    return tf


def build_cell_traces(sc, scale: float = 1.0) -> list:
    """Per-cell event traces for a cells scenario: partition FIRST, then
    transform per cell.

    The synthesized base trace is split across cells by a seeded
    categorical draw at the topology's origin weights — exact flow
    conservation (every invocation lands in exactly one cell, function ids
    keep the SHARED id space) — and each cell then applies the scenario's
    transform stack with its own phase offset, so follow-the-sun topologies
    see genuinely time-staggered diurnal waves of the same population.
    """
    from repro.core.trace import synthesize
    from repro.scenarios.transforms import apply_transforms
    topo: CellTopology = sc.cells
    if topo is None:
        raise ValueError(f"scenario {sc.name!r} has no cell topology")
    cfg = sc.scaled_config(scale)
    base = synthesize(cfg)
    c_count = topo.cell_count
    rng = np.random.default_rng(cfg.seed ^ _ROUTE_SALT)
    assign = rng.choice(c_count, size=len(base), p=topo.weights())
    out = []
    for c in range(c_count):
        keep = assign == c
        sub = Trace(base.t[keep], base.fn[keep].astype(np.int32),
                    base.dur[keep], base.profile, base.duration_s)
        tfs = tuple(_phase_shifted(tf, topo, c) for tf in sc.transforms)
        out.append(apply_transforms(sub, cfg, tfs, seed=cfg.seed + 17 * c))
    return out

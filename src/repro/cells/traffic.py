"""The cell router: weighted origin split, spill overflow, failover flux.

Routing is expressed as a (C, C) row-stochastic FLUX MATRIX M, where
``M[c, d]`` is the fraction of traffic originating at cell c that is
served by cell d this tick:

* an ALIVE cell keeps ``1 - s_c`` of its own traffic and spills ``s_c``
  (its overflow fraction, gated by the spill threshold) to warm siblings,
  distributed proportionally to their free warm slots — "the cheapest warm
  sibling" in fluid form;
* a DEAD cell's whole row is the failover distribution — survivors ordered
  by the same ``route_skew`` preference the origin weights use.

Every row sums to exactly 1 (mass conservation — pinned by
``tests/test_cells.py``), so the routed arrival matrix
``einsum('cd,cf->df', M, arr)`` redistributes, never creates or destroys,
load.  The fluid engine traces this math inside the chunked scan
(``route_skew`` and ``spill_threshold`` are traced policy axes, hence
sweepable batch dimensions); the oracle uses the numpy twin to split
redirected arrivals at failover time.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_EPS = 1e-9


# ---------------------------------------------------------------------------
# numpy side (oracle / host precomputation)
# ---------------------------------------------------------------------------


def failover_dist_np(alive: np.ndarray, route_skew: float) -> np.ndarray:
    """(C,) redistribution over ALIVE cells, preference exp(-skew * c);
    uniform over alive cells when the skew weights underflow."""
    alive = np.asarray(alive, np.float64)
    w = alive * np.exp(-float(route_skew) * np.arange(len(alive)))
    tot = w.sum()
    if tot <= _EPS:
        n = max(alive.sum(), 1.0)
        return alive / n
    return w / tot


# ---------------------------------------------------------------------------
# traced side (fluid engine)
# ---------------------------------------------------------------------------


def failover_dist(alive, route_skew):
    """Traced twin of ``failover_dist_np`` (``route_skew`` may be a traced
    scalar — a sweepable axis)."""
    c = alive.shape[0]
    w = alive * jnp.exp(-route_skew * jnp.arange(c, dtype=jnp.float32))
    tot = w.sum()
    uniform = alive / jnp.maximum(alive.sum(), 1.0)
    return jnp.where(tot > _EPS, w / jnp.maximum(tot, _EPS), uniform)


def spill_fraction(queue_tot, arr_tot, warm_slots, threshold):
    """(C,) fraction of each cell's incoming traffic to spill: the backlog
    overflow above ``threshold`` queued-per-warm-slot, expressed as a
    fraction of this tick's arrivals, clipped to [0, 1].  threshold <= 0
    disables spill exactly (the parity scenarios run with it off)."""
    cap = threshold * jnp.maximum(warm_slots, 1.0)
    overflow = jnp.maximum(queue_tot + arr_tot - cap, 0.0)
    s = jnp.clip(overflow / jnp.maximum(arr_tot, _EPS), 0.0, 1.0)
    return jnp.where(threshold > 0.0, s, 0.0)


def flux_matrix(alive, spill, free_slots, fail_d):
    """(C, C) row-stochastic routing flux.

    ``alive``/``spill``/``free_slots`` are (C,); ``fail_d`` is the failover
    distribution over alive cells.  Spill from cell c lands on OTHER alive
    cells proportionally to their free warm slots; when no sibling has free
    capacity the spill stays home (the row falls back to the identity), so
    rows always sum to 1.
    """
    c = alive.shape[0]
    eye = jnp.eye(c, dtype=jnp.float32)
    pref = alive * jnp.maximum(free_slots, 0.0)
    others = pref[None, :] * (1.0 - eye)
    denom = others.sum(axis=1, keepdims=True)
    spill_rows = jnp.where(denom > _EPS,
                           others / jnp.maximum(denom, _EPS), eye)
    alive_rows = (1.0 - spill)[:, None] * eye + spill[:, None] * spill_rows
    return alive[:, None] * alive_rows \
        + (1.0 - alive)[:, None] * fail_d[None, :]

"""Multi-region cells: routed traffic, triggers, failover storms.

One ``CellTopology`` on a ``Scenario`` turns the single-cluster simulation
into N regional cells — each wrapping its own instance pool and node fleet
(spot tiers included) — behind a weighted/spill router and an otter-style
trigger layer (scheduled pre-provisioning + reactive thresholds,
reconciled by ``ConvergenceFleetPolicy``).  Both engines lower from the
same spec: the oracle steps per-cell ``EventSim`` replicas with cross-cell
failover re-queues (``repro.cells.oracle``), the fluid engine grows a
leading cell axis in the chunked scan's carry with the router as a traced
flux matrix (``repro.cells.fluid``) — so every cells scenario doubles as
an oracle-vs-fluid parity measurement.

Importing this package registers the ``cells`` policy family.  The engine
modules are imported lazily by the runner/sweep dispatchers (they pull in
jax program construction this package's plain-data layer does not need).
"""

from repro.cells import family as _family  # noqa: F401  (registers "cells")
from repro.cells.topology import (CellTopology, ReactiveTrigger,
                                  ScheduledTrigger, build_cell_traces)
from repro.cells.triggers import ConvergenceFleetPolicy

__all__ = ["CellTopology", "ScheduledTrigger", "ReactiveTrigger",
           "ConvergenceFleetPolicy", "build_cell_traces"]

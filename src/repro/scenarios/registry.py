"""The scenario catalogue (EXPERIMENTS.md documents each one's knobs).

Eight scenarios spanning the workload families the serverless literature
cares about: Shahrad'20's diurnal cycles and rare-but-bursty long tail,
flash crowds, multi-tenant interference, the paper's own 2000-function /
~3.5M-invocation KWOK-scale replay (Fig. 9), a 100k-function rate-based
planet-scale push of the same figure, a fleet-cost stress run
for the two-level autoscaling layer (Fig. 10 territory), and a spot-fleet
preemption storm for the capacity-tier layer (Fig. 12 territory) — plus
the multi-region cells family (``repro.cells``, Fig. 14 territory): a
regional failover storm, a follow-the-sun scheduled-trigger rotation, and
a correlated cross-region spot-reclaim storm.
"""

from __future__ import annotations

from repro.cells import CellTopology, ScheduledTrigger
from repro.core.simjax import JaxFleet
from repro.core.trace import TraceConfig
from repro.fleet.billing import IDEAL
from repro.fleet.spot import SPOT_DEFAULT
from repro.scenarios.spec import PolicySpec, Scenario
from repro.scenarios.transforms import (BurstInject, RateScale, Splice,
                                        TenantMerge, TimeWarp)

_REGISTRY: dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    if scenario.name in _REGISTRY:
        raise ValueError(f"duplicate scenario {scenario.name!r}")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def list_scenarios() -> list[str]:
    return sorted(_REGISTRY)


register(Scenario(
    name="diurnal",
    description="Azure-like diurnal waves: a monotone time-warp concentrates "
                "the same 400-function load into two day/night cycles, "
                "stressing keepalive choices across load troughs.",
    figure="extends Fig. 3/4 (slowdown + memory vs keepalive)",
    base=TraceConfig(num_functions=400, duration_s=4800,
                     target_total_rps=62.5, seed=21),
    transforms=(TimeWarp(period_frac=0.5, depth=0.8),),
    policy=PolicySpec(kind="sync", keepalive_s=600),
    num_nodes=12,
))

register(Scenario(
    name="flash_crowd",
    description="Steady traffic, then the 20 hottest functions spike 6x for "
                "8% of the run: cold-start storms and queueing on the head.",
    figure="extends Fig. 2/5 (queueing CDF + creation rate)",
    base=TraceConfig(num_functions=400, duration_s=4800,
                     target_total_rps=62.5, seed=22),
    transforms=(BurstInject(at_frac=0.6, width_frac=0.08, factor=6.0,
                            top_k=20),),
    policy=PolicySpec(kind="async", window_s=60, target=0.7),
    num_nodes=16,
))

register(Scenario(
    name="cold_tail",
    description="Cold-start-heavy long tail: 600 rarely-invoked functions "
                "(sub-1/15min rates) under a short keepalive — churn "
                "overhead dominates useful work.",
    figure="extends Fig. 5/6 (creation rate + CPU overhead)",
    # burst_amp=0: pure Poisson gaps — the sparse-function regime where the
    # keepalive-expiry renewal model is exact (clustered gaps would need a
    # burstiness correction on both engines' warm-hit probability)
    base=TraceConfig(num_functions=600, duration_s=4800,
                     target_total_rps=8.0, max_rate=0.05, burst_amp=0.0,
                     seed=23),
    transforms=(RateScale(0.8),),
    policy=PolicySpec(kind="sync", keepalive_s=60),
    num_nodes=8,
))

register(Scenario(
    name="multi_tenant",
    description="Two tenants share one cluster: a second population at half "
                "the base load joins mid-stack, and a regime-change splice "
                "breaks window-average assumptions halfway through.",
    figure="extends Fig. 7 (interference / container concurrency)",
    base=TraceConfig(num_functions=200, duration_s=3600,
                     target_total_rps=30.0, seed=24),
    transforms=(Splice(at_frac=0.5), TenantMerge(num_functions_frac=1.0,
                                                 rps_frac=0.5)),
    policy=PolicySpec(kind="async", window_s=120, target=0.7),
    num_nodes=12,
))

register(Scenario(
    name="fig9_production",
    description="The paper's KWOK-scale hybrid replay: 2000 functions / "
                "~3.5M invocations; only the chunked lax.scan path is "
                "feasible at full scale (oracle runs at reduced scale).",
    figure="reproduces Fig. 9 (large-scale trade-off)",
    base=TraceConfig(num_functions=2000, duration_s=4800,
                     target_total_rps=729.0, seed=9),
    policy=PolicySpec(kind="sync", keepalive_s=600),
    num_nodes=50,
    oracle_ok=False,
))

register(Scenario(
    name="fig9_planet",
    description="Planet-scale fluid replay: 100k functions / ~50M "
                "invocations of rate-based (pre-binned Poisson-count) "
                "traffic.  Event synthesis and the oracle are both "
                "infeasible here; the scenario exists to exercise the "
                "device-sharded chunked scan (RunSpec.devices) and the "
                "long-tail clustering transform (RunSpec.cluster).",
    figure="extends Fig. 9 (large-scale trade-off, pushed 50x)",
    base=TraceConfig(num_functions=100_000, duration_s=2400.0,
                     target_total_rps=20_900.0, seed=13),
    policy=PolicySpec(kind="sync", keepalive_s=600, tick_s=2.0),
    num_nodes=2500,
    oracle_ok=False,
    chunk_ticks=256,
    rate_trace=True,
))

register(Scenario(
    name="fleet_cost_stress",
    description="Two-level autoscaling under load swings: rate-scaled "
                "Poisson traffic with an injected flash crowd drives node "
                "provisioning churn against a cooldown-gated fleet — the "
                "same sync-keepalive policy family the Fig. 10 cost "
                "frontier sweeps.",
    figure="extends Fig. 10 (dollar-cost frontier)",
    base=TraceConfig(num_functions=300, duration_s=3600,
                     target_total_rps=45.0, burst_amp=0.0, seed=26),
    transforms=(RateScale(1.2),
                BurstInject(at_frac=0.55, width_frac=0.08, factor=4.0,
                            top_k=15)),
    policy=PolicySpec(kind="sync", keepalive_s=600),
    fleet=JaxFleet(node_memory_mb=32_768.0, provision_s=60.0, min_nodes=1,
                   max_nodes=48, util_target=0.7, warm_frac=0.25,
                   cooldown_s=120.0),
))

register(Scenario(
    name="spot_storm",
    description="A 60%-spot fleet under a preemption hazard: the market "
                "keeps reclaiming warm capacity (2-min notice), in-flight "
                "work re-queues, and every eviction triggers a cold-start "
                "storm — the spot-aware policy holds hazard-scaled warm "
                "headroom and the bill discounts only the spot tier.",
    figure="new Fig. 12 (spot cost-vs-p99 frontier)",
    # pure Poisson gaps (burst_amp=0): the keepalive-expiry renewal model's
    # exact regime, so the parity band measures the SPOT model, not gap
    # burstiness (see cold_tail)
    base=TraceConfig(num_functions=300, duration_s=3600,
                     target_total_rps=45.0, burst_amp=0.0, seed=27),
    transforms=(RateScale(1.2),),
    policy=PolicySpec(kind="spot_aware", keepalive_s=600,
                      extra={"spot_fraction": 0.6,
                             "hazard_per_hour": SPOT_DEFAULT.hazard_per_hour}),
    fleet=JaxFleet(node_memory_mb=16_384.0, provision_s=60.0, min_nodes=1,
                   max_nodes=64, util_target=0.7, warm_frac=0.25,
                   cooldown_s=120.0,
                   reclaim_notice_s=SPOT_DEFAULT.reclaim_notice_s),
    billing=IDEAL.with_spot_discount(SPOT_DEFAULT.discount),
))

register(Scenario(
    name="region_failover",
    description="Three routed cells (skewed origin weights) and the "
                "largest one dies 60% into the run: its queued + in-flight "
                "work re-queues on the survivors and its later traffic "
                "redirects along the failover preference — the "
                "failover-storm cost of multi-region warm pools.",
    figure="new Fig. 14 (failover-storm overhead)",
    # 120 functions (not 240): the skewed partition makes the smallest
    # cell's per-function traffic ~6x sparser than the single-cell
    # scenarios, and the keepalive renewal model's sparse-regime error
    # compounds with the failover transient.  Denser per-function rates +
    # a mild warp keep the seed-averaged p99/memory parity inside the 15%
    # band (creation rate is out-of-band for partitioned warped traffic —
    # the fig9_production limitation, see EXPERIMENTS.md).
    base=TraceConfig(num_functions=120, duration_s=3600,
                     target_total_rps=36.0, burst_amp=0.0, seed=31),
    transforms=(TimeWarp(period_frac=0.5, depth=0.4),),
    policy=PolicySpec(kind="cells", keepalive_s=600,
                      extra={"spot_fraction": 0.0, "hazard_per_hour": 0.0,
                             "cell_count": 3.0, "spill_threshold": 0.0,
                             "route_skew": 0.5}),
    fleet=JaxFleet(node_memory_mb=16_384.0, provision_s=60.0, min_nodes=1,
                   max_nodes=32, util_target=0.7, warm_frac=0.25,
                   cooldown_s=120.0),
    cells=CellTopology(cell_count=3, route_skew=0.5, fail_cell=0,
                       fail_frac=0.6),
))

register(Scenario(
    name="follow_the_sun",
    description="Three equal cells, one diurnal wave phase-staggered a "
                "third of a cycle apart, and a scheduled (cron-style) "
                "trigger pre-provisioning each region before its morning: "
                "the otter-style scheduled-scaling layer, measured as "
                "keeping-warm overhead.",
    figure="new Fig. 14 (scheduled pre-provisioning)",
    base=TraceConfig(num_functions=240, duration_s=3600,
                     target_total_rps=36.0, burst_amp=0.0, seed=32),
    transforms=(TimeWarp(period_frac=1.0, depth=0.7),),
    policy=PolicySpec(kind="cells", keepalive_s=600,
                      extra={"spot_fraction": 0.0, "hazard_per_hour": 0.0,
                             "cell_count": 3.0, "spill_threshold": 0.0,
                             "route_skew": 0.0}),
    fleet=JaxFleet(node_memory_mb=16_384.0, provision_s=60.0, min_nodes=1,
                   max_nodes=32, util_target=0.7, warm_frac=0.25,
                   cooldown_s=120.0),
    cells=CellTopology(
        cell_count=3, phase_spread=1.0,
        scheduled=(ScheduledTrigger(cell=0, start_frac=0.00,
                                    end_frac=0.35, floor=6),
                   ScheduledTrigger(cell=1, start_frac=0.30,
                                    end_frac=0.65, floor=6),
                   ScheduledTrigger(cell=2, start_frac=0.60,
                                    end_frac=0.95, floor=6))),
))

register(Scenario(
    name="cell_hazard_corr",
    description="Four cells buying 60% spot capacity under a reclaim "
                "hazard that is 70% CORRELATED across regions: one shared "
                "storm process reclaims every cell's spot nodes together, "
                "so failover headroom planned against independent hazards "
                "meets simultaneous cross-region eviction storms.",
    figure="new Fig. 14 (correlated reclaim storms)",
    base=TraceConfig(num_functions=240, duration_s=3600,
                     target_total_rps=36.0, burst_amp=0.0, seed=33),
    transforms=(RateScale(1.1),),
    policy=PolicySpec(kind="cells", keepalive_s=600,
                      extra={"spot_fraction": 0.6,
                             "hazard_per_hour": SPOT_DEFAULT.hazard_per_hour,
                             "cell_count": 4.0, "spill_threshold": 0.0,
                             "route_skew": 0.0}),
    fleet=JaxFleet(node_memory_mb=16_384.0, provision_s=60.0, min_nodes=1,
                   max_nodes=48, util_target=0.7, warm_frac=0.25,
                   cooldown_s=120.0,
                   reclaim_notice_s=SPOT_DEFAULT.reclaim_notice_s),
    cells=CellTopology(cell_count=4, hazard_corr=0.7),
    billing=IDEAL.with_spot_discount(SPOT_DEFAULT.discount),
))

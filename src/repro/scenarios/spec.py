"""Declarative scenario specs: one object drives both simulation engines.

A ``Scenario`` names a base workload (``TraceConfig``), a stack of trace
transforms, an autoscaling policy, and the cluster/fleet shape.  The runner
(``repro.scenarios.runner``) replays it through the discrete-event oracle
(``repro.core.eventsim``) AND the chunked ``lax.scan`` simulator
(``repro.core.simjax``) from this one spec, so every scenario doubles as a
fidelity check of the fluid model — the paper's hybrid methodology.

``PolicySpec`` is the bridge: a plain-data policy description that lowers to
the oracle's stateful per-function ``Policy`` objects on one side and to the
branchless traced ``JaxPolicy`` on the other.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

from repro.cells.topology import CellTopology
from repro.core.policies import Policy
from repro.core.policy_api import get_family
from repro.core.simjax import JaxFleet, JaxPolicy
from repro.core.trace import (RateTrace, Trace, TraceConfig, synthesize,
                              synthesize_rates)
from repro.fleet.billing import IDEAL, BillingProfile
from repro.scenarios.transforms import Transform, apply_transforms


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Engine-neutral autoscaling-policy description.

    ``kind`` names a ``repro.core.policy_api`` registry family ("sync",
    "async", "hybrid", "learned", ...); both lowering directions — the
    traced ``JaxPolicy`` and the oracle's per-function ``Policy`` factory —
    are delegated to that family, so a newly registered policy is runnable
    through every engine and scenario without touching this module.

    ``tick_s`` is the control-loop period used on BOTH sides (the oracle's
    reconcile tick and the fluid dt): comparing engines at different loop
    periods conflates policy behavior with sampling granularity — a coarser
    oracle tick accumulates larger queue spikes and inflates churn.
    """
    kind: str = "sync"
    keepalive_s: float = 600.0         # hybrid: the adaptive keepalive's cap
    window_s: float = 60.0
    target: float = 0.7
    container_concurrency: int = 1
    tick_s: float = 1.0
    prewarm_s: float = 0.0             # hybrid pre-warm lead (fluid side)
    theta: Any = None                  # learned-family weight pytree
    extra: Any = None                  # {axis: value} for novel family axes

    def family(self):
        try:
            return get_family(self.kind)
        except KeyError as e:
            raise ValueError(str(e)) from None

    def to_jax(self) -> JaxPolicy:
        return JaxPolicy(family=self.family().name,
                         keepalive_s=self.keepalive_s, window_s=self.window_s,
                         target=self.target, cc=self.container_concurrency,
                         prewarm_s=self.prewarm_s, theta=self.theta,
                         extra=self.extra)

    def factory(self) -> Callable[[int], Policy]:
        return self.family().oracle_factory(self)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One registered workload scenario (see ``repro.scenarios.registry``)."""
    name: str
    description: str
    figure: str                        # which paper figure this extends
    base: TraceConfig
    transforms: Tuple[Transform, ...] = ()
    policy: PolicySpec = PolicySpec()
    num_nodes: int = 8                 # static cluster size (no fleet)
    fleet: Optional[JaxFleet] = None   # two-level autoscaling when set
    oracle_ok: bool = True             # discrete-event replay feasible at 1.0x
    chunk_ticks: int = 512             # simjax time-chunk length
    # the billing spec this scenario's rows are costed with (a spot
    # scenario carries its tier discount here so every consumer —
    # frontier, bench gate, CLIs — bills it identically by default).
    # Generalizes the old ``prices: PriceBook`` field: a BillingProfile
    # carries the PriceBook knobs PLUS the provider-side semantics
    # (rounding, fees, GB-s metering, warm tier — see repro.fleet.billing)
    billing: BillingProfile = IDEAL
    # rate-based workload: synthesize per-tick Poisson COUNTS (RateTrace)
    # instead of a flat event stream — the planet-scale path, where a 50M
    # event sort would dwarf the simulation itself.  Rate-based scenarios
    # are fluid-only (no event stream for the oracle to replay) and cannot
    # stack event-level transforms.
    rate_trace: bool = False
    # multi-region cells: a non-trivial topology partitions the workload
    # across N routed cells with failover + trigger semantics; both engines
    # dispatch to repro.cells (mutually exclusive with rate_trace and the
    # sharded-cluster path — the runner enforces this)
    cells: Optional[CellTopology] = None

    def scaled_config(self, scale: float = 1.0) -> TraceConfig:
        """Shrink the workload isotropically (functions, duration, load) for
        smoke runs; transforms are fraction-based, so they apply unchanged."""
        if scale == 1.0:
            return self.base
        return dataclasses.replace(
            self.base,
            num_functions=max(8, int(round(self.base.num_functions * scale))),
            duration_s=max(240.0, self.base.duration_s * scale),
            target_total_rps=max(0.5, self.base.target_total_rps * scale))

    def build_trace(self, scale: float = 1.0) -> Union[Trace, RateTrace]:
        cfg = self.scaled_config(scale)
        if self.rate_trace:
            if self.transforms:
                raise ValueError(
                    f"scenario {self.name!r}: rate_trace scenarios cannot "
                    f"apply event-stream transforms")
            return synthesize_rates(cfg, tick_s=self.policy.tick_s)
        return apply_transforms(synthesize(cfg), cfg, self.transforms,
                                seed=cfg.seed)

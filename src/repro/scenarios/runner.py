"""Replay a Scenario through both simulation engines and emit metric rows.

One ``Scenario`` spec, two engines:

* ``eventsim`` — the discrete-event oracle (exact per-request latency,
  per-instance keepalive timers, real placement);
* ``simjax``  — the chunked ``lax.scan`` fluid simulator (production scale,
  no per-tick histories).

Each engine produces one metric row with a shared key core (slowdown /
normalized memory / creation rate / CPU overhead / node accounting), so a
scenario run doubles as an oracle-vs-fluid parity measurement — the hybrid
methodology of the paper's Fig. 9, generalized to a scenario family.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.runspec import RunSpec
from repro.core.simjax import JaxFleet, simulate_chunked
from repro.fleet.billing import (BillingProfile, apply_throttle, bill_sim,
                                 bill_summary, resolve_profile)
from repro.fleet.nodes import NodeFleet, NodeType
from repro.fleet.policies import UtilizationFleetPolicy
from repro.fleet.spot import (CapacityTier, SpotMarket, SpotNodeFleet,
                              get_tier)
from repro.scenarios.cluster import cluster_functions
from repro.scenarios.registry import get_scenario
from repro.scenarios.spec import PolicySpec, Scenario

ENGINES = ("eventsim", "simjax")

# the metric core both engines report; parity is judged on the first three
PARITY_KEYS = ("slowdown_geomean_p99", "normalized_memory", "creation_rate")


def _spot_knobs(spec: PolicySpec) -> tuple[float, float]:
    """The (spot_fraction, hazard_per_hour) a policy spec carries, if its
    family declares the spot axes (they ride the ``extra`` mapping)."""
    extra = dict(spec.extra or {})
    return (float(extra.get("spot_fraction", 0.0)),
            float(extra.get("hazard_per_hour", 0.0)))


def oracle_node_type(jf: JaxFleet) -> NodeType:
    """The node shape a traced fleet lowers to: the default shape scaled
    to the fleet's node size at constant $/GB-hour (also the basis the
    frontier engine and fig12 bill on)."""
    base = NodeType()
    ratio = jf.node_memory_mb / base.memory_mb
    return NodeType(memory_mb=jf.node_memory_mb, provision_s=jf.provision_s,
                    vcpus=base.vcpus * ratio,
                    price_per_hour=base.price_per_hour * ratio)


def _oracle_fleet(jf: JaxFleet, spec: Optional[PolicySpec] = None,
                  seed: int = 0) -> NodeFleet:
    """Lower the traced fleet parameters to the oracle's NodeFleet (the same
    mapping the two-level parity tests pin).  A policy spec carrying spot
    axes lowers to a ``SpotNodeFleet`` whose market runs the spec's hazard
    with the fleet's reclaim notice (seeded: parity replays are
    deterministic)."""
    nt = oracle_node_type(jf)
    policy = UtilizationFleetPolicy(min_nodes=int(jf.min_nodes),
                                    max_nodes=int(jf.max_nodes),
                                    util_target=jf.util_target,
                                    warm_frac=jf.warm_frac)
    sf, hz = _spot_knobs(spec) if spec is not None else (0.0, 0.0)
    if sf > 0.0 or hz > 0.0:
        tier = CapacityTier("spot", hazard_per_hour=hz,
                            reclaim_notice_s=jf.reclaim_notice_s)
        return SpotNodeFleet(policy, node_type=nt, cooldown_s=jf.cooldown_s,
                             spot_fraction=sf,
                             market=SpotMarket(tier, seed=seed))
    return NodeFleet(policy, node_type=nt, cooldown_s=jf.cooldown_s)


def apply_tier(sc: Scenario, tier: CapacityTier) -> Optional[Scenario]:
    """Re-spec a scenario to run under the given capacity tier: its
    policy's ``hazard_per_hour`` axis, the fleet's reclaim notice, and the
    tier discount in the billing spec.  Returns None when the scenario
    cannot express a tier (no fleet, or its policy family declares no spot
    axes) — the CLI reports those instead of silently running them
    unchanged."""
    if sc.fleet is None \
            or "hazard_per_hour" not in sc.policy.family().axis_names():
        return None
    extra = {**dict(sc.policy.extra or {}),
             "hazard_per_hour": tier.hazard_per_hour}
    return dataclasses.replace(
        sc,
        policy=dataclasses.replace(sc.policy, extra=extra),
        fleet=dataclasses.replace(sc.fleet,
                                  reclaim_notice_s=tier.reclaim_notice_s),
        billing=sc.billing.with_spot_discount(tier.discount))


def _billing_node_type(sc: Scenario) -> NodeType:
    """The node shape a scenario's bill is denominated in (both engines)."""
    return oracle_node_type(sc.fleet) if sc.fleet is not None else NodeType()


def _run_eventsim(sc: Scenario, trace, sim: SimConfig, obs=None,
                  detail: Optional[dict] = None,
                  billing: Optional[BillingProfile] = None) -> dict:
    if isinstance(trace, list):
        # multi-region cells: per-cell EventSim replicas + failover
        # (lifecycle tracing via ``obs`` is a single-cluster feature and
        # is not threaded through the cell replicas)
        from repro.cells.oracle import run_cells_eventsim
        return run_cells_eventsim(sc, trace, sim, detail=detail,
                                  billing=billing)
    if sc.fleet is not None:
        cluster = Cluster(max(1, int(sc.fleet.min_nodes)),
                          node_memory_mb=sc.fleet.node_memory_mb)
        fleet = _oracle_fleet(sc.fleet, sc.policy, seed=sim.seed)
    else:
        cluster = Cluster(sc.num_nodes)
        fleet = None
    res = EventSim(trace, cluster, sc.policy.factory(), sim, fleet=fleet,
                   obs=obs).run()
    if detail is not None:
        detail["oracle_result"] = res
    row = compute(res).row()
    if billing is not None:
        # exact per-record billed durations (SimResult.billed_duration_totals)
        row.update(bill_sim(res, trace, billing,
                            node_type=_billing_node_type(sc)).row())
    return row


def _run_simjax(sc: Scenario, trace, sim: SimConfig, telemetry: int = 0,
                billing: Optional[BillingProfile] = None,
                devices: int = 0, detail: Optional[dict] = None) -> dict:
    if isinstance(trace, list):
        # multi-region cells: a leading cell axis in the chunked scan
        # (telemetry slots are a single-cluster feature; per-cell
        # attribution lands in detail["cell_rows"] instead)
        if devices > 0:
            raise ValueError("cells scenarios do not shard over devices "
                             "yet: the cell axis owns the scan's batch "
                             "leading dimension")
        from repro.cells.fluid import run_cells_fluid
        row = run_cells_fluid(sc, trace, sim, billing=billing,
                              detail=detail)
    else:
        # dt = the oracle's reconcile tick: both engines share one control
        # period
        row = simulate_chunked(trace, sc.policy.to_jax(), sim=sim,
                               dt=sim.tick_s, num_nodes=sc.num_nodes,
                               fleet=sc.fleet, chunk_ticks=sc.chunk_ticks,
                               spec=RunSpec(telemetry=telemetry,
                                            billing=billing,
                                            devices=devices))
    if billing is not None:
        row = {**row, **bill_summary(row, billing,
                                     node_type=_billing_node_type(sc),
                                     dt=sim.tick_s).row()}
    return row


def run_scenario(scenario: Union[str, Scenario],
                 sim: Optional[SimConfig] = None,
                 detail: Optional[dict] = None,
                 *, spec: Optional[RunSpec] = None) -> list[dict]:
    """Build the scenario trace once and replay it through each engine.

    Run configuration lands through ``spec`` (a ``repro.core.runspec
    .RunSpec``): engines / scale / force_oracle / obs / telemetry /
    billing, plus the planet-scale knobs — ``devices`` (shard the fluid
    scan's function axis over that many local devices), ``cluster`` (a
    mean-rps threshold below which functions are bucketed into weighted
    super-functions, see ``repro.scenarios.cluster``), and ``tier`` (a
    capacity-tier name or ``CapacityTier``, applied via ``apply_tier``;
    a scenario that cannot express a tier raises).  ``spec`` is the ONLY
    way to pass run configuration — the transitional loose keyword forms
    were removed.  ``sim`` and ``detail`` are genuine per-call arguments,
    not run configuration.

    The oracle leg is skipped for scenarios flagged ``oracle_ok=False``
    unless the run is shrunk (scale <= 0.25) or ``force_oracle`` is set —
    replaying ~3.5M discrete events is exactly what the chunked scan exists
    to avoid.  Rate-based runs (``Scenario.rate_trace`` or clustering)
    have NO event stream for the oracle to replay: the eventsim leg drops
    silently, ``force_oracle`` notwithstanding.

    Observability (repro.obs): pass a ``SpanRecorder`` as ``obs`` to trace
    the oracle leg's request/instance/node lifecycles; ``telemetry=S``
    attaches S-slot downsampled series + attribution sums to the fluid
    leg's row.  Both default off and change nothing when off.  ``detail``,
    when given a dict, receives ``"oracle_result"`` (the raw ``SimResult``
    the attribution ledger reads) and ``"fluid_summary"``.

    ``billing`` (a ``repro.fleet.billing`` profile or name, default off)
    bills BOTH engines' rows through the profile — the oracle by exact
    per-record duration rounding, the fluid leg by the in-scan analytic
    expectation — applies the profile's cpu-throttle term to the shared
    trace, and tags each row with the profile name.  A profile given BY
    NAME inherits the scenario's spot discount (the tier is workload
    state, not provider semantics); a profile OBJECT is used verbatim.
    """
    spec = spec if spec is not None else RunSpec()
    if not isinstance(spec, RunSpec):
        raise TypeError("run_scenario() spec= must be a RunSpec, got "
                        f"{type(spec).__name__}")
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    if spec.tier is not None:
        tier = (get_tier(spec.tier) if isinstance(spec.tier, str)
                else spec.tier)
        tiered = apply_tier(sc, tier)
        if tiered is None:
            raise ValueError(
                f"scenario {sc.name!r} cannot express capacity tier "
                f"{tier.name!r}: no fleet, or its policy family declares "
                f"no spot axes")
        sc = tiered
    bp = (resolve_profile(spec.billing, sc.billing)
          if spec.billing is not None else None)
    # both engines run the same control-loop period (see PolicySpec.tick_s)
    sim = sim or SimConfig(tick_s=sc.policy.tick_s)
    rate_based = sc.rate_trace or spec.cluster > 0
    # a trivial topology (one cell, no failure/triggers/correlation) runs
    # the plain single-cluster path — byte-identical to cells=None
    cells_active = sc.cells is not None and not sc.cells.is_trivial
    if cells_active and rate_based:
        raise ValueError(
            f"scenario {sc.name!r}: cells topologies partition an event "
            f"stream — rate_trace / clustered runs cannot carry them")
    runnable = []
    for engine in spec.engines:
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; pick from {ENGINES}")
        if engine == "eventsim" and (rate_based or not (
                sc.oracle_ok or spec.scale <= 0.25 or spec.force_oracle)):
            continue
        runnable.append(engine)
    if not runnable:       # don't synthesize a multi-million-event trace
        return []          # just to run nothing
    if cells_active:
        from repro.cells.topology import build_cell_traces
        trace = build_cell_traces(sc, spec.scale)
        if bp is not None:
            trace = [apply_throttle(t, bp) for t in trace]
        meta_fns, meta_inv = trace[0].num_functions, sum(map(len, trace))
    else:
        trace = sc.build_trace(spec.scale)
        if bp is not None:
            # the throttled trace is SHARED: both engines replay the same
            # memory-stretched durations, so parity judges the billing
            # model, not a one-sided duration transform (identity under
            # ``ideal``)
            trace = apply_throttle(trace, bp)
        if spec.cluster > 0:
            # cluster AFTER throttling: the throttle stretches durations
            # the bucket key quantizes on, so the order is load-bearing
            trace = cluster_functions(trace, spec.cluster, tick_s=sim.tick_s)
        meta_fns, meta_inv = trace.num_functions, len(trace)
    meta = {"scenario": sc.name, "scale": spec.scale, "figure": sc.figure,
            "num_functions": meta_fns, "invocations": meta_inv}
    if bp is not None:
        meta["billing"] = bp.name
    rows = []
    for engine in runnable:
        t0 = time.time()
        if engine == "eventsim":
            metrics = _run_eventsim(sc, trace, sim, obs=spec.obs,
                                    detail=detail, billing=bp)
        else:
            metrics = _run_simjax(sc, trace, sim, telemetry=spec.telemetry,
                                  billing=bp, devices=spec.devices,
                                  detail=detail)
            if detail is not None:
                detail["fluid_summary"] = metrics
        rows.append({**meta, "engine": engine,
                     "wall_s": round(time.time() - t0, 3), **metrics})
    return rows


def billed_parity(scenario: Union[str, Scenario],
                  billing: Union[str, BillingProfile],
                  scale: float = 0.25,
                  sim: Optional[SimConfig] = None) -> dict:
    """Replay a scenario through BOTH engines under a billing profile and
    return the relative oracle-vs-fluid gaps of the billed dollar totals —
    the acceptance gate for the provider-calibrated billing engine (≤15%
    on ``total_cost`` at 0.25x, the scale the parity band is calibrated
    at)."""
    rows = run_scenario(scenario, sim=sim,
                        spec=RunSpec(scale=scale, force_oracle=True,
                                     billing=billing))
    by = {r["engine"]: r for r in rows}
    if not {"eventsim", "simjax"} <= set(by):
        raise RuntimeError("billed_parity needs both engine legs; got "
                           f"{sorted(by)}")
    out = {}
    for k in ("total_cost", "billed_gb_s"):
        a, b = by["eventsim"][k], by["simjax"][k]
        out[k] = abs(a - b) / max(abs(a), 1e-9)
    return out


def frontier(scenarios: Optional[Sequence[str]] = None,
             space=None, spot_check: int = 0,
             log=None, coarse_frac: float = 0.1, eps: float = 0.15,
             survivor_cap: int = 12,
             telemetry=None, *, spec: Optional[RunSpec] = None):
    """Scenario-side entry point into the frontier engine: search the joint
    (policy x fleet) space across the given scenarios (default: every
    registered event-level scenario) with the coarse+refine schedule,
    optionally oracle-checking ``spot_check`` sampled winners per scenario.

    Run configuration (scale / billing / devices / cluster) lands through
    ``spec`` only — the loose ``scale=`` / ``billing=`` shim keywords were
    removed.  The search-shape knobs (``space``, ``coarse_frac``, ``eps``,
    ``survivor_cap``, ``spot_check``) and the sinks (``log``,
    ``telemetry`` — a ``repro.obs.RunTelemetry``) are genuine parameters
    of THIS function, spelled out explicitly so a typo fails as a
    TypeError instead of vanishing into ``**kw``.

    Returns ``(FrontierResult, spot_records)``; see ``repro.opt.search``.
    (Imported lazily: ``repro.opt`` builds on this package.)
    """
    from repro.opt.search import (DEFAULT_SPACE, frontier_search,
                                  oracle_spot_check)
    spec = spec if spec is not None else RunSpec()
    if not isinstance(spec, RunSpec):
        raise TypeError("frontier() spec= must be a RunSpec, got "
                        f"{type(spec).__name__}")
    result = frontier_search(scenarios, space=space or DEFAULT_SPACE,
                             scale=spec.scale, coarse_frac=coarse_frac,
                             eps=eps, survivor_cap=survivor_cap,
                             billing=spec.billing, log=log,
                             telemetry=telemetry, devices=spec.devices,
                             cluster=spec.cluster)
    checks = oracle_spot_check(result, k=spot_check) if spot_check else []
    return result, checks


def parity_report(rows: Sequence[dict]) -> dict:
    """Relative oracle-vs-fluid gap per parity metric; {} unless both
    engines are present."""
    by = {r["engine"]: r for r in rows}
    if not {"eventsim", "simjax"} <= set(by):
        return {}
    out = {}
    for k in PARITY_KEYS:
        a, b = by["eventsim"][k], by["simjax"][k]
        out[k] = abs(a - b) / max(abs(a), 1e-9)
    return out

"""Composable trace transforms — the algebra under the scenario registry.

Each transform is a small frozen dataclass mapping ``Trace -> Trace``; a
scenario layers several of them on one synthesized base trace.  Positions
and periods are expressed as *fractions of the trace duration* so the same
transform stack survives ``Scenario.build_trace(scale=...)`` shrinking (the
CI smoke path) and full production scale unchanged.

Transforms that need fresh randomness (thinning, replication jitter,
splicing in an alternative arrival realization) draw it from a generator
seeded by the scenario, so scenario traces are reproducible end to end.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.trace import Trace, TraceConfig, merge_traces, synthesize


class Transform:
    """Protocol: ``__call__(trace, cfg, rng) -> Trace`` where *cfg* is the
    (possibly scale-shrunk) TraceConfig the trace was synthesized from."""

    def __call__(self, trace: Trace, cfg: TraceConfig,
                 rng: np.random.Generator) -> Trace:
        raise NotImplementedError


def _resorted(trace: Trace, t, fn, dur, duration_s=None) -> Trace:
    order = np.argsort(t, kind="stable")
    return Trace(np.asarray(t)[order], np.asarray(fn, np.int32)[order],
                 np.asarray(dur)[order], trace.profile,
                 trace.duration_s if duration_s is None else duration_s)


@dataclasses.dataclass(frozen=True)
class TimeWarp(Transform):
    """Monotone remap of arrival times g(t) = t - A sin(2πt/period + φ):
    local arrival rate is multiplied by 1/g'(t) ∈ [1/(1+depth), 1/(1-depth)],
    so the SAME invocations arrive in diurnal waves — total load is
    preserved, only its placement in time changes (Shahrad'20's diurnal
    cycles).  ``phase`` shifts where in the cycle the run starts; the
    multi-region cells layer (repro.cells) staggers it per cell to model
    follow-the-sun offsets.  phase=0 is the historical transform exactly."""
    period_frac: float = 0.5       # cycle length as a fraction of duration
    depth: float = 0.8             # 0 = identity; must stay < 1 for monotone g
    phase: float = 0.0             # radians; per-cell follow-the-sun offset

    def __call__(self, trace, cfg, rng):
        period = max(self.period_frac * trace.duration_s, 1e-9)
        amp = self.depth * period / (2 * np.pi)
        t = trace.t - amp * np.sin(2 * np.pi * trace.t / period + self.phase)
        t = np.clip(t, 0.0, trace.duration_s)
        return _resorted(trace, t, trace.fn, trace.dur)


@dataclasses.dataclass(frozen=True)
class RateScale(Transform):
    """Scale aggregate load by ``factor``: < 1 thins arrivals Bernoulli-wise,
    > 1 replicates each arrival (integer part + Bernoulli fraction) with a
    small time jitter so replicas don't collide on one tick."""
    factor: float = 1.0
    jitter_s: float = 1.0

    def __call__(self, trace, cfg, rng):
        if self.factor == 1.0:
            return trace
        n = len(trace)
        copies = np.full(n, int(self.factor), np.int64)
        copies += rng.uniform(size=n) < (self.factor - int(self.factor))
        idx = np.repeat(np.arange(n), copies)
        t = trace.t[idx].copy()
        # the first copy of each arrival keeps its time; replicas get jitter
        extra = np.concatenate([[False], idx[1:] == idx[:-1]])
        t[extra] += rng.uniform(0, self.jitter_s, int(extra.sum()))
        t = np.clip(t, 0.0, trace.duration_s)
        return _resorted(trace, t, trace.fn[idx], trace.dur[idx])


@dataclasses.dataclass(frozen=True)
class Splice(Transform):
    """Head/tail splice: keep arrivals before ``at_frac`` from the base
    trace and replace everything after with an independent arrival
    realization of the SAME function population (seed offset) — a regime
    change mid-experiment that breaks window-average assumptions."""
    at_frac: float = 0.5
    seed_offset: int = 104729

    def __call__(self, trace, cfg, rng):
        cut = self.at_frac * trace.duration_s
        alt = synthesize(dataclasses.replace(cfg, seed=cfg.seed + self.seed_offset),
                         profile=trace.profile)
        head = trace.t < cut
        tail = alt.t >= cut
        return _resorted(trace,
                         np.concatenate([trace.t[head], alt.t[tail]]),
                         np.concatenate([trace.fn[head], alt.fn[tail]]),
                         np.concatenate([trace.dur[head], alt.dur[tail]]))


@dataclasses.dataclass(frozen=True)
class BurstInject(Transform):
    """Flash crowd: inside [at_frac, at_frac + width_frac) the ``top_k``
    highest-rate functions receive ``factor``x their arrivals — existing
    window invocations are replicated with jitter, modelling a sudden
    external traffic spike concentrated on the popular head."""
    at_frac: float = 0.6
    width_frac: float = 0.05
    factor: float = 8.0
    top_k: int = 20

    def __call__(self, trace, cfg, rng):
        t0 = self.at_frac * trace.duration_s
        t1 = t0 + self.width_frac * trace.duration_s
        hot = np.argsort(trace.profile.rate)[-self.top_k:]
        in_burst = ((trace.t >= t0) & (trace.t < t1)
                    & np.isin(trace.fn, hot))
        reps = int(round(self.factor)) - 1
        if reps <= 0 or not in_burst.any():
            return trace
        idx = np.repeat(np.nonzero(in_burst)[0], reps)
        t = np.concatenate([trace.t, rng.uniform(t0, t1, len(idx))])
        fn = np.concatenate([trace.fn, trace.fn[idx]])
        dur = np.concatenate([trace.dur, trace.dur[idx]])
        return _resorted(trace, t, fn, dur)


@dataclasses.dataclass(frozen=True)
class TenantMerge(Transform):
    """Multi-tenant interference: synthesize a second function population
    (``rps_frac`` of the base aggregate rate) and interleave it onto the
    same cluster, re-keying its function ids past the base population."""
    num_functions_frac: float = 0.5
    rps_frac: float = 0.5
    seed_offset: int = 7919

    def __call__(self, trace, cfg, rng):
        other_cfg = dataclasses.replace(
            cfg,
            num_functions=max(1, int(cfg.num_functions * self.num_functions_frac)),
            target_total_rps=cfg.target_total_rps * self.rps_frac,
            seed=cfg.seed + self.seed_offset)
        return merge_traces(trace, synthesize(other_cfg))


def apply_transforms(trace: Trace, cfg: TraceConfig,
                     transforms, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed ^ 0x5CE7A110)
    for tf in transforms:
        trace = tf(trace, cfg, rng)
    return trace

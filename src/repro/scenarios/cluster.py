"""Long-tail function clustering: weighted super-functions for the fluid scan.

Production serverless populations are dominated by a long tail of
near-identical, rarely-invoked functions (the Azure trace's bottom decades
carry most of the FUNCTIONS and almost none of the LOAD).  The chunked
scan's cost is linear in the function axis, so at planet scale the tail is
pure overhead: 90k cold functions each simulate the same dynamics.

``cluster_functions`` buckets functions below a mean-rps threshold by
quantized (rate, duration, memory, sigma) and replaces each bucket with ONE
representative — the bucket's rate-MEDOID member's per-tick arrival column —
carrying a ``weights`` entry equal to the member count.  Exactness argument
(see also ``simjax._make_step``): the fluid scan is deterministic given
per-tick counts, per-function dynamics only couple through reductions that
are LINEAR in per-function contributions, and identical members evolve
identically — so k identical functions equal one representative weighted k,
exactly.  The representative must be a REAL member column, not the
bucket-mean column: averaging k Poisson realizations smooths away the
burstiness that drives cold starts (the mean column under-counts creations
by ~25% on the planet trace), while a medoid realization keeps the gap
statistics of a genuine member.  Real buckets are only NEAR-identical
(finite quantization), so the residual is second-order in the bin width;
the parity test (tests/test_sharding.py) pins it ≤1% on the headline
metrics.

The output is always a :class:`repro.core.trace.RateTrace` (clustered
columns are fractional mean counts); event-level oracle legs are therefore
unavailable on clustered runs — the runner drops them.
"""

from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core.trace import FunctionProfile, RateTrace, Trace, rate_matrix

__all__ = ["cluster_functions"]


def cluster_functions(trace: Union[Trace, RateTrace], below_rps: float,
                      bins_per_octave: int = 10,
                      tick_s: Optional[float] = None) -> RateTrace:
    """Bucket functions with mean rate < ``below_rps`` into weighted
    super-functions; hot functions stay exact with weight 1.

    ``bins_per_octave`` sets the quantization of the (log rate, log
    duration) bucket key — 10 bins per factor-2 keeps members within ~±3.5%
    of their bucket's geometric center (the planet-trace parity sweep:
    6 bins leaves a 3.7% creation-rate gap, 10 bins ≤0.25% on every
    headline metric).  Memory (exact) and dur_sigma (rounded) complete the
    key, so a bucket is homogeneous in every input the scan reads per
    function.  ``tick_s`` is the binning tick when the input is an
    event-level Trace (default 1 s); RateTraces keep theirs.
    """
    if isinstance(trace, RateTrace):
        tick = trace.tick_s
        counts = np.asarray(trace.counts, np.float64)
        base_w = (np.ones(trace.num_functions) if trace.weights is None
                  else np.asarray(trace.weights, np.float64))
    else:
        tick = float(tick_s if tick_s is not None else 1.0)
        counts = rate_matrix(trace, tick).astype(np.float64)
        base_w = np.ones(trace.num_functions)
    prof = trace.profile
    t_ticks, f = counts.shape
    rates = counts.mean(axis=0) / tick

    with np.errstate(divide="ignore"):
        lg_rate = np.round(np.log2(rates) * bins_per_octave)
        lg_dur = np.round(np.log2(np.maximum(prof.dur_median, 1e-9))
                          * bins_per_octave)
    cold = rates < below_rps

    # bucket id per function: hot functions get singleton buckets in their
    # original order, cold functions group by the quantized key
    bucket_of = np.empty(f, np.int64)
    key_to_id: dict = {}
    members: list[list[int]] = []
    for i in range(f):
        if not cold[i]:
            bucket_of[i] = len(members)
            members.append([i])
            continue
        key = (float(lg_rate[i]), float(lg_dur[i]),
               float(prof.memory_mb[i]), round(float(prof.dur_sigma[i]), 6))
        bid = key_to_id.get(key)
        if bid is None:
            bid = key_to_id[key] = len(members)
            members.append([])
        bucket_of[i] = bid
        members[bid].append(i)
    b = len(members)

    # (at 100k functions the python work above is O(F) dict ops; the heavy
    # lifting below is numpy scatter-adds)
    w_out = np.zeros(b)
    np.add.at(w_out, bucket_of, base_w)

    def wmean(v):
        out = np.zeros(b)
        np.add.at(out, bucket_of, np.asarray(v, np.float64) * base_w)
        return out / w_out

    # representative counts = the column of the bucket's rate-MEDOID member
    # (the member whose mean rate is closest to the bucket's weighted mean).
    # A real realization, not the bucket-mean column: averaging Poisson
    # columns smooths the burstiness that drives cold starts.
    mean_rate = wmean(rates)
    rep = np.empty(b, np.int64)
    for bid, mem in enumerate(members):
        idx = np.asarray(mem)
        rep[bid] = idx[np.argmin(np.abs(rates[idx] - mean_rate[bid]))]
    new_counts = counts[:, rep].astype(np.float32)

    # bucket profiles: rate/duration as weighted (geometric for the
    # log-binned duration) means of near-identical members; memory and
    # sigma are constant within a bucket by construction
    new_prof = FunctionProfile(
        rate=wmean(prof.rate),
        dur_median=np.exp(wmean(np.log(np.maximum(prof.dur_median, 1e-9)))),
        dur_sigma=wmean(prof.dur_sigma),
        memory_mb=wmean(prof.memory_mb),
        phase=wmean(prof.phase),
    )
    return RateTrace(new_counts, tick, new_prof, float(trace.duration_s),
                     weights=w_out)

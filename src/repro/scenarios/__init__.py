# Declarative workload scenarios: a registry of named (trace x transforms x
# policy x fleet) specs, replayable through both the discrete-event oracle
# and the chunked lax.scan simulator from one spec.
from repro.scenarios.cluster import cluster_functions  # noqa: F401
from repro.scenarios.registry import (  # noqa: F401
    get_scenario,
    list_scenarios,
    register,
)
from repro.scenarios.runner import (  # noqa: F401
    ENGINES,
    PARITY_KEYS,
    frontier,
    parity_report,
    run_scenario,
)
from repro.scenarios.spec import PolicySpec, Scenario  # noqa: F401
from repro.scenarios.transforms import (  # noqa: F401
    BurstInject,
    RateScale,
    Splice,
    TenantMerge,
    TimeWarp,
    Transform,
    apply_transforms,
)

"""Shared CLI flags for the launch entry points.

The three launchers (``repro.launch.scenarios``, ``repro.launch.frontier``,
``repro.launch.trace``) accept one common run-configuration vocabulary —
``--scale`` / ``--billing`` / ``--tier`` / ``--devices`` / ``--cluster``
plus a per-CLI telemetry form — declared HERE once instead of three
copy-pasted ``add_argument`` blocks.  ``validate_run_flags`` performs the
friendly-error checks (unknown billing profile / capacity tier, more
devices than the host exposes, a negative clustering threshold) with the
launchers' exit-2 contract: print the registered choices to stderr, return
2, never traceback.

These flags map one-to-one onto ``repro.core.runspec.RunSpec`` fields;
each launcher builds its spec from the parsed namespace and threads it
through ``run_scenario`` / ``frontier_search``.
"""

from __future__ import annotations

import argparse
import sys
from typing import Optional


def add_run_flags(ap: argparse.ArgumentParser, *,
                  scale_default: float = 1.0,
                  scale_help: Optional[str] = None,
                  telemetry: Optional[str] = None) -> argparse.ArgumentParser:
    """Declare the shared run-configuration flags on *ap*.

    ``telemetry`` picks the launcher's telemetry form: ``"dir"`` (the
    scenario runner's ``--telemetry DIR`` + ``--telemetry-slots``),
    ``"flag"`` (the frontier's boolean ``--telemetry``), ``"slots"`` (the
    trace CLI's ``--slots``), or None.
    """
    ap.add_argument("--scale", type=float, default=scale_default,
                    help=scale_help or "isotropic workload shrink factor "
                                       f"(default {scale_default:g})")
    ap.add_argument("--billing", default=None, metavar="PROFILE",
                    help="bill through this billing profile (rounding, "
                         "minimum duration, per-request and per-GB-s fees, "
                         "cpu throttle); see --list for registered profiles")
    ap.add_argument("--tier", default=None,
                    help="run spot-capable scenarios under this capacity "
                         "tier (hazard, reclaim notice, discount); "
                         "see --list for registered tiers")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="shard the fluid scan over N local devices "
                         "(0 = unsharded; on CPU expose devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N)")
    ap.add_argument("--cluster", type=float, default=0.0, metavar="RPS",
                    help="bucket functions below this mean-rps threshold "
                         "into weighted super-functions before simulating "
                         "(0 = off; fluid-only — the oracle leg drops)")
    if telemetry == "dir":
        ap.add_argument("--telemetry", default=None, metavar="DIR",
                        help="attach in-scan telemetry to the simjax leg "
                             "and write timeline_<scenario>.csv per "
                             "scenario here (requires a simjax leg)")
        ap.add_argument("--telemetry-slots", type=int, default=200,
                        help="downsampled timeline resolution (default 200)")
    elif telemetry == "flag":
        ap.add_argument("--telemetry", action="store_true",
                        help="record search-run telemetry (per-stage sims/"
                             "wall/hypervolume, spot-check demotion counts, "
                             "training-loss series) to telemetry.json in "
                             "--out-dir")
    elif telemetry == "slots":
        ap.add_argument("--slots", type=int, default=200,
                        help="fluid timeline resolution (default 200)")
    return ap


def validate_run_flags(args: argparse.Namespace) -> int:
    """Friendly-error validation of the shared flags: returns 0 when every
    value resolves, 2 (the launchers' usage-error exit) after printing the
    registered choices to stderr otherwise."""
    if args.billing is not None:
        from repro.fleet.billing import get_profile, list_profiles
        try:
            get_profile(args.billing)
        except KeyError:
            # a friendly listing, not a KeyError traceback
            print(f"unknown billing profile {args.billing!r}",
                  file=sys.stderr)
            print(f"registered profiles: {', '.join(list_profiles())} "
                  f"(see --list)", file=sys.stderr)
            return 2
    if args.tier is not None:
        from repro.fleet.spot import get_tier, list_tiers
        try:
            get_tier(args.tier)
        except KeyError:
            # a friendly listing, not a KeyError traceback
            print(f"unknown capacity tier {args.tier!r}", file=sys.stderr)
            print(f"registered tiers: {', '.join(list_tiers())} "
                  f"(see --list)", file=sys.stderr)
            return 2
    if args.devices < 0:
        print(f"--devices must be >= 0, got {args.devices}", file=sys.stderr)
        return 2
    if args.devices > 0:
        import jax
        n = len(jax.devices())
        if args.devices > n:
            print(f"--devices {args.devices}: only {n} local device(s) "
                  f"visible — on CPU set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count={args.devices}",
                  file=sys.stderr)
            return 2
    if args.cluster < 0.0:
        print(f"--cluster must be >= 0 (a mean-rps threshold), got "
              f"{args.cluster}", file=sys.stderr)
        return 2
    return 0


def add_search_flags(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Search-engine selection flags (frontier-style launchers): which
    engine walks the space (``--algo``), how much simulation it may spend
    (``--budget``), and the seed behind every stochastic choice
    (``--seed``) — same shared-vocabulary contract as ``add_run_flags``."""
    ap.add_argument("--algo", default="grid", metavar="ALGO",
                    help="search engine: 'grid' enumerates the space's "
                         "cartesian product; 'evo' runs the NSGA-II "
                         "population optimizer at the same evaluation "
                         "budget (repro.opt.evo)")
    ap.add_argument("--budget", type=int, default=None, metavar="N",
                    help="evo evaluation budget in simulated candidate-"
                         "scenario pairs (default: exactly what the grid "
                         "would cost, for a like-for-like comparison; "
                         "ignored under --algo grid)")
    ap.add_argument("--seed", type=int, default=0, metavar="S",
                    help="seed for every stochastic search choice (evo "
                         "variation, spot-check winner sampling); a seeded "
                         "run replays bit-for-bit (default 0)")
    return ap


def validate_search_flags(args: argparse.Namespace) -> int:
    """Friendly-error validation of the search flags: exit-2 contract,
    printing the registered engines instead of a traceback."""
    from repro.opt.search import SEARCH_ALGOS
    if args.algo not in SEARCH_ALGOS:
        # a friendly listing, not a ValueError traceback
        print(f"unknown search algo {args.algo!r}", file=sys.stderr)
        print(f"registered algos: {', '.join(SEARCH_ALGOS)}",
              file=sys.stderr)
        return 2
    if args.budget is not None and args.budget <= 0:
        print(f"--budget must be a positive candidate-scenario pair count, "
              f"got {args.budget}", file=sys.stderr)
        return 2
    return 0


def unknown_scenarios(names) -> int:
    """Exit-2 helper shared by the launchers: print the friendly listing
    for any unregistered scenario names; 0 when all resolve."""
    from repro.scenarios import list_scenarios
    unknown = [n for n in names if n not in list_scenarios()]
    if not unknown:
        return 0
    # a friendly listing, not a KeyError traceback
    print(f"unknown scenario(s): {', '.join(unknown)}", file=sys.stderr)
    print("registered scenarios (see --list for details):", file=sys.stderr)
    for n in list_scenarios():
        print(f"  {n}", file=sys.stderr)
    return 2

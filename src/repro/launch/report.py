"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""

from __future__ import annotations

import glob
import json
import os

from repro.launch.dryrun import ARTIFACTS, HBM_BW, LINK_BW, PEAK_FLOPS


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}.json"))):
        d = json.load(open(path))
        if d.get("error"):
            rows.append(f"| {d['arch']} | {d['shape']} | ERROR | | | | |")
            continue
        if d.get("skipped"):
            continue
        mem = d["memory"]
        per = d["per_device"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['compile_s']:.0f}s "
            f"| {_fmt_bytes(mem['argument_bytes'])} | {_fmt_bytes(mem['temp_bytes'])} "
            f"| {per['flops']:.2e} | {per['collective_bytes']:.2e} |")
    head = (f"| arch | shape | compile | args GiB/dev | temp GiB/dev "
            f"| HLO FLOPs/dev | coll B/dev |\n|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table(mesh: str = "16x16") -> str:
    import benchmarks.roofline as rl
    rows = []
    for r in rl.report(mesh):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f} "
            f"| {r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} "
            f"| {r['bottleneck']} | {r['useful_ratio']:.2f} | {r['mfu']*100:.1f}% |")
    head = ("| arch | shape | compute ms | memory ms | collective ms "
            "| bound | MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import io
    import sys
    from contextlib import redirect_stdout
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    buf = io.StringIO()
    with redirect_stdout(buf):   # suppress emit() noise from roofline.report
        if which == "dryrun":
            out = dryrun_table(sys.argv[2] if len(sys.argv) > 2 else "16x16")
        else:
            out = roofline_table(sys.argv[2] if len(sys.argv) > 2 else "16x16")
    print(out)

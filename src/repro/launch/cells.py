"""Build the (step_fn, abstract inputs) pair for every (arch x shape) cell.

``input_specs`` returns weak-type-correct ShapeDtypeStructs with
NamedShardings attached — shardable stand-ins, no device allocation — so a
cell can be ``jit(...).lower(*specs).compile()``d on any mesh without
materializing a single parameter.

Cell kinds:
  train    -> full train_step (fwd + bwd + AdamW update), params fp32 master
  prefill  -> serving prefill: logits + KV-cache fill, params bf16, no remat
  decode   -> serving decode: one token against a seq_len cache, params bf16
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.distributed import sharding as shlib
from repro.models import registry
from repro.training import optimizer as opt
from repro.training.train_step import TrainConfig, train_step


def _with_shardings(shape_tree, spec_tree, mesh: Mesh):
    is_spec = lambda l: l is None or isinstance(l, tuple)

    def conv(sd, spec):
        pspec = shlib.logical_to_spec(spec or (), mesh)
        pspec = shlib.sanitize_spec(pspec, sd.shape, mesh)
        return jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=NamedSharding(mesh, pspec))

    return jax.tree.map(conv, shape_tree, spec_tree, is_leaf=lambda l: is_spec(l) and not isinstance(l, jax.ShapeDtypeStruct))


def _abstract_params(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = False):
    shapes = jax.eval_shape(lambda: registry.init_params(cfg, jax.random.PRNGKey(0)))
    with shlib.use_mesh(mesh):
        specs = registry.param_specs(cfg)
        if fsdp:
            specs = shlib.fsdp_specs(specs, shapes)
    return _with_shardings(shapes, specs, mesh), shapes, specs


def _sd(mesh, shape, dtype, *logical):
    spec = shlib.sanitize_spec(shlib.logical_to_spec(logical, mesh), shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *, train: bool):
    b, s = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {"tokens": _sd(mesh, (b, s), jnp.int32, "batch", None)}
    if train:
        batch["targets"] = _sd(mesh, (b, s), jnp.int32, "batch", None)
        batch["loss_mask"] = _sd(mesh, (b, s), jnp.float32, "batch", None)
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sd(mesh, (b, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16, "batch", None, "embed")
    if cfg.family == "encdec":
        batch["enc_embeds"] = _sd(mesh, (b, cfg.encoder_seq, cfg.d_model),
                                  jnp.bfloat16, "batch", None, "embed")
    return batch


def serving_config(cfg: ModelConfig, kind: str = "decode") -> ModelConfig:
    """Serving cells: bf16 weights, no remat.  DECODE additionally unrolls
    layers — a scan-carried KV cache is restacked (fully rewritten) every
    token, which the §Perf iteration measured at 13x the decode memory term;
    unrolled layers give per-layer donated caches that update in place.
    PREFILL keeps the scan: its one restack per layer is amortized over the
    whole sequence, and unrolling blows up live-buffer footprint."""
    return cfg.replace(param_dtype="bfloat16", remat="none",
                       scan_layers=(kind != "decode"))


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Pick gradient-accumulation depth so per-device microbatch activations
    stay bounded (~8k tokens/device) while the microbatch stays shardable."""
    dp = shlib.mesh_axis_size("batch", mesh)
    tokens_per_dev = shape.global_batch * shape.seq_len // max(dp, 1)
    target = 4096 if cfg.d_model >= 6144 else 8192   # wide models: smaller slabs
    n = 1
    while tokens_per_dev // n > target and shape.global_batch // (2 * n) >= dp:
        n *= 2
    return n


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh, *,
               fsdp: bool = True, n_microbatches: Optional[int] = None,
               overrides: Optional[dict] = None):
    if overrides:
        cfg = cfg.replace(**overrides)
    return _build_cell(cfg, shape_name, mesh, fsdp=fsdp,
                       n_microbatches=n_microbatches)


def _build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh, *,
                fsdp: bool = True, n_microbatches: Optional[int] = None):
    """-> (fn, args_tree, donate_argnums). jit as:
    jax.jit(fn, donate_argnums=...).lower(*args).compile()."""
    shape = SHAPES[shape_name]

    if shape.kind == "train":
        aparams, pshapes, pspecs = _abstract_params(cfg, mesh, fsdp=fsdp)
        oshapes = jax.eval_shape(opt.adamw_init, pshapes)
        with shlib.use_mesh(mesh):
            ospecs = opt.opt_specs(pspecs, pshapes)
        aopt = _with_shardings(oshapes, ospecs, mesh)
        batch = _batch_specs(cfg, shape, mesh, train=True)
        n_micro = n_microbatches or default_microbatches(cfg, shape, mesh)
        tcfg = TrainConfig(n_microbatches=n_micro)

        def fn(params, opt_state, b):
            with shlib.use_mesh(mesh):
                return train_step(cfg, tcfg, params, opt_state, b)

        return fn, (aparams, aopt, batch), (0, 1)

    scfg = serving_config(cfg, shape.kind)
    aparams, _, _ = _abstract_params(scfg, mesh)
    b = shape.global_batch

    if shape.kind == "prefill":
        cshapes = registry.cache_shapes(scfg, b, shape.seq_len)
        with shlib.use_mesh(mesh):
            cspecs = registry.cache_specs(scfg)
        acache = _with_shardings(cshapes, cspecs, mesh)
        batch = _batch_specs(scfg, shape, mesh, train=False)

        def fn(params, cache, bt):
            with shlib.use_mesh(mesh):
                return registry.prefill(scfg, params, cache, bt)

        return fn, (aparams, acache, batch), (1,)

    # decode: one new token against a seq_len-deep cache
    cshapes = registry.cache_shapes(scfg, b, shape.seq_len)
    with shlib.use_mesh(mesh):
        cspecs = registry.cache_specs(scfg)
    acache = _with_shardings(cshapes, cspecs, mesh)
    tokens = _sd(mesh, (b, 1), jnp.int32, "batch", None)
    pos = _sd(mesh, (b,), jnp.int32, "batch")

    def fn(params, cache, tok, p):
        with shlib.use_mesh(mesh):
            return registry.decode_step(scfg, params, cache, tok, p)

    return fn, (aparams, acache, tokens, pos), (1,)


def input_specs(arch_cfg: ModelConfig, shape_name: str, mesh: Mesh):
    """Deliverable (e): ShapeDtypeStruct stand-ins for every model input."""
    _, args, _ = build_cell(arch_cfg, shape_name, mesh)
    return args

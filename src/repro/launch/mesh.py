"""Production meshes.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax;
smoke tests and benchmarks see the single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (for smoke/e2e runs)."""
    return jax.make_mesh((1, 1), ("data", "model"))

"""Trip-count-aware cost analysis over optimized HLO text.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis counts a
``while`` body ONCE, so any scanned layer stack (the only way to keep compile
time sane at 80+ layers) under-counts FLOPs/bytes/collectives by the trip
count.  Optimized HLO text carries ``known_trip_count`` in each while's
backend_config; this module walks the computation graph, costs each op from
its printed shapes, and multiplies through loops.

Conventions:
* flops: dot = 2*prod(out)*prod(contracted); conv = 2*prod(out)*kernel/groups;
  elementwise/reduce ~= 1 op per input element (coarse, like XLA's own
  accounting for non-dot ops).
* bytes: per *materializing* op = operand bytes + output bytes.  Fusion
  computations contribute their inner dot flops but only their call-site
  bytes (fused intermediates never touch HBM) — this approximates post-fusion
  HBM traffic, which is what the memory roofline term needs.
* collective bytes: summed operand sizes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute (async *-start counted,
  *-done free), multiplied through loops.

Shapes in the per-device HLO are shard shapes, so every number reported here
is PER DEVICE; multiply by chip count for global totals.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "reshape", "iota",
    "get-dimension-size", "opt-barrier", "add-dependency",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
_ASYNC_DONE = {"all-reduce-done", "all-gather-done", "collective-permute-done"}


def _shape_info(type_str: str) -> tuple[float, list[list[int]]]:
    """Total bytes + list of dims-lists for (possibly tuple) type string."""
    total = 0.0
    dims_list = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in dims.split(",") if x] if dims else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_list.append(dims)
    return total, dims_list


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.collectives.items():
            slot = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += v["count"] * mult
            slot["bytes"] += v["bytes"] * mult


_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")


def _parse_computations(txt: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    current: Optional[str] = None
    for line in txt.splitlines():
        stripped = line.strip()
        if stripped.startswith(("%", "ENTRY")) and stripped.endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%([\w\.\-]+)", stripped)
            current = m.group(1)
            comps[current] = []
            if stripped.startswith("ENTRY"):
                comps["__entry__"] = comps[current]
            continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        rest = line[m.end():]
        # type: balanced if tuple, else token
        if rest.startswith("("):
            depth = 0
            for i, ch in enumerate(rest):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            type_str, rest2 = rest[:i + 1], rest[i + 1:].lstrip()
        else:
            sp = rest.index(" ")
            type_str, rest2 = rest[:sp], rest[sp + 1:].lstrip()
        om = re.match(r"([\w\-]+)\(", rest2)
        if not om:
            continue
        opcode = om.group(1)
        depth, start = 0, om.end() - 1
        for i in range(start, len(rest2)):
            depth += rest2[i] == "("
            depth -= rest2[i] == ")"
            if depth == 0:
                break
        operand_str = rest2[start + 1:i]
        attrs = rest2[i + 1:]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        if opcode == "parameter":
            attrs = operand_str.strip() + " " + attrs   # keep the index
        comps[current].append(Op(m.group(1), opcode, type_str, operands, attrs))
    return comps


class HloCostModel:
    def __init__(self, txt: str):
        self.comps = _parse_computations(txt)
        self._memo: dict[str, Cost] = {}

    # -- per-op flop models ----------------------------------------------------

    def _dot_flops(self, op: Op, shapes: dict[str, str]) -> float:
        out_bytes, out_dims = _shape_info(op.type_str)
        lhs_type = shapes.get(op.operands[0], "")
        _, lhs_dims = _shape_info(lhs_type)
        if not lhs_dims or not out_dims:
            return 0.0
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        contract = 1
        if cdims and cdims.group(1):
            for i in (int(x) for x in cdims.group(1).split(",")):
                if i < len(lhs_dims[0]):
                    contract *= lhs_dims[0][i]
        out_elems = 1
        for d in out_dims[0]:
            out_elems *= d
        return 2.0 * out_elems * contract

    def _conv_flops(self, op: Op, shapes: dict[str, str]) -> float:
        _, out_dims = _shape_info(op.type_str)
        _, k_dims = _shape_info(shapes.get(op.operands[1], ""))
        if not out_dims or not k_dims:
            return 0.0
        out_elems = 1
        for d in out_dims[0]:
            out_elems *= d
        kernel = 1
        for d in k_dims[0]:
            kernel *= d
        groups = 1
        g = re.search(r"feature_group_count=(\d+)", op.attrs)
        if g:
            groups = int(g.group(1))
        # kernel product includes in_ch*out_ch; flops = 2*out*kernel/out_ch/groups
        dl = re.search(r"dim_labels=\S*_(\S*?)->", op.attrs)
        out_ch = out_dims[0][-1] if out_dims[0] else 1
        if dl and "o" in dl.group(1):
            out_ch = k_dims[0][dl.group(1).index("o")]
        return 2.0 * out_elems * kernel / max(out_ch, 1) / max(groups, 1)

    # -- computation costing -----------------------------------------------------

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # break cycles defensively
        total = Cost()
        ops = self.comps.get(name, [])
        shapes = {op.name: op.type_str for op in ops}
        for op in ops:
            total.add(self._op_cost(op, shapes))
        self._memo[name] = total
        return total

    def _called(self, attrs: str, key: str) -> list[str]:
        m = re.search(key + r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?", attrs)
        if not m:
            return []
        return [x.strip().lstrip("%") for x in m.group(1).split(",")]

    def _op_cost(self, op: Op, shapes: dict[str, str]) -> Cost:
        c = Cost()
        out_bytes, _ = _shape_info(op.type_str)
        opc = op.opcode

        if opc in _FREE_OPS or opc in _ASYNC_DONE:
            return c

        if opc == "while":
            trip = 1.0
            m = re.search(r'known_trip_count\D*?(\d+)', op.attrs)
            if m:
                trip = float(m.group(1))
            for key in ("body", "condition"):
                for callee in self._called(op.attrs, key):
                    c.add(self.comp_cost(callee), trip)
            return c

        if opc in ("call", "async-start"):
            for callee in self._called(op.attrs, "to_apply") + self._called(op.attrs, "called_computations"):
                c.add(self.comp_cost(callee))
            return c

        if opc == "conditional":
            for callee in self._called(op.attrs, "branch_computations") \
                    + self._called(op.attrs, "true_computation") \
                    + self._called(op.attrs, "false_computation"):
                c.add(self.comp_cost(callee))
            c.bytes += out_bytes
            return c

        in_bytes = sum(_shape_info(shapes.get(o, ""))[0] for o in op.operands)

        # slice-granular memory ops: hardware touches the slice, not the
        # whole buffer (in-place DUS / windowed DS) — without this, scan
        # residual stacking is over-counted by the stack depth.
        if opc == "dynamic-slice":
            c.bytes += 2 * out_bytes
            return c
        if opc == "dynamic-update-slice":
            upd = _shape_info(shapes.get(op.operands[1], ""))[0] if len(op.operands) > 1 else out_bytes
            c.bytes += 2 * upd
            return c
        if opc == "gather":
            c.bytes += 2 * out_bytes
            return c
        if opc == "scatter":
            upd = _shape_info(shapes.get(op.operands[-1], ""))[0] if op.operands else out_bytes
            c.bytes += 2 * upd
            return c

        if opc == "fusion":
            for callee in self._called(op.attrs, "calls"):
                inner = self.comp_cost(callee)
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.collectives.items():
                    slot = c.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
                    slot["count"] += v["count"]
                    slot["bytes"] += v["bytes"]
            c.bytes += self._fusion_bytes(op, shapes, in_bytes, out_bytes)
            return c

        if opc in _COLLECTIVES:
            base = opc.replace("-start", "")
            cb = in_bytes if base in ("reduce-scatter", "all-to-all") else max(in_bytes, out_bytes)
            slot = c.collectives.setdefault(base, {"count": 0.0, "bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += cb
            c.coll_bytes += cb
            c.bytes += in_bytes + out_bytes
            return c

        if opc == "dot":
            c.flops += self._dot_flops(op, shapes)
        elif opc == "convolution":
            c.flops += self._conv_flops(op, shapes)
        elif opc in ("reduce", "reduce-window", "scatter", "select-and-scatter", "sort"):
            c.flops += in_bytes / 4.0  # ~1 op per input element
        elif opc in ("custom-call", "rng", "rng-bit-generator", "infeed", "outfeed",
                     "send", "recv", "copy-start", "copy-done", "domain"):
            pass
        else:
            c.flops += out_bytes / 4.0  # elementwise-ish: 1 op per output element

        c.bytes += in_bytes + out_bytes
        return c

    def _fusion_bytes(self, op: Op, shapes: dict, in_bytes: float,
                      out_bytes: float) -> float:
        """Fusion call-site bytes with slice-granular access accounting.

        Inside a loop body, fusions often take a big loop-invariant buffer as
        a parameter and read only a dynamic-slice of it (scan xs / saved remat
        stacks), or alias it and write only a dynamic-update-slice (scan ys /
        stacking).  Hardware touches the slice; charging the full buffer per
        iteration over-counts by the trip count.  Parameters consumed
        exclusively by dynamic-slice are charged at slice size; a root
        dynamic-update-slice charges 2x the update and the aliased output
        charges nothing.
        """
        callees = self._called(op.attrs, "calls")
        if not callees:
            return in_bytes + out_bytes
        ops = self.comps.get(callees[0], [])
        if not ops:
            return in_bytes + out_bytes
        # dtype-conversion-only fusions are an XLA:CPU artifact: the CPU
        # backend upcasts bf16 dot operands to f32 through materialized
        # converts; the TPU MXU consumes bf16 directly and such converts fuse
        # into producers/consumers.  Charge zero.
        _layout_ops = {"convert", "copy", "bitcast", "reshape", "broadcast",
                       "parameter", "tuple", "get-tuple-element", "constant"}
        if all(o.opcode in _layout_ops for o in ops):
            return 0.0
        inner_shapes = {o.name: o.type_str for o in ops}
        # parameter index -> inner op name (index kept in attrs by the parser)
        param_by_idx: dict[int, str] = {}
        for o in ops:
            if o.opcode == "parameter":
                m = re.match(r"(\d+)", o.attrs)
                if m:
                    param_by_idx[int(m.group(1))] = o.name
        consumers: dict[str, list] = {}
        for o in ops:
            for ref in o.operands:
                consumers.setdefault(ref, []).append(o)

        def _elems(ts):
            _, dims = _shape_info(ts)
            n = 1
            for d in (dims[0] if dims else []):
                n *= d
            return n

        total = 0.0
        out_elems = _elems(op.type_str)
        # match on element count, not bytes: XLA:CPU sometimes round-trips a
        # bf16 buffer through f32 around the DUS (dtype differs, dims match)
        dus_root = next((o for o in ops if o.opcode == "dynamic-update-slice"
                         and _elems(o.type_str) == out_elems), None)
        aliased_param = dus_root.operands[0] if dus_root and dus_root.operands else None

        for k, operand in enumerate(op.operands):
            pname = param_by_idx.get(k)
            if pname is None:
                total += _shape_info(shapes.get(operand, ""))[0]
                continue
            cons = consumers.get(pname, [])
            if pname == aliased_param:
                continue  # aliased in-place buffer: charged via the update
            if cons and all(c.opcode == "dynamic-slice" for c in cons):
                total += sum(_shape_info(c.type_str)[0] for c in cons)
            else:
                total += _shape_info(shapes.get(operand, ""))[0]

        if dus_root is not None:
            upd = _shape_info(inner_shapes.get(dus_root.operands[1], ""))[0] \
                if len(dus_root.operands) > 1 else out_bytes
            total += 2 * upd
        else:
            total += out_bytes
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost("__entry__")


def breakdown(txt: str, top: int = 20, key: str = "bytes") -> list[tuple[float, str]]:
    """Attribute per-device bytes/flops to op_name tags (for perf iteration)."""
    model = HloCostModel(txt)
    mult: dict[str, float] = {"__entry__": 1.0}
    seen = {"__entry__"}
    q = ["__entry__"]
    while q:
        c = q.pop(0)
        for op in model.comps.get(c, []):
            tgts, f = [], 1.0
            if op.opcode == "while":
                m = re.search(r'known_trip_count\D*?(\d+)', op.attrs)
                f = float(m.group(1)) if m else 1.0
                tgts = model._called(op.attrs, "body") + model._called(op.attrs, "condition")
            elif op.opcode == "call":
                tgts = model._called(op.attrs, "to_apply")
            for t in tgts:
                mult[t] = mult.get(t, 0.0) + mult[c] * f
                if t not in seen:
                    seen.add(t)
                    q.append(t)
    acc: dict[str, float] = {}
    for cname, ops in model.comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        shapes = {op.name: op.type_str for op in ops}
        for op in ops:
            if op.opcode == "while":
                continue  # bodies counted via their own computations
            c = model._op_cost(op, shapes)
            val = getattr(c, key if key != "bytes" else "bytes")
            if val:
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                tag = meta.group(1) if meta else op.opcode
                tag = re.sub(r"\[.*?\]", "", tag)
                tag = f"{op.opcode}:{'/'.join(tag.split('/')[-2:])[:70]}"
                acc[tag] = acc.get(tag, 0.0) + val * m
    return sorted(((v, k) for k, v in acc.items()), reverse=True)[:top]


def analyze_hlo_text(txt: str) -> dict:
    cost = HloCostModel(txt).entry_cost()
    return {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "collective_bytes_per_device": cost.coll_bytes,
        "collectives": cost.collectives,
    }

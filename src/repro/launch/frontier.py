"""Frontier-search CLI — discover cost-efficient autoscaling configs.

Sweeps the joint (policy x fleet) parameter space through the vmapped
chunked ``lax.scan`` simulator across registered scenarios (coarse grid at
``coarse_frac`` x scale, successive-halving refine at full scale), then
emits per-scenario Pareto fronts, the cross-scenario robust frontier, and
oracle spot-check verdicts on sampled winners.

  PYTHONPATH=src python -m repro.launch.frontier --scale 0.1
  PYTHONPATH=src python -m repro.launch.frontier --scenario cold_tail \\
      --scenario diurnal --scale 0.25 --out-dir frontier_out
  PYTHONPATH=src python -m repro.launch.frontier --scale 1.0 --spot-check 5
  PYTHONPATH=src python -m repro.launch.frontier --scenario cold_tail \\
      --scale 0.25 --learned --learn-steps 60
  PYTHONPATH=src python -m repro.launch.frontier --scenario fleet_cost_stress \\
      --scale 0.1 --algo evo --budget 24 --seed 0

``--algo evo`` swaps the exhaustive grid for the NSGA-II population
optimizer (``repro.opt.evo``) over the same space and scenarios, budgeted
in simulated candidate-scenario pairs (``--budget``; default: exactly the
grid's own cost) — everything downstream (spot-checks, demotion, outputs)
applies unchanged.

``--learned`` additionally trains the gradient-learned policy family per
scenario (``repro.opt.learned``: jax.grad through the chunked scan),
evaluates it at the refine scale against the swept frontier, and
oracle-confirms it where the discrete replay is feasible.

Outputs in ``--out-dir``:
  frontier_<scenario>.csv   refined rows, with ``front``/``robust`` flags
  frontier_robust.csv       the robust frontier (one row per point x scenario)
  frontier.json             search summary + spot-check + learned records

Exit status is non-zero when a scenario ends with an empty oracle-confirmed
front or (with spot checks enabled) an oracle-feasible scenario where no
sampled winner passed the parity band.
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import sys

from repro.fleet.billing import get_profile, list_profiles
from repro.launch.flags import (add_run_flags, add_search_flags,
                                unknown_scenarios, validate_run_flags,
                                validate_search_flags)
from repro.opt.frontier import frontier_slack
from repro.opt.search import frontier_search, oracle_spot_check
from repro.opt.space import SWEEPABLE
from repro.scenarios import get_scenario, list_scenarios

_METRICS = ["cost_per_million", "slowdown_geomean_p99", "normalized_memory",
            "creation_rate", "cpu_overhead", "nodes_mean", "node_cost",
            "idle_cost", "churn_cost", "completed", "total_cost",
            "request_cost", "duration_cost", "warm_pool_cost", "billed_gb_s"]


def _columns(rows: list[dict]) -> list[str]:
    knobs = sorted({k for r in rows for k in r} & SWEEPABLE)
    return (["scenario", "point_id"] + knobs + _METRICS
            + ["front", "robust", "scale"])


def _write_csv(path: str, rows: list[dict]) -> None:
    # an empty robust frontier is a finding, not a missing artifact: the
    # file still lands, header-only, so downstream tooling sees the schema
    cols = _columns(rows) if rows else (["scenario", "point_id"] + _METRICS
                                        + ["front", "robust", "scale"])
    with open(path, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=cols, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow({k: (f"{v:.6g}" if isinstance(v, float) else v)
                        for k, v in r.items() if k in cols})


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.frontier",
        description="Cross-scenario multi-objective autoscaling-parameter "
                    "search (coarse+refine, Pareto + robust fronts).")
    ap.add_argument("--scenario", action="append", default=None,
                    help="scenario name (repeatable; default: every "
                         "registered event-level scenario)")
    ap.add_argument("--coarse-frac", type=float, default=0.1,
                    help="coarse stage runs at this fraction of --scale")
    ap.add_argument("--eps", type=float, default=0.15,
                    help="survivor slack band around the coarse front")
    ap.add_argument("--cap", type=int, default=12,
                    help="max survivors per scenario")
    ap.add_argument("--spot-check", type=int, default=3, metavar="K",
                    help="oracle-verify K winners per oracle-feasible "
                         "scenario, demoting refuted points (0 disables)")
    ap.add_argument("--learned", action="store_true",
                    help="also train the gradient-learned policy per "
                         "scenario and compare it against the swept front")
    ap.add_argument("--learn-steps", type=int, default=60,
                    help="gradient steps for --learned (default 60)")
    ap.add_argument("--learn-scale", type=float, default=None,
                    help="training trace scale for --learned "
                         "(default: the coarse scale)")
    ap.add_argument("--out-dir", default="frontier_out",
                    help="where CSV/JSON land (default frontier_out/)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--quiet", action="store_true")
    add_run_flags(ap, scale_default=1.0,
                  scale_help="refine-stage trace scale (default 1.0)",
                  telemetry="flag")
    add_search_flags(ap)
    return ap


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.list:
        for name in list_scenarios():
            sc = get_scenario(name)
            print(f"{name:20s} {sc.figure:45s} {sc.description}")
        from repro.core.policy_api import get_family, list_families
        from repro.core.simjax import _PFLEET
        from repro.fleet.spot import get_tier, list_tiers
        print("\nsweepable policy axes (per registered family):")
        for fam_name in list_families():
            fam = get_family(fam_name)
            axes = ", ".join(fam.sweepable_axes()) or "-"
            print(f"  {fam_name:12s} {axes}")
        print(f"fleet axes: {', '.join(_PFLEET)}")
        print("capacity tiers: " + ", ".join(
            f"{n} ({get_tier(n).price_multiplier:.2f}x, "
            f"{get_tier(n).hazard_per_hour:g}/h)" for n in list_tiers()))
        print("billing profiles (--billing):")
        for n in list_profiles():
            print(f"  {n:12s} {get_profile(n).description}")
        return 0

    say = (lambda s: None) if args.quiet else \
        (lambda s: print(s, file=sys.stderr))
    rc = validate_run_flags(args) or validate_search_flags(args)
    if rc:
        return rc
    if args.scenario:
        rc = unknown_scenarios(args.scenario)
        if rc:
            return rc
        names = list(args.scenario)
    else:
        # rate-based scenarios (fig9_planet) join a search only when named
        # explicitly — same default frontier_search applies
        names = [n for n in list_scenarios()
                 if not get_scenario(n).rate_trace]

    targets = names
    spot_check = args.spot_check
    if args.tier is not None:
        # search the TIERED scenario objects: hazard/notice/discount from
        # the named capacity tier.  Oracle spot-checks would replay the
        # UNTIERED registry entries (the check resolves scenarios by
        # name), so they are skipped under --tier.
        from repro.fleet.spot import get_tier
        from repro.scenarios.runner import apply_tier
        tier = get_tier(args.tier)
        targets = []
        for n in names:
            tiered = apply_tier(get_scenario(n), tier)
            if tiered is None:
                print(f"note: {n} has no spot-capable policy/fleet; "
                      f"--tier {tier.name} ignored for it", file=sys.stderr)
                targets.append(n)
            else:
                targets.append(tiered)
        if spot_check > 0:
            say(f"note: oracle spot-checks are skipped under --tier "
                f"{tier.name} (they replay untiered registry entries)")
            spot_check = 0

    telem = None
    if args.telemetry:
        from repro.obs import RunTelemetry
        telem = RunTelemetry()
    result = frontier_search(targets, scale=args.scale,
                             coarse_frac=args.coarse_frac, eps=args.eps,
                             survivor_cap=args.cap, billing=args.billing,
                             log=say, telemetry=telem, devices=args.devices,
                             cluster=args.cluster, algo=args.algo,
                             budget=args.budget, seed=args.seed)
    checks = []
    if spot_check > 0:
        import numpy as np
        checks = oracle_spot_check(result, k=spot_check, log=say,
                                   telemetry=telem,
                                   rng=np.random.default_rng(args.seed))

    learned_records = []
    if args.learned:
        from repro.opt.learned import confirm, evaluate_trained, train_policy
        learn_scale = args.learn_scale if args.learn_scale is not None \
            else result.coarse_scale
        for name in sorted(result.fronts):
            sc = get_scenario(name)
            res = train_policy(name, scale=learn_scale,
                               steps=args.learn_steps, log=say,
                               telemetry=telem)
            row = evaluate_trained(name, res, scale=args.scale,
                                   billing=args.billing)
            front = result.fronts[name]
            slack = frontier_slack(row, front)
            rec = {"scenario": name, "train": res.summary(),
                   "cost_per_million": row["cost_per_million"],
                   "slowdown_geomean_p99": row["slowdown_geomean_p99"],
                   "frontier_slack": slack,
                   "on_front": slack <= 1.0 + 1e-9}
            if sc.oracle_ok:
                rec["oracle"] = confirm(name, res)
            learned_records.append(rec)
            say(f"learned {name}: cost {row['cost_per_million']:.3g} "
                f"p99 {row['slowdown_geomean_p99']:.3g} "
                f"slack {slack:.3f}"
                + (f" oracle {'ok' if rec.get('oracle', {}).get('pass') else 'REFUTED'}"
                   if "oracle" in rec else ""))

    os.makedirs(args.out_dir, exist_ok=True)
    robust = set(result.robust_ids)
    for name, rows in sorted(result.refined.items()):
        front_ids = {r["point_id"] for r in result.fronts[name]}
        for r in rows:
            r["front"] = r["point_id"] in front_ids
            r["robust"] = r["point_id"] in robust
        _write_csv(os.path.join(args.out_dir, f"frontier_{name}.csv"), rows)
    _write_csv(os.path.join(args.out_dir, "frontier_robust.csv"),
               result.robust_rows())

    payload = {"summary": result.summary(),
               "spot_checks": checks,
               "learned": learned_records,
               "argv": {"scale": args.scale, "coarse_frac": args.coarse_frac,
                        "eps": args.eps, "cap": args.cap,
                        "spot_check": args.spot_check,
                        "learned": args.learned,
                        "billing": args.billing, "tier": args.tier,
                        "devices": args.devices, "cluster": args.cluster,
                        "algo": args.algo, "budget": args.budget,
                        "seed": args.seed}}
    with open(os.path.join(args.out_dir, "frontier.json"), "w") as fh:
        json.dump(payload, fh, indent=2, default=float)
    if telem is not None:
        tpath = os.path.join(args.out_dir, "telemetry.json")
        telem.write_json(tpath)
        say(f"run telemetry ({len(telem.events)} events) -> {tpath}")

    failures = []
    for name in sorted(result.fronts):
        if not result.fronts[name]:
            failures.append(f"{name}: empty oracle-confirmed front")
    if args.spot_check > 0:
        by = {}
        for c in checks:
            by.setdefault(c["scenario"], []).append(c)
        for name, recs in sorted(by.items()):
            n_ok = sum(r["pass"] for r in recs)
            say(f"spot-check {name}: {n_ok}/{len(recs)} winners confirmed")
            if n_ok == 0:
                failures.append(f"{name}: no sampled winner passed the "
                                f"oracle parity band")
    say(f"robust frontier: {len(result.robust_ids)} point(s) "
        f"{[result.points[i] for i in result.robust_ids]}")
    say(f"total wall {result.wall_s:.1f}s; outputs in {args.out_dir}/")
    for f in failures:
        print(f"FRONTIER FAILURE: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Trace one scenario through both engines with full observability.

The one-command window into WHERE autoscaling overhead goes: replays a
registered scenario through the discrete-event oracle with request/
instance/node lifecycle spans recorded, and through the chunked ``lax.scan``
simulator with in-scan telemetry attached, then prints the two engines'
overhead-attribution ledgers side by side (creation / eviction-storm /
keepalive-idle / master-control CPU; busy / warm-idle / pipeline memory)
with their component-level parity gaps.

Usage:
  PYTHONPATH=src python -m repro.launch.trace diurnal
  PYTHONPATH=src python -m repro.launch.trace spot_storm --scale 0.1
  PYTHONPATH=src python -m repro.launch.trace diurnal --out-dir trace_out \\
      --slots 400 --check

Outputs in ``--out-dir`` (default ``trace_out/``):
  trace.json             oracle span tree, Chrome-trace format — load it in
                         Perfetto (ui.perfetto.dev) or chrome://tracing
  timeline_oracle.csv    the oracle's per-tick memory/node samples
  timeline_simjax.csv    the fluid engine's downsampled telemetry series
  ledger.json            both ledgers + component parity gaps + span stats

``--check`` exits non-zero when span validation, either engine's
attribution-sum consistency, or (with both engines) the component parity
band fails — the CI smoke gate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.runspec import RunSpec
from repro.launch.flags import (add_run_flags, unknown_scenarios,
                                validate_run_flags)
from repro.obs import (SpanRecorder, attribution_table, check_ledger,
                       ledger_from_chunked, ledger_from_eventsim,
                       ledger_parity, validate, write_oracle_timeline_csv,
                       write_timeline_csv)
from repro.scenarios import get_scenario, run_scenario

# the component-parity band --check judges: same 15% the aggregate
# parity tests pin (see repro.obs.ledger.ledger_parity for normalization)
PARITY_TOL = 0.15


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.trace",
        description="Replay one scenario through both engines with spans, "
                    "telemetry, and the overhead-attribution ledger.")
    ap.add_argument("scenario", help="registered scenario name")
    ap.add_argument("--out-dir", default="trace_out",
                    help="artifact directory (default trace_out/)")
    ap.add_argument("--engines", default="both",
                    choices=["both", "eventsim", "simjax"])
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on span-validation, attribution-sum, or "
                         "component-parity failure (the CI gate)")
    add_run_flags(ap, scale_default=0.25,
                  scale_help="trace scale (default 0.25, the oracle-"
                             "feasible parity calibration point)",
                  telemetry="slots")
    return ap


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)

    rc = unknown_scenarios([args.scenario]) or validate_run_flags(args)
    if rc:
        return rc

    engines = (("eventsim", "simjax") if args.engines == "both"
               else (args.engines,))
    target = args.scenario
    if args.tier is not None:
        from repro.fleet.spot import get_tier
        from repro.scenarios.runner import apply_tier
        tier = get_tier(args.tier)
        tiered = apply_tier(get_scenario(args.scenario), tier)
        if tiered is None:
            print(f"note: {args.scenario} has no spot-capable policy/"
                  f"fleet; --tier {tier.name} ignored", file=sys.stderr)
        else:
            target = tiered
    rate_based = (get_scenario(args.scenario).rate_trace
                  or args.cluster > 0)
    if rate_based and "eventsim" in engines:
        print("note: rate-based workload (rate_trace scenario or "
              "--cluster); the oracle leg is skipped — fluid-only ledger",
              file=sys.stderr)
    obs = SpanRecorder(enabled=True) if "eventsim" in engines else None
    detail: dict = {}
    rows = run_scenario(target, detail=detail,
                        spec=RunSpec(engines=engines, scale=args.scale,
                                     force_oracle="eventsim" in engines,
                                     obs=obs, telemetry=max(1, args.slots),
                                     billing=args.billing,
                                     devices=args.devices,
                                     cluster=args.cluster))
    os.makedirs(args.out_dir, exist_ok=True)

    failures: list[str] = []
    ledgers = []
    span_stats: dict = {}

    if obs is not None:
        path = os.path.join(args.out_dir, "trace.json")
        obs.write_json(path)
        problems = validate(obs)
        span_stats = {"spans": len(obs.spans),
                      "validation_problems": problems}
        print(f"span trace: {len(obs.spans)} spans -> {path}"
              + (f"  [{len(problems)} VALIDATION PROBLEMS]"
                 if problems else ""))
        for p in problems[:10]:
            print(f"  span problem: {p}", file=sys.stderr)
        failures += problems

    if "oracle_result" in detail:
        res = detail["oracle_result"]
        path = os.path.join(args.out_dir, "timeline_oracle.csv")
        write_oracle_timeline_csv(res, path)
        print(f"oracle timeline ({len(res.sample_times)} ticks) -> {path}")
        led = ledger_from_eventsim(res)
        failures += check_ledger(led)
        ledgers.append(led)

    if "fluid_summary" in detail:
        summary = detail["fluid_summary"]
        telem = summary.get("telemetry")
        if telem:
            path = os.path.join(args.out_dir, "timeline_simjax.csv")
            write_timeline_csv(telem, path)
            print(f"fluid timeline ({telem['slots']} slots) -> {path}")
            led = ledger_from_chunked(summary)
            failures += check_ledger(led)
            ledgers.append(led)

    gaps: dict = {}
    if ledgers:
        print()
        print(attribution_table(ledgers))
        if len(ledgers) == 2:
            gaps = ledger_parity(ledgers[0], ledgers[1])
            bad = {k: g for k, g in gaps.items() if g > PARITY_TOL}
            for k, g in bad.items():
                failures.append(f"component parity {k}: gap {g:.3f} "
                                f"> {PARITY_TOL}")

    # the telemetry series already landed in timeline_simjax.csv; the
    # ledger JSON keeps the scalar rows only
    rows = [{k: v for k, v in r.items() if k != "telemetry"} for r in rows]
    payload = {"scenario": args.scenario, "scale": args.scale,
               "rows": rows, "spans": span_stats,
               "ledgers": [led.row() for led in ledgers],
               "component_parity": gaps, "failures": failures}
    lpath = os.path.join(args.out_dir, "ledger.json")
    with open(lpath, "w") as fh:
        json.dump(payload, fh, indent=2, default=float)
    print(f"\nledger -> {lpath}")

    for f in failures:
        print(f"TRACE FAILURE: {f}", file=sys.stderr)
    return 1 if (args.check and failures) else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Perf-iteration driver: lower+compile one cell under a named variant and
report the roofline terms.  Used by the EXPERIMENTS.md §Perf loop.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch granite-34b \
      --shape decode_32k --variant unroll
"""

import argparse
import json
import os
import time


def _setup_xla_env() -> None:
    """Fake a 512-device host for mesh experiments.  Called from main()
    BEFORE jax is imported (run() imports it lazily): mutating XLA_FLAGS at
    module import time would leak into anything that merely imports this
    module (tests, tooling) and silently poison an already-initialized jax.
    Caller-provided XLA_FLAGS are preserved; the device-count flag this
    module REQUIRES (the production mesh lays out over 512 fake devices)
    is appended unless the caller already pinned one."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        flags = (flags + " " if flags else "") \
            + "--xla_force_host_platform_device_count=512"
    if os.environ.get("REPRO_XLA_EXTRA"):
        flags += " " + os.environ["REPRO_XLA_EXTRA"]
    os.environ["XLA_FLAGS"] = flags

VARIANTS: dict[str, dict] = {
    "baseline": {},
    # decode
    "unroll": {"overrides": {"scan_layers": False}},
    "unroll_seqshard": {"overrides": {"scan_layers": False}, "kv_seq": "model"},
    # train
    "remat_dots": {"overrides": {"remat": "dots"}},
    "micro4": {"n_microbatches": 4},
    "micro16": {"n_microbatches": 16},
    "micro4_dots": {"n_microbatches": 4, "overrides": {"remat": "dots"}},
    "no_fsdp": {"fsdp": False},
    "ragged_moe": {"overrides": {"moe_impl": "ragged"}},
    "ragged_micro4": {"overrides": {"moe_impl": "ragged"}, "n_microbatches": 4},
    "cap10": {"overrides": {"capacity_factor": 1.0}},
    "micro4_cap10": {"n_microbatches": 4, "overrides": {"capacity_factor": 1.0}},
    "qblock1k": {"qblock": 1024},
    "scores_bf16": {"overrides": {"attn_scores_dtype": "bfloat16"}},
    "scores_bf16_micro4": {"overrides": {"attn_scores_dtype": "bfloat16"},
                           "n_microbatches": 4},
}


def run(arch: str, shape: str, variant: str) -> dict:
    import jax

    from repro.configs import get_config
    from repro.distributed import sharding as shlib
    from repro.launch import cells as cell_lib
    from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS
    from repro.launch.hlo_analysis import analyze_hlo_text
    from repro.launch.mesh import make_production_mesh

    spec = dict(VARIANTS[variant])
    if spec.pop("kv_seq", None):
        shlib.LOGICAL_RULES["kv_seq"] = "model"
    if spec.pop("qblock", None):
        pass  # q_block is currently fixed in the model; reserved
    cfg = get_config(arch)
    mesh = make_production_mesh()
    fn, args, donate = cell_lib.build_cell(
        cfg, shape, mesh, fsdp=spec.pop("fsdp", True),
        n_microbatches=spec.pop("n_microbatches", None),
        overrides=spec.pop("overrides", None))

    t0 = time.time()
    compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    r = analyze_hlo_text(compiled.as_text())
    out = {
        "arch": arch, "shape": shape, "variant": variant,
        "compile_s": round(compile_s, 1),
        "compute_ms": r["flops_per_device"] / PEAK_FLOPS * 1e3,
        "memory_ms": r["bytes_per_device"] / HBM_BW * 1e3,
        "collective_ms": r["collective_bytes_per_device"] / LINK_BW * 1e3,
        "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
        "arg_gib": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
        "collectives": r["collectives"],
    }
    out["step_ms_bound"] = max(out["compute_ms"], out["memory_ms"],
                               out["collective_ms"])
    return out


def main():
    _setup_xla_env()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    out = run(args.arch, args.shape, args.variant)
    out.pop("collectives")
    print(json.dumps(out))


if __name__ == "__main__":
    main()

"""Serving launcher: autoscaled model serving on the local device.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --policy sync \
      --keepalive 30 --duration 30 --rps 2

Runs the REAL control plane (repro.core.control_plane) over real JAX model
replicas; prints the paper's metrics for the run.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.core.control_plane import ControlPlane, JaxWorkerBackend
from repro.core.policies import make_policy
from repro.serving.engine import ServeRequest


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--policy", default="sync", choices=["sync", "async", "hybrid"])
    ap.add_argument("--keepalive", type=float, default=30.0)
    ap.add_argument("--window", type=float, default=10.0)
    ap.add_argument("--target", type=float, default=0.7)
    ap.add_argument("--cc", type=int, default=2)
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--rps", type=float, default=1.0)
    ap.add_argument("--functions", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch).replace(param_dtype="bfloat16", remat="none")
    kw = {"container_concurrency": args.cc}
    if args.policy == "sync":
        kw["keepalive_s"] = args.keepalive
    elif args.policy == "async":
        kw.update(window_s=args.window, target=args.target)
    backend = JaxWorkerBackend(cfg, max_slots=args.cc, max_seq=64)
    cp = ControlPlane(backend, lambda f: make_policy(args.policy, **kw),
                      num_functions=args.functions)

    rng = np.random.default_rng(0)
    arrivals = np.sort(rng.uniform(0, args.duration,
                                   int(args.rps * args.duration)))
    fns = rng.integers(0, args.functions, len(arrivals))
    t0 = time.monotonic()
    i = 0
    mem_samples, busy_samples = [], []
    while True:
        now = time.monotonic() - t0
        while i < len(arrivals) and arrivals[i] <= now:
            cp.submit(ServeRequest(rid=i, fn=int(fns[i]), prompt=[1, 2, 3],
                                   max_new_tokens=args.max_new_tokens,
                                   arrival_t=now), now)
            i += 1
        cp.tick(now)
        snap = cp.snapshot()
        mem_samples.append(snap["memory_bytes"])
        busy_samples.append(max(snap["busy_memory_bytes"], 1))
        if i >= len(arrivals) and len(cp.completed) >= len(arrivals):
            break
        if now > args.duration + 120:
            break
        time.sleep(0.005)

    lat = [r.done_t - r.arrival_t for r in cp.completed]
    cold = [r.cold for r in cp.completed]
    print(f"served {len(cp.completed)}/{len(arrivals)} requests")
    print(f"latency p50={np.percentile(lat,50):.2f}s p99={np.percentile(lat,99):.2f}s")
    print(f"cold fraction: {np.mean(cold)*100:.1f}%")
    print(f"instance creations: {backend.creations}, teardowns: {backend.teardowns}")
    print(f"measured cold starts: {[f'{c:.2f}' for c in backend.cold_start_times[:5]]}")
    print(f"normalized memory: {np.mean(mem_samples)/np.mean(busy_samples):.2f}")


if __name__ == "__main__":
    main()

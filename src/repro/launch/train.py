"""Training launcher: real steps on the local device(s), checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 50

Fault tolerance: checkpoints are step-atomic; rerunning the same command
resumes from the latest complete checkpoint (data pipeline included — batches
are a pure function of (seed, step)).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import registry
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, jax_batch_at
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                         total_steps=args.steps))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch)

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    start = 0
    if args.ckpt_dir:
        restored = ckpt.restore_latest(args.ckpt_dir, {"p": params, "o": opt_state})
        if restored:
            start, tree, extra = restored
            params, opt_state = tree["p"], tree["o"]
            print(f"resumed from step {start}")

    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        extras["enc_embeds"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = jax_batch_at(dc, step, extras)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0:
            print(f"step {step+1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, {"p": params, "o": opt_state},
                      extra={"arch": args.arch})
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s")
    return params


if __name__ == "__main__":
    main()

"""Scenario runner CLI — the entry point behind the scenario benchmarks.

Replays registered workload scenarios through the discrete-event oracle
and/or the chunked lax.scan simulator and emits one CSV metric row per
(scenario, engine) pair, the format ``benchmarks/`` consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.scenarios --list
  PYTHONPATH=src python -m repro.launch.scenarios --scenario diurnal
  PYTHONPATH=src python -m repro.launch.scenarios --all --scale 0.25
  PYTHONPATH=src python -m repro.launch.scenarios --scenario flash_crowd \\
      --engines simjax --scale 1.0 --csv out.csv

``--scale`` shrinks the workload isotropically (functions, duration, load)
— transforms are fraction-based, so the scenario's shape is preserved; the
CI smoke job runs the smallest scenario at a small scale through BOTH
engines.  At full scale the oracle leg of scenarios flagged
``oracle_ok=False`` (the 2000-function Fig. 9 replay) is skipped unless
``--force-oracle`` is given; the chunked simulator handles them easily.

The shared run-configuration flags (``--scale`` / ``--billing`` /
``--tier`` / ``--devices`` / ``--cluster``) are declared in
``repro.launch.flags`` and map onto ``repro.core.runspec.RunSpec``:
``--devices 8`` shards the fluid scan's function axis across eight local
devices (pair with XLA_FLAGS=--xla_force_host_platform_device_count=8 on
CPU), ``--cluster 0.05`` buckets the sub-0.05-rps long tail into weighted
super-functions (fluid-only: the oracle leg drops).
"""

from __future__ import annotations

import argparse
import csv
import sys

from repro.core.runspec import RunSpec
from repro.fleet.billing import get_profile, list_profiles
from repro.fleet.spot import get_tier, list_tiers
from repro.launch.flags import (add_run_flags, unknown_scenarios,
                                validate_run_flags)
from repro.scenarios import (ENGINES, get_scenario, list_scenarios,
                             parity_report, run_scenario)
from repro.scenarios.runner import apply_tier

# stable CSV column order: identity, run info, the paper metric core, then
# the billed-dollar columns (empty unless --billing is given)
_COLUMNS = ["scenario", "engine", "scale", "num_functions", "invocations",
            "wall_s", "slowdown_geomean_p99", "normalized_memory",
            "creation_rate", "cpu_overhead", "worker_share", "nodes_mean",
            "completed", "dropped", "figure", "billing", "total_cost",
            "cost_per_million", "billed_gb_s"]


def _emit(rows: list[dict], out) -> None:
    writer = csv.DictWriter(out, fieldnames=_COLUMNS, extrasaction="ignore")
    writer.writeheader()
    for r in rows:
        writer.writerow({k: (f"{v:.6g}" if isinstance(v, float) else v)
                         for k, v in r.items() if k in _COLUMNS})


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.scenarios",
        description="Replay workload scenarios through both simulators.")
    ap.add_argument("--scenario", action="append", default=None,
                    help="scenario name (repeatable); see --list")
    ap.add_argument("--all", action="store_true", help="run every scenario")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--engines", default="both",
                    choices=["both", "eventsim", "simjax"])
    ap.add_argument("--csv", default=None, help="write CSV here (default stdout)")
    ap.add_argument("--parity", action="store_true",
                    help="print oracle-vs-simjax relative gaps to stderr")
    ap.add_argument("--force-oracle", action="store_true",
                    help="run the discrete-event oracle even for scenarios "
                         "flagged infeasible at this scale")
    ap.add_argument("--cells", type=int, default=None, metavar="N",
                    help="override a cells scenario's cell count (ignored "
                         "with a note for scenarios without a topology)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record the oracle leg's request/instance/node "
                         "lifecycle spans and write a Chrome-trace JSON "
                         "here (requires exactly one scenario and an "
                         "eventsim leg)")
    add_run_flags(ap, scale_default=1.0, telemetry="dir")
    return ap


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.list:
        for name in list_scenarios():
            sc = get_scenario(name)
            print(f"{name:20s} {sc.figure:45s} {sc.description}")
        print("\ncapacity tiers (--tier):")
        for name in list_tiers():
            t = get_tier(name)
            print(f"  {name:12s} {t.price_multiplier:.2f}x on-demand, "
                  f"{t.hazard_per_hour:g} reclaims/node-hour, "
                  f"{t.reclaim_notice_s:g}s notice")
        print("\nbilling profiles (--billing):")
        for name in list_profiles():
            print(f"  {name:12s} {get_profile(name).description}")
        return 0

    rc = validate_run_flags(args)
    if rc:
        return rc
    tier = get_tier(args.tier) if args.tier is not None else None

    names = list_scenarios() if args.all else (args.scenario or [])
    if not names:
        ap.error("pick --scenario NAME (repeatable), --all, or --list")
    rc = unknown_scenarios(names)
    if rc:
        return rc
    engines = ENGINES if args.engines == "both" else (args.engines,)
    if args.cluster > 0 and "eventsim" in engines:
        print("note: --cluster produces a rate-based workload; the "
              "eventsim leg is skipped", file=sys.stderr)

    # observability flags are validated up front, friendly-error style:
    # a span trace needs exactly one oracle leg, telemetry a simjax leg
    if args.trace_out is not None:
        if "eventsim" not in engines:
            print("--trace-out records the oracle leg; pick --engines "
                  "both or eventsim", file=sys.stderr)
            return 2
        if len(names) != 1:
            print(f"--trace-out records one scenario's spans, got "
                  f"{len(names)}; pick a single --scenario", file=sys.stderr)
            return 2
    if args.telemetry is not None and "simjax" not in engines:
        print("--telemetry samples the simjax leg; pick --engines both "
              "or simjax", file=sys.stderr)
        return 2

    obs = None
    if args.trace_out is not None:
        from repro.obs import SpanRecorder
        obs = SpanRecorder(enabled=True)
    telem_slots = (max(1, args.telemetry_slots)
                   if args.telemetry is not None else 0)
    if args.telemetry is not None:
        import os
        os.makedirs(args.telemetry, exist_ok=True)

    rows = []
    for name in names:
        target = name
        if tier is not None:
            tiered = apply_tier(get_scenario(name), tier)
            if tiered is None:
                print(f"note: {name} has no spot-capable policy/fleet; "
                      f"--tier {tier.name} ignored for it", file=sys.stderr)
            else:
                target = tiered
        if args.cells is not None:
            sc_obj = get_scenario(target) if isinstance(target, str) \
                else target
            if sc_obj.cells is None:
                print(f"note: {name} has no cell topology; --cells ignored "
                      f"for it", file=sys.stderr)
            else:
                import dataclasses
                # the topology re-validates, so a fail_cell or trigger
                # aimed at a now-missing cell errors loudly here
                target = dataclasses.replace(
                    sc_obj, cells=dataclasses.replace(
                        sc_obj.cells, cell_count=args.cells))
        detail: dict = {}
        sc_rows = run_scenario(target, detail=detail,
                               spec=RunSpec(engines=engines,
                                            scale=args.scale,
                                            force_oracle=args.force_oracle,
                                            obs=obs, telemetry=telem_slots,
                                            billing=args.billing,
                                            devices=args.devices,
                                            cluster=args.cluster))
        if args.telemetry is not None and "fluid_summary" in detail \
                and detail["fluid_summary"].get("telemetry"):
            from repro.obs import write_timeline_csv
            import os
            path = os.path.join(args.telemetry, f"timeline_{name}.csv")
            write_timeline_csv(detail["fluid_summary"]["telemetry"], path)
            print(f"telemetry timeline -> {path}", file=sys.stderr)
        rows.extend(sc_rows)
        if args.parity:
            gaps = parity_report(sc_rows)
            if gaps:
                print(f"parity {name}: " +
                      " ".join(f"{k}={v:.3f}" for k, v in gaps.items()),
                      file=sys.stderr)

    if obs is not None:
        if not obs.spans:
            print("note: no spans recorded — the oracle leg was skipped at "
                  "this scale (see --force-oracle)", file=sys.stderr)
        obs.write_json(args.trace_out)
        print(f"span trace ({len(obs.spans)} spans) -> {args.trace_out}",
              file=sys.stderr)

    if args.csv:
        with open(args.csv, "w", newline="") as fh:
            _emit(rows, fh)
    else:
        _emit(rows, sys.stdout)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

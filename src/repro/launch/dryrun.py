import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_XLA_EXTRA"):  # e.g. --xla_dump_to=... for debugging
    os.environ["XLA_FLAGS"] += " " + os.environ["REPRO_XLA_EXTRA"]

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline terms from the compiled artifact.

The two lines above MUST stay the first statements in this module (before
any jax import) — jax locks the device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all [--multi-pod both]
With --arch all, each cell runs in a subprocess (crash isolation, bounded
RSS); results land in artifacts/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link


def run_cell(arch: str, shape_name: str, multi_pod: bool, save_hlo: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.configs.base import cells
    from repro.launch import cells as cell_lib
    from repro.launch.hlo_analysis import analyze_hlo_text
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if shape_name not in cells(arch):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k skipped for full-attention arch (DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    fn, args, donate = cell_lib.build_cell(cfg, shape_name, mesh)

    t0 = time.time()
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    hlo = compiled.as_text()
    mine = analyze_hlo_text(hlo)

    flops_dev = mine["flops_per_device"]
    bytes_dev = mine["bytes_per_device"]
    coll_dev = mine["collective_bytes_per_device"]

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "xla_cost_analysis": {"flops": ca.get("flops"),
                              "bytes_accessed": ca.get("bytes accessed")},
        "per_device": {"flops": flops_dev, "bytes": bytes_dev,
                       "collective_bytes": coll_dev},
        "collectives": mine["collectives"],
        "roofline_s": {
            "compute": flops_dev / PEAK_FLOPS,
            "memory": bytes_dev / HBM_BW,
            "collective": coll_dev / LINK_BW,
        },
    }
    terms = result["roofline_s"]
    result["bottleneck"] = max(terms, key=terms.get)
    if save_hlo:
        result["hlo_path"] = _artifact_path(arch, shape_name, multi_pod, ext=".hlo.txt")
        with open(result["hlo_path"], "w") as f:
            f.write(hlo)
    # spec-mandated prints
    print(mem)
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    return result


def _artifact_path(arch, shape, multi_pod, ext=".json"):
    os.makedirs(ARTIFACTS, exist_ok=True)
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(ARTIFACTS, f"{arch}__{shape}__{mesh}{ext}")


def _run_one_subprocess(arch, shape, multi_pod, save_hlo) -> dict:
    path = _artifact_path(arch, shape, multi_pod)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--multi-pod", "on" if multi_pod else "off",
           "--out", path]
    if save_hlo:
        cmd.append("--save-hlo")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=3600)
    if r.returncode != 0 or not os.path.exists(path):
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "error": (r.stderr or "")[-2000:]}
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="off", choices=["on", "off", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    from repro.configs import list_archs
    from repro.configs.base import cells

    archs = list_archs() if args.arch == "all" else [args.arch]
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]

    if len(archs) == 1 and args.shape != "all":
        # single cell, in-process
        res = {}
        for mp in pods:
            try:
                res = run_cell(archs[0], args.shape, mp, save_hlo=args.save_hlo)
            except Exception:
                res = {"arch": archs[0], "shape": args.shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": traceback.format_exc()[-3000:]}
            out = args.out or _artifact_path(archs[0], args.shape, mp)
            with open(out, "w") as f:
                json.dump(res, f, indent=1)
            print(json.dumps({k: res.get(k) for k in
                              ("arch", "shape", "mesh", "compile_s",
                               "bottleneck", "error", "skipped")}))
        sys.exit(0 if "error" not in res else 1)

    failures = 0
    for arch in archs:
        shapes = cells(arch) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mp in pods:
                t0 = time.time()
                res = _run_one_subprocess(arch, shape, mp, args.save_hlo)
                ok = "error" not in res
                failures += not ok
                print(f"{'OK  ' if ok else 'FAIL'} {arch:22s} {shape:12s} "
                      f"{'2x16x16' if mp else '16x16':8s} "
                      f"{time.time()-t0:6.1f}s  bottleneck={res.get('bottleneck')}",
                      flush=True)
                if not ok:
                    print("  " + res["error"].splitlines()[-1] if res.get("error") else "")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

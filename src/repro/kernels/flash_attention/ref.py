"""Pure-jnp oracle for the flash attention kernel.

q: (B, H, S, D); k, v: (B, K, T, D) with H = K * G (GQA).
Supports causal masking, sliding windows and gemma-style logit softcap.
"""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        q_offset: int = 0):
    b, h, s, d = q.shape
    kheads, t = k.shape[1], k.shape[2]
    g = h // kheads
    qr = q.reshape(b, kheads, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qr, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = q_offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)

"""Flash attention forward, Pallas TPU.

Layout: q (BH, S, D); k, v (BK, T, D) — batch and heads flattened so the
grid's first dim is one (batch, q-head) pair; the GQA group mapping
(q head -> kv head) happens in the BlockSpec index_maps.

Grid: (BH, S // block_q, T // block_k), dimension semantics
(parallel, parallel, arbitrary): the innermost kv dim runs sequentially per
(bh, qi) so the online-softmax accumulators can live in VMEM scratch:
  m (block_q, 1) running max, l (block_q, 1) running denominator,
  acc (block_q, D) fp32 running numerator.
Output is written once, on the last kv block (standard revisiting pattern).

VMEM budget per step (bf16, block_q = block_k = 512, D = 128):
  q 128KB + k 128KB + v 128KB + acc 256KB + scores 1MB(f32) ~= 1.7MB << 16MB.
MXU alignment: block_q/block_k multiples of 128; D padded by Mosaic if < 128.

Sliding windows skip fully-masked kv blocks via @pl.when (no FLOPs issued on
TPU for those grid points beyond the branch).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], block_q: int, block_k: int,
               n_kv: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    q_start = qi * block_q + q_offset
    k_start = kj * block_k

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level relevance: causal -> kv block must start at/before the last
    # q row; window -> kv block must end after the first q row's window start
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window is not None:
        relevant = relevant & (k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, D)
        k = k_ref[0].astype(jnp.float32)          # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        q_offset: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q: (BH, S, D); k, v: (BK, T, D); BH = BK * G.  Returns (BH, S, D)."""
    bh, s, d = q.shape
    bk, t, _ = k.shape
    g = bh // bk
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0
    n_kv = t // block_k
    grid = (bh, s // block_q, n_kv)

    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_kv=n_kv,
        q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, _g=g: (b // _g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j, _g=g: (b // _g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)

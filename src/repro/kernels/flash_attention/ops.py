"""Jit'd public wrapper: (B, S, H, D) model layout -> kernel layout."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_offset", "block_q", "block_k",
    "interpret", "impl"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_offset: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False, impl: str = "pallas"):
    """q: (B, S, H, D); k, v: (B, T, K, D).  Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    if impl == "ref":
        out = flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            softcap=softcap, q_offset=q_offset)
        return out.transpose(0, 2, 1, 3)
    # (B, S, H, D) -> (B*H, S, D) with q heads grouped by kv head so that the
    # kernel's index_map b // g lands on the right kv head
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    vv = v.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    out = flash_attention_fwd(qk, kk, vv, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)

"""Pure-jnp oracle for single-token GQA decode attention.

q: (B, K, G, D) one query token per sequence (G q-heads per kv head);
k, v: (B, K, T, D) full cache; pos: (B,) current absolute positions
(keys at indices > pos are masked).
"""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, *, softcap: Optional[float] = None):
    b, kh, g, d = q.shape
    t = k.shape[2]
    scores = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    mask = jnp.arange(t)[None, :] <= pos[:, None]          # (B, T)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bkgt,bktd->bkgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)

"""Single-token decode attention (FlashDecoding-style), Pallas TPU.

One grid row per (batch, kv-head); the G grouped q-heads form the row
dimension of the MXU matmul (G x block_k scores per step), so GQA decode
keeps the MXU busy even at query length 1.  The kv axis is the innermost
sequential grid dim; online-softmax accumulators (m, l, acc) live in VMEM
scratch and the output is written on the last kv block.

kv blocks beyond the current position (pos is a per-batch s32 scalar in
SMEM) are skipped entirely with @pl.when — decode cost is O(pos), not
O(T_max), which is what makes the 500k-context decode shapes viable.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, softcap: Optional[float], block_k: int,
                n_kv: int, kv_heads: int):
    bk = pl.program_id(0)
    kj = pl.program_id(1)
    b = bk // kv_heads
    pos = pos_ref[b]
    k_start = kj * block_k

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k_start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (G, D)
        k = k_ref[0].astype(jnp.float32)          # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, pos, *, softcap: Optional[float] = None,
                         block_k: int = 512, interpret: bool = False):
    """q: (BK, G, D); k, v: (BK, T, D); pos: (B,) s32.  BK = B * kv_heads."""
    bk_total, g, d = q.shape
    t = k.shape[1]
    b = pos.shape[0]
    kv_heads = bk_total // b
    block_k = min(block_k, t)
    assert t % block_k == 0
    n_kv = t // block_k
    grid = (bk_total, n_kv)

    kernel = functools.partial(
        _dec_kernel, scale=1.0 / math.sqrt(d), softcap=softcap,
        block_k=block_k, n_kv=n_kv, kv_heads=kv_heads)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, g, d), lambda bkh, j, pos_ref: (bkh, 0, 0)),
                pl.BlockSpec((1, block_k, d), lambda bkh, j, pos_ref: (bkh, j, 0)),
                pl.BlockSpec((1, block_k, d), lambda bkh, j, pos_ref: (bkh, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, g, d), lambda bkh, j, pos_ref: (bkh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bk_total, g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, k, v)

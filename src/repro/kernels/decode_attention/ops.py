"""Jit'd wrapper: model layout (B, 1, H, D) + cache (B, T, K, D) -> kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_fwd
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("softcap", "block_k", "interpret", "impl"))
def decode_attention(q, k, v, pos, *, softcap: Optional[float] = None,
                     block_k: int = 512, interpret: bool = False,
                     impl: str = "pallas"):
    """q: (B, 1, H, D); k, v: (B, T, K, D); pos: (B,).  -> (B, 1, H, D)."""
    b, _, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, d)
    if impl == "ref":
        out = decode_attention_ref(qg, k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), pos, softcap=softcap)
        return out.reshape(b, 1, h, d)
    qk = qg.reshape(b * kh, g, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    vv = v.transpose(0, 2, 1, 3).reshape(b * kh, t, d)
    out = decode_attention_fwd(qk, kk, vv, pos.astype(jnp.int32),
                               softcap=softcap, block_k=block_k,
                               interpret=interpret)
    return out.reshape(b, 1, h, d)

"""RWKV6 wkv recurrence, chunked-parallel, Pallas TPU.

Grid (BH, T // C): the chunk axis is sequential ("arbitrary"); the head state
S (D x D fp32) lives in VMEM scratch and is carried across chunks.  Within a
chunk the recurrence is evaluated with three small matmuls (intra-chunk
scores, intra @ v, cross = r' @ S) — the MXU form of the GLA/RWKV chunked
algorithm — with exponent centering at the chunk midpoint so fp32 never
overflows (|logw| <= 8, C = 16 -> exponents bounded by +-64).

VMEM per step (C = 16, D = 64): 4 x (C, D) inputs + S (D, D) f32 = ~25 KB.
On real hardware several heads would be packed per program to fill the
128-lane dimension; the block shapes here are what interpret mode validates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_ref, *,
                 chunk: int, n_chunks: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)              # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = w_ref[0].astype(jnp.float32)             # logw, (C, D)
    u = u_ref[0].astype(jnp.float32)              # (1, D)

    la = jnp.cumsum(lw, axis=0)                   # inclusive within chunk
    la_prev = la - lw
    mid = la[chunk // 2][None, :]                 # centering constant

    qq = r * jnp.exp(la_prev - mid)               # (C, D)
    kk = k * jnp.exp(mid - la)

    scores = jax.lax.dot_general(qq, kk, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(si < ti, scores, 0.0)      # strict lower triangle
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)   # (C, 1)
    intra = intra + bonus * v

    S = s_ref[...]                                # (Dk, Dv)
    cross = jax.lax.dot_general(r * jnp.exp(la_prev), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = (intra + cross).astype(y_ref.dtype)

    w_all = jnp.exp(la[chunk - 1])[:, None]       # (D, 1)
    kdec = k * jnp.exp(la[chunk - 1][None, :] - la)
    s_ref[...] = w_all * S + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(cj == n_chunks - 1)
    def _emit_state():
        sout_ref[0] = s_ref[...]


def rwkv6_scan_fwd(r, k, v, logw, u, *, chunk: int = 16,
                   interpret: bool = False):
    """r/k/v/logw: (BH, T, D); u: (BH, D).  T % chunk == 0.
    Returns (y (BH, T, D) fp32, S (BH, D, D) fp32); initial state zero."""
    bh, t, d = r.shape
    assert t % chunk == 0
    n_chunks = t // chunk
    grid = (bh, n_chunks)

    kernel = functools.partial(_rwkv_kernel, chunk=chunk, n_chunks=n_chunks)
    y, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, d, d), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u)
    return y, s

"""Jit'd wrapper for the RWKV6 wkv kernel (model layout (B, T, H, D))."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_fwd
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "impl"))
def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 16, interpret: bool = False,
               impl: str = "pallas"):
    """r/k/v/logw: (B, T, H, D); u: (H, D).
    Returns (y (B, T, H, D) fp32, S (B, H, D, D) fp32)."""
    b, t, h, d = r.shape
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    uu = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, d)
    if impl == "ref":
        s0 = jnp.zeros((b * h, d, d), jnp.float32)
        y, s = rwkv6_scan_ref(fold(r), fold(k), fold(v), fold(logw), uu, s0)
    else:
        pad = (-t) % chunk
        args = [fold(r), fold(k), fold(v), fold(logw)]
        if pad:
            args = [jnp.pad(a, ((0, 0), (0, pad), (0, 0))) for a in args[:3]] + \
                   [jnp.pad(args[3], ((0, 0), (0, pad), (0, 0)),
                            constant_values=-1e-4)]
        y, s = rwkv6_scan_fwd(*args, uu, chunk=chunk, interpret=interpret)
        y = y[:, :t]
    y = y.reshape(b, h, t, d).transpose(0, 2, 1, 3)
    return y, s.reshape(b, h, d, d)

"""Naive per-token oracle for the RWKV6 wkv recurrence.

r/k/v/logw: (BH, T, D); u: (BH, D); s0: (BH, D, D) fp32.
  S_t = diag(exp(logw_t)) S_{t-1} + k_t^T v_t
  y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
Returns (y (BH, T, D) fp32, S_final).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u, s0):
    r, k, v, logw = (a.astype(jnp.float32) for a in (r, k, v, logw))
    u = u.astype(jnp.float32)

    def step(S, ts):
        r_t, k_t, v_t, w_t = ts                       # (BH, D) each
        kv = k_t[..., :, None] * v_t[..., None, :]    # (BH, Dk, Dv)
        y = jnp.einsum("bd,bdv->bv", r_t, S + u[..., None] * kv)
        S = jnp.exp(w_t)[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    S, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), S

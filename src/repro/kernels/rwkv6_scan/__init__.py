from repro.kernels.rwkv6_scan.ops import rwkv6_scan  # noqa: F401
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref  # noqa: F401

"""Jit'd wrapper for the grouped expert FFN kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gemm.kernel import moe_expert_ffn_fwd
from repro.kernels.moe_gemm.ref import moe_expert_ffn_ref


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret", "impl"))
def moe_expert_ffn(x, wg, wu, wo, *, block_c: int = 128, block_f: int = 128,
                   interpret: bool = False, impl: str = "pallas"):
    """x: (E, C, d); wg, wu: (E, d, f); wo: (E, f, d) -> (E, C, d)."""
    if impl == "ref":
        return moe_expert_ffn_ref(x, wg, wu, wo)
    return moe_expert_ffn_fwd(x, wg, wu, wo, block_c=block_c, block_f=block_f,
                              interpret=interpret)

from repro.kernels.moe_gemm.ops import moe_expert_ffn  # noqa: F401
from repro.kernels.moe_gemm.ref import moe_expert_ffn_ref  # noqa: F401

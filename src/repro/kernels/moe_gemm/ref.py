"""Pure-jnp oracle for the grouped expert FFN (SwiGLU per expert).

x: (E, C, d) capacity buffers; wg, wu: (E, d, f); wo: (E, f, d).
out = silu(x @ wg) * (x @ wu) @ wo, per expert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_expert_ffn_ref(x, wg, wu, wo):
    xf = x.astype(jnp.float32)
    gate = jnp.einsum("ecd,edf->ecf", xf, wg.astype(jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", xf, wu.astype(jnp.float32))
    h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.float32)).astype(x.dtype)

"""Grouped expert FFN (MegaBlocks-style batched SwiGLU), Pallas TPU.

Grid (E, C // block_c, f // block_f): experts and token blocks parallel, the
expert-hidden axis f sequential ("arbitrary") so all three matmuls fuse in
one pass: per (e, c, j) step compute h_j = silu(x wg_j) * (x wu_j) for a
block_f slice of the hidden dim and accumulate h_j @ wo_j into a
(block_c, d) fp32 VMEM scratch; the output block is written once on the last
j.  Expert weights stream through VMEM a (d, block_f) tile at a time.

VMEM per step (block_c = 128, block_f = 128, d = 2048, bf16):
  x 512KB + wg/wu 2x512KB + wo 512KB + acc 1MB fp32 ~= 3 MB << 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(x_ref, wg_ref, wu_ref, wo_ref, o_ref, acc_ref, *, n_f: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)            # (block_c, d)
    wg = wg_ref[0].astype(jnp.float32)          # (d, block_f)
    wu = wu_ref[0].astype(jnp.float32)
    wo = wo_ref[0].astype(jnp.float32)          # (block_f, d)

    gate = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    up = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h = (gate * jax.lax.logistic(gate)) * up    # silu(gate) * up
    acc_ref[...] += jax.lax.dot_general(h, wo, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(j == n_f - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_expert_ffn_fwd(x, wg, wu, wo, *, block_c: int = 128,
                       block_f: int = 128, interpret: bool = False):
    """x: (E, C, d); wg, wu: (E, d, f); wo: (E, f, d) -> (E, C, d)."""
    e, c, d = x.shape
    f = wg.shape[2]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    assert c % block_c == 0 and f % block_f == 0
    n_f = f // block_f
    grid = (e, c // block_c, n_f)

    kernel = functools.partial(_moe_kernel, n_f=n_f)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e_, i, j: (e_, i, 0)),
            pl.BlockSpec((1, d, block_f), lambda e_, i, j: (e_, 0, j)),
            pl.BlockSpec((1, d, block_f), lambda e_, i, j: (e_, 0, j)),
            pl.BlockSpec((1, block_f, d), lambda e_, i, j: (e_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e_, i, j: (e_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wg, wu, wo)

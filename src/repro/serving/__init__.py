from repro.serving.engine import ModelReplica, ServeRequest  # noqa: F401

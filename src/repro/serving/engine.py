"""Model replicas: the serverless "instance" backed by a real JAX model.

Cold start = weight init/load + XLA compile of the decode step (measured —
this is the real-system analogue of the paper's sandbox creation).  A warm
replica serves up to ``container_concurrency`` requests simultaneously via
slot-based continuous batching: every ``step()`` advances all active slots by
one token (consuming prompt tokens first, then generating).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry


@dataclasses.dataclass
class ServeRequest:
    rid: int
    fn: int
    prompt: list[int]
    max_new_tokens: int = 16
    arrival_t: float = 0.0
    dispatch_t: float = float("nan")
    first_token_t: float = float("nan")
    done_t: float = float("nan")
    output: list[int] = dataclasses.field(default_factory=list)
    cold: bool = False

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


class ModelReplica:
    """One warm instance: resident weights + compiled step fns + KV cache."""

    def __init__(self, cfg: ModelConfig, *, max_slots: int = 4,
                 max_seq: int = 256, seed: int = 0):
        t0 = time.monotonic()
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.params = registry.init_params(cfg, jax.random.PRNGKey(seed))
        self.cache = registry.init_cache(cfg, max_slots, max_seq)
        self._step = jax.jit(
            lambda p, c, tok, pos: registry.decode_step(cfg, p, c, tok, pos),
            donate_argnums=(1,))
        # trigger compile (part of the cold start, like a first-request warmup)
        tok = jnp.zeros((max_slots, 1), jnp.int32)
        pos = jnp.zeros((max_slots,), jnp.int32)
        lg, self.cache = self._step(self.params, self.cache, tok, pos)
        lg.block_until_ready()
        self.cache = registry.init_cache(cfg, max_slots, max_seq)
        self.cold_start_s = time.monotonic() - t0

        self.slots: list[Optional[ServeRequest]] = [None] * max_slots
        self._pos = np.zeros(max_slots, np.int32)
        self._next_tok = np.zeros(max_slots, np.int32)
        self._prompt_left: list[list[int]] = [[] for _ in range(max_slots)]
        self.idle_since: float = time.monotonic()
        self.created_t = time.monotonic()

    # -- memory accounting (the paper's per-instance footprint) ------------------

    def memory_bytes(self) -> int:
        leaves = jax.tree.leaves(self.params) + jax.tree.leaves(self.cache)
        return int(sum(l.size * l.dtype.itemsize for l in leaves))

    # -- slot management -----------------------------------------------------------

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    @property
    def in_flight(self) -> int:
        return self.max_slots - self.free_slots

    def add(self, req: ServeRequest, now: float) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                req.dispatch_t = now
                self._pos[i] = 0
                prompt = req.prompt[:self.max_seq - req.max_new_tokens - 1]
                self._prompt_left[i] = list(prompt[1:])
                self._next_tok[i] = prompt[0] if prompt else 0
                return True
        return False

    # -- the serving loop body --------------------------------------------------------

    def step(self, now: float) -> list[ServeRequest]:
        """Advance every active slot one token; return completed requests."""
        if self.in_flight == 0:
            return []
        toks = jnp.asarray(self._next_tok[:, None])
        pos = jnp.asarray(self._pos)
        logits, self.cache = self._step(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)

        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._pos[i] += 1
            if self._prompt_left[i]:
                self._next_tok[i] = self._prompt_left[i].pop(0)
                continue
            # generating
            if not req.output and np.isnan(req.first_token_t):
                req.first_token_t = now
            req.output.append(int(nxt[i]))
            self._next_tok[i] = nxt[i]
            if req.done or self._pos[i] >= self.max_seq - 1:
                req.done_t = now
                finished.append(req)
                self.slots[i] = None
        if self.in_flight == 0:
            self.idle_since = now
        return finished

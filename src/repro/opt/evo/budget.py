"""Exact evaluation-budget accounting for the population optimizer.

The unit of account is one SIMULATED CANDIDATE-SCENARIO PAIR — the same
thing the grid pays for: ``evaluate_scenario`` reports its deduped
simulation count as ``rows[0]["sims"]``, and ``grid_budget`` (in
``repro.opt.evo.engine``) prices the coarse grid in exactly those units,
so "evo at the grid's budget" is a like-for-like claim, not a vibe.

Two kinds of entries:

* ``spend``  — search-stage work (seed generation, offspring evaluations,
  gradient-refinement steps).  Counted against ``total``; overdrawing
  raises ``BudgetExhausted`` so a mis-sized generation fails loudly
  instead of quietly inflating the comparison.
* ``record`` — off-budget work the ledger still tracks (the full-scale
  refine pass mirrors the grid pipeline's refine stage, which the
  hypervolume-at-budget comparisons never count for the grid either).

``spent`` is always exactly ``sum(n for on-budget entries)`` — the
invariant ``tests/test_evo.py`` pins.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional


class BudgetExhausted(RuntimeError):
    """A search stage tried to simulate past the declared budget."""


@dataclasses.dataclass
class EvalBudget:
    """Append-only ledger of candidate-scenario-pair evaluations."""
    total: int
    ledger: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.total <= 0:
            raise ValueError(f"EvalBudget total must be positive, got "
                             f"{self.total}")

    # -- accounting --------------------------------------------------------

    @property
    def spent(self) -> int:
        """On-budget pairs consumed so far (exact: the ledger sum)."""
        return sum(e["n"] for e in self.ledger if e["on_budget"])

    @property
    def recorded(self) -> int:
        """Every pair the ledger saw, off-budget refine work included."""
        return sum(e["n"] for e in self.ledger)

    @property
    def remaining(self) -> int:
        return self.total - self.spent

    @property
    def exhausted(self) -> bool:
        return self.remaining <= 0

    def can_afford(self, n: int) -> bool:
        return n <= self.remaining

    def spend(self, n: int, stage: str, scenario: Optional[str] = None,
              generation: Optional[int] = None) -> None:
        """Charge ``n`` pairs against the budget; raises on overdraft."""
        if n < 0:
            raise ValueError(f"cannot spend a negative pair count ({n})")
        if n > self.remaining:
            raise BudgetExhausted(
                f"stage {stage!r} needs {n} candidate-scenario pairs but "
                f"only {self.remaining} of {self.total} remain")
        self.ledger.append({"stage": stage, "scenario": scenario,
                            "generation": generation, "n": int(n),
                            "on_budget": True})

    def record(self, n: int, stage: str, scenario: Optional[str] = None,
               generation: Optional[int] = None) -> None:
        """Track ``n`` pairs of off-budget work (refine fidelity pass)."""
        if n < 0:
            raise ValueError(f"cannot record a negative pair count ({n})")
        self.ledger.append({"stage": stage, "scenario": scenario,
                            "generation": generation, "n": int(n),
                            "on_budget": False})

    # -- reporting ---------------------------------------------------------

    def by_stage(self) -> dict:
        out: dict = {}
        for stage, group in itertools.groupby(
                sorted(self.ledger, key=lambda e: e["stage"]),
                key=lambda e: e["stage"]):
            out[stage] = sum(e["n"] for e in group)
        return out

    def summary(self) -> dict:
        return {"total": self.total, "spent": self.spent,
                "remaining": self.remaining, "recorded": self.recorded,
                "by_stage": self.by_stage()}

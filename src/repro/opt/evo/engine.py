"""The NSGA-II-style population search behind ``frontier_search(algo="evo")``.

Where the grid ENUMERATES a cartesian product whose cost is exponential in
the axis count, this engine SEARCHES: a population of candidate
configurations evolves under non-dominated sorting + crowding selection,
simulated-binary crossover and polynomial mutation inside the
AxisSpec-clipped gene boxes (``repro.opt.evo.genome``), with every
generation evaluated as ONE batched vmapped ``simulate_chunked`` call per
scenario (``evaluate_scenario`` — structural ``cell_count`` genes regroup
the per-cell trace partition exactly as grid sweep points do, and
``RunSpec(devices=N)`` shards the candidate batch when available).  The
simulator is cheap; the population exploits that.

Search effort is governed by an ``EvalBudget`` in SIMULATED
CANDIDATE-SCENARIO PAIRS — the same unit the grid pays (``grid_budget``
prices the coarse grid's deduped simulations), so hypervolume-at-budget is
a like-for-like comparison.  The run is seeded from one cheap coarse-grid
generation (evenly strided through the product order, so extremes are
covered), evolves until the budget is exhausted, optionally spends an
endgame slice on GRADIENT refinement of elite individuals' continuous
policy leaves (``opt.learned.refine_leaves``: jax.grad through the chunked
scan, charged at 2 pairs per step for the backward pass), and finally
re-runs the per-scenario epsilon-survivors at full scale — the same
coarse -> survive -> refine -> reduce contract as the grid, returning the
same ``FrontierResult`` so the oracle-demotion spot-check gate applies
UNCHANGED.  Candidates listed in ``forbidden`` (e.g. config classes a
previous spot-check demoted) are masked out of seeding and offspring
generation alike.

Every generation reports its per-scenario front hypervolume through the
``RunTelemetry`` hooks (``evo_generation`` events), so convergence is
observable in ``frontier_out/telemetry.json``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.core.policy_api import get_family
from repro.core.runspec import RunSpec
from repro.fleet.billing import BillingProfile
from repro.opt.evo.budget import EvalBudget
from repro.opt.evo.genome import Genome, genome_from_space, point_key
from repro.opt.evo.nsga import (nsga_rank, polynomial_mutation,
                                sbx_crossover, tournament_pick)
from repro.opt.frontier import (X_DEFAULT, Y_DEFAULT, epsilon_survivors,
                                pareto_front, robust_front)
from repro.opt.space import DEFAULT_SPACE, SearchSpace
from repro.scenarios.registry import get_scenario, list_scenarios
from repro.scenarios.spec import Scenario


@dataclasses.dataclass(frozen=True)
class EvoConfig:
    """Population-optimizer knobs (the defaults are what the CI gate and
    the fig15 benchmark run)."""
    population: int = 16          # offspring per generation (upper bound)
    seed_frac: float = 0.5        # budget share of the coarse-grid seeding
    target_generations: int = 3   # sizing aim for the evolution phase
    max_generations: int = 64     # hard stop (budget normally binds first)
    elite_cap: int = 32           # parent-pool truncation (rank, crowding)
    tournament: int = 2
    eta_sbx: float = 12.0         # SBX spread (higher = children nearer)
    eta_mut: float = 20.0         # mutation concentration
    p_cx: float = 0.9
    p_mut: Optional[float] = None  # per-gene mutation prob (None = 1/n)
    # per-gene prob of snapping an offspring gene to a grid rung value —
    # walks the grid graph around the elites, recovering product corners
    # the strided seeding skipped (a pure-continuous mutation almost never
    # re-hits an exact unseeded rung combination)
    p_lattice: float = 0.3
    grad_steps: int = 6           # Adam steps per refined elite (0 = off)
    grad_elites: int = 2
    grad_lr: float = 0.08
    # gradient refinement only fires on budgets where its charge (2 pairs
    # per step — forward + backward) is a minority share
    grad_min_budget: int = 64


def grid_budget(space: SearchSpace,
                scenarios: Sequence[Union[str, Scenario]]) -> int:
    """What the coarse grid would pay, in simulated candidate-scenario
    pairs: per scenario, the number of DISTINCT effective configurations
    (``opt.search._effective_key`` — inert axes collapsed) in the space's
    cartesian product.  ``evo_search``'s default budget, making
    ``algo="evo"`` equal-footed with ``algo="grid"`` by construction."""
    from repro.opt.search import _effective_key
    pts = space.points()
    total = 0
    for s in scenarios:
        sc = get_scenario(s) if isinstance(s, str) else s
        fam = get_family(sc.policy.kind).name
        total += len({_effective_key(p, fam) for p in pts})
    return total


def evo_search(scenarios: Optional[Sequence[Union[str, Scenario]]] = None,
               space: SearchSpace = DEFAULT_SPACE, scale: float = 1.0,
               coarse_frac: float = 0.1, eps: float = 0.15,
               survivor_cap: int = 12,
               billing: Union[str, BillingProfile, None] = None,
               log: Optional[Callable[[str], None]] = None,
               telemetry=None, devices: int = 0, cluster: float = 0.0, *,
               budget: Optional[int] = None, seed: int = 0,
               config: EvoConfig = EvoConfig(), refine: bool = True,
               forbidden: Sequence[dict] = (),
               evaluate: Optional[Callable] = None):
    """Population search over ``space`` across ``scenarios``; returns the
    same ``FrontierResult`` as ``frontier_search`` (which dispatches here
    for ``algo="evo"``).

    The SEARCH stage runs at ``coarse_frac * scale`` (clamped like the
    grid's coarse stage) under ``budget`` total candidate-scenario pairs
    (default: the grid's own cost, ``grid_budget``).  ``refine=False``
    skips the full-scale survivor pass and reports the search-stage rows
    as the refined set — the hypervolume-at-budget benchmark uses this
    with ``coarse_frac=1.0`` so every simulated pair is at the comparison
    scale.  ``forbidden`` masks candidate config classes (dicts of knob
    values) out of seeding and variation — the re-entry hook for config
    classes the oracle previously demoted.  ``evaluate`` overrides the
    simulator call (tests inject analytic evaluators); it must return
    ``evaluate_scenario``-shaped rows (X/Y metric keys + ``sims``).
    """
    from repro.opt.search import (MIN_COARSE_SCALE, FrontierResult,
                                  _front_hypervolume)
    t_start = time.time()
    say = log or (lambda s: None)
    tel = telemetry.emit if telemetry is not None else (lambda *a, **k: None)
    if scenarios is None:
        scenarios = [n for n in list_scenarios()
                     if not get_scenario(n).rate_trace]
    scs: dict[str, Scenario] = {}
    for s in scenarios:
        sc = get_scenario(s) if isinstance(s, str) else s
        scs[sc.name] = sc
    if not scs:
        raise ValueError("evo_search needs at least one scenario")
    S = len(scs)
    families = sorted({get_family(sc.policy.kind).name
                       for sc in scs.values()})
    genome = genome_from_space(space, families)
    if budget is None:
        budget = grid_budget(space, scs.values())
    bud = EvalBudget(budget)
    rng = np.random.default_rng(seed)
    coarse_scale = min(max(scale * coarse_frac, MIN_COARSE_SCALE), scale)
    run_spec = RunSpec(billing=billing, devices=devices, cluster=cluster)
    if evaluate is None:
        from repro.opt.search import evaluate_scenario

        def evaluate(sc, pts, scale_):
            return evaluate_scenario(sc, pts,
                                     spec=run_spec.replace(scale=scale_))

    # -- candidate registry ------------------------------------------------
    points: list[dict] = []
    key_to_pid: dict[tuple, int] = {}
    rows: dict[str, dict[int, dict]] = {name: {} for name in scs}
    forbidden_keys = {point_key(genome.project(p)) for p in forbidden}

    def register(pt: dict) -> Optional[int]:
        k = point_key(pt)
        if k in key_to_pid or k in forbidden_keys:
            return None
        key_to_pid[k] = len(points)
        points.append(pt)
        return key_to_pid[k]

    def eval_generation(pids: Sequence[int], stage: str, gen: int) -> None:
        pts = [points[i] for i in pids]
        for name, sc in scs.items():
            out = evaluate(sc, pts, coarse_scale)
            bud.spend(out[0]["sims"] if out else 0, stage, name, gen)
            for pid, r in zip(pids, out):
                r["point_id"] = pid
                rows[name][pid] = r
            tel("evo_generation", scenario=name, generation=gen,
                stage=stage, new_points=len(pts),
                sims=out[0]["sims"] if out else 0,
                budget_spent=bud.spent, budget_total=bud.total,
                hypervolume=_front_hypervolume(list(rows[name].values())))
        say(f"evo gen {gen} ({stage}): {len(pids)} candidates, "
            f"budget {bud.spent}/{bud.total}")

    def objective_matrix(name: str) -> np.ndarray:
        F = np.full((len(points), 2), np.inf)
        for pid, r in rows[name].items():
            x = r.get(X_DEFAULT, np.nan)
            y = r.get(Y_DEFAULT, np.nan)
            if np.isfinite(x) and np.isfinite(y):
                F[pid] = (x, y)
        return F

    def combined_fitness() -> tuple[np.ndarray, np.ndarray, dict]:
        """Cross-scenario NSGA fitness: a candidate's rank is its BEST
        per-scenario front rank (specialists of any scenario and robust
        all-rounders both score well — mirroring the grid's pooled
        survivor union), crowding its best spread."""
        n = len(points)
        best_rank = np.full(n, np.inf)
        best_crowd = np.zeros(n)
        per_rank: dict[str, np.ndarray] = {}
        for name in scs:
            ranks, crowd = nsga_rank(objective_matrix(name))
            # the quarantine front (non-finite rows) must not count as a
            # real rank: push it to inf so an everywhere-NaN candidate
            # never wins a tournament
            finite = np.isfinite(objective_matrix(name)).all(axis=1)
            r = np.where(finite, ranks.astype(float), np.inf)
            per_rank[name] = r
            better = r < best_rank
            best_crowd = np.where(better, crowd, best_crowd)
            best_rank = np.minimum(best_rank, r)
            same = r == best_rank
            best_crowd = np.where(same, np.maximum(best_crowd, crowd),
                                  best_crowd)
        return best_rank, best_crowd, per_rank

    # -- generation 0: one cheap coarse-grid seeding ----------------------
    seen: set = set()
    cands: list[dict] = []
    for p in space.points():
        q = genome.project(p)
        k = point_key(q)
        if k not in seen and k not in forbidden_keys:
            seen.add(k)
            cands.append(q)
    cap0 = bud.remaining // S
    if cap0 < 2:
        raise ValueError(
            f"budget {budget} cannot seed {S} scenario(s): at least "
            f"{2 * S} candidate-scenario pairs are needed")
    k0 = min(len(cands), max(2, int(round(config.seed_frac * budget)) // S),
             cap0)
    # per-gene grid rung values (variation space): corner seeds + lattice
    # mutation both draw from these
    rungs = [np.unique([genome.encode(c)[gi] for c in cands])
             for gi in range(len(genome.genes))] if cands else []
    # corner-first seeding: on the monotone landscapes grids are built
    # for, the per-scenario optima sit at EXTREME rung combinations — a
    # linspace stride through product order walks the interior and skips
    # most corners, so enumerate the 2^k corner candidates first (seeded
    # shuffle when they exceed the seed allowance) and fill the remainder
    # with the evenly-strided interior
    seed_vecs: list[np.ndarray] = []
    if rungs and len(genome.genes) <= 10:
        import itertools
        corners = [np.asarray(c, dtype=float) for c in
                   itertools.product(*[(r[0], r[-1]) for r in rungs])]
        seed_vecs = [corners[i] for i in rng.permutation(len(corners))]
    idx = np.unique(np.linspace(0, len(cands) - 1, k0).round().astype(int))
    # seeds ride the same encode/decode lattice as offspring, so a later
    # variation landing on a seed value shares its key (no wasted re-sim)
    seed_vecs += [genome.encode(cands[i]) for i in idx]
    pids = []
    for v in seed_vecs:
        if len(pids) >= k0:
            break
        pid = register(genome.decode(v))
        if pid is not None:
            pids.append(pid)
    eval_generation(pids, "seed", 0)

    # -- evolution ---------------------------------------------------------
    lo, hi = genome.lo, genome.hi
    p_mut = config.p_mut if config.p_mut is not None \
        else 1.0 / max(len(genome.genes), 1)
    P_nom = max(2, int(np.ceil(max(budget // S - k0, 1)
                               / max(config.target_generations, 1))))
    grad_done = config.grad_steps <= 0 or budget < config.grad_min_budget
    gen = 0
    while gen < config.max_generations:
        gen += 1
        cap = bud.remaining // S
        if cap < 1:
            break
        best_rank, best_crowd, per_rank = combined_fitness()
        order = np.lexsort((-best_crowd, best_rank))
        pool = np.asarray([i for i in order if np.isfinite(best_rank[i])][
            :config.elite_cap], dtype=int)
        if pool.size == 0:
            pool = np.arange(len(points))

        batch: list[int] = []
        if not grad_done and cap <= P_nom + config.population:
            # endgame: spend a slice on gradient refinement of elites
            grad_done = True
            for pid in _grad_elite_ids(pool, best_rank, best_crowd,
                                       config.grad_elites):
                cost = 2 * config.grad_steps   # forward + backward per step
                if not bud.can_afford(cost + S):
                    break
                name = min(scs, key=lambda nm: per_rank[nm][pid])
                refined = _refine_elite(scs[name], points[pid], genome,
                                        coarse_scale, config, billing)
                bud.spend(cost, "grad", name, gen)
                new_pid = register(genome.decode(genome.encode(refined)))
                if new_pid is not None:
                    batch.append(new_pid)
                    say(f"evo grad: refined point {pid} -> "
                        f"{points[new_pid]} on {name}")

        P = min(config.population, P_nom, bud.remaining // S)
        attempts = 0
        while len(batch) < P and attempts < 30 * P:
            attempts += 1
            i = tournament_pick(rng, best_rank, best_crowd, pool,
                                config.tournament)
            j = tournament_pick(rng, best_rank, best_crowd, pool,
                                config.tournament)
            c1, c2 = sbx_crossover(rng, genome.encode(points[i]),
                                   genome.encode(points[j]), lo, hi,
                                   eta=config.eta_sbx, p_cx=config.p_cx)
            for c in (c1, c2):
                if len(batch) >= P:
                    break
                c = polynomial_mutation(rng, c, lo, hi, eta=config.eta_mut,
                                        p_mut=p_mut)
                if rungs and config.p_lattice > 0:
                    # walk the grid graph around the elites: snapped genes
                    # let offspring land exactly on product corners the
                    # strided seeding skipped (dedup makes re-hits free)
                    for gi in np.flatnonzero(
                            rng.random(len(c)) < config.p_lattice):
                        c[gi] = rungs[gi][rng.integers(len(rungs[gi]))]
                pid = register(genome.decode(c))
                if pid is not None:
                    batch.append(pid)
            if attempts > 10 * P and len(batch) < P:
                # random immigrant: small discrete spaces exhaust the
                # neighborhood of the elites long before the budget
                pid = register(genome.decode(rng.uniform(lo, hi)))
                if pid is not None:
                    batch.append(pid)
        if not batch:
            say(f"evo gen {gen}: candidate space exhausted "
                f"({len(points)} distinct points)")
            break
        eval_generation(batch, "evolve", gen)

    # -- reduce (and optionally refine at full scale) ----------------------
    coarse = {name: [rows[name][pid] for pid in sorted(rows[name])]
              for name in scs}
    if refine and scale - coarse_scale > 1e-12:
        survivors = {name: {r["point_id"]
                            for r in epsilon_survivors(rs, eps=eps,
                                                       cap=survivor_cap)}
                     for name, rs in coarse.items()}
        ids = sorted(set().union(*survivors.values())
                     | set(robust_front(coarse)))
        refined: dict[str, list[dict]] = {}
        for name, sc in scs.items():
            out = evaluate(sc, [points[i] for i in ids], scale)
            bud.record(out[0]["sims"] if out else 0, "refine", name)
            for r, pid in zip(out, ids):
                r["point_id"] = pid
            refined[name] = out
            say(f"evo refine {name}: {len(ids)} survivors at {scale}x")
    else:
        refined = {name: list(rs) for name, rs in coarse.items()}
    fronts = {name: pareto_front(rs) for name, rs in refined.items()}
    robust_ids = robust_front(refined)
    tel("evo_done", generations=gen, points=len(points),
        robust_points=len(robust_ids), budget=bud.summary(),
        wall_s=round(time.time() - t_start, 3))
    say(f"evo done: {len(points)} candidates over {gen} generation(s), "
        f"budget {bud.spent}/{bud.total}, robust {len(robust_ids)}")
    return FrontierResult(space=space, points=points, scale=scale,
                          coarse_scale=coarse_scale, coarse=coarse,
                          refined=refined, fronts=fronts,
                          robust_ids=robust_ids,
                          wall_s=time.time() - t_start, billing=billing,
                          devices=devices, cluster=cluster,
                          algo="evo", budget=bud)


def _grad_elite_ids(pool: np.ndarray, ranks: np.ndarray, crowd: np.ndarray,
                    k: int) -> list[int]:
    order = sorted(pool.tolist(), key=lambda i: (ranks[i], -crowd[i]))
    return [int(i) for i in order[:max(k, 0)]]


def _refine_elite(sc: Scenario, point: dict, genome: Genome, scale: float,
                  config: EvoConfig, billing) -> dict:
    """Gradient-refine one elite's continuous policy genes on ``sc`` via
    the existing ``opt.learned`` machinery (jax.grad through the scan)."""
    from repro.opt.learned import refine_leaves
    fam = get_family(sc.policy.kind)
    axes = [g.name for g in genome.genes
            if not g.fleet and not g.integer and g.name in fam.axis_names()]
    if not axes:
        return dict(point)
    return refine_leaves(sc, point, axes=axes, scale=scale,
                         steps=config.grad_steps, lr=config.grad_lr,
                         billing=billing)

"""NSGA-II primitives: non-dominated sort, crowding distance, simulated
binary crossover (SBX), polynomial mutation, tournament selection.

Pure numpy over (n, m) objective matrices (every objective MINIMIZED) and
flat gene vectors — no jax, no simulator: the engine owns the mapping from
gene vectors to simulated rows.  Every stochastic operator takes an
explicit ``numpy.random.Generator``; there is deliberately no module-level
randomness anywhere in this package, so a seeded search replays
bit-for-bit.

Rows with any non-finite objective (the zero-completion NaN convention —
a candidate whose shrunk trace completed nothing) are quarantined in a
final worst front with zero crowding: they lose every selection
tournament but never crash the sort.
"""

from __future__ import annotations

import numpy as np


def non_dominated_sort(F: np.ndarray) -> tuple[np.ndarray, list]:
    """Fast non-dominated sort of an (n, m) objective matrix (minimize).

    Returns ``(ranks, fronts)``: ``ranks[i]`` is the 0-based front index of
    row i, ``fronts`` the list of index arrays front-by-front.  Non-finite
    rows land in one extra trailing front.
    """
    F = np.asarray(F, dtype=float)
    if F.ndim != 2:
        raise ValueError(f"objective matrix must be 2-D, got shape {F.shape}")
    n = F.shape[0]
    ranks = np.full(n, -1, dtype=int)
    finite = np.isfinite(F).all(axis=1)
    idx = np.flatnonzero(finite)
    fronts: list = []
    if idx.size:
        G = F[idx]
        k = idx.size
        # dom[i, j]: i dominates j  (<= everywhere, < somewhere)
        le = (G[:, None, :] <= G[None, :, :]).all(axis=2)
        lt = (G[:, None, :] < G[None, :, :]).any(axis=2)
        dom = le & lt
        n_dominators = dom.sum(axis=0)
        assigned = np.zeros(k, dtype=bool)
        level = 0
        while not assigned.all():
            cur = np.flatnonzero((n_dominators == 0) & ~assigned)
            if cur.size == 0:       # cycles are impossible; guard anyway
                cur = np.flatnonzero(~assigned)
            fronts.append(idx[cur])
            ranks[idx[cur]] = level
            assigned[cur] = True
            # retire the current front's domination edges
            n_dominators = n_dominators - dom[cur].sum(axis=0)
            n_dominators[assigned] = -1
            level += 1
    bad = np.flatnonzero(~finite)
    if bad.size:
        fronts.append(bad)
        ranks[bad] = len(fronts) - 1
    return ranks, fronts


def crowding_distance(F: np.ndarray, front: np.ndarray) -> np.ndarray:
    """Crowding distance of one front's rows (index array into ``F``):
    boundary points get ``inf``, interior points the normalized perimeter
    of their objective-space neighbor box.  Non-finite rows get 0."""
    F = np.asarray(F, dtype=float)
    front = np.asarray(front, dtype=int)
    k = front.size
    dist = np.zeros(k)
    if k == 0:
        return dist
    G = F[front]
    ok = np.isfinite(G).all(axis=1)
    if not ok.any():
        return dist
    for m in range(G.shape[1]):
        col = G[:, m]
        order = np.argsort(col, kind="stable")
        order = order[ok[order]]
        if order.size < 2:
            continue
        span = col[order[-1]] - col[order[0]]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        gaps = (col[order[2:]] - col[order[:-2]]) / span
        dist[order[1:-1]] = dist[order[1:-1]] + gaps
    return dist


def nsga_rank(F: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """``(ranks, crowding)`` over the whole matrix — the NSGA-II fitness:
    lower rank wins; within a rank, larger crowding wins."""
    ranks, fronts = non_dominated_sort(F)
    crowd = np.zeros(len(F))
    for front in fronts:
        crowd[front] = crowding_distance(F, front)
    return ranks, crowd


def tournament_pick(rng: np.random.Generator, ranks: np.ndarray,
                    crowd: np.ndarray, pool: np.ndarray,
                    k: int = 2) -> int:
    """Binary (size-``k``) tournament over ``pool`` indices: best rank,
    ties broken by crowding, then by the rng."""
    pool = np.asarray(pool, dtype=int)
    picks = pool[rng.integers(0, pool.size, size=max(2, k))]
    best = picks[0]
    for c in picks[1:]:
        if (ranks[c] < ranks[best]
                or (ranks[c] == ranks[best] and crowd[c] > crowd[best])):
            best = c
    return int(best)


def sbx_crossover(rng: np.random.Generator, a: np.ndarray, b: np.ndarray,
                  lo: np.ndarray, hi: np.ndarray, eta: float = 12.0,
                  p_cx: float = 0.9) -> tuple[np.ndarray, np.ndarray]:
    """Simulated binary crossover (Deb & Agrawal): per-gene spread factor
    beta with density ~ beta^eta, children clipped to [lo, hi].  Genes
    cross with probability ``p_cx`` each; otherwise both children inherit
    the parents' values."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    u = rng.random(a.shape)
    beta = np.where(u <= 0.5,
                    (2.0 * u) ** (1.0 / (eta + 1.0)),
                    (1.0 / (2.0 * (1.0 - u))) ** (1.0 / (eta + 1.0)))
    cross = rng.random(a.shape) < p_cx
    beta = np.where(cross, beta, 1.0)
    c1 = 0.5 * ((1.0 + beta) * a + (1.0 - beta) * b)
    c2 = 0.5 * ((1.0 - beta) * a + (1.0 + beta) * b)
    return np.clip(c1, lo, hi), np.clip(c2, lo, hi)


def polynomial_mutation(rng: np.random.Generator, x: np.ndarray,
                        lo: np.ndarray, hi: np.ndarray, eta: float = 20.0,
                        p_mut: float | None = None) -> np.ndarray:
    """Polynomial mutation (Deb): each gene mutates with probability
    ``p_mut`` (default 1/n) by a bounded perturbation whose density
    concentrates near the parent for large ``eta``.  Output is clipped to
    [lo, hi] — mutation can NEVER leave the declared bounds."""
    x = np.asarray(x, dtype=float)
    lo = np.asarray(lo, dtype=float)
    hi = np.asarray(hi, dtype=float)
    n = x.size
    if p_mut is None:
        p_mut = 1.0 / max(n, 1)
    span = np.maximum(hi - lo, 1e-12)
    u = rng.random(n)
    # distance-to-bound terms keep the perturbation inside the box
    d_lo = (x - lo) / span
    d_hi = (hi - x) / span
    left = u < 0.5
    pw = 1.0 / (eta + 1.0)
    dq_l = (2.0 * u + (1.0 - 2.0 * u)
            * (1.0 - d_lo) ** (eta + 1.0)) ** pw - 1.0
    dq_r = 1.0 - (2.0 * (1.0 - u) + 2.0 * (u - 0.5)
                  * (1.0 - d_hi) ** (eta + 1.0)) ** pw
    delta = np.where(left, dq_l, dq_r)
    mutate = rng.random(n) < p_mut
    y = np.where(mutate, x + delta * span, x)
    return np.clip(y, lo, hi)

"""Gene encoding: SearchSpace -> bounded real vector and back.

The grid's ``SearchSpace`` names the knobs and (by its candidate values)
seeds the search; the declared ``AxisSpec`` bounds are the SEARCH BOX.  A
policy gene's box is the tightest (lo, hi) any searched family declares
for that axis — NOT the grid's [min, max]: the whole point of replacing
enumeration is that SBX/mutation can interpolate between grid rungs and
push BEYOND them (a keepalive ladder topping out at 1200 s does not bound
where the cost optimum lives), while a mutated candidate can never leave
the declared envelope (``evaluate_points`` would reject it loudly).
Fleet knobs carry no AxisSpec; their box stays the grid's [min, max].

Three gene classes:

* continuous — ordinary traced axes (keepalive, target, warm_frac, ...);
* integer    — axes the engines round (``cc``, ``cell_count``): decoded
  values snap to whole numbers, so crossover cannot manufacture a
  fractional container-concurrency;
* structural — ``cell_count`` additionally regroups the trace partition:
  ``evaluate_scenario`` already buckets sweep points by its rounded value
  and runs one batched multi-cell scan per group, so the evo engine needs
  no special dispatch — it just keeps the gene integral.

Continuous genes whose box is positive and spans two-plus decades (a
keepalive declared over [1 s, 86400 s]) operate in LOG space: SBX and
mutation see log(v), so variation steps are multiplicative — a mutation
from 1200 s explores 800/1800 s, not 1200 +- 2000 s of an 86k-wide linear
box whose perturbations are either negligible or wild.  Timescale knobs
are ratio-scaled quantities; searching them linearly wastes the budget.

Axes a knob grid declares but NO searched scenario's family reads are
DROPPED from the genome (mirroring ``opt.search._effective_key``'s inert-
axis collapse): evolving an axis the simulator ignores would spend budget
mutating noise.  Knob grids with a single candidate become frozen
constants carried into every decoded point.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core.policy_api import get_family, list_families
from repro.core.simjax import _PFLEET
from repro.opt.space import SearchSpace, active_knobs

# axes the engines consume as whole numbers; ``cell_count`` is additionally
# structural (it rebuilds the per-cell trace partition, grouped by
# evaluate_scenario) — see repro.cells.family
INTEGER_AXES = frozenset({"cc", "cell_count"})
STRUCTURAL_AXES = frozenset({"cell_count"})


@dataclasses.dataclass(frozen=True)
class Gene:
    """One evolvable knob: its box (natural units) and its class.  ``log``
    genes expose log-transformed coordinates to the variation operators."""
    name: str
    lo: float
    hi: float
    integer: bool = False
    structural: bool = False
    fleet: bool = False
    log: bool = False

    def to_vec(self, v: float) -> float:
        """Natural value -> variation-space coordinate."""
        return float(np.log(v)) if self.log else float(v)

    def from_vec(self, x: float) -> float:
        """Variation-space coordinate -> natural value.  Log genes snap to
        12 significant digits so exp(log(v)) round-trips exactly — a seed
        decoded through the lattice must simulate the very same knob value
        the grid evaluated, not a 1e-13-perturbed neighbour."""
        if not self.log:
            return float(x)
        v = float(f"{float(np.exp(x)):.12g}")
        return float(min(max(v, self.lo), self.hi))


def _axis_bounds(name: str, families: Optional[Iterable[str]]) -> tuple:
    """The tightest declared (lo, hi) for a policy axis across the searched
    families (falling back to every registered family when none of the
    searched ones declares it — the knob is then inert anyway)."""
    fams = list(families) if families else list_families()
    los, his = [], []
    for f in fams:
        fam = get_family(f)
        if name in fam.axis_names():
            ax = fam.axis(name)
            los.append(ax.lo)
            his.append(ax.hi)
    if not los:
        for f in list_families():
            fam = get_family(f)
            if name in fam.axis_names():
                ax = fam.axis(name)
                los.append(ax.lo)
                his.append(ax.hi)
    if not los:                      # fleet knobs have no AxisSpec
        return -np.inf, np.inf
    return max(los), min(his)


@dataclasses.dataclass(frozen=True)
class Genome:
    """An ordered gene tuple + frozen constants; encode/decode both ways."""
    genes: tuple
    fixed: tuple = ()                # ((knob, value), ...) single-candidate

    def __post_init__(self):
        if not self.genes:
            raise ValueError("genome has no evolvable genes: every searched "
                             "knob is either inert for the searched "
                             "scenarios' families or single-valued")

    @property
    def names(self) -> tuple:
        return tuple(g.name for g in self.genes)

    @property
    def lo(self) -> np.ndarray:
        """Variation-space lower bounds (log-transformed for log genes) —
        what SBX/mutation receive as the box."""
        return np.asarray([g.to_vec(g.lo) for g in self.genes])

    @property
    def hi(self) -> np.ndarray:
        return np.asarray([g.to_vec(g.hi) for g in self.genes])

    def encode(self, point: dict) -> np.ndarray:
        """Point dict -> variation-space gene vector (missing genes sit at
        their lower bound; values clipped into the box)."""
        return np.asarray([
            g.to_vec(float(np.clip(float(point.get(g.name, g.lo)),
                                   g.lo, g.hi)))
            for g in self.genes])

    def repair(self, vec: np.ndarray) -> np.ndarray:
        """Clip into the variation-space box and snap integer genes —
        idempotent; applied after every variation so decoded candidates
        are always legal."""
        v = np.clip(np.asarray(vec, dtype=float), self.lo, self.hi)
        for i, g in enumerate(self.genes):
            if g.integer:                       # integer genes never log
                v[i] = float(np.clip(np.round(v[i]), g.lo, g.hi))
        return v

    def decode(self, vec: np.ndarray) -> dict:
        """Variation-space vector -> point dict in natural units
        (repaired), frozen constants included so decoded points stay
        comparable with grid points."""
        v = self.repair(vec)
        out = {g.name: g.from_vec(v[i]) for i, g in enumerate(self.genes)}
        out.update(dict(self.fixed))
        return out

    def project(self, point: dict) -> dict:
        """Restrict a (grid) point to the genome's knobs — the inert-axis
        collapse applied to candidate identity."""
        out = {g.name: float(point[g.name]) for g in self.genes
               if g.name in point}
        out.update((k, v) for k, v in self.fixed if k in point)
        return out


def genome_from_space(space: SearchSpace,
                      families: Optional[Sequence[str]] = None) -> Genome:
    """Build the genome a ``SearchSpace`` spans for the given scenario
    families (None = keep every knob)."""
    act: Optional[set] = None
    if families is not None:
        act = set()
        for f in families:
            act |= set(active_knobs(f))
    genes, fixed = [], []
    for knob, vals in {**space.policy, **space.fleet}.items():
        is_fleet = knob in _PFLEET
        if act is not None and not is_fleet and knob not in act:
            continue                     # inert for every searched family
        vals = [float(v) for v in vals]
        lo, hi = min(vals), max(vals)
        if not is_fleet:
            ax_lo, ax_hi = _axis_bounds(knob, families)
            if lo < ax_lo or hi > ax_hi:
                raise ValueError(f"knob {knob!r}: grid range [{lo}, {hi}] "
                                 f"leaves the declared axis bounds "
                                 f"[{ax_lo}, {ax_hi}]")
            if len(set(vals)) > 1 and np.isfinite([ax_lo, ax_hi]).all():
                # the grid SEEDS; the declared axis bounds are the box
                lo, hi = ax_lo, ax_hi
        integer = knob in INTEGER_AXES
        if integer:
            lo, hi = float(np.ceil(lo)), float(np.floor(hi))
        if lo == hi:
            fixed.append((knob, lo))
            continue
        # ratio-scaled knobs (positive box spanning 2+ decades, e.g. a
        # keepalive over [1 s, 86400 s]) vary in log space
        use_log = not integer and lo > 0 and hi / lo >= 100.0
        genes.append(Gene(name=knob, lo=lo, hi=hi, integer=integer,
                          structural=knob in STRUCTURAL_AXES,
                          fleet=is_fleet, log=use_log))
    return Genome(genes=tuple(genes), fixed=tuple(fixed))


def point_key(point: dict, decimals: int = 9) -> tuple:
    """Canonical hashable identity of a candidate (rounded so float noise
    from crossover arithmetic cannot mint spurious 'new' candidates)."""
    return tuple(sorted((k, round(float(v), decimals))
                        for k, v in point.items()))

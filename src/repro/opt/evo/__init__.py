"""Population-based multi-objective optimizer (NSGA-II style) for the
policy/fleet search space — the ``algo="evo"`` engine behind
``repro.opt.search.frontier_search``.

Layout:

* ``budget``  — ``EvalBudget``: exact candidate-scenario-pair accounting.
* ``nsga``    — sort/crowding/SBX/mutation primitives (pure numpy).
* ``genome``  — SearchSpace -> bounded gene vectors (AxisSpec-clipped,
  integer/structural axes honored).
* ``engine``  — ``evo_search``: the generational loop, batched simulator
  evaluation, gradient elite refinement, FrontierResult construction.
"""

from repro.opt.evo.budget import BudgetExhausted, EvalBudget
from repro.opt.evo.engine import EvoConfig, evo_search, grid_budget
from repro.opt.evo.genome import (INTEGER_AXES, STRUCTURAL_AXES, Gene,
                                  Genome, genome_from_space, point_key)
from repro.opt.evo.nsga import (crowding_distance, non_dominated_sort,
                                nsga_rank, polynomial_mutation,
                                sbx_crossover, tournament_pick)

__all__ = [
    "BudgetExhausted", "EvalBudget", "EvoConfig", "evo_search",
    "grid_budget", "INTEGER_AXES", "STRUCTURAL_AXES", "Gene", "Genome",
    "genome_from_space", "point_key", "crowding_distance",
    "non_dominated_sort", "nsga_rank", "polynomial_mutation",
    "sbx_crossover", "tournament_pick",
]

# Frontier engine: cross-scenario multi-objective search over the joint
# (policy x fleet) parameter space — coarse vmapped grid or the NSGA-II
# population optimizer (repro.opt.evo), successive-halving refine,
# per-scenario Pareto fronts, the cross-scenario robust frontier, oracle
# spot-checks on sampled winners, and gradient-learned policies through
# the differentiable chunked scan.
from repro.opt.evo import (  # noqa: F401
    BudgetExhausted,
    EvalBudget,
    EvoConfig,
    evo_search,
    grid_budget,
)
from repro.opt.frontier import (  # noqa: F401
    epsilon_survivors,
    frontier_slack,
    hypervolume,
    pareto_front,
    robust_front,
)
from repro.opt.learned import (  # noqa: F401
    TrainResult,
    confirm,
    evaluate_trained,
    make_loss,
    refine_leaves,
    train_policy,
)
from repro.opt.search import (  # noqa: F401
    SEARCH_ALGOS,
    FrontierResult,
    default_fleet,
    evaluate_points,
    evaluate_scenario,
    frontier_search,
    oracle_spot_check,
    point_scenario,
    sample_front,
)
from repro.opt.space import (  # noqa: F401
    DEFAULT_SPACE,
    SWEEPABLE,
    SearchSpace,
    active_knobs,
    grid_points,
)

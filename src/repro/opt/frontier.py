"""Pareto machinery for the frontier engine (pure, engine-agnostic).

Three reducers over metric rows (dicts), all minimizing both axes:

* ``pareto_front``      — the non-dominated subset (the canonical
  implementation; ``repro.fleet.sweep`` re-exports it);
* ``epsilon_survivors`` — the front plus every point within a relative
  ``eps`` band of it, capped — the successive-halving survivor rule;
* ``robust_front``      — given per-scenario row sets sharing point ids,
  the points dominated in NO scenario (the cross-scenario frontier: a
  config you can deploy without knowing which workload you'll get).

Rows with non-finite values on either axis are ignored: a NaN slowdown
(e.g. a shrunk trace where no function clears the minimum request count)
compares False against everything and would otherwise pollute the front.
"""

from __future__ import annotations

import math
from typing import Mapping, Sequence

X_DEFAULT = "cost_per_million"
Y_DEFAULT = "slowdown_geomean_p99"


def _finite(rows: Sequence[dict], x: str, y: str) -> list[dict]:
    return [r for r in rows
            if math.isfinite(r.get(x, math.nan))
            and math.isfinite(r.get(y, math.nan))]


def pareto_front(rows: Sequence[dict], x: str = X_DEFAULT,
                 y: str = Y_DEFAULT) -> list[dict]:
    """Non-dominated subset (minimize both axes), sorted by x.  Ties on both
    axes survive together (neither strictly improves on the other)."""
    rows = _finite(rows, x, y)
    out = [r for r in rows
           if not any(o[x] <= r[x] and o[y] <= r[y]
                      and (o[x] < r[x] or o[y] < r[y]) for o in rows)]
    return sorted(out, key=lambda r: (r[x], r[y]))


def frontier_slack(row: dict, front: Sequence[dict], x: str = X_DEFAULT,
                   y: str = Y_DEFAULT) -> float:
    """How far a row sits from a front, as the smallest uniform relative
    inflation that makes some front point dominate it: min over front of
    max(r.x/f.x, r.y/f.y).  1.0 on the front; 1.2 = within 20%.  Assumes
    positive metrics (cost > 0, slowdown >= 1).  An EMPTY front (every
    candidate demoted or NaN) yields ``inf`` — nothing is "on" a front
    that does not exist, so downstream on_front checks read False instead
    of silently passing."""
    if not front:
        return math.inf
    return min(max(row[x] / max(f[x], 1e-12), row[y] / max(f[y], 1e-12))
               for f in front)


def epsilon_survivors(rows: Sequence[dict], x: str = X_DEFAULT,
                      y: str = Y_DEFAULT, eps: float = 0.15,
                      cap: int = 12) -> list[dict]:
    """Successive-halving survivor rule: every point within ``eps`` relative
    slack of the Pareto front, nearest-first, at most ``cap`` points.  The
    band keeps coarse-stage near-ties alive — a point 5% off the 0.1x front
    may win at full scale, where transients the shrunk trace cannot express
    (provisioning pipelines, burst widths) are resolved."""
    rows = _finite(rows, x, y)
    front = pareto_front(rows, x, y)
    ranked = sorted(rows, key=lambda r: frontier_slack(r, front, x, y))
    return [r for r in ranked
            if frontier_slack(r, front, x, y) <= 1.0 + eps][:cap]


def hypervolume(rows: Sequence[dict], x_ref: float, y_ref: float,
                x: str = X_DEFAULT, y: str = Y_DEFAULT) -> float:
    """Dominated-area hypervolume of the rows' Pareto front w.r.t. the
    reference point ``(x_ref, y_ref)`` (both axes minimized).

    One scalar that shrinks when the frontier retreats ANYWHERE — the
    multi-objective regression signal bench-smoke tracks per scenario over
    time (ROADMAP: "multi-objective CI tracking"): a point-wise metric gate
    misses a front that got strictly worse in the middle while its
    endpoints held.  Points at or beyond the reference contribute nothing;
    0.0 means no row dominates the reference point at all.

    An empty or all-non-finite row set returns ``nan`` — PR 7's
    zero-completion convention: "the measurement does not exist" must
    stay distinguishable from "a frontier exists but dominates nothing"
    (a genuine 0.0), or a scenario whose every candidate failed would
    read as a mere regression instead of a broken run."""
    if not _finite(rows, x, y):
        return math.nan
    front = [r for r in pareto_front(rows, x, y)
             if r[x] < x_ref and r[y] < y_ref]
    hv, y_prev = 0.0, y_ref
    for r in front:                       # sorted by x ascending, y descending
        hv += (x_ref - r[x]) * (y_prev - r[y])
        y_prev = r[y]
    return hv


def robust_front(rows_by_scenario: Mapping[str, Sequence[dict]],
                 x: str = X_DEFAULT, y: str = Y_DEFAULT,
                 key: str = "point_id") -> list:
    """Cross-scenario robust frontier: the point ids evaluated in EVERY
    scenario that are dominated in NONE of them.

    Per-scenario fronts answer "what is optimal for this workload"; their
    intersection-of-non-dominance answers "what is never a mistake" — the
    paper's closing object, a configuration whose cost/performance trade
    cannot be strictly beaten no matter which scenario materializes.
    Dominance inside each scenario is judged against that scenario's FULL
    row set, so a robust point must survive specialists it will never see
    elsewhere.  Returns ids sorted for determinism; [] when the scenario
    sets share no points."""
    if not rows_by_scenario:
        return []
    per = {name: _finite(rows, x, y)
           for name, rows in rows_by_scenario.items()}
    common = None
    for rows in per.values():
        ids = {r[key] for r in rows}
        common = ids if common is None else common & ids
    out = []
    for pid in common or ():
        dominated = False
        for rows in per.values():
            r = next(rr for rr in rows if rr[key] == pid)
            if any(o[x] <= r[x] and o[y] <= r[y]
                   and (o[x] < r[x] or o[y] < r[y]) for o in rows):
                dominated = True
                break
        if not dominated:
            out.append(pid)
    return sorted(out)

"""Cross-scenario frontier search over the joint (policy x fleet) space.

The engine answers the paper's closing question — "new, cost-efficient
autoscaling strategies" — by SEARCHING instead of replaying: every
candidate configuration (keepalive / utilization target / container
concurrency / hybrid pre-warm lead x warm-pool / packing-headroom fleet
knobs) runs through ONE vmapped chunked ``lax.scan`` per scenario, then a
successive-halving refine re-runs the promising region at full fidelity:

1. **coarse**   — the whole grid, every registered scenario, on a shrunk
   trace (``coarse_frac`` x the target scale): hundreds of simulations for
   roughly the price of one, since points share a compiled scan;
2. **survive**  — per scenario, the Pareto front plus an ``eps`` slack band
   (``opt.frontier.epsilon_survivors``), capped;
3. **refine**   — the UNION of every scenario's survivors (plus the coarse
   robust candidates) re-runs in EVERY scenario at the full target scale:
   a shared candidate pool is what makes cross-scenario dominance a fair
   comparison at refine fidelity, and scenario A's specialists double as
   fallback candidates when the oracle later demotes B's;
4. **reduce**   — per-scenario Pareto fronts + the robust frontier (points
   dominated in NO scenario) over the refined rows.

``oracle_spot_check`` then replays sampled frontier winners through the
discrete-event oracle so the frontier is trusted simulation, not a
fluid-model artifact (the same <=15% parity band the scenario tests pin).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.eventsim import SimConfig
from repro.core.policy_api import get_family
from repro.core.runspec import RunSpec
from repro.core.simjax import (_PFLEET, JaxFleet, JaxPolicy,
                               _chunked_summaries, stack_params)
from repro.core.trace import Trace
from repro.fleet.billing import (BillingProfile, apply_throttle,
                                 bill_summary, resolve_profile)
from repro.fleet.nodes import NodeType
from repro.opt.frontier import (X_DEFAULT, Y_DEFAULT, epsilon_survivors,
                                frontier_slack, hypervolume, pareto_front,
                                robust_front)
from repro.opt.space import DEFAULT_SPACE, SWEEPABLE, SearchSpace, active_knobs
from repro.scenarios.registry import get_scenario, list_scenarios
from repro.scenarios.spec import Scenario


def evaluate_points(trace: Trace, policy: JaxPolicy, fleet: JaxFleet,
                    points: Sequence[dict], sim: SimConfig = SimConfig(),
                    dt: float = 1.0, node_type: Optional[NodeType] = None,
                    billing: Union[str, BillingProfile, None] = None,
                    warmup_frac: float = 0.5,
                    chunk_ticks: int = 512, devices: int = 0, *,
                    cells=None) -> list[dict]:
    """Run every parameter point through one vmapped chunked scan; return
    one row per point: {params..., metrics..., cost fields...}.  Rows are
    billed through the ``billing`` profile (``repro.fleet.billing``;
    default ``ideal`` — bitwise the pre-billing ``cost_report`` math).
    ``devices`` > 0 shards the vmapped batch over that many local devices
    along the point axis (the largest divisor of the unique-point count
    that fits; one compiled dispatch either way).

    This is the generalized core behind ``repro.fleet.sweep.sweep``: every
    policy axis the family declares sweepable is a traced batch axis
    alongside the six fleet knobs (the per-point params pytrees are stacked
    leaf-wise, so arbitrary-shaped policies batch the same way four scalar
    knobs did).  A knob another family declares (e.g. ``target`` under a
    sync scenario) is accepted but inert, exactly as the flat parameter
    vector behaved; ``evaluate_scenario`` collapses such duplicates before
    simulating.  Every override is bounds-checked against its declaration,
    so a NaN or out-of-range sweep value fails loudly here.

    ``cells`` switches the batch to the multi-region engine: a
    ``(traces, topology)`` pair (per-cell trace partition +
    ``repro.cells.CellTopology``) routes the whole point batch through
    ``repro.cells.fluid.cells_chunked_summaries`` instead of the
    single-cell scan.  ``trace`` is then only consulted for metadata; all
    points share ONE topology — ``evaluate_scenario`` groups points by
    their (structural) ``cell_count`` before calling here.  Incompatible
    with ``devices`` sharding.
    """
    pts = list(points) if points else [{}]
    # validate against the LIVE registry (sweepable_knobs()), not the
    # import-time SWEEPABLE snapshot — families registered later must be
    # honored here exactly as SearchSpace honors them
    from repro.opt.space import sweepable_knobs
    legal = sweepable_knobs()
    unknown = {k for p in pts for k in p} - legal
    if unknown:
        raise ValueError(f"unsweepable params {sorted(unknown)}; "
                         f"traced params are {sorted(legal)}")

    fam = get_family(policy.family)
    base = policy.params()
    trees, fleets = [], np.tile(fleet.params(), (len(pts), 1))
    axis_names = set(fam.axis_names())
    for i, p in enumerate(pts):
        tree = dict(base)
        for k, v in p.items():
            if not np.isfinite(v):
                # every override — fleet knobs and other families' inert
                # knobs included — must at least be finite, or a NaN rides
                # silently to the CI gate's last-resort check
                raise ValueError(f"sweep value {k}={v!r} is not finite")
            if k in _PFLEET:
                fleets[i, _PFLEET.index(k)] = v
            elif k in axis_names:
                ax = fam.axis(k)
                if v < ax.lo or v > ax.hi:
                    raise ValueError(
                        f"sweep value {k}={v!r} outside the declared bounds "
                        f"[{ax.lo}, {ax.hi}] of family {fam.name!r}")
                tree[k] = float(v)
            # else: another family's sweepable knob — inert here
        trees.append(tree)
    pols = stack_params(trees)

    prof = resolve_profile(billing)
    if cells is not None:
        if devices > 0:
            raise ValueError("cells sweeps do not shard over devices: the "
                             "cell axis owns the scan's leading dimension")
        from repro.cells.fluid import cells_chunked_summaries
        cell_traces, topo = cells
        summaries = cells_chunked_summaries(
            cell_traces, topo, policy, pols, fleets, sim=sim, dt=dt,
            num_nodes=0, provision_s=fleet.provision_s, has_fleet=True,
            chunk_ticks=chunk_ticks, warmup_frac=warmup_frac, nbins=256,
            billing=prof)
    else:
        summaries = _chunked_summaries(
            trace, policy, pols, fleets, sim=sim, dt=dt, num_nodes=0,
            provision_s=fleet.provision_s, has_fleet=True,
            chunk_ticks=chunk_ticks, warmup_frac=warmup_frac, nbins=256,
            billing=prof, devices=devices)

    if node_type is None:
        # derive a shape from the fleet's node size at the default $/GB-hour
        base = NodeType()
        ratio = fleet.node_memory_mb / base.memory_mb
        node_type = NodeType(memory_mb=fleet.node_memory_mb,
                             vcpus=base.vcpus * ratio,
                             price_per_hour=base.price_per_hour * ratio,
                             provision_s=fleet.provision_s)
    nt = node_type
    rows = []
    for i, p in enumerate(pts):
        s = summaries[i]
        node_mem = fleets[i, _PFLEET.index("node_memory_mb")]
        if node_mem != nt.memory_mb:
            # sweeping node size: scale price and vCPUs linearly ($/GB-hour
            # held constant) so cost rows stay comparable across shapes
            ratio = node_mem / nt.memory_mb
            nt_i = NodeType(name=nt.name, memory_mb=float(node_mem),
                            vcpus=nt.vcpus * ratio,
                            price_per_hour=nt.price_per_hour * ratio,
                            provision_s=nt.provision_s)
        else:
            nt_i = nt
        cap_mb = max(s["nodes_mean"] * node_mem, 1e-9)
        cost = bill_summary(s, prof, node_type=nt_i, dt=dt, cap_mb=cap_mb)
        rows.append({**p, **s, **cost.row()})
    return rows


def default_fleet(sc: Scenario) -> JaxFleet:
    """An elastic twin of a static-cluster scenario: same node shape, the
    static size as headroom cap (x2 so the search can buy burst capacity).
    Cost needs node accounting, so the frontier always runs two-level."""
    if sc.fleet is not None:
        return sc.fleet
    return JaxFleet(node_memory_mb=NodeType().memory_mb,
                    min_nodes=1.0, max_nodes=float(max(4, 2 * sc.num_nodes)))


def _effective_key(point: dict, family: str) -> tuple:
    """Collapse knobs the scenario's policy family never reads, so inert
    grid axes do not multiply simulation work (point ids stay distinct)."""
    active = set(active_knobs(family)) | set(_PFLEET)
    return tuple(sorted((k, v) for k, v in point.items() if k in active))


def evaluate_scenario(scenario: Union[str, Scenario], points: Sequence[dict],
                      sim: Optional[SimConfig] = None,
                      dedupe: bool = True, *,
                      spec: Optional[RunSpec] = None) -> list[dict]:
    """Evaluate every point against one scenario's workload; one row per
    point, tagged with ``point_id`` (the index into ``points``) and the
    scenario identity so downstream reducers can join across scenarios.

    Run configuration (scale / billing / devices / cluster) lands through
    ``spec`` (``repro.core.runspec.RunSpec``) only — the loose ``scale=``
    / ``billing=`` shim keywords were removed.  ``sim`` and ``dedupe``
    are genuine per-call arguments.  ``spec.cluster`` > 0 buckets the
    long tail into weighted super-functions before the sweep
    (throttle-then-cluster); ``devices`` shards the point batch (see
    ``evaluate_points``).

    ``spec.billing`` defaults to the scenario's own profile (a spot
    scenario carries its tier discount there); a profile given by name
    inherits that discount.  The profile's cpu-throttle term stretches
    the trace BEFORE simulation, so a provider profile is a different
    workload, not just a different invoice."""
    spec = spec if spec is not None else RunSpec()
    if not isinstance(spec, RunSpec):
        raise TypeError("evaluate_scenario() spec= must be a RunSpec, got "
                        f"{type(spec).__name__}")
    scale = spec.scale
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    sim = sim or SimConfig(tick_s=sc.policy.tick_s)
    prof = resolve_profile(spec.billing, sc.billing)
    policy = sc.policy.to_jax()
    fleet = default_fleet(sc)
    cells_active = sc.cells is not None and not sc.cells.is_trivial
    if cells_active and spec.cluster > 0:
        raise ValueError(f"scenario {sc.name!r}: cells topologies partition "
                         f"an event stream — clustered sweeps cannot carry "
                         f"them")

    pts = list(points)
    if dedupe:
        uniq: dict[tuple, int] = {}
        order = []
        for p in pts:
            key = _effective_key(p, policy.family)
            if key not in uniq:
                uniq[key] = len(order)
                order.append(p)
            # remember which unique simulation backs each point
        backing = [uniq[_effective_key(p, policy.family)] for p in pts]
    else:
        order, backing = pts, list(range(len(pts)))

    t0 = time.time()
    if cells_active:
        # ``cell_count`` is STRUCTURAL: it changes the trace partition, not
        # just traced math, so points are grouped by its rounded value and
        # each group runs one batched multi-cell scan over its own
        # partition.  (``route_skew`` overrides stay traced — they steer
        # failover/spill preference; the origin partition keeps the
        # topology's static skew.)
        from repro.cells.topology import build_cell_traces
        uniq_rows: list = [None] * len(order)
        base_count = sc.cells.cell_count
        groups: dict[int, list[int]] = {}
        for i, p in enumerate(order):
            c = int(round(p.get("cell_count", base_count)))
            groups.setdefault(c, []).append(i)
        n_functions = 0
        for c, idxs in sorted(groups.items()):
            topo = (sc.cells if c == base_count
                    else dataclasses.replace(sc.cells, cell_count=c))
            cell_traces = [apply_throttle(t, prof) for t in
                           build_cell_traces(dataclasses.replace(
                               sc, cells=topo), scale)]
            n_functions = cell_traces[0].num_functions
            sub = evaluate_points(cell_traces[0], policy, fleet,
                                  [order[i] for i in idxs], sim=sim,
                                  dt=sim.tick_s, billing=prof,
                                  chunk_ticks=sc.chunk_ticks,
                                  cells=(cell_traces, topo))
            for i, r in zip(idxs, sub):
                uniq_rows[i] = r
    else:
        trace = apply_throttle(sc.build_trace(scale), prof)
        if spec.cluster > 0:
            from repro.scenarios.cluster import cluster_functions
            trace = cluster_functions(trace, spec.cluster, tick_s=sim.tick_s)
        n_functions = trace.num_functions
        uniq_rows = evaluate_points(trace, policy, fleet, order, sim=sim,
                                    dt=sim.tick_s, billing=prof,
                                    chunk_ticks=sc.chunk_ticks,
                                    devices=spec.devices)
    wall = time.time() - t0
    rows = []
    for pid, p in enumerate(pts):
        base = uniq_rows[backing[pid]]
        rows.append({**base, **p, "point_id": pid, "scenario": sc.name,
                     "scale": scale, "policy_kind": sc.policy.kind,
                     "num_functions": n_functions,
                     "sims": len(order), "stage_wall_s": round(wall, 3)})
    return rows


@dataclasses.dataclass
class FrontierResult:
    """Everything the coarse+refine search produced."""
    space: SearchSpace
    points: list[dict]                   # the full candidate set (id = index)
    scale: float                         # refine-stage trace scale
    coarse_scale: float
    coarse: dict[str, list[dict]]        # scenario -> rows (all points)
    refined: dict[str, list[dict]]       # scenario -> rows (refine set only)
    fronts: dict[str, list[dict]]        # scenario -> Pareto front (refined)
    robust_ids: list[int]                # robust frontier point ids (refined)
    wall_s: float
    # the billing spec every row was costed with — spot-check backfills
    # must re-evaluate on the same basis or dominance comparisons are
    # garbage (None = each scenario's own profile, the default)
    billing: Union[str, BillingProfile, None] = None
    # the sharding / clustering basis of every row, for the same reason
    devices: int = 0
    cluster: float = 0.0
    # which search engine produced this result ("grid" enumerates, "evo"
    # evolves — see repro.opt.evo) and, for evo, the exact evaluation
    # ledger (an ``repro.opt.evo.EvalBudget``)
    algo: str = "grid"
    budget: Optional[object] = None

    def robust_rows(self) -> list[dict]:
        """The robust frontier as rows: one per (robust point, scenario),
        at refine fidelity — the CSV/JSON the CLI emits."""
        out = []
        for pid in self.robust_ids:
            for name, rows in sorted(self.refined.items()):
                r = next((rr for rr in rows if rr["point_id"] == pid), None)
                if r is not None:
                    out.append(r)
        return out

    def summary(self) -> dict:
        return {
            "algo": self.algo,
            "budget": self.budget.summary() if self.budget is not None
            else None,
            "scale": self.scale, "coarse_scale": self.coarse_scale,
            "n_points": len(self.points), "wall_s": round(self.wall_s, 3),
            "scenarios": {
                name: {
                    "coarse_sims": self.coarse[name][0]["sims"]
                    if self.coarse[name] else 0,
                    "refined_points": len(self.refined[name]),
                    "front": [
                        {k: r[k] for k in (*r.keys() & SWEEPABLE, "point_id",
                                           X_DEFAULT, Y_DEFAULT)}
                        for r in self.fronts[name]],
                } for name in sorted(self.fronts)},
            "robust_point_ids": self.robust_ids,
            "robust_points": [self.points[i] for i in self.robust_ids],
        }


def _front_hypervolume(rows: Sequence[dict]) -> float:
    """Dominated-area hypervolume of a row set's Pareto front, referenced
    just beyond the set's own worst finite corner — the per-round search
    progress number the telemetry stream carries (comparable within one
    search, not across searches)."""
    xs = [r[X_DEFAULT] for r in rows if np.isfinite(r.get(X_DEFAULT, np.nan))]
    ys = [r[Y_DEFAULT] for r in rows if np.isfinite(r.get(Y_DEFAULT, np.nan))]
    if not xs or not ys:
        return 0.0
    return hypervolume(rows, x_ref=1.05 * max(xs), y_ref=1.05 * max(ys))


# coarse stage floor: below ~0.05x, Scenario.scaled_config's clamps
# (>=8 functions, >=240 s) take over and the grid would be ranked on a
# degenerate workload unrelated to the refine-stage one
MIN_COARSE_SCALE = 0.05

# the search engines frontier_search can dispatch to; the CLI validates
# its --algo flag against this tuple (repro.launch.flags)
SEARCH_ALGOS = ("grid", "evo")


def frontier_search(scenarios: Optional[Sequence[Union[str, Scenario]]] = None,
                    space: SearchSpace = DEFAULT_SPACE, scale: float = 1.0,
                    coarse_frac: float = 0.1, eps: float = 0.15,
                    survivor_cap: int = 12,
                    billing: Union[str, BillingProfile, None] = None,
                    log: Optional[Callable[[str], None]] = None,
                    telemetry=None, devices: int = 0,
                    cluster: float = 0.0, *, algo: str = "grid",
                    budget: Optional[int] = None, seed: int = 0,
                    forbidden: Sequence[dict] = (),
                    evo_config=None) -> FrontierResult:
    """The coarse -> survive -> refine -> reduce pipeline over every given
    scenario (default: every registered event-level scenario).  ``scale``
    is the refine-stage trace scale; the coarse grid runs at
    ``coarse_frac * scale``, clamped to [MIN_COARSE_SCALE, scale] so a
    small search scale never pushes the coarse traces onto their
    degenerate size floors.

    ``algo`` picks the search engine over the SAME space and contract:
    ``"grid"`` (default) enumerates the cartesian product as described
    above; ``"evo"`` dispatches to the population optimizer
    (``repro.opt.evo.evo_search`` — NSGA-II selection over the same
    coarse scale, budgeted in simulated candidate-scenario pairs, grid
    parity by default via ``grid_budget``).  ``budget`` / ``seed`` /
    ``forbidden`` / ``evo_config`` parameterize the evo engine and are
    ignored by the grid (enumeration has no stochastic state and always
    costs exactly its deduped product).  Both engines return the same
    ``FrontierResult`` (tagged ``algo``), so ``oracle_spot_check`` and
    the CLI output paths apply unchanged.

    ``devices`` shards each stage's candidate batch over local devices
    (the point axis, see ``evaluate_points``); ``cluster`` buckets each
    scenario's long tail below that mean-rps threshold into weighted
    super-functions first.  Rate-based scenarios (``rate_trace=True``,
    e.g. fig9_planet) are excluded from the default scenario set — the
    oracle spot-check cannot replay them and their size would dwarf every
    other stage; name one explicitly to search it.

    ``telemetry`` (a ``repro.obs.RunTelemetry``) receives one event per
    stage x scenario carrying sims / wall / front size / hypervolume."""
    if algo not in SEARCH_ALGOS:
        raise ValueError(f"unknown search algo {algo!r}; "
                         f"choose from {list(SEARCH_ALGOS)}")
    if algo == "evo":
        from repro.opt.evo.engine import EvoConfig, evo_search
        return evo_search(scenarios, space, scale, coarse_frac, eps,
                          survivor_cap, billing, log, telemetry, devices,
                          cluster, budget=budget, seed=seed,
                          config=evo_config or EvoConfig(),
                          forbidden=forbidden)
    t_start = time.time()
    say = log or (lambda s: None)
    tel = telemetry.emit if telemetry is not None else (lambda *a, **k: None)
    if scenarios is None:
        scenarios = [n for n in list_scenarios()
                     if not get_scenario(n).rate_trace]
    # Scenario OBJECTS are honored verbatim (a tiered re-spec from
    # apply_tier is not the registry entry of the same name)
    scs = {}
    for s in scenarios:
        sc = get_scenario(s) if isinstance(s, str) else s
        scs[sc.name] = sc
    points = space.points()
    coarse_scale = min(max(scale * coarse_frac, MIN_COARSE_SCALE), scale)
    run_spec = RunSpec(billing=billing, devices=devices, cluster=cluster)

    coarse: dict[str, list[dict]] = {}
    for name, sc in scs.items():
        coarse[name] = evaluate_scenario(
            sc, points, spec=run_spec.replace(scale=coarse_scale))
        say(f"coarse {name}: {coarse[name][0]['sims']} sims for "
            f"{len(points)} points in {coarse[name][0]['stage_wall_s']}s")
        tel("frontier_coarse", scenario=name, sims=coarse[name][0]["sims"],
            points=len(points), wall_s=coarse[name][0]["stage_wall_s"],
            hypervolume=_front_hypervolume(coarse[name]))

    survivors = {name: {r["point_id"]
                        for r in epsilon_survivors(rows, eps=eps,
                                                   cap=survivor_cap)}
                 for name, rows in coarse.items()}
    robust_candidates = set(robust_front(coarse))
    say(f"survivors/scenario: "
        f"{ {n: len(s) for n, s in sorted(survivors.items())} }; "
        f"{len(robust_candidates)} robust candidates")

    # one shared refine pool: every scenario's survivors + robust candidates
    ids = sorted(set().union(*survivors.values()) | robust_candidates) \
        if survivors else sorted(robust_candidates)
    sub = [points[i] for i in ids]
    refined: dict[str, list[dict]] = {}
    for name, sc in scs.items():
        rows = evaluate_scenario(sc, sub, spec=run_spec.replace(scale=scale))
        for r, pid in zip(rows, ids):     # re-key to global point ids
            r["point_id"] = pid
        refined[name] = rows
        say(f"refine {name}: {rows[0]['sims'] if rows else 0} sims for "
            f"{len(ids)} pooled survivors")
        tel("frontier_refine", scenario=name,
            sims=rows[0]["sims"] if rows else 0, survivors=len(ids),
            wall_s=rows[0]["stage_wall_s"] if rows else 0.0,
            front_size=len(pareto_front(rows)),
            hypervolume=_front_hypervolume(rows))

    fronts = {name: pareto_front(rows) for name, rows in refined.items()}
    robust_ids = robust_front(refined)
    tel("frontier_reduce", robust_points=len(robust_ids),
        wall_s=round(time.time() - t_start, 3))
    return FrontierResult(space=space, points=points, scale=scale,
                          coarse_scale=coarse_scale, coarse=coarse,
                          refined=refined, fronts=fronts,
                          robust_ids=robust_ids,
                          wall_s=time.time() - t_start, billing=billing,
                          devices=devices, cluster=cluster)


# ---------------------------------------------------------------------------
# oracle spot-checks: trust, but verify the fluid frontier
# ---------------------------------------------------------------------------

# per-scenario parity keys documented out-of-band (EXPERIMENTS.md: the
# renewal-matched expiry under-expires on strongly bursty sparse tails,
# which surfaces as a creation-rate gap on the production replay)
_PARITY_EXCLUDE: Mapping[str, tuple] = {"fig9_production": ("creation_rate",)}


def point_scenario(sc: Scenario, point: dict) -> Scenario:
    """Rebuild a scenario pinned to one searched configuration, so BOTH
    engines (oracle + fluid) replay exactly that point.

    Policy knobs always apply.  Fleet knobs apply only when the scenario is
    itself fleet-enabled: the parity band covers the instance-level metrics
    (slowdown / memory / creation), and the oracle's node layer is
    calibrated in the registered fleet configuration — grafting an elastic
    min_nodes=1 fleet onto a scenario specced with a static cluster puts
    its oracle leg outside that envelope (provision transients at every
    load wave), which measurement shows costs 2-5x the parity budget."""
    pol_rep = {}
    if "keepalive_s" in point:
        pol_rep["keepalive_s"] = float(point["keepalive_s"])
    if "target" in point:
        pol_rep["target"] = float(point["target"])
    if "cc" in point:
        pol_rep["container_concurrency"] = int(point["cc"])
    if "prewarm_s" in point:
        pol_rep["prewarm_s"] = float(point["prewarm_s"])
    # novel axes the scenario's family declares (e.g. the spot_aware
    # family's spot_fraction / hazard_per_hour) ride the ``extra`` mapping
    # — both lowerings (to_jax and the oracle fleet) read them from there
    fam_axes = set(sc.policy.family().axis_names())
    named = {"keepalive_s", "target", "cc", "prewarm_s"}
    novel = {k: float(v) for k, v in point.items()
             if k in fam_axes and k not in named and k not in _PFLEET}
    if novel:
        pol_rep["extra"] = {**dict(sc.policy.extra or {}), **novel}
    fleet = None
    if sc.fleet is not None:
        fleet = dataclasses.replace(
            sc.fleet, **{k: float(v) for k, v in point.items()
                         if k in _PFLEET})
    return dataclasses.replace(sc, policy=dataclasses.replace(sc.policy,
                                                              **pol_rep),
                               fleet=fleet)


def hazard_parity_gaps(sc_point: Scenario, scale: float,
                       seeds: Optional[Sequence[int]] = None) -> dict:
    """Oracle-vs-fluid parity gaps for one pinned scenario.

    The oracle leg is averaged over ``seeds`` — by default three market
    seeds when the scenario's policy runs a preemption hazard (the fluid
    model is the hazard process's EXPECTATION, so a single Poisson reclaim
    realization would dominate the verdict) and a single replay otherwise.
    Shared by the spot-check machinery and the fig12 benchmark."""
    from repro.scenarios.runner import PARITY_KEYS, run_scenario
    if seeds is None:
        hz = float((dict(sc_point.policy.extra or {})
                    ).get("hazard_per_hour", 0.0))
        seeds = (0, 1, 2) if hz > 0.0 else (0,)
    fluid = run_scenario(sc_point, spec=RunSpec(engines=("simjax",),
                                                scale=scale))[0]
    acc = {m: 0.0 for m in PARITY_KEYS}
    for seed in seeds:
        row = run_scenario(sc_point,
                           spec=RunSpec(engines=("eventsim",), scale=scale,
                                        force_oracle=True),
                           sim=SimConfig(tick_s=sc_point.policy.tick_s,
                                         seed=seed))[0]
        for m in PARITY_KEYS:
            acc[m] += row[m] / len(seeds)
    return {m: abs(acc[m] - fluid[m]) / max(abs(acc[m]), 1e-9)
            for m in PARITY_KEYS}


def sample_front(front: Sequence[dict], k: int,
                 rng: Optional[np.random.Generator] = None) -> list[dict]:
    """Up to ``k`` winners along a (cost-sorted) front: evenly spaced by
    default, or — given an explicit seeded ``rng`` — a reproducible draw
    that keeps both endpoints and samples the interior without
    replacement.  All randomness on the spot-check path is INJECTED
    through this parameter; there is no module-level RNG to make two
    "identical" runs sample different winners."""
    if not front or k <= 0:
        return []
    if len(front) <= k:
        return list(front)
    if rng is None:
        idx = np.unique(np.linspace(0, len(front) - 1, k).round().astype(int))
    elif k == 1:
        idx = np.asarray([rng.integers(0, len(front))])
    else:
        interior = rng.choice(len(front) - 2,
                              size=min(k - 2, len(front) - 2),
                              replace=False) + 1
        idx = np.unique(np.concatenate(
            ([0, len(front) - 1], interior))).astype(int)
    return [front[i] for i in idx]


def oracle_spot_check(result: FrontierResult, k: int = 3,
                      scale: Optional[float] = None, tol: float = 0.15,
                      demote: bool = True, include_infeasible: bool = False,
                      log: Optional[Callable[[str], None]] = None,
                      telemetry=None,
                      rng: Optional[np.random.Generator] = None
                      ) -> list[dict]:
    """Replay sampled frontier winners per oracle-feasible scenario through
    BOTH engines and judge the oracle-vs-fluid gap against the parity band.

    Runs at 0.25 scale by default regardless of the search scale: that is
    the scale where the discrete-event oracle is feasible AND where the
    parity band is calibrated — smaller traces are noise-dominated (a
    handful of functions carry the geomean), larger ones make the oracle
    leg the bottleneck.  Scenarios flagged ``oracle_ok=False`` (the
    production replay) are skipped by default — their discrete replay is
    feasible at 0.25x but costs minutes per point, blowing the CI budget;
    ``include_infeasible=True`` checks them anyway, with their
    ``_PARITY_EXCLUDE`` waivers applied (fig9's creation rate, see
    EXPERIMENTS.md).

    With ``demote`` (default), a winner the oracle refutes is REMOVED from
    that scenario's front (and from the robust frontier) and the front is
    re-derived without it; checking continues until ``k`` winners pass or
    2k candidates have been tried.  The emitted frontier is therefore the
    oracle-confirmed one — fluid-only points outside the calibrated
    envelope are demoted, not shipped — and every demotion is returned in
    the records, so nothing fails silently.

    Points whose policy runs a preemption hazard (the spot axes) replay
    the oracle over three market seeds and are judged against the AVERAGE
    (``hazard_parity_gaps``): the fluid model is the hazard process's
    expectation, and a handful of Poisson reclaim draws at 0.25x would
    otherwise dominate the verdict.

    ``rng`` (a seeded ``numpy.random.Generator``) randomizes which front
    winners are sampled, reproducibly; the default keeps the historical
    deterministic even spacing (see ``sample_front``).
    """
    check_scale = 0.25 if scale is None else scale
    say = log or (lambda s: None)
    tel = telemetry.emit if telemetry is not None else (lambda *a, **k: None)
    records = []
    for name in sorted(result.fronts):
        sc = get_scenario(name)
        if not (sc.oracle_ok or include_infeasible):
            continue
        exclude = set(_PARITY_EXCLUDE.get(name, ()))
        family = sc.policy.to_jax().family

        def check_key(pid: int) -> tuple:
            # the configuration class one oracle replay actually verifies:
            # active policy knobs, plus fleet knobs only when the scenario's
            # oracle leg runs a fleet (see point_scenario) — points
            # differing only in knobs the check cannot see share one
            # verdict, so checking them separately would waste the budget
            # on duplicate replays
            active = set(active_knobs(family))
            if sc.fleet is not None:
                active |= set(_PFLEET)
            return tuple(sorted((kk, v) for kk, v in
                                result.points[pid].items() if kk in active))

        rows = list(result.refined[name])
        checked: set[tuple] = set()
        passed = 0
        budget = 2 * k
        # demotion fallback: coarse classes nearest the coarse front, so a
        # scenario whose whole refined pool gets refuted can still descend
        # into the next-best configurations instead of ending frontless
        cfront = pareto_front(result.coarse[name])
        backups = sorted(result.coarse[name],
                         key=lambda r: frontier_slack(r, cfront))
        while passed < k and budget > 0:
            front = pareto_front(rows)
            classes: list[dict] = []       # one representative per class
            seen = set(checked)
            for r in front:
                key = check_key(r["point_id"])
                if key not in seen:
                    seen.add(key)
                    classes.append(r)
            todo = sample_front(classes, k - passed, rng=rng)
            if not todo:
                if any(check_key(r["point_id"]) not in checked for r in rows):
                    # unchecked classes remain but are dominated by already
                    # confirmed winners — every winner is verified, done
                    break
                nxt = next((b for b in backups
                            if check_key(b["point_id"]) not in checked), None)
                if nxt is None:
                    break
                pid = nxt["point_id"]
                newrow = evaluate_scenario(
                    sc, [result.points[pid]],
                    spec=RunSpec(scale=result.scale, billing=result.billing,
                                 devices=result.devices,
                                 cluster=result.cluster))[0]
                newrow["point_id"] = pid
                rows.append(newrow)
                result.refined[name] = rows
                say(f"spot {name}: backfilled point {pid} "
                    f"{result.points[pid]} from the coarse grid")
                continue
            for row in todo:
                pid = row["point_id"]
                key = check_key(pid)
                checked.add(key)
                budget -= 1
                point = result.points[pid]
                gaps = hazard_parity_gaps(point_scenario(sc, point),
                                          check_scale)
                judged = {m: g for m, g in gaps.items() if m not in exclude}
                ok = bool(judged) and all(g <= tol for g in judged.values())
                records.append({
                    "scenario": name, "point_id": pid, "point": point,
                    "scale": check_scale, "gaps": gaps, "pass": ok,
                    "demoted": demote and not ok,
                })
                say(f"spot {name} point {pid} {point}: "
                    + ("ok " if ok else "DEMOTED ")
                    + " ".join(f"{m}={g:.3f}" for m, g in gaps.items()))
                if ok:
                    passed += 1
                elif demote:
                    # the oracle refuted the fluid claim for this whole
                    # configuration class, not just this grid point
                    rows = [r for r in rows
                            if check_key(r["point_id"]) != key]
                    result.refined[name] = rows
                if budget <= 0:
                    break
        result.fronts[name] = pareto_front(result.refined[name])
        mine = [r for r in records if r["scenario"] == name]
        tel("spot_check", scenario=name, checked=len(mine),
            passed=sum(r["pass"] for r in mine),
            demoted=sum(r["demoted"] for r in mine),
            front_size=len(result.fronts[name]),
            hypervolume=_front_hypervolume(result.refined[name]))
    if demote:
        # demotions change each scenario's surviving row set; the robust
        # frontier is recomputed over the confirmed rows (a demotion can
        # both remove robust points and promote ones its class shadowed)
        result.robust_ids = robust_front(result.refined)
    return records

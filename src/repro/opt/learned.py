"""Gradient-learned autoscaling policies through the differentiable scan.

The policy-as-pytree redesign makes the policy itself the optimization
variable: a family's ``learnable`` axes (e.g. the learned family's MLP
weight pytree ``theta``) ride the chunked ``lax.scan`` as traced leaves, so
``jax.grad`` of a scalar objective w.r.t. those leaves differentiates
through every simulated tick — the ROADMAP's "learned policies" item.

The objective is a SMOOTH SURROGATE of the frontier axes, not the frontier
metrics themselves: the reported p99 slowdown runs through a histogram
scatter-add and a host-side bisection (zero/undefined gradients), so
training minimizes

    loss = cost_per_million_proxy + w_lat * slowdown_proxy

where the cost proxy bills the scan's node-seconds, master-CPU, billed
GB-s, idle-memory and completion sums through a ``repro.fleet.billing``
profile (bitwise the old node+master repricing under ``ideal``; provider
profiles add the per-request / per-GB-s / warm-pool terms so training
optimizes the SAME dollars the frontier ranks on), and the slowdown proxy
replaces the
per-function p99 with a differentiable tail estimate: per function,
1 + (mean wait + delay-weighted mean wait + warm hop) / mean duration,
geometric-averaged with arrival weights.  The delay-weighted mean
(sum w*d^2 / sum w*d) up-weights exactly the long-delay mass that drives
the p99, without sorting.

Trained policies are CLAIMS until the oracle confirms them: ``confirm``
replays the trained configuration through the discrete-event oracle and
judges the standard parity band, reusing the same spot-check/demotion
contract the frontier engine applies to swept winners.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eventsim import SimConfig
from repro.core.policies import init_theta
from repro.core.policy_api import get_family
from repro.core.simjax import (_PFLEET, JaxPolicy, _init_state, _make_step,
                               _prep_static)
from repro.core.runspec import RunSpec
from repro.core.trace import Trace, gap_statistics, rate_matrix
from repro.fleet.billing import (BillingProfile, apply_throttle,
                                 resolve_profile)
from repro.fleet.nodes import NodeType
from repro.opt.search import default_fleet, evaluate_scenario
from repro.scenarios.registry import get_scenario
from repro.scenarios.runner import parity_report, run_scenario
from repro.scenarios.spec import Scenario


def make_loss(trace: Trace, policy: JaxPolicy, sim: SimConfig = SimConfig(),
              dt: float = 1.0, num_nodes: int = 8, fleet=None,
              warmup_frac: float = 0.5, w_lat: float = 4.0,
              trunc_ticks: int = 64, node_type: NodeType = NodeType(),
              billing: Union[str, BillingProfile, None] = None):
    """Build ``(loss_fn, params0)``: a jit-able scalar objective over the
    policy's params PYTREE, differentiable w.r.t. every leaf (a learned
    family's weights, but equally a sync policy's ``keepalive_s`` — the
    gradient-correctness test differentiates exactly that).

    The loss runs the same segmented scan shape as ``simulate_chunked``,
    with one addition: the carried state is ``stop_gradient``-ed at chunk
    boundaries (truncated backprop-through-time, window ``trunc_ticks``).
    Full-horizon BPTT through this recurrence amplifies the adjoint by a
    few percent per tick — by ~100 ticks the float32 cotangents overflow to
    NaN — while the policy's causal influence on cost/latency is
    concentrated well inside a minute; truncation keeps the gradient both
    finite and informative.  Per-tick statistics are accumulated as sums
    inside the scan (no (T, F) histories), so training scales like the
    chunked simulator."""
    arr_np = rate_matrix(trace, dt)
    n_ticks, f = arr_np.shape
    trunc = max(1, min(int(trunc_ticks), n_ticks))
    n_chunks = -(-n_ticks // trunc)
    pad = n_chunks * trunc - n_ticks
    arr = jnp.asarray(np.concatenate(
        [arr_np, np.zeros((pad, f), arr_np.dtype)]))
    dur, mem, cold_ticks, wbuf, cpu_consts = _prep_static(trace, policy,
                                                          sim, dt)
    lam0 = jnp.asarray(arr_np.mean(axis=0) / dt, jnp.float32)
    gq, alive_tab, tail_tab = gap_statistics(trace)
    gaps = jnp.asarray(gq, jnp.float32)
    gap_tab = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                           (alive_tab, tail_tab))
    has_fleet = fleet is not None
    prov_ticks = max(1, int(round((fleet.provision_s if has_fleet else 0.0)
                                  / dt)))
    fl = jnp.asarray(fleet.params() if has_fleet else np.zeros(len(_PFLEET)),
                     jnp.float32)
    warm_tick = int(n_ticks * warmup_frac)
    # padded ticks advance state but carry zero weight, like _chunk_impl
    mask = jnp.asarray(((np.arange(n_chunks * trunc) >= warm_tick)
                        & (np.arange(n_chunks * trunc) < n_ticks))
                       .astype(np.float32))
    # per-TIER node rates: the spot discount applies only to the scan's
    # spot node-seconds (ys[12]), exactly as repro.fleet.costs bills —
    # discounting the whole fleet would overstate any partial-spot savings
    prof = resolve_profile(billing)
    od_rate = node_type.price_per_hour
    spot_rate = od_rate * (1.0 - prof.spot_discount)
    billed_w = jnp.asarray(prof.billed_weights(trace.profile), jnp.float32)
    dur_mean = jnp.asarray(np.asarray(dur), jnp.float32)
    family = policy.family

    def loss_fn(params) -> jnp.ndarray:
        step = _make_step(arr, dur, mem, billed_w, lam0, gaps, gap_tab,
                          params, fl, cpu_consts,
                          float(num_nodes), family=family, dt=dt,
                          cold_ticks=cold_ticks, wbuf=wbuf,
                          prov_ticks=prov_ticks, has_fleet=has_fleet)

        def tick(carry, t):
            st, a_tot, d1, d2, scalars = carry
            st, ys = step(st, t)
            delay, arr_t, arr_delayed = ys[0], ys[1], ys[2]
            m = mask[t]
            w = arr_delayed * m
            scalars = scalars + m * jnp.stack(
                [ys[10], ys[8], ys[11], ys[12], ys[13], ys[4] - ys[5]])
            # ^ nodes, cpu_master, completed, spot nodes, billed GB-s,
            #   idle (warm-pool) MB
            return (st, a_tot + arr_t * m, d1 + w * delay,
                    d2 + w * delay * delay, scalars), None

        def chunk(carry, c):
            st, *acc = carry
            st = jax.tree.map(jax.lax.stop_gradient, st)   # truncated BPTT
            (st, *acc), _ = jax.lax.scan(
                tick, (st, *acc), c * trunc + jnp.arange(trunc))
            return (st, *acc), None

        init_nodes = fl[0] if has_fleet else jnp.asarray(float(num_nodes))
        init = (_init_state(f, cold_ticks, wbuf, prov_ticks, init_nodes),
                jnp.zeros(f), jnp.zeros(f), jnp.zeros(f), jnp.zeros(6))
        (_, a_tot, d1, d2, scalars), _ = jax.lax.scan(
            chunk, init, jnp.arange(n_chunks))

        # $-cost proxy billed through the profile: node-seconds per tier
        # (weighted — serverless profiles zero the node axis), master CPU,
        # plus the provider terms (per-request fee, billed GB-s via
        # ys[13]'s analytic expectation, warm-pool GB-s from the idle
        # memory sum).  Under ``ideal`` every added term is x*0 and the
        # weight is 1.0, so the proxy is bitwise the old node+master math.
        node_seconds, master_s = scalars[0] * dt, scalars[1]
        spot_seconds = jnp.minimum(scalars[3] * dt, node_seconds)
        completed = jnp.maximum(scalars[2], 1.0)
        warm_gb_s = jnp.maximum(scalars[5], 0.0) * dt / 1024.0
        cost = (((node_seconds - spot_seconds) / 3600.0 * od_rate
                 + spot_seconds / 3600.0 * spot_rate)
                * prof.node_hour_weight
                + master_s / 3600.0 * prof.master_vcpu_per_hour
                + prof.per_request * completed
                + prof.per_gb_s * scalars[4]
                + prof.warm_gb_s_rate * warm_gb_s)
        cost_per_million = cost / completed * 1e6
        # slowdown proxy: mean wait + delay-weighted mean wait per function
        mean_wait = d1 / jnp.maximum(a_tot, 1e-9)
        tail_wait = d2 / jnp.maximum(d1, 1e-9)
        slow = 1.0 + (mean_wait + tail_wait + sim.warm_latency_s) / dur_mean
        wf = a_tot / (a_tot + 1.0)          # smooth min-request weighting
        slow_geo = jnp.exp((wf * jnp.log(slow)).sum()
                           / jnp.maximum(wf.sum(), 1e-9))
        return cost_per_million + w_lat * slow_geo

    return loss_fn, policy.params()


def _adam_update(g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
    v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
    def upd(m_, v_):
        mh = m_ / (1 - b1 ** t)
        vh = v_ / (1 - b2 ** t)
        return lr * mh / (jnp.sqrt(vh) + eps)
    return jax.tree.map(upd, m, v), m, v


@dataclasses.dataclass
class TrainResult:
    policy: JaxPolicy            # the trained configuration
    scenario: str
    scale: float
    history: list                # loss per step
    wall_s: float

    def summary(self) -> dict:
        return {"scenario": self.scenario, "scale": self.scale,
                "steps": len(self.history) - 1,
                "loss_initial": self.history[0], "loss_final": self.history[-1],
                "wall_s": round(self.wall_s, 3)}


def train_policy(scenario: Union[str, Scenario], family: str = "learned",
                 scale: float = 0.25, steps: int = 80, lr: float = 0.05,
                 seed: int = 0, w_lat: float = 4.0,
                 sim: Optional[SimConfig] = None,
                 log: Optional[Callable[[str], None]] = None,
                 telemetry=None) -> TrainResult:
    """Train a policy family's learnable leaves on one scenario's workload
    by Adam over ``jax.grad`` of the surrogate loss, through the scan.

    Only the axes the family declares ``learnable`` move; sweepable scalar
    knobs stay at the spec's values (they belong to the frontier grid).
    ``telemetry`` (a ``repro.obs.RunTelemetry``) receives the full
    training-loss series, one ``train_step`` event per gradient step.
    """
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    say = log or (lambda s: None)
    tel = telemetry.emit if telemetry is not None else (lambda *a, **k: None)
    fam = get_family(family)
    learnable = set(fam.learnable_axes())
    if not learnable:
        raise ValueError(f"policy family {family!r} declares no learnable "
                         f"axes; registered learnable families train here")
    sim = sim or SimConfig(tick_s=sc.policy.tick_s)
    # the learned family's weight pytree gets its deterministic init here;
    # other families' learnable axes start from the spec/extra values
    spec = dataclasses.replace(sc.policy, kind=family,
                               theta=init_theta(seed)
                               if "theta" in learnable else sc.policy.theta)
    policy = spec.to_jax()
    # train on the workload as the scenario's provider actually runs it
    # (cpu-throttled durations; identity under ``ideal``)
    trace = apply_throttle(sc.build_trace(scale), sc.billing)
    fleet = default_fleet(sc)
    loss_fn, params0 = make_loss(trace, policy, sim=sim, dt=sim.tick_s,
                                 fleet=fleet, w_lat=w_lat,
                                 billing=sc.billing)

    frozen = {k: v for k, v in params0.items() if k not in learnable}
    theta = {k: jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), v)
             for k, v in params0.items() if k in learnable}

    @jax.jit
    def value_and_grad(th):
        return jax.value_and_grad(lambda t: loss_fn({**frozen, **t}))(th)

    t0 = time.time()
    m = jax.tree.map(jnp.zeros_like, theta)
    v = jax.tree.map(jnp.zeros_like, theta)
    history: list = []
    best, best_theta = float("inf"), theta
    for t in range(1, steps + 1):
        val, g = value_and_grad(theta)      # loss AT the current theta
        history.append(float(val))
        tel("train_step", scenario=sc.name, step=t, loss=float(val))
        if float(val) < best:
            best, best_theta = float(val), theta
        delta, m, v = _adam_update(g, m, v, t, lr)
        theta = jax.tree.map(lambda p, d: p - d, theta, delta)
        if t % max(1, steps // 5) == 0:
            say(f"train[{sc.name}] step {t}/{steps}: loss {float(val):.4f}")
    val, _ = value_and_grad(theta)          # the final point joins the race
    history.append(float(val))
    if float(val) < best:
        best, best_theta = float(val), theta
    # write EVERY trained leaf back into the spec, whatever its axis name:
    # spec fields (theta, keepalive_s, ...) are replaced directly, novel
    # axes land in the ``extra`` mapping — a family is never silently
    # returned untrained because its learnable axis isn't called "theta"
    vals = {k: jax.tree.map(np.asarray, v) for k, v in best_theta.items()}
    spec_fields = {f.name for f in dataclasses.fields(spec)}
    spec_map = {"cc": "container_concurrency"}
    rep, extra_new = {}, dict(spec.extra or {})
    for k, v in vals.items():
        fk = spec_map.get(k, k)
        if fk in spec_fields:
            rep[fk] = v
        else:
            extra_new[k] = v
    trained = dataclasses.replace(spec, extra=extra_new or None, **rep)
    return TrainResult(policy=trained.to_jax(), scenario=sc.name, scale=scale,
                       history=history, wall_s=time.time() - t0)


def refine_leaves(scenario: Union[str, Scenario], point: dict,
                  axes: Sequence[str], scale: float = 0.25, steps: int = 6,
                  lr: float = 0.08, w_lat: float = 4.0,
                  sim: Optional[SimConfig] = None,
                  billing: Union[str, BillingProfile, None] = None) -> dict:
    """Gradient-refine the named CONTINUOUS policy axes of one searched
    point on one scenario: a few Adam steps over ``jax.grad`` of the same
    surrogate loss ``train_policy`` minimizes, differentiating the scalar
    leaves (keepalive_s, target, prewarm_s, ...) instead of a weight
    pytree — the local-polish move the evo engine applies to elite
    individuals, reaching configurations BETWEEN any grid's rungs.

    Returns a new point dict: ``point`` with each refined axis replaced by
    its best-loss value, clipped into the family's declared AxisSpec
    bounds (so a refined elite is always re-evaluable).  Axes the pinned
    policy's params pytree does not carry are skipped; with nothing to
    refine the point is returned unchanged."""
    from repro.opt.search import point_scenario
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    sc_pin = point_scenario(sc, point)
    fam = get_family(sc_pin.policy.kind)
    sim = sim or SimConfig(tick_s=sc_pin.policy.tick_s)
    prof = resolve_profile(billing, sc.billing)
    policy = sc_pin.policy.to_jax()
    fleet = default_fleet(sc_pin)
    fleet = dataclasses.replace(fleet, **{k: float(v)
                                          for k, v in point.items()
                                          if k in _PFLEET})
    trace = apply_throttle(sc_pin.build_trace(scale), prof)
    loss_fn, params0 = make_loss(trace, policy, sim=sim, dt=sim.tick_s,
                                 fleet=fleet, w_lat=w_lat, billing=prof)
    live = [a for a in axes
            if a in params0 and np.ndim(params0[a]) == 0
            and a in fam.axis_names()]
    if not live:
        return dict(point)
    frozen = {k: v for k, v in params0.items() if k not in live}
    theta = {k: jnp.asarray(params0[k], jnp.float32) for k in live}

    @jax.jit
    def value_and_grad(th):
        return jax.value_and_grad(lambda t: loss_fn({**frozen, **t}))(th)

    m = jax.tree.map(jnp.zeros_like, theta)
    v = jax.tree.map(jnp.zeros_like, theta)
    best, best_theta = float("inf"), theta

    def clip(th):
        # a gradient step must not leave the declared envelope: clip each
        # leaf into its AxisSpec bounds after every update
        return {k: jnp.clip(t, fam.axis(k).lo, fam.axis(k).hi)
                for k, t in th.items()}

    for t in range(1, steps + 1):
        val, g = value_and_grad(theta)
        if float(val) < best:
            best, best_theta = float(val), theta
        # relative step: the leaves live on wildly different scales
        # (keepalive in seconds vs target in [0, 4]), so Adam's unit step
        # is rescaled by each leaf's magnitude
        delta, m, v = _adam_update(g, m, v, t, lr)
        theta = clip({k: theta[k] - delta[k] * jnp.maximum(
            jnp.abs(theta[k]), 1.0) for k in theta})
    val, _ = value_and_grad(theta)
    if float(val) < best:
        best, best_theta = float(val), theta
    return {**point, **{k: float(np.clip(float(v_), fam.axis(k).lo,
                                         fam.axis(k).hi))
                        for k, v_ in best_theta.items()}}


def learned_scenario(sc: Scenario, result: TrainResult) -> Scenario:
    """The scenario re-specced to run the trained policy (both engines)."""
    pol = result.policy
    spec = dataclasses.replace(
        sc.policy, kind=pol.family, keepalive_s=pol.keepalive_s,
        window_s=pol.window_s, target=pol.target,
        container_concurrency=pol.cc, prewarm_s=pol.prewarm_s,
        theta=pol.theta, extra=pol.extra)
    return dataclasses.replace(sc, policy=spec)


def evaluate_trained(scenario: Union[str, Scenario], result: TrainResult,
                     scale: float = 1.0,
                     billing: Union[str, BillingProfile, None] = None) -> dict:
    """One frontier-style metric row (cost, p99, memory, ...) for the
    trained policy at the given scale — comparable against swept rows
    billed on the same basis."""
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    return evaluate_scenario(learned_scenario(sc, result), [{}],
                             spec=RunSpec(scale=scale, billing=billing))[0]


def confirm(scenario: Union[str, Scenario], result: TrainResult,
            scale: float = 0.25, tol: float = 0.15) -> dict:
    """Oracle spot-check of the trained policy: replay the learned
    configuration through BOTH engines and judge the parity band — the
    same trust gate swept frontier winners pass before being shipped."""
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    rows = run_scenario(learned_scenario(sc, result),
                        spec=RunSpec(scale=scale, force_oracle=True))
    gaps = parity_report(rows)
    ok = bool(gaps) and all(g <= tol for g in gaps.values())
    return {"scenario": sc.name, "scale": scale, "gaps": gaps, "pass": ok}

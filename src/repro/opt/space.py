"""Joint (policy x fleet) search spaces over the traced simulator knobs.

A ``SearchSpace`` is two {knob: candidate values} grids — one over the
policy axes the registered families DECLARE sweepable
(``repro.core.policy_api``: keepalive, utilization target, container
concurrency, hybrid pre-warm lead, ...) and one over the traced fleet axes
(``simjax._PFLEET``) — whose cartesian product is the candidate set the
frontier engine sweeps through one vmapped chunked scan per scenario.

Not every knob acts under every policy family (an async reconciler never
reads the keepalive; a sync policy never reads the utilization target), so
``active_knobs`` names the axes with effect per family — DERIVED from each
family's ``AxisSpec`` declarations, not a hand-written table; the engine
collapses inert axes before simulating and broadcasts results back,
turning e.g. a 96-point grid into 32 distinct simulations for a sync
scenario while keeping point ids comparable across scenarios — which is
what makes the cross-scenario robust frontier well-defined.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Mapping, Sequence, Tuple, Union

from repro.core.policy_api import get_family, sweepable_policy_axes
from repro.core.simjax import _PFLEET


def sweepable_knobs() -> set:
    """Every knob a ``SearchSpace`` may grid over: the union of all
    registered families' sweepable axes plus the fleet vector."""
    return sweepable_policy_axes() | set(_PFLEET)


# snapshot at import for cheap membership checks; families registered later
# are still honored by sweepable_knobs() / SearchSpace validation
SWEEPABLE = sweepable_knobs()


def active_knobs(family: Union[str, int]) -> Tuple[str, ...]:
    """The sweepable policy axes a family actually reads — straight from
    its ``AxisSpec`` declarations (accepts a registry name or the legacy
    integer kind)."""
    return get_family(family).sweepable_axes()


def grid_points(grid: Mapping[str, Sequence]) -> list[dict]:
    """Cartesian product of a {param: values} grid, as one dict per point."""
    keys = list(grid)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(grid[k] for k in keys))]


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """A {knob: candidates} grid split along the policy/fleet seam."""
    policy: Mapping[str, Sequence[float]] = dataclasses.field(
        default_factory=dict)
    fleet: Mapping[str, Sequence[float]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        pol_axes = sweepable_policy_axes()
        bad = (set(self.policy) - pol_axes) | (set(self.fleet) - set(_PFLEET))
        if bad:
            raise ValueError(f"unsweepable knobs {sorted(bad)}; traced axes "
                             f"are {sorted(pol_axes | set(_PFLEET))}")
        for knob, vals in {**self.policy, **self.fleet}.items():
            if len(vals) == 0:
                raise ValueError(f"knob {knob!r} has no candidate values")
            for v in vals:
                if not math.isfinite(float(v)):
                    raise ValueError(f"knob {knob!r} has a non-finite "
                                     f"candidate {v!r}")

    def points(self) -> list[dict]:
        """The full candidate set; index order is the stable point id."""
        return grid_points({**self.policy, **self.fleet})

    def size(self) -> int:
        vals = list(self.policy.values()) + list(self.fleet.values())
        n = 1
        for v in vals:
            n *= len(v)
        return n


# The default joint space: the paper's keepalive ladder (Fig. 3-6) x the
# Knative utilization targets (Fig. 7-8) x the spot-tier purchase fraction
# (Fig. 12), crossed with the fleet's warm-pool and packing-headroom
# knobs.  96 raw points; inert-axis collapsing keeps a sync scenario at 16
# simulations and an async one at 12 (``spot_fraction`` only acts under
# the spot_aware family, so it collapses everywhere else).  ``cc`` and
# ``prewarm_s`` are fully traced axes and sweepable in custom spaces, but
# stay out of the DEFAULT grid: the fluid model's cc>1 creation/slowdown
# fidelity and the hybrid's pre-warm are outside the oracle-calibrated
# parity envelope (EXPERIMENTS.md, Frontier section), so their winners
# would only be demoted by the oracle spot-check.  ``hazard_per_hour``
# stays out too — it is the MARKET's reclaim rate, not an operator choice;
# sweep it in custom spaces (benchmarks/fig12_spot_frontier.py) to compare
# markets.
DEFAULT_SPACE = SearchSpace(
    policy={
        "keepalive_s": (60.0, 300.0, 600.0, 1200.0),
        "target": (0.5, 0.7, 1.0),
        "spot_fraction": (0.0, 0.6),
    },
    fleet={
        "util_target": (0.6, 0.8),
        "warm_frac": (0.0, 0.25),
    },
)

"""Joint (policy x fleet) search spaces over the traced simulator knobs.

A ``SearchSpace`` is two {knob: candidate values} grids — one over the
traced policy axes (``simjax._PPOL``: keepalive, utilization target,
container concurrency, hybrid pre-warm lead) and one over the traced fleet
axes (``simjax._PFLEET``) — whose cartesian product is the candidate set
the frontier engine sweeps through one vmapped chunked scan per scenario.

Not every knob acts under every policy family (an async reconciler never
reads the keepalive; a sync policy never reads the utilization target), so
``active_knobs`` names the axes with effect per ``JaxPolicy.kind``; the
engine collapses inert axes before simulating and broadcasts results back,
turning e.g. a 96-point grid into 32 distinct simulations for a sync
scenario while keeping point ids comparable across scenarios — which is
what makes the cross-scenario robust frontier well-defined.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence, Tuple

from repro.core.simjax import _PFLEET, _PPOL

SWEEPABLE = set(_PPOL) | set(_PFLEET)

# policy knobs with effect per JaxPolicy.kind (fleet knobs always act)
_ACTIVE = {
    0: ("keepalive_s", "cc"),                 # sync keepalive
    1: ("target", "cc"),                      # async window reconciler
    2: ("keepalive_s", "cc", "prewarm_s"),    # hybrid histogram + pre-warm
}


def active_knobs(kind: int) -> Tuple[str, ...]:
    """The policy axes a ``JaxPolicy`` of this kind actually reads."""
    return _ACTIVE[kind]


def grid_points(grid: Mapping[str, Sequence]) -> list[dict]:
    """Cartesian product of a {param: values} grid, as one dict per point."""
    keys = list(grid)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(grid[k] for k in keys))]


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """A {knob: candidates} grid split along the policy/fleet seam."""
    policy: Mapping[str, Sequence[float]] = dataclasses.field(
        default_factory=dict)
    fleet: Mapping[str, Sequence[float]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        bad = (set(self.policy) - set(_PPOL)) | (set(self.fleet) - set(_PFLEET))
        if bad:
            raise ValueError(f"unsweepable knobs {sorted(bad)}; traced axes "
                             f"are {sorted(SWEEPABLE)}")
        for knob, vals in {**self.policy, **self.fleet}.items():
            if len(vals) == 0:
                raise ValueError(f"knob {knob!r} has no candidate values")

    def points(self) -> list[dict]:
        """The full candidate set; index order is the stable point id."""
        return grid_points({**self.policy, **self.fleet})

    def size(self) -> int:
        vals = list(self.policy.values()) + list(self.fleet.values())
        n = 1
        for v in vals:
            n *= len(v)
        return n


# The default joint space: the paper's keepalive ladder (Fig. 3-6) x the
# Knative utilization targets (Fig. 7-8), crossed with the fleet's
# warm-pool and packing-headroom knobs.  48 raw points; inert-axis
# collapsing brings a sync scenario to 16 simulations and an async one
# to 12.  ``cc`` and ``prewarm_s`` are fully traced axes and sweepable in
# custom spaces, but stay out of the DEFAULT grid: the fluid model's cc>1
# creation/slowdown fidelity and the hybrid's pre-warm are outside the
# oracle-calibrated parity envelope (EXPERIMENTS.md, Frontier section), so
# their winners would only be demoted by the oracle spot-check.
DEFAULT_SPACE = SearchSpace(
    policy={
        "keepalive_s": (60.0, 300.0, 600.0, 1200.0),
        "target": (0.5, 0.7, 1.0),
    },
    fleet={
        "util_target": (0.6, 0.8),
        "warm_frac": (0.0, 0.25),
    },
)

"""Fig 7: container concurrency 1 -> 4 cuts CPU overhead ~3x (async, w=60,
target=0.7)."""

from __future__ import annotations

from benchmarks.common import emit, run_policy
from repro.core.policies import AsyncConcurrencyPolicy


def run():
    out = {}
    for cc in (1, 2, 4):
        m, dt = run_policy(lambda f, c=cc: AsyncConcurrencyPolicy(
            window_s=60, target=0.7, container_concurrency=c))
        out[cc] = m
        emit(f"fig7_cc{cc}", dt * 1e6,
             f"cpu={m.cpu_overhead*100:.1f}%;rate={m.creation_rate:.3f}/s;"
             f"slowdown={m.slowdown_geomean_p99:.2f}")
    return out


if __name__ == "__main__":
    run()

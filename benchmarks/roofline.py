"""Roofline report: reads the dry-run artifacts (artifacts/dryrun/*.json) and
prints the three terms + bottleneck + MODEL_FLOPS/HLO_FLOPs per cell."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import SHAPES, get_config
from repro.launch.dryrun import ARTIFACTS, HBM_BW, LINK_BW, PEAK_FLOPS


def _attn_flops_fwd(cfg, batch: int, seq: int, kind: str) -> float:
    """Forward score+output matmul FLOPs summed over layers (causal halved;
    sliding windows bound the key span; decode sees one query against the
    mean context seq/2).  SSM/linear-attention layers have no score matmul."""
    if cfg.family == "ssm":
        return 0.0
    from repro.models.stack import layer_windows
    h, dh = cfg.num_heads, cfg.head_dim
    if cfg.use_mla:
        dh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    total = 0.0
    for w in layer_windows(cfg):
        if kind == "decode":
            span = min(w or seq, seq / 2)
            total += 4.0 * batch * h * span * dh          # qlen = 1
        else:
            span = min(w or seq, seq)
            causal = 0.5 if span == seq else 1.0
            total += 4.0 * batch * seq * span * dh * h * causal
    return total


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs: parameter matmuls (6ND train / 2ND inference)
    + attention score/output matmuls.  Remat recompute, MoE dispatch einsums
    and capacity padding are deliberately excluded — the HLO/model ratio
    exposes them as overhead."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * b * s + 3.0 * _attn_flops_fwd(cfg, b, s, "train")
    if shape.kind == "prefill":
        return 2.0 * n * b * s + _attn_flops_fwd(cfg, b, s, "prefill")
    return 2.0 * n * b + _attn_flops_fwd(cfg, b, s, "decode")


def load_cells(mesh: str = "16x16") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("error") or d.get("skipped"):
            continue
        out.append(d)
    return out


def report(mesh: str = "16x16") -> list[dict]:
    rows = []
    for d in load_cells(mesh):
        arch, shape = d["arch"], d["shape"]
        per_dev = d["per_device"]
        n_chips = d["n_chips"]
        terms = d["roofline_s"]
        mf = model_flops(arch, shape)
        hlo_global = per_dev["flops"] * n_chips
        useful = mf / max(hlo_global, 1e-9)
        step_time = max(terms.values())
        mfu = (mf / n_chips / step_time) / PEAK_FLOPS if step_time > 0 else 0.0
        row = dict(arch=arch, shape=shape, mesh=mesh,
                   compute_s=terms["compute"], memory_s=terms["memory"],
                   collective_s=terms["collective"],
                   bottleneck=d["bottleneck"], useful_ratio=useful, mfu=mfu)
        rows.append(row)
        emit(f"roofline_{arch}_{shape}_{mesh}", d.get("compile_s", 0) * 1e6,
             f"compute={terms['compute']*1e3:.2f}ms;memory={terms['memory']*1e3:.2f}ms;"
             f"collective={terms['collective']*1e3:.2f}ms;bound={d['bottleneck']};"
             f"useful={useful:.2f};roofline_frac={mfu:.3f}")
    return rows


def run():
    rows = report("16x16")
    if not rows:
        emit("roofline", 0.0, "NO_ARTIFACTS_RUN_DRYRUN_FIRST")
    return rows


if __name__ == "__main__":
    run()

"""Fig 12 (beyond-paper): the spot-fleet cost-vs-p99 frontier, with and
without preemption.

Spot nodes cut the dollar cost of keeping warm by ~65% — IF the model
prices the eviction-driven cold-start storms they cause.  This benchmark
sweeps (keepalive x spot purchase fraction) on the ``spot_storm`` scenario
twice through the vmapped chunked scan: once under the scenario's
preemption hazard and once with the hazard zeroed (the naive savings a
preemption-blind model reports), then

* finds the cheapest all-on-demand configuration and the spot
  configurations that beat it at equal-or-better p99 (the acceptance
  claim: spot savings survive honest eviction modelling),
* quantifies how much of the naive savings preemption claws back,
* oracle-confirms the winning spot point — discrete replay, standard
  parity band, AND an oracle-side bill strictly below the oracle's bill
  for the best on-demand point.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.runspec import RunSpec
from repro.fleet.billing import bill_sim
from repro.opt import evaluate_scenario, grid_points, pareto_front
from repro.opt.search import hazard_parity_gaps, point_scenario
from repro.scenarios import get_scenario
from repro.scenarios.runner import oracle_node_type

SCENARIO = "spot_storm"
EVAL_SCALE = 0.25           # the oracle-feasible, parity-calibrated scale

GRID = {
    "keepalive_s": (60.0, 600.0),
    "spot_fraction": (0.0, 0.3, 0.6, 0.9),
}


def _oracle_bill(sc, point, scale):
    """Replay one configuration through the discrete-event oracle and bill
    it through the scenario's billing profile on the same node-shape basis
    as the fluid rows (the profile carries the spot discount)."""
    from repro.scenarios.runner import _oracle_fleet
    sc_p = point_scenario(sc, point)
    sim = SimConfig(tick_s=sc_p.policy.tick_s)
    trace = sc_p.build_trace(scale)
    fleet = _oracle_fleet(sc_p.fleet, sc_p.policy, seed=sim.seed)
    cluster = Cluster(max(1, int(sc_p.fleet.min_nodes)),
                      node_memory_mb=sc_p.fleet.node_memory_mb)
    res = EventSim(trace, cluster, sc_p.policy.factory(), sim,
                   fleet=fleet).run()
    return bill_sim(res, trace, sc.billing,
                    node_type=oracle_node_type(sc_p.fleet))


def run(scale: float = 1.0, confirm: bool = True):
    """``scale`` multiplies the benchmark's own (already reduced) scale;
    ``confirm=False`` skips the oracle legs (the deterministic quick tier
    gates the fluid cost ratio only)."""
    t0 = time.time()
    eval_scale = max(0.05, EVAL_SCALE * scale)
    sc = get_scenario(SCENARIO)
    points = grid_points(GRID)

    rows = evaluate_scenario(sc, points, spec=RunSpec(scale=eval_scale))
    naive = evaluate_scenario(sc, [{**p, "hazard_per_hour": 0.0}
                                   for p in points],
                              spec=RunSpec(scale=eval_scale))

    od = [r for r in rows if r["spot_fraction"] == 0.0]
    best_od = min(od, key=lambda r: r["cost_per_million"])
    beats = sorted((r for r in rows if r["spot_fraction"] > 0.0
                    and r["cost_per_million"] < best_od["cost_per_million"]
                    and r["slowdown_geomean_p99"]
                    <= best_od["slowdown_geomean_p99"]),
                   key=lambda r: r["cost_per_million"])
    # without the oracle legs the fluid's cheapest beat stands; with them,
    # only an oracle-CONFIRMED candidate may be the winner (demotion
    # contract: all-refuted -> no winner, not a refuted one)
    winner = beats[0] if beats and not confirm else None

    front = pareto_front(rows)
    for r, r0 in zip(rows, naive):
        tag = "PARETO" if any(f is r for f in front) else "dom"
        name = (f"fig12_ka{r['keepalive_s']:.0f}"
                f"_spot{r['spot_fraction']:.1f}")
        # clawback: the share of the naive (hazard-blind) saving that
        # preemption takes back in this configuration
        emit(name, 0.0,
             f"cost={r['cost_per_million']:.2f};"
             f"naive_cost={r0['cost_per_million']:.2f};"
             f"p99={r['slowdown_geomean_p99']:.3f};{tag}")

    check = {}
    if confirm and beats:
        # walk the beating configs cheapest-first and ship the first one
        # the oracle confirms — the frontier engine's demotion contract
        bill_od = _oracle_bill(sc, {k: best_od[k] for k in GRID},
                               eval_scale)
        for cand in beats[:3]:
            point = {k: cand[k] for k in GRID}
            gaps = hazard_parity_gaps(point_scenario(sc, point), eval_scale)
            ok = all(g <= 0.15 for g in gaps.values())
            bill_spot = _oracle_bill(sc, point, eval_scale)
            check = {"parity_ok": ok, "gaps": gaps, "point": point,
                     "oracle_spot_cost": bill_spot.cost_per_million,
                     "oracle_od_cost": bill_od.cost_per_million,
                     "oracle_cheaper":
                     bill_spot.cost_per_million < bill_od.cost_per_million}
            if ok and check["oracle_cheaper"]:
                winner = cand
                break
    ratio = (winner["cost_per_million"] / best_od["cost_per_million"]
             if winner else float("nan"))
    emit("fig12_spot_vs_od", (time.time() - t0) * 1e6,
         f"cost_ratio={ratio:.3f};best_od={best_od['cost_per_million']:.2f};"
         + ("oracle=" + ("ok" if check.get("parity_ok")
                         and check.get("oracle_cheaper") else "refuted")
            if check else "oracle=skipped"))
    return rows, naive, winner, best_od, check


if __name__ == "__main__":
    run()

"""Fig 11 (beyond-paper): the gradient-learned policy vs the tuned hybrid.

The paper closes by calling for "new, cost-efficient autoscaling
strategies"; the policy-as-pytree redesign makes the policy itself the
optimization variable.  This benchmark trains the learned keepalive family
(``repro.opt.learned``: jax.grad through the chunked scan on a cost+latency
surrogate) on one scenario, then evaluates it at a larger scale against the
hand-tuned baselines — the hybrid histogram at the paper's default cap and
the sync keepalive ladder's best point — on the (cost, p99) plane, plus an
oracle parity readout for the trained configuration.
"""

from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit
from repro.core.runspec import RunSpec
from repro.opt import evaluate_scenario, frontier_slack, pareto_front
from repro.opt.learned import confirm, evaluate_trained, train_policy
from repro.scenarios import get_scenario

# fleet_cost_stress: dense-rate functions keep the whole keepalive range
# inside the oracle-calibrated parity envelope, so the trained policy's
# claim is oracle-confirmable (sparse scenarios' mid-keepalive region is
# not — see EXPERIMENTS.md, "Fluid-model parity envelope")
SCENARIO = "fleet_cost_stress"
TRAIN_SCALE, EVAL_SCALE = 0.25, 0.25
STEPS = 50


def baseline_rows(scale: float = EVAL_SCALE) -> list[dict]:
    sc = get_scenario(SCENARIO)
    rows = []
    for r in evaluate_scenario(sc, [{"keepalive_s": float(ka)}
                                    for ka in (60.0, 300.0, 600.0)],
                               spec=RunSpec(scale=scale)):
        rows.append({**r, "name": f"sync_ka{int(r['keepalive_s'])}"})
    hybrid = dataclasses.replace(
        sc, policy=dataclasses.replace(sc.policy, kind="hybrid"))
    rows.append({**evaluate_scenario(hybrid, [{}],
                                     spec=RunSpec(scale=scale))[0],
                 "name": "hybrid_tuned"})
    return rows


def run(scale: float = 1.0):
    """``scale`` multiplies the benchmark's own (already reduced) scales."""
    t0 = time.time()
    train_scale = max(0.05, TRAIN_SCALE * scale)
    eval_scale = max(0.05, EVAL_SCALE * scale)
    res = train_policy(SCENARIO, scale=train_scale, steps=STEPS)
    learned = {**evaluate_trained(SCENARIO, res, scale=eval_scale),
               "name": "learned"}
    base = baseline_rows(eval_scale)
    rows = base + [learned]
    front = pareto_front(rows)
    slack = frontier_slack(learned, pareto_front(base))
    check = confirm(SCENARIO, res, scale=eval_scale)
    for r in rows:
        tag = "PARETO" if any(f is r for f in front) else "dom"
        emit(f"fig11_{r['name']}", 0.0,
             f"cost={r['cost_per_million']:.3f};"
             f"p99={r['slowdown_geomean_p99']:.3f};{tag}")
    emit("fig11_learned_vs_tuned", (time.time() - t0) * 1e6,
         f"slack={slack:.3f};loss0={res.history[0]:.2f};"
         f"lossN={min(res.history):.2f};oracle="
         + ("ok" if check["pass"] else "refuted"))
    return rows, slack, check


if __name__ == "__main__":
    run()

"""Shared harness: one trace + one cached parameter sweep reused by all the
figure benchmarks (figs 3,4,5,6,8 are different views of the same sweep, as
in the paper)."""

from __future__ import annotations

import functools
import time

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import Metrics, compute
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy
from repro.core.trace import TraceConfig, synthesize

# the "400-function" experiment, scaled to bench runtime on 1 CPU core:
# 200 functions x 40 min; warmup = first half (paper: 80 min, discard 40).
TRACE_CFG = TraceConfig(num_functions=200, duration_s=2400,
                        target_total_rps=31.25, seed=0)

KEEPALIVES = [30, 60, 120, 300, 600, 1200, 1800]
WINDOWS = [30, 60, 120, 300, 600, 1200, 1800]
TARGETS = [0.5, 0.7, 1.0]


@functools.lru_cache(maxsize=1)
def trace():
    return synthesize(TRACE_CFG)


def run_policy(policy_factory, num_nodes: int = 8, failures=None) -> tuple[Metrics, float]:
    t0 = time.time()
    res = EventSim(trace(), Cluster(num_nodes), policy_factory, SimConfig(),
                   failures=failures).run()
    return compute(res), time.time() - t0


@functools.lru_cache(maxsize=1)
def sweep_sync() -> dict:
    return {ka: run_policy(lambda f, k=ka: SyncKeepalivePolicy(keepalive_s=k))[0]
            for ka in KEEPALIVES}


@functools.lru_cache(maxsize=1)
def sweep_async() -> dict:
    out = {}
    for w in WINDOWS:
        for tgt in TARGETS:
            out[(w, tgt)] = run_policy(
                lambda f, w_=w, t_=tgt: AsyncConcurrencyPolicy(
                    window_s=w_, target=t_))[0]
    return out


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")

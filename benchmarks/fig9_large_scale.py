"""Fig 9: the KWOK-scale experiment — 2000 functions / ~3.5M invocations on
50 simulated worker nodes, REAL policy math, vectorized lax.scan workers.
Paper: at this scale Kn-Sync becomes Pareto-optimal in the trade-off space."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.simjax import JaxPolicy, simulate, summarize
from repro.core.trace import TraceConfig, synthesize


def run():
    tc = TraceConfig(num_functions=2000, duration_s=4800,
                     target_total_rps=729.0, seed=9)   # ~3.5M invocations
    trace = synthesize(tc)
    rows = {}
    configs = [("sync_ka60", JaxPolicy(kind=0, keepalive_s=60)),
               ("sync_ka600", JaxPolicy(kind=0, keepalive_s=600)),
               ("sync_ka1800", JaxPolicy(kind=0, keepalive_s=1800)),
               ("async_w60_t0.7", JaxPolicy(kind=1, window_s=60, target=0.7)),
               ("async_w600_t0.7", JaxPolicy(kind=1, window_s=600, target=0.7)),
               ("async_w600_t1.0", JaxPolicy(kind=1, window_s=600, target=1.0))]
    for name, pol in configs:
        t0 = time.time()
        s = summarize(simulate(trace, pol, num_nodes=50))
        dt = time.time() - t0
        rows[name] = s
        emit(f"fig9_{name}", dt * 1e6,
             f"slowdown={s['slowdown_geomean_p99']:.2f};"
             f"mem={s['normalized_memory']:.2f};cpu={s['cpu_overhead']*100:.1f}%;"
             f"n={len(trace)}")
    return rows


if __name__ == "__main__":
    run()

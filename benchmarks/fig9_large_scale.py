"""Fig 9: the KWOK-scale experiment — 2000 functions / ~3.5M invocations on
50 simulated worker nodes, REAL policy math, vectorized lax.scan workers.
Paper: at this scale Kn-Sync becomes Pareto-optimal in the trade-off space.

Runs through the CHUNKED scan (`repro.core.simjax.simulate_chunked`) via the
``fig9_production`` scenario spec: summary statistics accumulate inside the
scan carry, so the replay never materializes (ticks x functions) histories —
the whole six-policy sweep fits in well under a GB of host memory."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.simjax import JaxPolicy, simulate_chunked
from repro.scenarios import get_scenario


def run():
    sc = get_scenario("fig9_production")
    trace = sc.build_trace()
    rows = {}
    configs = [("sync_ka60", JaxPolicy(kind=0, keepalive_s=60)),
               ("sync_ka600", JaxPolicy(kind=0, keepalive_s=600)),
               ("sync_ka1800", JaxPolicy(kind=0, keepalive_s=1800)),
               ("async_w60_t0.7", JaxPolicy(kind=1, window_s=60, target=0.7)),
               ("async_w600_t0.7", JaxPolicy(kind=1, window_s=600, target=0.7)),
               ("async_w600_t1.0", JaxPolicy(kind=1, window_s=600, target=1.0))]
    for name, pol in configs:
        t0 = time.time()
        s = simulate_chunked(trace, pol, num_nodes=sc.num_nodes,
                             chunk_ticks=sc.chunk_ticks)
        dt = time.time() - t0
        rows[name] = s
        emit(f"fig9_{name}", dt * 1e6,
             f"slowdown={s['slowdown_geomean_p99']:.2f};"
             f"mem={s['normalized_memory']:.2f};cpu={s['cpu_overhead']*100:.1f}%;"
             f"n={len(trace)}")
    return rows


if __name__ == "__main__":
    run()

"""Fig 3: geomean p99 slowdown vs keepalive (sync) / window x target (async).
Paper: saturation beyond 600 s; sync 18.9 -> 3.8; async ~6.4-7.1 at 600 s."""

from __future__ import annotations

import time

from benchmarks.common import KEEPALIVES, TARGETS, WINDOWS, emit, sweep_async, sweep_sync


def run():
    t0 = time.time()
    sy = sweep_sync()
    asy = sweep_async()
    dt = (time.time() - t0) * 1e6
    for ka in KEEPALIVES:
        emit(f"fig3_sync_ka{ka}", dt / (len(KEEPALIVES) + len(asy)),
             f"slowdown={sy[ka].slowdown_geomean_p99:.2f}")
    for tgt in TARGETS:
        for w in WINDOWS:
            emit(f"fig3_async_w{w}_t{tgt}", dt / (len(KEEPALIVES) + len(asy)),
                 f"slowdown={asy[(w, tgt)].slowdown_geomean_p99:.2f}")
    return sy, asy


if __name__ == "__main__":
    run()

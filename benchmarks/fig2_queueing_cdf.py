"""Fig 2: queueing-time CDF — sync (bimodal) vs async (smooth tail)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, trace
from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import queueing_cdf
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy


def run():
    rows = []
    for name, pf in [
        ("sync_ka600", lambda f: SyncKeepalivePolicy(keepalive_s=600)),
        ("async_w600", lambda f: AsyncConcurrencyPolicy(window_s=600, target=0.7)),
    ]:
        t0 = time.time()
        res = EventSim(trace(), Cluster(8), pf, SimConfig()).run()
        xs, ys = queueing_cdf(res)
        dt = time.time() - t0
        p50 = float(np.interp(0.50, ys, xs))
        p99 = float(np.interp(0.99, ys, xs))
        mid_mass = float(((xs > 0.1) & (xs < 0.8)).mean())  # bimodality probe
        rows.append((name, dt, p50, p99, mid_mass))
        emit(f"fig2_{name}", dt * 1e6,
             f"q50={p50*1e3:.1f}ms;q99={p99*1e3:.0f}ms;midmass={mid_mass:.3f}")
    return rows


if __name__ == "__main__":
    run()

"""Fig 4: normalized memory usage vs keepalive / window x target.
Paper: sync 2.9 -> 10 over 30 s -> 1800 s; async 2.7 -> 7.4 (target 0.7)."""

from __future__ import annotations

from benchmarks.common import KEEPALIVES, TARGETS, WINDOWS, emit, sweep_async, sweep_sync


def run():
    sy, asy = sweep_sync(), sweep_async()
    for ka in KEEPALIVES:
        emit(f"fig4_sync_ka{ka}", 0.0, f"norm_mem={sy[ka].normalized_memory:.2f}")
    for tgt in TARGETS:
        for w in WINDOWS:
            emit(f"fig4_async_w{w}_t{tgt}", 0.0,
                 f"norm_mem={asy[(w, tgt)].normalized_memory:.2f}")
    return sy, asy


if __name__ == "__main__":
    run()

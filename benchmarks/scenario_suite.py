"""Scenario suite: every registered workload scenario through the chunked
lax.scan simulator at full scale (plus the discrete-event oracle where it is
feasible, for an in-row parity readout).

One emitted row per (scenario, engine): the paper's four metrics plus wall
time — the scenario catalogue's qualitative claims (EXPERIMENTS.md) in
benchmark form."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.runspec import RunSpec
from repro.scenarios import get_scenario, list_scenarios, parity_report, \
    run_scenario


def run(scale: float = 1.0):
    out = {}
    for name in list_scenarios():
        # rate-based scenarios (fig9_planet) are fluid-only and carry a
        # dedicated wall gate in benchmarks/fig9_planet.py; even shrunk
        # they would dominate this suite's wall clock
        if get_scenario(name).rate_trace:
            continue
        t0 = time.time()
        # oracle joins only where feasible at this scale (runner decides);
        # shrunk runs (the --quick CI tier) get it on every scenario
        rows = run_scenario(name, spec=RunSpec(scale=scale))
        elapsed = time.time() - t0
        gaps = parity_report(rows)
        for r in rows:
            tag = (f"slowdown={r['slowdown_geomean_p99']:.2f};"
                   f"mem={r['normalized_memory']:.2f};"
                   f"rate={r['creation_rate']:.3f};n={r['invocations']}")
            if gaps and r["engine"] == "simjax":
                tag += f";parity_slow={gaps['slowdown_geomean_p99']:.3f}"
            emit(f"scenario_{name}_{r['engine']}", r["wall_s"] * 1e6, tag)
        out[name] = {"rows": rows, "parity": gaps, "wall_s": elapsed}
    return out


if __name__ == "__main__":
    run()

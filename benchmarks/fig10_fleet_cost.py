"""Fig 10 (beyond-paper): the dollar-cost vs p99-slowdown frontier of
two-level autoscaling.

Sweeps node-pool size (max_nodes) x warm-pool fraction x instance keepalive
through the vmapped lax.scan sweep API (repro.fleet.sweep) — the whole grid
runs as one jit-compiled vmap, orders of magnitude faster than looping the
discrete-event oracle — then reports the Pareto set of
($/1M requests, p99 slowdown).
"""

from __future__ import annotations

import time

from benchmarks.common import emit, trace
from repro.core.simjax import JaxFleet, JaxPolicy
from repro.fleet.nodes import NodeType
from repro.fleet.sweep import sweep
from repro.opt.frontier import pareto_front

NODE_MB = 32_768.0
NODE_TYPE = NodeType(name="worker-8", memory_mb=NODE_MB, vcpus=8.0,
                     price_per_hour=0.39, provision_s=60.0)

KEEPALIVES = [30.0, 120.0, 600.0, 1800.0]
WARM_FRACS = [0.0, 0.25, 0.5]
MAX_NODES = [4.0, 8.0, 16.0]


def run():
    t0 = time.time()
    rows = sweep(
        trace(), JaxPolicy(kind=0, keepalive_s=600),
        JaxFleet(node_memory_mb=NODE_MB, provision_s=NODE_TYPE.provision_s,
                 min_nodes=1, util_target=0.7, cooldown_s=120.0),
        grid={"keepalive_s": KEEPALIVES, "warm_frac": WARM_FRACS,
              "max_nodes": MAX_NODES},
        node_type=NODE_TYPE)
    elapsed = time.time() - t0
    front = {id(r) for r in pareto_front(rows)}
    us_per_cfg = elapsed / len(rows) * 1e6
    for r in rows:
        tag = "PARETO" if id(r) in front else "dom"
        name = (f"fig10_ka{r['keepalive_s']:.0f}_warm{r['warm_frac']:.2f}"
                f"_n{r['max_nodes']:.0f}")
        emit(name, us_per_cfg,
             f"cost_per_1M={r['cost_per_million']:.2f};"
             f"slowdown={r['slowdown_geomean_p99']:.2f};"
             f"nodes={r['nodes_mean']:.1f};{tag}")
    return rows, front


if __name__ == "__main__":
    run()

"""Fig 8: the memory-performance trade-off space (slowdown vs normalized
memory for every swept config) + Pareto set.  Paper (small scale): async
target=1.0 is the most cost-efficient.

Rewired through the frontier engine: the sync keepalive ladder and the
async (window x target) grid run as vmapped chunked scans via
``repro.opt.evaluate_scenario`` (one compiled scan per policy family /
window), instead of one discrete-event replay per configuration — which is
what lets the quick CI tier afford this figure at all.  ``window_s`` is a
structural knob (it sizes the scan's window buffer), so each window gets
its own evaluation; everything else is a traced batch axis.
"""

from __future__ import annotations

import time

from benchmarks.common import KEEPALIVES, TARGETS, TRACE_CFG, WINDOWS, emit
from repro.core.runspec import RunSpec
from repro.opt import evaluate_scenario, pareto_front
from repro.scenarios import PolicySpec, Scenario

NUM_NODES = 8


def _scenario(policy: PolicySpec) -> Scenario:
    return Scenario(name="fig8", description="benchmark trace",
                    figure="Fig. 8", base=TRACE_CFG, policy=policy,
                    num_nodes=NUM_NODES)


def sweep_rows(scale: float = 1.0) -> list[dict]:
    rows = []
    sc = _scenario(PolicySpec(kind="sync"))
    for r in evaluate_scenario(sc, [{"keepalive_s": float(ka)}
                                    for ka in KEEPALIVES],
                               spec=RunSpec(scale=scale)):
        rows.append({**r, "name": f"sync_ka{int(r['keepalive_s'])}"})
    for w in WINDOWS:
        sc = _scenario(PolicySpec(kind="async", window_s=float(w)))
        for r in evaluate_scenario(sc, [{"target": float(t)}
                                        for t in TARGETS],
                                   spec=RunSpec(scale=scale)):
            rows.append({**r, "name": f"async_w{w}_t{r['target']}"})
    return rows


def run(scale: float = 1.0):
    t0 = time.time()
    rows = sweep_rows(scale)
    front = pareto_front(rows, x="normalized_memory",
                         y="slowdown_geomean_p99")
    front_names = {r["name"] for r in front}
    for r in rows:
        tag = "PARETO" if r["name"] in front_names else "dom"
        emit(f"fig8_{r['name']}", 0.0,
             f"mem={r['normalized_memory']:.2f};"
             f"slowdown={r['slowdown_geomean_p99']:.2f};"
             f"cost={r['cost_per_million']:.2f};{tag}")
    wall = time.time() - t0
    return rows, front, wall


if __name__ == "__main__":
    run()

"""Fig 8: the memory-performance trade-off space (slowdown vs normalized
memory for every swept config) + Pareto set.  Paper (small scale): async
target=1.0 is the most cost-efficient."""

from __future__ import annotations

from benchmarks.common import KEEPALIVES, TARGETS, WINDOWS, emit, sweep_async, sweep_sync


def pareto(points):
    """points: list of (mem, slow, name); returns non-dominated subset."""
    out = []
    for m, s, n in points:
        if not any(m2 <= m and s2 <= s and (m2 < m or s2 < s)
                   for m2, s2, _ in points):
            out.append((m, s, n))
    return sorted(out)


def run():
    sy, asy = sweep_sync(), sweep_async()
    pts = [(sy[ka].normalized_memory, sy[ka].slowdown_geomean_p99, f"sync_ka{ka}")
           for ka in KEEPALIVES]
    pts += [(asy[(w, t)].normalized_memory, asy[(w, t)].slowdown_geomean_p99,
             f"async_w{w}_t{t}") for w in WINDOWS for t in TARGETS]
    front = pareto(pts)
    for m, s, n in pts:
        tag = "PARETO" if (m, s, n) in front else "dom"
        emit(f"fig8_{n}", 0.0, f"mem={m:.2f};slowdown={s:.2f};{tag}")
    return pts, front


if __name__ == "__main__":
    run()

"""Fig 14 (beyond-paper): multi-region cells — the cost of keeping warm
everywhere.

The paper's overhead characterization is single-cluster; production
serverless fleets split the same function population across regional
cells behind a weighted router with scheduled/reactive pre-provisioning
(``repro.cells``).  This benchmark runs the three cells scenarios through
BOTH engines:

* ``region_failover`` — a deterministic regional outage at 60% of the run
  storms the survivors with redirected + re-queued traffic;
* ``follow_the_sun`` — phase-staggered diurnal waves with cron windows
  pre-warming each region before its morning;
* ``cell_hazard_corr`` — correlated spot-reclaim storms across cells;

and reports per-scenario parity (the oracle-vs-fluid acceptance readout,
slowdown + memory — creation rate is out-of-band for partitioned warped
traffic, see EXPERIMENTS.md) plus a fluid-only ``cell_count`` sweep of the
failover scenario: the resilience-vs-overhead frontier as the same
workload spreads over 1..4 cells (more cells = smaller blast radius but
more warm pools to keep).

Gate metrics for the quick tier: ``fig14_failover_p99`` (the failover
scenario's fluid slowdown — deterministic, fixed seed),
``fig14_cell_parity`` (the worst slowdown gap across the three
scenarios), and ``fig14_wall_s``.
"""

from __future__ import annotations

import math
import time

from benchmarks.common import emit
from repro.core.runspec import RunSpec
from repro.scenarios import parity_report, run_scenario

EVAL_SCALE = 0.25           # the oracle-feasible, parity-calibrated scale

SCENARIOS = ("region_failover", "follow_the_sun", "cell_hazard_corr")
CELL_COUNTS = (1, 2, 3, 4)  # sweep axis: 1 = no redundancy (outage kills
                            # everything), 4 = maximal spread


def run(scale: float = 1.0, parity: bool = True, sweep: bool = True):
    """``scale`` multiplies the benchmark's own (already reduced) scale;
    ``parity=False`` runs the fluid legs only; ``sweep=False`` (the quick
    tier) skips the cell-count frontier.  Returns ``{"p99": failover
    fluid p99, "parity": worst slowdown gap, "sweep": rows-or-None,
    "wall_s": total}``."""
    t0 = time.time()
    eval_scale = max(0.05, EVAL_SCALE * scale)
    engines = ("eventsim", "simjax") if parity else ("simjax",)

    failover_p99 = float("nan")
    max_gap = 0.0 if parity else float("nan")
    for name in SCENARIOS:
        rows = run_scenario(name, spec=RunSpec(scale=eval_scale,
                                               engines=engines))
        sim_row = next(r for r in rows if r["engine"] == "simjax")
        tag = (f"slowdown={sim_row['slowdown_geomean_p99']:.2f};"
               f"mem={sim_row['normalized_memory']:.2f};"
               f"nodes={sim_row['nodes_mean']:.2f};"
               f"n={sim_row['invocations']}")
        if parity:
            gaps = parity_report(rows)
            max_gap = max(max_gap, gaps["slowdown_geomean_p99"])
            tag += (f";parity_slow={gaps['slowdown_geomean_p99']:.3f};"
                    f"parity_mem={gaps['normalized_memory']:.3f}")
        emit(f"fig14_{name}", sim_row["wall_s"] * 1e6, tag)
        if name == "region_failover":
            failover_p99 = sim_row["slowdown_geomean_p99"]

    sweep_rows = None
    if sweep:
        # resilience-vs-overhead: the SAME failover workload over 1..4
        # cells (fluid-only; cell_count is a structural sweep axis, so the
        # search layer batches each partition separately)
        from repro.opt import evaluate_scenario
        pts = [{"cell_count": float(c)} for c in CELL_COUNTS]
        sweep_rows = evaluate_scenario(
            "region_failover", pts,
            spec=RunSpec(scale=eval_scale, billing="ideal"))
        for r in sweep_rows:
            emit(f"fig14_cells_{int(r['cell_count'])}", 0.0,
                 f"slowdown={r['slowdown_geomean_p99']:.2f};"
                 f"mem={r['normalized_memory']:.2f};"
                 f"cost={r['cost_per_million']:.4g}")

    wall = time.time() - t0
    emit("fig14_region_failover", wall * 1e6,
         f"failover_p99={failover_p99:.3f};max_parity={max_gap:.3f};"
         f"sweep={'1-4' if sweep else 'off'}")
    if parity and not math.isfinite(max_gap):
        raise RuntimeError("fig14 parity produced a non-finite gap")
    return {"p99": failover_p99, "parity": max_gap, "sweep": sweep_rows,
            "wall_s": wall}


if __name__ == "__main__":
    run()

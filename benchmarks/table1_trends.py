"""Table 1: the sign of each d(metric)/d(parameter) — the paper's summary.

Expected (paper):
  keepalive+   -> slowdown DOWN, memory UP,  overhead DOWN
  window+      -> slowdown DOWN, memory UP,  overhead DOWN
  target+      -> slowdown UP,   memory DOWN, overhead DOWN
  concurrency+ -> slowdown ~,    memory DOWN, overhead DOWN
"""

from __future__ import annotations

from benchmarks.common import emit, run_policy, sweep_async, sweep_sync
from repro.core.policies import AsyncConcurrencyPolicy


def _sign(lo, hi, tol=0.02):
    if hi > lo * (1 + tol):
        return "UP"
    if hi < lo * (1 - tol):
        return "DOWN"
    return "~"


def run():
    sy, asy = sweep_sync(), sweep_async()
    rows = {}

    rows["keepalive"] = (
        _sign(sy[1800].slowdown_geomean_p99, sy[30].slowdown_geomean_p99),
        _sign(sy[30].normalized_memory, sy[1800].normalized_memory),
        _sign(sy[1800].cpu_overhead, sy[30].cpu_overhead))
    # report as effect of INCREASING the parameter:
    rows["keepalive"] = (
        _sign(sy[30].slowdown_geomean_p99, sy[1800].slowdown_geomean_p99),
        _sign(sy[30].normalized_memory, sy[1800].normalized_memory),
        _sign(sy[30].cpu_overhead, sy[1800].cpu_overhead))
    rows["window"] = (
        _sign(asy[(30, 0.7)].slowdown_geomean_p99, asy[(1800, 0.7)].slowdown_geomean_p99),
        _sign(asy[(30, 0.7)].normalized_memory, asy[(1800, 0.7)].normalized_memory),
        _sign(asy[(30, 0.7)].cpu_overhead, asy[(1800, 0.7)].cpu_overhead))
    rows["target"] = (
        _sign(asy[(600, 0.5)].slowdown_geomean_p99, asy[(600, 1.0)].slowdown_geomean_p99),
        _sign(asy[(600, 0.5)].normalized_memory, asy[(600, 1.0)].normalized_memory),
        _sign(asy[(600, 0.5)].cpu_overhead, asy[(600, 1.0)].cpu_overhead))

    cc1, _ = run_policy(lambda f: AsyncConcurrencyPolicy(
        window_s=60, target=0.7, container_concurrency=1))
    cc4, _ = run_policy(lambda f: AsyncConcurrencyPolicy(
        window_s=60, target=0.7, container_concurrency=4))
    rows["container_conc"] = (
        _sign(cc1.slowdown_geomean_p99, cc4.slowdown_geomean_p99, tol=0.3),
        _sign(cc1.normalized_memory, cc4.normalized_memory),
        _sign(cc1.cpu_overhead, cc4.cpu_overhead))

    for param, (slow, mem, ovh) in rows.items():
        emit(f"table1_{param}", 0.0, f"slowdown={slow};memory={mem};overhead={ovh}")
    return rows


if __name__ == "__main__":
    run()

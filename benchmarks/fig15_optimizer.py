"""Fig 15 (beyond-paper): hypervolume at a fixed evaluation budget —
population optimizer vs the coarse grid.

The paper's search question ("new, cost-efficient autoscaling strategies")
is a budget question in disguise: both engines spend SIMULATED
CANDIDATE-SCENARIO PAIRS, so the fair comparison pins that budget and asks
which engine buys more frontier.  Per scenario, the grid enumerates its
deduped product (``grid_budget`` pairs exactly); the evo engine
(``repro.opt.evo``) gets the SAME budget at the SAME scale
(``coarse_frac=1.0, refine=False`` — every pair at the comparison
fidelity) and the dominated-area hypervolume of each engine's full
evaluated row set is measured against the shared CI reference point.

Reported per scenario: both hypervolumes and their ratio grid/evo —
<= 1.0 means evo matched or beat enumeration at equal spend; the gate
metric (``fig15_hv_at_budget`` in ``run.py --quick``) is the WORST ratio
across the three scenarios, so evo regressing anywhere trips CI.

Scenarios: two sync workloads (``fleet_cost_stress``, ``diurnal``) on the
DEFAULT_SPACE, plus the multi-region ``region_failover`` on a cells space
that sweeps ``cell_count`` — exercising the structural-gene path, where
crossover must keep the partition count integral while the engine regroups
per-cell traces exactly as grid sweep points do.
"""

from __future__ import annotations

import math
import time

from benchmarks.common import emit
from repro.core.runspec import RunSpec
from repro.opt import (DEFAULT_SPACE, SearchSpace, evaluate_scenario,
                       evo_search, grid_budget, hypervolume)

# the quick tier's shared hypervolume reference point (run.py HV_REF):
# generously above every scenario's observed front, so dominated area is
# well-defined for both engines on every scenario
HV_REF = (2000.0, 50.0)

SCENARIOS = ("fleet_cost_stress", "diurnal", "region_failover")

# the cells scenario sweeps structure: cell_count is a STRUCTURAL gene
# (integer partitions of the event stream), spot_fraction rides the
# spot_aware base family, and the fleet packing knob crosses both
CELLS_SPACE = SearchSpace(
    policy={
        "keepalive_s": (60.0, 300.0, 1200.0),
        "spot_fraction": (0.0, 0.6),
        "cell_count": (2.0, 4.0, 8.0),
    },
    fleet={
        "util_target": (0.6, 0.8),
    },
)


def space_for(scenario: str) -> SearchSpace:
    return CELLS_SPACE if scenario == "region_failover" else DEFAULT_SPACE


def compare(scenario: str, scale: float = 0.1, seed: int = 0) -> dict:
    """One equal-budget duel on one scenario: grid rows vs evo rows, both
    hypervolumes, and the grid/evo ratio (<= 1.0: evo matched or won)."""
    space = space_for(scenario)
    budget = grid_budget(space, [scenario])

    t0 = time.time()
    grid_rows = evaluate_scenario(scenario, space.points(),
                                  spec=RunSpec(scale=scale))
    grid_wall = time.time() - t0
    grid_hv = hypervolume(grid_rows, *HV_REF)

    t0 = time.time()
    res = evo_search([scenario], space=space, scale=scale, coarse_frac=1.0,
                     budget=budget, seed=seed, refine=False)
    evo_wall = time.time() - t0
    evo_rows = res.coarse[scenario]
    evo_hv = hypervolume(evo_rows, *HV_REF)

    ratio = (grid_hv / evo_hv
             if math.isfinite(evo_hv) and evo_hv > 0 else math.inf)
    return {"scenario": scenario, "budget": budget,
            "grid_hv": grid_hv, "evo_hv": evo_hv, "ratio": ratio,
            "grid_wall_s": grid_wall, "evo_wall_s": evo_wall,
            "evo_points": len(res.points),
            "evo_spent": res.budget.spent}


def run(scale: float = 0.1, seed: int = 0, scenarios=SCENARIOS) -> dict:
    """The three-scenario duel; returns per-scenario results plus the
    worst (largest) grid/evo hypervolume ratio — the CI gate metric."""
    results = []
    for name in scenarios:
        r = compare(name, scale=scale, seed=seed)
        results.append(r)
        emit(f"fig15_{name}", r["evo_wall_s"] * 1e6,
             f"budget={r['budget']};grid_hv={r['grid_hv']:.4g};"
             f"evo_hv={r['evo_hv']:.4g};ratio={r['ratio']:.4f}")
    worst = max((r["ratio"] for r in results), default=math.inf)
    emit("fig15_hv_at_budget", 0.0, f"worst_ratio={worst:.4f}")
    return {"results": results, "worst_ratio": worst}


if __name__ == "__main__":
    run()

"""Fig 13 (beyond-paper): provider billing semantics reshape the frontier.

The paper's dollar axis (and figs 8/10/12 here) prices infrastructure:
node-hours plus master CPU.  Real serverless bills meter something else —
per-request fees plus rounded, minimum-censored GB-s of billed duration
(AWS Lambda at 1 ms, Cloud Run at 100 ms) with a provisioned-concurrency
tier for the warm pool.  This benchmark re-evaluates the frontier grid
under the ``ideal`` profile and under each provider profile and quantifies
how much the provider semantics REORDER the configuration ranking:

* per (scenario, provider): the normalized Kendall distance between the
  ``cost_per_million`` rankings (share of point pairs whose cost order
  flips), and the symmetric-difference share of the Pareto-front
  membership;
* the CI gate metric is ``fig13_billing_rank_delta`` = 1 / max rank
  shift — lower-is-better like every gate metric, and infinite (gate
  fails non-finite) if the billing engine stops producing ANY ranking
  shift, i.e. the provider profiles silently collapsed into ``ideal``;
* oracle-vs-fluid BILLED-cost parity legs at the 0.25x calibration scale
  (the ``billed_parity`` acceptance band; the full per-scenario sweep
  lives in tests/test_billing.py).

Per-scenario CSVs (ideal vs provider cost + front membership per point)
land in ``fig13_out/`` (override with ``FIG13_OUT``) for the CI artifact
upload.
"""

from __future__ import annotations

import csv
import os
import time

from benchmarks.common import emit
from repro.core.runspec import RunSpec
from repro.opt import evaluate_scenario, pareto_front
from repro.opt.space import DEFAULT_SPACE
from repro.scenarios.runner import billed_parity

EVAL_SCALE = 0.25           # the oracle-feasible, parity-calibrated scale
PARITY_SCALE = 0.25         # billed_parity's band is calibrated here

# a sync keepalive ladder, a diurnal trough workload, and the fleet-knob
# scenario: the regimes where rounding/minimum/per-GB-s billing plausibly
# reorders keepalive and warm-pool choices
SCENARIOS = ("cold_tail", "diurnal", "fleet_cost_stress")
PROVIDERS = ("aws_lambda", "gcr")
# quick-gate parity legs (oracle replay per leg; every registered scenario
# is covered by the slow-marked test instead)
PARITY_SCENARIOS = ("cold_tail", "diurnal")


def _costs(rows) -> dict:
    return {r["point_id"]: r["cost_per_million"] for r in rows}


def rank_shift(rows_a, rows_b) -> float:
    """Normalized Kendall distance between the cost rankings: the share of
    point pairs strictly ordered in both runs whose order flips."""
    ca, cb = _costs(rows_a), _costs(rows_b)
    ids = sorted(ca)
    disc = tot = 0
    for i in range(len(ids)):
        for j in range(i + 1, len(ids)):
            da = ca[ids[i]] - ca[ids[j]]
            db = cb[ids[i]] - cb[ids[j]]
            if da == 0.0 or db == 0.0:
                continue
            tot += 1
            disc += (da > 0.0) != (db > 0.0)
    return disc / tot if tot else 0.0


def front_shift(rows_a, rows_b) -> float:
    """Symmetric-difference share of Pareto-front membership between the
    two billings (0 = identical fronts, 1 = disjoint)."""
    fa = {r["point_id"] for r in pareto_front(rows_a)}
    fb = {r["point_id"] for r in pareto_front(rows_b)}
    union = fa | fb
    return len(fa ^ fb) / len(union) if union else 0.0


def _write_csv(out_dir: str, name: str, by_billing: dict) -> None:
    fronts = {b: {r["point_id"] for r in pareto_front(rows)}
              for b, rows in by_billing.items()}
    billings = list(by_billing)
    cols = (["point_id"]
            + [f"cost_{b}" for b in billings]
            + [f"front_{b}" for b in billings])
    path = os.path.join(out_dir, f"fig13_{name}.csv")
    with open(path, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(cols)
        for r in by_billing[billings[0]]:
            pid = r["point_id"]
            costs = {b: _costs(by_billing[b])[pid] for b in billings}
            w.writerow([pid] + [f"{costs[b]:.6g}" for b in billings]
                       + [int(pid in fronts[b]) for b in billings])


def run(scale: float = 1.0, parity: bool = True, out_dir: str = None):
    """``scale`` multiplies the benchmark's own (already reduced) scale;
    ``parity=False`` skips the oracle parity legs (grid-only).  Returns
    ``{"rank_shift": max, "front_shift": max, "parity": max_or_nan,
    "detail": {...}}`` — the quick tier gates 1/rank_shift and parity."""
    t0 = time.time()
    eval_scale = max(0.05, EVAL_SCALE * scale)
    out_dir = out_dir or os.environ.get("FIG13_OUT", "fig13_out")
    os.makedirs(out_dir, exist_ok=True)
    points = DEFAULT_SPACE.points()

    detail: dict = {}
    max_rank = max_front = 0.0
    for name in SCENARIOS:
        by_billing = {"ideal": evaluate_scenario(
            name, points, spec=RunSpec(scale=eval_scale, billing="ideal"))}
        for prov in PROVIDERS:
            rows = evaluate_scenario(
                name, points, spec=RunSpec(scale=eval_scale, billing=prov))
            by_billing[prov] = rows
            rs = rank_shift(by_billing["ideal"], rows)
            fs = front_shift(by_billing["ideal"], rows)
            detail[(name, prov)] = {"rank_shift": rs, "front_shift": fs}
            max_rank, max_front = max(max_rank, rs), max(max_front, fs)
            emit(f"fig13_{name}_{prov}", 0.0,
                 f"rank_shift={rs:.3f};front_shift={fs:.3f};"
                 f"best_ideal={min(_costs(by_billing['ideal']).values()):.4g};"
                 f"best_{prov}={min(_costs(rows).values()):.4g}")
        _write_csv(out_dir, name, by_billing)

    max_parity = float("nan")
    if parity:
        max_parity = 0.0
        for name in PARITY_SCENARIOS:
            for prov in PROVIDERS:
                gaps = billed_parity(name, prov, scale=PARITY_SCALE)
                detail[(name, prov)]["parity_total_cost"] = gaps["total_cost"]
                max_parity = max(max_parity, gaps["total_cost"])
                emit(f"fig13_parity_{name}_{prov}", 0.0,
                     f"total_cost_gap={gaps['total_cost']:.3f};"
                     f"billed_gb_s_gap={gaps['billed_gb_s']:.3f}")

    inv = 1.0 / max_rank if max_rank > 0.0 else float("inf")
    emit("fig13_billing_delta", (time.time() - t0) * 1e6,
         f"rank_delta_inv={inv:.3f};max_rank_shift={max_rank:.3f};"
         f"max_front_shift={max_front:.3f};max_parity={max_parity:.3f};"
         f"csv={out_dir}/")
    return {"rank_shift": max_rank, "front_shift": max_front,
            "parity": max_parity, "detail": detail}


if __name__ == "__main__":
    run()

"""Fig. 9 pushed to planet scale: 100k functions / ~50M invocations through
long-tail clustering and the device-sharded chunked lax.scan.

The paper's KWOK-scale replay (fig9_production, 2000 functions) showed the
fluid engine removing the oracle's event-replay bottleneck; this benchmark
pushes the same figure 50x further — a population no event-level pipeline
could even synthesize in the time the simulation takes — through the
rate-based (pre-binned Poisson-count) workload path, weighted
super-function clustering (100k -> ~21k simulated functions at the 1 rps
threshold, ≤0.25% on every headline metric), and the shard_mapped per-tick
step of ``repro.core.simjax``.  Full scale lands around 30-40 s on one
host either way.

Devices default to every local device when more than one is visible (CI's
sharded smoke job exposes eight via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); a single-device
host runs the unsharded dispatch, bit-for-bit the same numbers.

The quick tier gates ``fig9_planet_wall_s`` at 0.25x (25k functions,
~12.5M invocations) — the planet path's lost-jit / lost-sharding /
lost-clustering regression canary.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.runspec import RunSpec
from repro.scenarios import run_scenario


def run(scale: float = 1.0, devices: int | None = None,
        cluster: float = 1.0):
    if devices is None:
        import jax
        n = len(jax.devices())
        devices = n if n > 1 else 0
    t0 = time.time()
    row = run_scenario("fig9_planet",
                       spec=RunSpec(engines=("simjax",), scale=scale,
                                    devices=devices, cluster=cluster))[0]
    wall = time.time() - t0
    emit("fig9_planet", wall * 1e6,
         f"functions={row['num_functions']};"
         f"invocations={row['invocations']};"
         f"slowdown={row['slowdown_geomean_p99']:.3f};"
         f"mem={row['normalized_memory']:.2f};"
         f"devices={devices};cluster={cluster:g};wall={wall:.1f}s")
    return row, wall


if __name__ == "__main__":
    run()

"""Fig 5: instance creation rate vs keepalive / window x target.
Paper: sync 1.8 -> 0.12 -> 0.05 /s; async 2.9 -> 0.09 /s; target 0.5 -> 1.0
cuts rate ~45% at w=60."""

from __future__ import annotations

from benchmarks.common import KEEPALIVES, TARGETS, WINDOWS, emit, sweep_async, sweep_sync


def run():
    sy, asy = sweep_sync(), sweep_async()
    for ka in KEEPALIVES:
        emit(f"fig5_sync_ka{ka}", 0.0, f"rate={sy[ka].creation_rate:.3f}/s")
    for tgt in TARGETS:
        for w in WINDOWS:
            emit(f"fig5_async_w{w}_t{tgt}", 0.0,
                 f"rate={asy[(w, tgt)].creation_rate:.3f}/s")
    return sy, asy


if __name__ == "__main__":
    run()

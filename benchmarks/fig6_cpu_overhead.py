"""Fig 6: normalized CPU overhead vs keepalive / window x target (+ the
worker/master split).  Paper: sync 30% -> 12%; async 43% -> 15% -> 12%;
~80% of overhead on workers."""

from __future__ import annotations

from benchmarks.common import KEEPALIVES, TARGETS, WINDOWS, emit, sweep_async, sweep_sync


def run():
    sy, asy = sweep_sync(), sweep_async()
    for ka in KEEPALIVES:
        m = sy[ka]
        emit(f"fig6_sync_ka{ka}", 0.0,
             f"cpu={m.cpu_overhead*100:.1f}%;worker_share={m.worker_share*100:.0f}%")
    for tgt in TARGETS:
        for w in WINDOWS:
            m = asy[(w, tgt)]
            emit(f"fig6_async_w{w}_t{tgt}", 0.0,
                 f"cpu={m.cpu_overhead*100:.1f}%;worker_share={m.worker_share*100:.0f}%")
    return sy, asy


if __name__ == "__main__":
    run()

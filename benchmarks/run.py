# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import importlib

MODULES = [
    "benchmarks.fig2_queueing_cdf",
    "benchmarks.fig3_slowdown",
    "benchmarks.fig4_memory",
    "benchmarks.fig5_creation_rate",
    "benchmarks.fig6_cpu_overhead",
    "benchmarks.fig7_container_concurrency",
    "benchmarks.fig8_tradeoff",
    "benchmarks.fig9_large_scale",
    "benchmarks.fig10_fleet_cost",
    "benchmarks.scenario_suite",
    "benchmarks.table1_trends",
    "benchmarks.roofline",
]


def main() -> None:
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        mod = importlib.import_module(mod_name)
        mod.run()


if __name__ == '__main__':
    main()

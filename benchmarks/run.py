"""Benchmark driver.

Default: every figure benchmark, printing ``name,us_per_call,derived`` CSV.

``--quick`` is the CI regression tier: fig8 through the frontier engine at
0.1x, the scenario suite at 0.1x (oracle legs included at that scale), the
per-scenario frontier hypervolumes, the fig12 spot-vs-on-demand cost
ratio (fluid-only, deterministic), and the fig13 billing-delta gate
(provider-vs-ideal frontier rank shift + billed oracle parity), the
fig14 multi-region cells gate (failover slowdown + the worst cells
oracle-vs-fluid gap), and the fig15 optimizer duel (worst evo-vs-grid
hypervolume ratio at equal evaluation budget), collected into a flat
{metric: value}
dict where EVERY metric is lower-is-better (wall seconds, p99 slowdown,
$/1M requests, memory ratio, cost ratio).
``--json`` writes it (BENCH_ci.json in CI); ``--baseline`` compares against
a checked-in reference and exits non-zero when any metric regresses more
than ``--tolerance`` (default 25%) — the bench-smoke CI gate.

  PYTHONPATH=src:. python benchmarks/run.py                      # full CSV
  PYTHONPATH=src:. python benchmarks/run.py --quick \\
      --json BENCH_ci.json --baseline benchmarks/baseline.json

``benchmarks/baseline.json`` provenance: deterministic metrics (p99 / cost
/ memory — fixed seeds) are checked in at their measured values; wall-clock
entries carry 3x headroom over the authoring machine, so with the 25% gate
tolerance a CI runner may be ~3.75x slower before the gate trips while a
lost-vmap-class regression (10x+) still fails.  To refresh: run --quick
--json, copy metric values verbatim, multiply *_wall_s by 3.
"""

from __future__ import annotations

import argparse
import importlib
import json
import math
import sys
import time

MODULES = [
    "benchmarks.fig2_queueing_cdf",
    "benchmarks.fig3_slowdown",
    "benchmarks.fig4_memory",
    "benchmarks.fig5_creation_rate",
    "benchmarks.fig6_cpu_overhead",
    "benchmarks.fig7_container_concurrency",
    "benchmarks.fig8_tradeoff",
    "benchmarks.fig9_large_scale",
    "benchmarks.fig9_planet",
    "benchmarks.fig10_fleet_cost",
    "benchmarks.fig11_learned_policy",
    "benchmarks.fig12_spot_frontier",
    "benchmarks.fig13_billing_delta",
    "benchmarks.fig14_region_failover",
    "benchmarks.fig15_optimizer",
    "benchmarks.scenario_suite",
    "benchmarks.table1_trends",
    "benchmarks.roofline",
]

QUICK_SCALE = 0.1

# hypervolume reference point on the (cost_per_million, p99 slowdown) plane
# for the quick tier's 0.1x coarse grids: generously above every scenario's
# observed front so the dominated area is well-defined and a frontier that
# retreats ANYWHERE shrinks it.  The gate metric is 1/hypervolume
# (lower-is-better, like every other gate metric).
HV_REF = (2000.0, 50.0)


def quick_hypervolume() -> dict:
    """Per-scenario frontier hypervolume over the DEFAULT_SPACE coarse grid
    (ROADMAP: multi-objective CI tracking — a point-wise metric gate misses
    a front that got strictly worse between its endpoints)."""
    from repro.core.runspec import RunSpec
    from repro.opt import DEFAULT_SPACE, evaluate_scenario, hypervolume
    from repro.scenarios import get_scenario, list_scenarios
    points = DEFAULT_SPACE.points()
    out = {}
    for name in list_scenarios():
        if get_scenario(name).rate_trace:
            continue   # the planet path has its own dedicated wall gate
        rows = evaluate_scenario(name, points,
                                 spec=RunSpec(scale=QUICK_SCALE))
        hv = hypervolume(rows, *HV_REF)
        # hypervolume's no-finite-rows sentinel is NaN (PR 7 convention);
        # the inverse gate metric must turn that into inf, not NaN, so the
        # baseline comparison fails loudly instead of comparing False
        out[f"frontier_hv_inv_{name}"] = (
            1.0 / hv if math.isfinite(hv) and hv > 0 else math.inf)
    return out


def run_quick() -> dict:
    """The regression-gate metric set: small, deterministic (fixed seeds)
    except the wall clocks, every value lower-is-better."""
    from benchmarks import fig8_tradeoff, scenario_suite
    metrics: dict[str, float] = {}

    rows, front, wall = fig8_tradeoff.run(scale=QUICK_SCALE)
    metrics["fig8_wall_s"] = round(wall, 3)
    metrics["fig8_best_p99"] = min(r["slowdown_geomean_p99"] for r in rows)
    metrics["fig8_best_mem"] = min(r["normalized_memory"] for r in rows)
    metrics["fig8_best_cost_per_million"] = min(r["cost_per_million"]
                                                for r in rows)

    t0 = time.time()
    suite = scenario_suite.run(scale=QUICK_SCALE)
    metrics["scenario_suite_wall_s"] = round(time.time() - t0, 3)
    for name, res in suite.items():
        for r in res["rows"]:
            if r["engine"] == "simjax":
                metrics[f"{name}_p99"] = r["slowdown_geomean_p99"]
                metrics[f"{name}_simjax_wall_s"] = r["wall_s"]

    t0 = time.time()
    metrics.update(quick_hypervolume())
    metrics["frontier_hv_wall_s"] = round(time.time() - t0, 3)

    # planet scale (fig9_planet, rate-based workload): gate the full
    # planet path — clustering plus the (un)sharded chunked dispatch — at
    # 0.25x (25k functions, ~12.5M invocations); a lost-jit-cache,
    # lost-sharding, or lost-clustering regression is a several-x movement
    # here.  Slowdown rides along as a determinism check.
    from benchmarks import fig9_planet
    row, wall = fig9_planet.run(scale=0.25)
    metrics["fig9_planet_wall_s"] = round(wall, 3)
    metrics["fig9_planet_quick_p99"] = row["slowdown_geomean_p99"]

    # spot frontier: the fluid (deterministic) winner-vs-on-demand cost
    # ratio must not regress — a rising ratio means the spot subsystem
    # stopped finding savings; the oracle-confirm legs run in the full
    # fig12 benchmark, not the gate (they are seeded but slow)
    from benchmarks import fig12_spot_frontier
    t0 = time.time()
    _, _, winner, best_od, _ = fig12_spot_frontier.run(
        scale=QUICK_SCALE / fig12_spot_frontier.EVAL_SCALE, confirm=False)
    metrics["fig12_wall_s"] = round(time.time() - t0, 3)
    metrics["fig12_spot_cost_ratio"] = (
        winner["cost_per_million"] / best_od["cost_per_million"]
        if winner is not None else math.inf)

    # billing delta (repro.fleet.billing): the provider profiles must keep
    # REORDERING the frontier (rank_delta_inv is 1/max rank shift — it
    # goes infinite, failing the non-finite check, if provider billing
    # collapses into ideal) and the billed oracle-vs-fluid parity legs
    # must stay inside their band (deterministic: fixed seeds)
    from benchmarks import fig13_billing_delta
    t0 = time.time()
    f13 = fig13_billing_delta.run(
        scale=QUICK_SCALE / fig13_billing_delta.EVAL_SCALE)
    metrics["fig13_wall_s"] = round(time.time() - t0, 3)
    metrics["fig13_billing_rank_delta"] = (
        1.0 / f13["rank_shift"] if f13["rank_shift"] > 0 else math.inf)
    metrics["fig13_billed_parity"] = f13["parity"]

    # multi-region cells (repro.cells): the three Fig. 14 scenarios
    # through BOTH engines at the 0.25 parity-calibration point (the
    # parity band does not hold below ~0.1x, see EXPERIMENTS.md) — gates
    # the failover scenario's fluid slowdown (deterministic: fixed
    # seed), the worst oracle-vs-fluid slowdown gap across the cells
    # family, and the wall clock; the cell-count frontier sweep runs in
    # the full benchmark only
    from benchmarks import fig14_region_failover
    t0 = time.time()
    f14 = fig14_region_failover.run(sweep=False)
    metrics["fig14_wall_s"] = round(time.time() - t0, 3)
    metrics["fig14_failover_p99"] = f14["p99"]
    metrics["fig14_cell_parity"] = f14["parity"]

    # optimizer duel (repro.opt.evo): hypervolume at the grid's own
    # evaluation budget, population search vs enumeration, on the three
    # fig15 scenarios (two sync + the structural-gene cells space).  The
    # gate metric is the WORST grid/evo ratio: <= 1 means evo matched or
    # beat the grid everywhere at equal spend, so a regression in seeding,
    # variation, or budget accounting shows up as the ratio rising above
    # its baseline (deterministic: fixed seed, fluid engine only)
    from benchmarks import fig15_optimizer
    t0 = time.time()
    f15 = fig15_optimizer.run(scale=QUICK_SCALE)
    metrics["fig15_wall_s"] = round(time.time() - t0, 3)
    metrics["fig15_hv_at_budget"] = f15["worst_ratio"]

    # attribution ledger (repro.obs): trace diurnal through BOTH engines at
    # the 0.25 parity-calibration point and gate on (a) attribution-sum
    # consistency — components must reconstruct the aggregate ratios
    # exactly, so the baseline is 0 and ANY inconsistency fails — and
    # (b) the worst component-level oracle-vs-fluid gap (deterministic:
    # fixed seeds, single scenario)
    from repro.core.runspec import RunSpec
    from repro.obs import (check_ledger, ledger_from_chunked,
                           ledger_from_eventsim, ledger_parity)
    from repro.scenarios import run_scenario
    t0 = time.time()
    detail: dict = {}
    run_scenario("diurnal", detail=detail,
                 spec=RunSpec(scale=0.25, telemetry=64))
    led_o = ledger_from_eventsim(detail["oracle_result"])
    led_f = ledger_from_chunked(detail["fluid_summary"])
    metrics["obs_wall_s"] = round(time.time() - t0, 3)
    metrics["obs_attribution_problems"] = float(
        len(check_ledger(led_o)) + len(check_ledger(led_f)))
    metrics["obs_component_gap"] = max(
        ledger_parity(led_o, led_f).values())
    return metrics


def compare(measured: dict, baseline: dict, tolerance: float) -> list[str]:
    """Every baseline metric must satisfy measured <= ref * (1+tolerance);
    a baseline key missing from the measurement is itself a failure (the
    gate must not silently narrow)."""
    failures = []
    for key, ref in baseline.items():
        got = measured.get(key)
        if got is None:
            failures.append(f"{key}: missing from measurement")
        elif not math.isfinite(got):
            # NaN compares False against everything — a NaN'd metric must
            # fail the gate, not slip through the > comparison
            failures.append(f"{key}: non-finite measurement {got}")
        elif got > ref * (1.0 + tolerance):
            failures.append(f"{key}: {got:.4g} > {ref:.4g} "
                            f"(+{(got / ref - 1) * 100:.0f}%, "
                            f"tolerance {tolerance * 100:.0f}%)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="figure benchmarks / CI gate")
    ap.add_argument("--quick", action="store_true",
                    help="regression tier: fig8 via the frontier engine at "
                         f"{QUICK_SCALE}x + scenario suite at {QUICK_SCALE}x")
    ap.add_argument("--json", default=None,
                    help="write the quick-tier metrics here")
    ap.add_argument("--baseline", default=None,
                    help="compare against this reference; exit 1 on regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative regression (default 0.25)")
    args = ap.parse_args(argv)

    if not args.quick:
        if args.baseline or args.json:
            # the gate must fail closed: a miswired invocation that forgot
            # --quick would otherwise "pass" without ever comparing
            ap.error("--json/--baseline require --quick")
        print("name,us_per_call,derived")
        for mod_name in MODULES:
            mod = importlib.import_module(mod_name)
            mod.run()
        return 0

    print("name,us_per_call,derived")
    metrics = run_quick()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2, sort_keys=True)
    if args.baseline:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
        failures = compare(metrics, baseline, args.tolerance)
        for f in failures:
            print(f"BENCH REGRESSION: {f}", file=sys.stderr)
        if failures:
            return 1
        print(f"bench gate: {len(baseline)} metrics within "
              f"{args.tolerance * 100:.0f}% of baseline", file=sys.stderr)
    return 0


if __name__ == '__main__':
    raise SystemExit(main())

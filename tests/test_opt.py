"""Frontier engine: Pareto/robust reducers, search-space dedupe, traced
policy axes (cc / pre-warm), the coarse+refine pipeline, and the CLI."""

import math

import numpy as np
import pytest

from repro.opt.frontier import pareto_front
from repro.opt.space import grid_points
from repro.core.runspec import RunSpec
from repro.core.simjax import JaxFleet, JaxPolicy, simulate_chunked
from repro.core.trace import TraceConfig, synthesize
from repro.opt import (DEFAULT_SPACE, SearchSpace, active_knobs,
                       epsilon_survivors, evaluate_points, evaluate_scenario,
                       frontier_search, point_scenario, robust_front)
from repro.scenarios import PolicySpec, get_scenario

TC = TraceConfig(num_functions=30, duration_s=600, target_total_rps=5, seed=11)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


# ---------------------------------------------------------------------------
# grid_points / pareto_front edge cases (fleet/sweep's stable surface)
# ---------------------------------------------------------------------------


def test_grid_points_empty_grid_is_single_empty_point():
    assert grid_points({}) == [{}]


def test_grid_points_product_order():
    assert grid_points({"a": [1, 2], "b": [3]}) == [
        {"a": 1, "b": 3}, {"a": 2, "b": 3}]


def test_pareto_front_empty_and_single():
    assert pareto_front([]) == []
    row = {"cost_per_million": 1.0, "slowdown_geomean_p99": 2.0}
    assert pareto_front([row]) == [row]


def _rows(pairs):
    return [{"cost_per_million": c, "slowdown_geomean_p99": s, "point_id": i}
            for i, (c, s) in enumerate(pairs)]


def test_pareto_front_ties_survive_together():
    rows = _rows([(1, 2), (1, 2), (2, 1)])
    front = pareto_front(rows)
    assert len(front) == 3                 # exact ties dominate neither way


def test_pareto_front_drops_dominated_and_nan():
    rows = _rows([(1, 5), (2, 3), (3, 4), (4, 1), (5, 2)])
    rows.append({"cost_per_million": 0.1,
                 "slowdown_geomean_p99": math.nan, "point_id": 9})
    front = pareto_front(rows)
    assert [(r["cost_per_million"], r["slowdown_geomean_p99"])
            for r in front] == [(1, 5), (2, 3), (4, 1)]


def test_epsilon_survivors_band_and_cap():
    rows = _rows([(1.0, 1.0), (1.05, 1.05), (2.0, 2.0)])
    keep = epsilon_survivors(rows, eps=0.10, cap=10)
    assert {r["point_id"] for r in keep} == {0, 1}   # 2x point is out of band
    assert len(epsilon_survivors(rows, eps=5.0, cap=2)) == 2


def test_epsilon_survivors_empty_and_all_nan():
    assert epsilon_survivors([]) == []
    nan_rows = [{"cost_per_million": math.nan,
                 "slowdown_geomean_p99": math.nan, "point_id": 0}]
    assert epsilon_survivors(nan_rows) == []


def test_hypervolume_sentinels():
    from repro.opt.frontier import hypervolume
    # labeled sentinel, not a silent 0.0: no finite rows means the metric
    # is undefined (PR 7 zero-completion convention)
    assert math.isnan(hypervolume([], 2000.0, 50.0))
    assert math.isnan(hypervolume(
        [{"cost_per_million": math.nan, "slowdown_geomean_p99": 1.0}],
        2000.0, 50.0))
    hv = hypervolume(_rows([(1000.0, 25.0)]), 2000.0, 50.0)
    assert hv == pytest.approx(1000.0 * 25.0)


def test_frontier_slack_empty_front_is_inf():
    from repro.opt.frontier import frontier_slack
    row = {"cost_per_million": 1.0, "slowdown_geomean_p99": 1.0}
    assert math.isinf(frontier_slack(row, []))
    assert not (frontier_slack(row, []) <= 1.0 + 1e-9)   # on_front stays False


# ---------------------------------------------------------------------------
# robust-frontier reducer
# ---------------------------------------------------------------------------


def test_robust_front_requires_no_domination_anywhere():
    by = {
        # point 0 wins scenario A, loses B; point 1 the reverse; point 2 is
        # non-dominated in both (cheapest in A, tied-best slowdown in B)
        "A": _rows([(1, 5), (4, 4), (2, 2)]),
        "B": _rows([(5, 1), (1, 5), (2, 1)]),
    }
    assert robust_front(by) == [2]


def test_robust_front_needs_presence_in_every_scenario():
    by = {"A": _rows([(1, 1)]), "B": _rows([(2, 2), (1, 1)])[1:]}
    # point 0 is unbeatable in A but absent from B's row set
    by["B"] = [{"cost_per_million": 1, "slowdown_geomean_p99": 1,
                "point_id": 5}]
    assert robust_front(by) == []
    assert robust_front({}) == []


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------


def test_search_space_validates_knobs():
    with pytest.raises(ValueError):
        SearchSpace(policy={"bogus": (1.0,)})
    with pytest.raises(ValueError):
        SearchSpace(fleet={"keepalive_s": (60.0,)})   # policy knob, wrong side
    with pytest.raises(ValueError):
        SearchSpace(policy={"target": ()})


def test_search_space_points_and_active_knobs():
    sp = SearchSpace(policy={"keepalive_s": (60.0, 600.0)},
                     fleet={"warm_frac": (0.0, 0.5)})
    assert sp.size() == 4 and len(sp.points()) == 4
    assert "keepalive_s" in active_knobs(0)
    assert "target" in active_knobs(1)
    assert "prewarm_s" in active_knobs(2)
    assert "target" not in active_knobs(0)


# ---------------------------------------------------------------------------
# traced policy axes: cc and pre-warm sweep through one vmapped scan
# ---------------------------------------------------------------------------


def test_cc_is_a_traced_batch_axis(trace):
    jf = JaxFleet(node_memory_mb=8192.0)
    rows = evaluate_points(trace, JaxPolicy(kind=1, window_s=60, target=0.7),
                           jf, [{"cc": 1.0}, {"cc": 4.0}])
    singles = [simulate_chunked(trace, JaxPolicy(kind=1, window_s=60,
                                                 target=0.7, cc=cc), fleet=jf)
               for cc in (1, 4)]
    for row, single in zip(rows, singles):
        assert row["instances_mean"] == pytest.approx(
            single["instances_mean"], rel=1e-4)
    # packing 4 requests per instance needs fewer instances
    assert rows[1]["instances_mean"] < rows[0]["instances_mean"]


def test_prewarm_trades_memory_for_latency(trace):
    jf = JaxFleet(node_memory_mb=8192.0)
    rows = evaluate_points(trace, JaxPolicy(kind=2, keepalive_s=1800.0), jf,
                           [{"prewarm_s": 0.0}, {"prewarm_s": 4.0}])
    assert rows[1]["slowdown_geomean_p99"] <= rows[0]["slowdown_geomean_p99"]
    assert rows[1]["mem_total_mean"] > rows[0]["mem_total_mean"]


def test_hybrid_policyspec_bridges_both_engines():
    spec = PolicySpec(kind="hybrid", keepalive_s=900, prewarm_s=2.0)
    assert spec.to_jax().kind == 2
    assert spec.to_jax().prewarm_s == 2.0
    pol = spec.factory()(0)
    assert pol.max_s == 900 and pol.synchronous


# ---------------------------------------------------------------------------
# scenario evaluation + the coarse/refine pipeline
# ---------------------------------------------------------------------------


def test_evaluate_scenario_collapses_inert_axes():
    pts = grid_points({"keepalive_s": [60.0, 600.0], "target": [0.5, 1.0]})
    rows = evaluate_scenario("cold_tail", pts, spec=RunSpec(scale=0.05))
    assert len(rows) == 4
    assert rows[0]["sims"] == 2            # target is inert for sync
    # inert twins share one simulation bit-for-bit
    by = {(r["keepalive_s"], r["target"]): r for r in rows}
    assert by[(60.0, 0.5)]["cost_per_million"] == \
        by[(60.0, 1.0)]["cost_per_million"]
    assert by[(60.0, 0.5)]["point_id"] != by[(60.0, 1.0)]["point_id"]


def test_point_scenario_keeps_static_cluster_static():
    sc = get_scenario("cold_tail")
    pinned = point_scenario(sc, {"keepalive_s": 300.0, "warm_frac": 0.5})
    assert pinned.fleet is None            # fleet knob dropped: no fleet leg
    assert pinned.policy.keepalive_s == 300.0
    fc = get_scenario("fleet_cost_stress")
    pinned = point_scenario(fc, {"keepalive_s": 300.0, "warm_frac": 0.5})
    assert pinned.fleet.warm_frac == 0.5


def test_frontier_search_small():
    space = SearchSpace(policy={"keepalive_s": (60.0, 600.0)},
                        fleet={"warm_frac": (0.0, 0.25)})
    res = frontier_search(["cold_tail", "fleet_cost_stress"], space=space,
                          scale=0.1, coarse_frac=0.5)
    assert set(res.coarse) == {"cold_tail", "fleet_cost_stress"}
    for name, rows in res.refined.items():
        assert rows, name
        # the refine pool is shared across scenarios
        assert {r["point_id"] for r in rows} == \
            {r["point_id"] for r in res.refined["cold_tail"]}
        assert res.fronts[name], name
        for r in res.fronts[name]:
            assert np.isfinite(r["cost_per_million"])
            assert np.isfinite(r["slowdown_geomean_p99"])
    # every robust point is non-dominated in every scenario's row set
    for pid in res.robust_ids:
        for rows in res.refined.values():
            assert any(r["point_id"] == pid for r in rows)
    summary = res.summary()
    assert summary["n_points"] == 4 and "scenarios" in summary


@pytest.mark.slow
def test_frontier_spot_check_confirms_winners():
    """Acceptance: sampled winners on an oracle-feasible scenario hold the
    15% band (cold_tail's short-keepalive family is squarely inside the
    calibrated envelope); refuted classes are demoted, not shipped."""
    from repro.opt import oracle_spot_check
    space = SearchSpace(policy={"keepalive_s": (60.0, 300.0)},
                        fleet={"warm_frac": (0.0,)})
    res = frontier_search(["cold_tail"], space=space, scale=0.25,
                          coarse_frac=0.4)
    recs = oracle_spot_check(res, k=2)
    assert recs
    assert any(r["pass"] for r in recs)
    confirmed = {r["point_id"] for r in res.fronts["cold_tail"]}
    for r in recs:
        if r["demoted"]:
            assert r["point_id"] not in confirmed


def test_frontier_cli_writes_artifacts(tmp_path):
    from repro.launch.frontier import main
    rc = main(["--scenario", "cold_tail", "--scale", "0.1",
               "--coarse-frac", "0.5", "--spot-check", "0",
               "--out-dir", str(tmp_path), "--quiet"])
    assert rc == 0
    assert (tmp_path / "frontier_cold_tail.csv").exists()
    assert (tmp_path / "frontier_robust.csv").exists()   # header even if empty
    assert (tmp_path / "frontier.json").exists()
    header = (tmp_path / "frontier_cold_tail.csv").read_text().splitlines()[0]
    assert "cost_per_million" in header and "front" in header

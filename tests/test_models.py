"""Per-arch smoke tests (deliverable f) + decode/prefill parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, get_smoke_config, list_archs
from repro.models import registry

pytestmark = pytest.mark.slow

ARCHS = list_archs()


def _batch(cfg, B, S, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_patches, cfg.d_model)).astype(cfg.cdtype)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)).astype(cfg.cdtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, _ = registry.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.training import make_train_step
    from repro.training.optimizer import adamw_init
    cfg = get_smoke_config(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg))
    params, opt, m = step(params, opt, _batch(cfg, 2, 32))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch).replace(
        compute_dtype="float32", param_dtype="float32", capacity_factor=64.0)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    logits_full, _ = registry.forward(cfg, params, batch)

    half = S // 2
    pre = dict(batch)
    pre["tokens"] = toks[:, :half]
    total = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    cache = registry.init_cache(cfg, B, total)
    lg_pre, cache = registry.prefill(cfg, params, cache, pre)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    assert float(jnp.max(jnp.abs(lg_pre - logits_full[:, :half]))) / scale < 2e-3

    off = cfg.num_patches if cfg.family == "vlm" else 0
    for t in range(half, S):
        lg, cache = registry.decode_step(
            cfg, params, cache, toks[:, t:t + 1], jnp.full((B,), t + off, jnp.int32))
        err = float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t]))) / scale
        assert err < 2e-3, (arch, t, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.num_layers >= 4
    assert cfg.vocab_size > 1000
    # param shapes are constructible without allocation
    shapes = jax.eval_shape(lambda: registry.init_params(cfg, jax.random.PRNGKey(0)))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    floor = 2e7 if arch == "whisper-tiny" else 1e8   # whisper-tiny is ~39M
    assert n > floor, f"{arch} params {n:,} suspiciously small"


def test_param_counts_plausible():
    # headline sizes should be within ~35% of the checkpoint names
    expect = {"granite-34b": 34e9, "minitron-8b": 8e9, "gemma2-27b": 27e9,
              "deepseek-moe-16b": 16e9, "deepseek-v2-lite-16b": 16e9,
              "rwkv6-3b": 3e9, "hymba-1.5b": 1.5e9}
    for arch, n_expect in expect.items():
        n = get_config(arch).param_count()
        assert 0.6 * n_expect < n < 1.45 * n_expect, (arch, f"{n:,}")


def test_moe_capacity_drops_are_the_only_decode_divergence():
    cfg = get_smoke_config("deepseek-moe-16b").replace(
        compute_dtype="float32", param_dtype="float32", capacity_factor=64.0)
    # with huge capacity, train path == decode path (verified above); with
    # tight capacity the train path drops tokens -> losses differ slightly
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    tight, _ = registry.loss_fn(cfg.replace(capacity_factor=1.0), params, batch)
    loose, _ = registry.loss_fn(cfg, params, batch)
    assert np.isfinite(float(tight)) and np.isfinite(float(loose))

"""End-to-end behaviour tests for the paper's system: the full
trace -> control plane -> metrics pipeline reproduces the paper's headline
qualitative findings (§1) on a reduced workload."""

import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute, queueing_cdf
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy
from repro.core.trace import TraceConfig, synthesize


@pytest.fixture(scope="module")
def trace():
    return synthesize(TraceConfig(num_functions=120, duration_s=1800,
                                  target_total_rps=20, seed=42))


@pytest.fixture(scope="module")
def sweep(trace):
    out = {}
    for ka in (30, 600):
        out[("sync", ka)] = compute(EventSim(
            trace, Cluster(8), lambda f, k=ka: SyncKeepalivePolicy(k)).run())
    for w in (30, 600):
        out[("async", w)] = compute(EventSim(
            trace, Cluster(8),
            lambda f, w_=w: AsyncConcurrencyPolicy(window_s=w_, target=0.7)).run())
    return out


def test_finding1_churn_overhead_band(sweep):
    """Paper: churn-driven CPU overhead is 10-40% of useful work and it is
    dominated by the instance creation rate."""
    for key, m in sweep.items():
        assert 0.03 < m.cpu_overhead < 1.0, (key, m.cpu_overhead)
    assert sweep[("sync", 30)].cpu_overhead > sweep[("sync", 600)].cpu_overhead
    assert sweep[("async", 30)].cpu_overhead > sweep[("async", 600)].cpu_overhead


def test_finding2_memory_overprovisioning(sweep):
    """Paper: allocated memory is 2-10x actively used, growing with
    keepalive/window."""
    for key, m in sweep.items():
        assert m.normalized_memory > 1.3, (key, m.normalized_memory)
    assert sweep[("sync", 600)].normalized_memory > sweep[("sync", 30)].normalized_memory


def test_finding3_cost_reduction_degrades_performance(sweep):
    """Paper: configs that cut memory/CPU pay for it in slowdown."""
    cheap = sweep[("sync", 30)]
    expensive = sweep[("sync", 600)]
    assert cheap.normalized_memory < expensive.normalized_memory
    assert cheap.cpu_overhead > expensive.cpu_overhead
    assert cheap.slowdown_geomean_p99 >= expensive.slowdown_geomean_p99


def test_finding_worker_side_dominates(sweep):
    """Paper: ~80% of the overhead originates on worker nodes."""
    m = sweep[("sync", 30)]
    assert m.worker_share > 0.6


@pytest.mark.slow
def test_sync_bimodal_vs_async_tail(trace):
    """Paper Fig 2: sync queueing is bimodal (0 or ~cold start); async has a
    smoother tail."""
    sync_res = EventSim(trace, Cluster(8), lambda f: SyncKeepalivePolicy(600)).run()
    async_res = EventSim(trace, Cluster(8),
                         lambda f: AsyncConcurrencyPolicy(window_s=600)).run()
    xs, ys = queueing_cdf(sync_res)
    # bimodal: the mass between 100ms and 800ms is nearly empty for sync
    mid = ((xs > 0.1) & (xs < 0.8)).mean()
    assert mid < 0.15, mid
    xa, ya = queueing_cdf(async_res)
    mid_async = ((xa > 0.1) & (xa < 0.8)).mean()
    assert mid_async >= mid


def test_cold_start_fraction_matches_paper_order(trace):
    """Paper §4.1.1: ~0.5% cold starts at a 10-minute keepalive."""
    m = compute(EventSim(trace, Cluster(8), lambda f: SyncKeepalivePolicy(600)).run())
    assert m.cold_fraction < 0.03

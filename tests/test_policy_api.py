"""Policy-as-pytree API: registry, declared-axis validation, gradient
correctness through the differentiable scan, the oracle round-trip parity
of every registered family, and the learned-policy training loop."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core.runspec import RunSpec
from repro.core.policies import init_theta, learned_keepalive
from repro.core.policy_api import (AxisSpec, PolicyFamily, get_family,
                                   list_families, sweepable_policy_axes)
from repro.core.simjax import JaxPolicy, simulate_chunked
from repro.core.trace import TraceConfig, gap_tables, synthesize
from repro.opt import active_knobs, evaluate_points, make_loss, train_policy
from repro.opt.learned import evaluate_trained
from repro.scenarios import (PolicySpec, get_scenario, parity_report,
                             run_scenario)

TC = TraceConfig(num_functions=30, duration_s=600, target_total_rps=5, seed=11)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_families():
    assert {"sync", "async", "hybrid", "learned"} <= set(list_families())
    for name in list_families():
        fam = get_family(name)
        assert fam.name == name and fam.axes
        # legacy integer kinds resolve to the same object
        if fam.kind is not None:
            assert get_family(fam.kind) is fam


def test_unknown_family_raises_with_listing():
    with pytest.raises(KeyError, match="registered"):
        get_family("bogus")
    with pytest.raises(KeyError):
        get_family(99)


def test_active_knobs_derived_from_declarations():
    # the former hand-written _ACTIVE table, now read off AxisSpec flags
    assert set(active_knobs("sync")) == {"keepalive_s", "cc"}
    assert set(active_knobs("async")) == {"target", "cc"}
    assert set(active_knobs("hybrid")) == {"keepalive_s", "cc", "prewarm_s"}
    assert set(active_knobs("learned")) == {"cc"}     # theta is learnable
    assert active_knobs(0) == active_knobs("sync")    # legacy ints still work
    assert sweepable_policy_axes() >= {"keepalive_s", "target", "cc",
                                       "prewarm_s"}
    assert "theta" not in sweepable_policy_axes()
    assert "theta" in get_family("learned").learnable_axes()


# ---------------------------------------------------------------------------
# construction-time validation (satellite: NaN knobs must fail loudly)
# ---------------------------------------------------------------------------


def test_nan_and_out_of_bounds_knobs_rejected():
    with pytest.raises(ValueError, match="not finite"):
        JaxPolicy(kind=0, keepalive_s=math.nan)
    with pytest.raises(ValueError, match="bounds"):
        JaxPolicy(kind=0, keepalive_s=-5.0)
    with pytest.raises(ValueError, match="bounds"):
        JaxPolicy(kind=1, target=0.0)
    with pytest.raises(ValueError):
        JaxPolicy(kind=1, window_s=0.0)
    theta = init_theta()
    theta["w1"] = theta["w1"] + math.nan
    with pytest.raises(ValueError, match="non-finite"):
        JaxPolicy(family="learned", theta=theta)
    # valid constructions still pass and resolve family <-> kind
    assert JaxPolicy(kind=2).family == "hybrid"
    assert JaxPolicy(family="learned").kind == 3


def test_sweep_values_validated_against_declared_bounds(trace):
    from repro.core.simjax import JaxFleet
    with pytest.raises(ValueError, match="finite"):
        evaluate_points(trace, JaxPolicy(kind=0), JaxFleet(),
                        [{"keepalive_s": math.nan}])
    with pytest.raises(ValueError, match="bounds"):
        evaluate_points(trace, JaxPolicy(kind=0), JaxFleet(),
                        [{"keepalive_s": -1.0}])
    # fleet knobs and other families' inert knobs are finite-checked too
    with pytest.raises(ValueError, match="finite"):
        evaluate_points(trace, JaxPolicy(kind=0), JaxFleet(),
                        [{"warm_frac": math.nan}])
    with pytest.raises(ValueError, match="finite"):
        evaluate_points(trace, JaxPolicy(kind=0), JaxFleet(),
                        [{"target": math.inf}])


def test_family_params_roundtrip_and_custom_registration():
    # params pytree mirrors the declared axes
    assert set(JaxPolicy(kind=0).params()) == {"keepalive_s", "cc"}
    assert set(JaxPolicy(family="learned").params()) == {"cc", "theta"}

    class Dummy(PolicyFamily):
        name = "dummy-test"
        axes = (AxisSpec("cc", 1.0, 8.0),)
    d = Dummy()
    with pytest.raises(ValueError, match="missing"):
        d.validate({})
    with pytest.raises(ValueError, match="unknown params"):
        d.validate({"cc": 1.0, "zz": 2.0})
    d.validate({"cc": 2.0})


@pytest.fixture
def scratch_registry():
    """Allow test registrations without polluting the process-global
    registry for later tests (or double-registering on re-runs)."""
    from repro.core import policy_api
    before = set(policy_api._FAMILIES)
    yield policy_api.register_family
    for name in set(policy_api._FAMILIES) - before:
        fam = policy_api._FAMILIES.pop(name)
        if fam.kind is not None:
            policy_api._BY_KIND.pop(fam.kind, None)


def test_novel_axis_families_need_no_simulator_surgery(trace,
                                                       scratch_registry):
    """A registered family may declare axes beyond JaxPolicy's legacy
    fields: values ride the ``extra`` mapping, sweep through the live
    registry, and a family without the engine-required cc axis is rejected
    at registration."""
    from repro.core.policy_api import CC_AXIS
    from repro.core.simjax import JaxFleet

    class NoCc(PolicyFamily):
        name = "nocc-test"
        axes = (AxisSpec("keepalive_s", 1.0, 1e4),)
    with pytest.raises(ValueError, match="'cc' axis"):
        scratch_registry(NoCc())

    class SpotSync(PolicyFamily):
        """Sync keepalive with a novel scalar axis (inert in decide)."""
        name = "spot-test"
        axes = (CC_AXIS, AxisSpec("keepalive_s", 1.0, 86_400.0),
                AxisSpec("spot_bid", 0.0, 1.0))
        decide = get_family("sync").__class__.decide
        _ka_eff = get_family("sync").__class__._ka_eff
    scratch_registry(SpotSync())

    with pytest.raises(ValueError, match="spot_bid"):
        JaxPolicy(family="spot-test")               # no value supplied
    pol = JaxPolicy(family="spot-test", extra={"spot_bid": 0.4})
    assert pol.params()["spot_bid"] == 0.4
    assert "spot_bid" in sweepable_policy_axes()    # live, not a snapshot
    # the novel axis is a legal sweep axis end-to-end (live registry)
    rows = evaluate_points(trace, pol, JaxFleet(node_memory_mb=8192.0),
                           [{"spot_bid": 0.1}, {"spot_bid": 0.9}])
    assert len(rows) == 2
    assert np.isfinite(rows[0]["cost_per_million"])


# ---------------------------------------------------------------------------
# gradient correctness through the scan (satellite)
# ---------------------------------------------------------------------------


def test_gradient_matches_finite_difference():
    """d(loss)/d(keepalive) from jax.grad through the scan must match a
    central finite difference — the property learned-policy training rests
    on.  The trace is short enough (64 ticks) to disable the truncated-BPTT
    window: with truncation active, ``stop_gradient`` is identity in the
    forward pass, so a finite difference measures the FULL sensitivity
    while jax.grad measures the truncated graph — they only coincide when
    nothing is truncated."""
    import jax
    tiny = synthesize(TraceConfig(num_functions=12, duration_s=64,
                                  target_total_rps=3, seed=3))
    loss_fn, params0 = make_loss(tiny, JaxPolicy(kind=0, keepalive_s=20.0),
                                 trunc_ticks=10 ** 6)
    g = float(jax.grad(loss_fn)(
        jax.tree.map(np.float32, params0))["keepalive_s"])
    h = 1.0
    up = float(loss_fn({**params0, "keepalive_s": np.float32(20.0 + h)}))
    dn = float(loss_fn({**params0, "keepalive_s": np.float32(20.0 - h)}))
    fd = (up - dn) / (2 * h)
    assert np.isfinite(g) and np.isfinite(fd) and g != 0.0
    assert g * fd > 0
    assert abs(g - fd) <= 0.05 * abs(fd), (g, fd)


# ---------------------------------------------------------------------------
# registry round-trip: every family through BOTH engines on diurnal
# ---------------------------------------------------------------------------

# hybrid's adaptive short keepalives interact with the oracle's first-free
# instance packing (churn concentrates on the marginal instance), which the
# fluid renewal model under-expires on time-warped traces: slowdown and
# creation rate sit outside the 15% band there (documented in
# EXPERIMENTS.md next to the fig9 creation-rate waiver); memory holds.
_ROUNDTRIP_WAIVED = {"hybrid": {"slowdown_geomean_p99": 0.30,
                                "creation_rate": 0.50}}


@pytest.mark.parametrize("family", ["sync", "async", "hybrid", "learned"])
def test_registry_roundtrip_parity_on_diurnal(family):
    """Acceptance: every registered policy family replays through BOTH
    engines from one spec on the diurnal scenario at 0.25x inside the
    15% parity band (minus the documented hybrid waivers)."""
    sc = get_scenario("diurnal")
    spec = dataclasses.replace(sc.policy, kind=family,
                               theta=init_theta(0) if family == "learned"
                               else None)
    rows = run_scenario(dataclasses.replace(sc, policy=spec),
                        spec=RunSpec(scale=0.25))
    assert {r["engine"] for r in rows} == {"eventsim", "simjax"}
    gaps = parity_report(rows)
    waived = _ROUNDTRIP_WAIVED.get(family, {})
    for metric, gap in gaps.items():
        assert gap <= waived.get(metric, 0.15), (family, metric, gap)


# ---------------------------------------------------------------------------
# learned policy: training loop + frontier placement
# ---------------------------------------------------------------------------


def test_untrained_learned_policy_equals_sync_default(trace):
    """Zero-init head: before training, the learned family is the sync
    keepalive at 600 s on the fluid engine — the parity gate's anchor."""
    a = simulate_chunked(trace, JaxPolicy(family="learned"))
    b = simulate_chunked(trace, JaxPolicy(kind=0, keepalive_s=600.0))
    for key in ("normalized_memory", "creation_rate", "instances_mean"):
        assert a[key] == pytest.approx(b[key], rel=1e-5), key


def test_learned_keepalive_network_shared_by_both_engines():
    theta = init_theta(0)
    kas = learned_keepalive(theta, np.asarray([1e-4, 0.01, 1.0]))
    assert np.all(np.isfinite(kas)) and np.all(kas > 0)
    # the oracle twin consults the same function
    spec = PolicySpec(kind="learned", theta=theta)
    pol = spec.factory()(0)
    pol.on_arrival(10.0, 0, 0, 0, 0)
    assert pol.keepalive(100.0) > 0


def test_train_policy_reduces_surrogate_loss():
    res = train_policy("cold_tail", scale=0.1, steps=12, lr=0.05)
    assert len(res.history) == 13
    assert all(np.isfinite(h) for h in res.history)
    assert min(res.history) <= res.history[0]
    row = evaluate_trained("cold_tail", res, scale=0.1)
    assert np.isfinite(row["cost_per_million"])
    assert row["policy_kind"] == "learned"
    s = res.summary()
    assert s["scenario"] == "cold_tail" and s["steps"] == 12


@pytest.mark.slow
def test_learned_policy_on_hybrid_frontier_with_oracle_confirmation():
    """Acceptance: the trained learned policy lands on (or beats) the
    hand-tuned baselines' cost/p99 frontier on cold_tail, and the oracle
    spot-check confirms the configuration (parity band)."""
    from benchmarks.fig11_learned_policy import run
    rows, slack, check = run()
    assert slack <= 1.05, slack          # on the tuned front (5% numerics)
    assert check["pass"], check


# ---------------------------------------------------------------------------
# gap tables (the empirical expiry input)
# ---------------------------------------------------------------------------


def test_gap_tables_shapes_and_limits(trace):
    alive, tail = gap_tables(trace)
    f = trace.num_functions
    assert alive.shape == tail.shape == (f, 56)
    assert np.all(np.diff(alive, axis=1) >= -1e-9)      # E monotone in ka
    assert np.all(np.diff(tail, axis=1) <= 1e-9)        # P monotone down
    assert np.all((tail >= 0) & (tail <= 1))
    assert np.all(alive[:, 0] <= alive[:, -1] + 1e-9)

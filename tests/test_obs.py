"""Observability: lifecycle spans, in-scan telemetry, attribution ledger.

The three contracts this file pins:

* OFF = FREE — telemetry/spans disabled change nothing, bit for bit;
* span trees are well-formed and cover every completed request;
* each engine's overhead attribution sums exactly to its aggregate
  ratios, and the two engines agree component-by-component within the
  same 15% band the aggregate parity tests use.
"""

import json
import math

import numpy as np
import pytest

from repro.core.control_plane import ControlPlane, SimWorkerBackend
from repro.core.metrics import compute, per_function_p99_slowdown
from repro.core.policies import SyncKeepalivePolicy
from repro.core.runspec import RunSpec
from repro.obs import (RunTelemetry, SpanRecorder, check_ledger,
                       ledger_from_chunked, ledger_from_eventsim,
                       ledger_parity, validate)
from repro.scenarios import run_scenario
from repro.serving.engine import ServeRequest

# the parity calibration point: oracle-feasible, bands pinned at <=15%
SCALE = 0.25


@pytest.fixture(scope="module")
def traced_diurnal():
    """One fully observed diurnal replay: spans on the oracle leg,
    telemetry on the fluid leg, raw results in ``detail``."""
    obs = SpanRecorder(enabled=True)
    detail = {}
    rows = run_scenario("diurnal", detail=detail,
                        spec=RunSpec(scale=SCALE, obs=obs, telemetry=16))
    return obs, detail, rows


# ---------------------------------------------------------------------------
# off = free
# ---------------------------------------------------------------------------

def test_telemetry_off_is_bit_for_bit():
    base = run_scenario("diurnal",
                        spec=RunSpec(engines=("simjax",), scale=0.1))[0]
    telem = run_scenario("diurnal",
                         spec=RunSpec(engines=("simjax",), scale=0.1,
                                      telemetry=8))[0]
    assert "telemetry" not in base
    for k, v in base.items():
        if k == "wall_s":
            continue
        assert telem[k] == v, f"telemetry perturbed {k}: {v} != {telem[k]}"


def test_spans_off_is_bit_for_bit():
    base = run_scenario("diurnal",
                        spec=RunSpec(engines=("eventsim",), scale=0.1))[0]
    obs = SpanRecorder(enabled=True)
    traced = run_scenario("diurnal",
                          spec=RunSpec(engines=("eventsim",), scale=0.1,
                                       obs=obs))[0]
    assert len(obs.spans) > 0
    for k, v in base.items():
        if k == "wall_s":
            continue
        if isinstance(v, float) and math.isnan(v):
            assert math.isnan(traced[k])
        else:
            assert traced[k] == v, f"spans perturbed {k}: {v} != {traced[k]}"


def test_disabled_recorder_is_falsy_and_inert():
    rec = SpanRecorder(enabled=False)
    assert not rec
    # instrumented code guards with `if rec:` — nothing should ever call
    # into a disabled recorder, so it stays empty by construction
    assert rec.spans == []


# ---------------------------------------------------------------------------
# span trees
# ---------------------------------------------------------------------------

def test_span_tree_well_formed(traced_diurnal):
    obs, detail, _ = traced_diurnal
    assert validate(obs) == []


def test_spans_cover_every_completed_request(traced_diurnal):
    obs, detail, _ = traced_diurnal
    res = detail["oracle_result"]
    by_name = {}
    for sp in obs.spans:
        by_name.setdefault(sp.name, []).append(sp)
    closed_requests = [sp for sp in by_name["request"]
                       if not sp.args.get("truncated")]
    # every completed request has a closed request span and at least one
    # execute child inside it
    assert len(closed_requests) >= len(res.records)
    execs = by_name["execute"]
    assert len(execs) >= len(res.records)
    parents = {sp.parent for sp in execs}
    assert parents <= {sp.sid for sp in by_name["request"]}
    # instance lifecycle is present on its own track
    assert len(by_name["instance_create"]) > 0
    assert all(sp.pid == "instances" for sp in by_name["instance_create"])


def test_node_spans_present_on_fleet_scenario():
    obs = SpanRecorder(enabled=True)
    run_scenario("spot_storm",
                 spec=RunSpec(engines=("eventsim",), scale=0.1, obs=obs))
    names = {sp.name for sp in obs.spans}
    assert "node_provision" in names
    assert validate(obs) == []


def test_recorder_end_twice_is_safe():
    rec = SpanRecorder(enabled=True)
    sid = rec.begin("x", "request", 0.0, pid="requests", tid=0)
    rec.end(sid, 1.0)
    rec.end(sid, 2.0)            # no-op, keeps the first close
    assert rec.spans[0].t1 == 1.0


# ---------------------------------------------------------------------------
# attribution ledger
# ---------------------------------------------------------------------------

def test_attribution_sums_to_aggregates_both_engines(traced_diurnal):
    _, detail, _ = traced_diurnal
    led_o = ledger_from_eventsim(detail["oracle_result"])
    led_f = ledger_from_chunked(detail["fluid_summary"])
    assert check_ledger(led_o, tol=1e-6) == []
    assert check_ledger(led_f, tol=1e-6) == []
    # the ledger's aggregates must equal the engines' reported metrics
    row = detail["fluid_summary"]
    assert led_f.normalized_memory == pytest.approx(
        row["normalized_memory"], rel=1e-6)


def test_component_parity_within_band(traced_diurnal):
    _, detail, _ = traced_diurnal
    gaps = ledger_parity(ledger_from_eventsim(detail["oracle_result"]),
                         ledger_from_chunked(detail["fluid_summary"]))
    assert gaps, "no components judged"
    for k, g in gaps.items():
        assert g <= 0.15, f"component {k} gap {g:.3f} exceeds the band"


def test_ledger_requires_telemetry():
    row = run_scenario("cold_tail",
                       spec=RunSpec(engines=("simjax",), scale=0.1))[0]
    with pytest.raises(ValueError):
        ledger_from_chunked(row)


# ---------------------------------------------------------------------------
# metrics satellites
# ---------------------------------------------------------------------------

def test_vectorized_p99_matches_reference(traced_diurnal):
    _, detail, _ = traced_diurnal
    res = detail["oracle_result"]
    by_fn = {}
    for r in res.records:
        if math.isnan(r.end):
            continue
        slow = max((r.end - r.arrival) / max(r.dur, 1e-6), 1.0)
        by_fn.setdefault(r.fn, []).append(slow)
    ref = sorted(float(np.percentile(v, 99)) for v in by_fn.values()
                 if len(v) >= 5)
    vec = sorted(per_function_p99_slowdown(res).tolist())
    assert vec == pytest.approx(ref, rel=1e-12)


def test_metrics_row_emits_dropped(traced_diurnal):
    _, detail, _ = traced_diurnal
    res = detail["oracle_result"]
    row = compute(res).row()
    assert row["dropped"] == res.dropped


# ---------------------------------------------------------------------------
# control plane spans (the serving-side oracle)
# ---------------------------------------------------------------------------

def test_control_plane_spans():
    obs = SpanRecorder(enabled=True)
    backend = SimWorkerBackend(cold_start_s=0.5, default_service_s=0.3)
    cp = ControlPlane(backend, lambda f: SyncKeepalivePolicy(
        keepalive_s=3.0, container_concurrency=1), num_functions=1, obs=obs)
    t = 0.0
    for i in range(3):
        cp.submit(ServeRequest(rid=i, fn=0, prompt=[], arrival_t=t), t)
    while len(cp.completed) < 3 and t < 20:
        t += 0.1
        cp.tick(t)
    for _ in range(60):          # keepalive expiry -> teardown instants
        t += 0.1
        cp.tick(t)
    obs.finish(t)
    assert validate(obs) == []
    by_name = {}
    for sp in obs.spans:
        by_name.setdefault(sp.name, []).append(sp)
    assert len(by_name["request"]) == 3
    assert len(by_name["execute"]) == 3
    assert len(by_name["instance_create"]) >= 1
    assert len(by_name["teardown"]) >= 1


# ---------------------------------------------------------------------------
# run telemetry + CLIs
# ---------------------------------------------------------------------------

def test_run_telemetry_series():
    tel = RunTelemetry()
    tel.emit("train_step", step=1, loss=2.0)
    tel.emit("train_step", step=2, loss=1.5)
    tel.emit("other", x=1)
    assert tel.series("train_step", "loss") == [2.0, 1.5]
    assert len(tel.to_json()["events"]) == 3


def test_trace_cli_end_to_end(tmp_path):
    from repro.launch.trace import main
    rc = main(["diurnal", "--out-dir", str(tmp_path), "--slots", "32",
               "--check"])
    assert rc == 0
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert trace["traceEvents"], "empty Chrome trace"
    phases = {e["ph"] for e in trace["traceEvents"]}
    assert "X" in phases and "M" in phases
    ledger = json.loads((tmp_path / "ledger.json").read_text())
    assert ledger["failures"] == []
    assert len(ledger["ledgers"]) == 2
    assert (tmp_path / "timeline_oracle.csv").exists()
    assert (tmp_path / "timeline_simjax.csv").exists()


def test_trace_cli_unknown_scenario_exit_2(tmp_path, capsys):
    from repro.launch.trace import main
    assert main(["no_such_scenario", "--out-dir", str(tmp_path)]) == 2


def test_scenarios_cli_flag_validation(tmp_path):
    from repro.launch.scenarios import main
    # a span trace needs an oracle leg
    assert main(["--scenario", "cold_tail", "--engines", "simjax",
                 "--trace-out", str(tmp_path / "t.json")]) == 2
    # telemetry needs a fluid leg
    assert main(["--scenario", "cold_tail", "--engines", "eventsim",
                 "--telemetry", str(tmp_path)]) == 2
    # one scenario per span trace
    assert main(["--scenario", "cold_tail", "--scenario", "diurnal",
                 "--trace-out", str(tmp_path / "t.json")]) == 2

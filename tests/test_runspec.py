"""The unified RunSpec API: validation, the deprecation shims on every
redesigned entry point (run_scenario / evaluate_scenario / simulate_chunked
/ frontier), and the fleet.sweep legacy re-export path."""

import warnings

import pytest

import repro.core.runspec as runspec
from repro.core.runspec import RunSpec, resolve_spec
from repro.core.simjax import JaxPolicy, simulate_chunked
from repro.core.trace import TraceConfig, synthesize
from repro.scenarios import run_scenario

TC = TraceConfig(num_functions=30, duration_s=600, target_total_rps=5, seed=11)


def setup_function(_fn):
    # warn_once keys persist per process; re-arm them so every test sees
    # the first-hit warning behaviour
    runspec._WARNED.clear()


# ---------------------------------------------------------------------------
# RunSpec construction
# ---------------------------------------------------------------------------


def test_defaults_and_replace():
    spec = RunSpec()
    assert spec.scale == 1.0
    assert spec.engines == ("eventsim", "simjax")
    assert spec.billing is None and spec.tier is None and spec.obs is None
    assert spec.telemetry == 0 and spec.devices == 0 and spec.cluster == 0.0
    assert not spec.force_oracle
    spec2 = spec.replace(scale=0.25, devices=4)
    assert (spec2.scale, spec2.devices) == (0.25, 4)
    assert spec.scale == 1.0  # frozen original untouched


def test_single_engine_string_normalizes_to_tuple():
    assert RunSpec(engines="simjax").engines == ("simjax",)


@pytest.mark.parametrize("bad", [
    {"scale": 0.0}, {"scale": -1.0}, {"scale": float("nan")},
    {"telemetry": -1}, {"devices": -2},
    {"cluster": -0.5}, {"cluster": float("inf")},
])
def test_validation_rejects(bad):
    with pytest.raises(ValueError):
        RunSpec(**bad)


def test_frozen():
    with pytest.raises(Exception):
        RunSpec().scale = 2.0


# ---------------------------------------------------------------------------
# resolve_spec: the one merge point for every shim
# ---------------------------------------------------------------------------


def test_spec_plus_legacy_is_ambiguous():
    with pytest.raises(TypeError, match="both spec="):
        resolve_spec("f", RunSpec(), {"scale": 0.5})


def test_spec_must_be_a_runspec():
    with pytest.raises(TypeError, match="must be a RunSpec"):
        resolve_spec("f", {"scale": 0.5}, {"scale": None})


def test_legacy_warns_once_per_entry_point():
    legacy = {"scale": 0.5, "billing": None}
    with pytest.warns(DeprecationWarning, match="loose keyword"):
        spec = resolve_spec("f", None, legacy)
    assert spec == RunSpec(scale=0.5)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second hit must stay silent
        assert resolve_spec("f", None, legacy) == RunSpec(scale=0.5)
    with pytest.warns(DeprecationWarning):  # distinct entry point re-warns
        resolve_spec("g", None, legacy)


def test_no_kwargs_is_silent_default():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_spec("f", None, {"scale": None}) == RunSpec()


# ---------------------------------------------------------------------------
# entry-point shims
# ---------------------------------------------------------------------------


def test_run_scenario_legacy_matches_spec():
    with pytest.warns(DeprecationWarning):
        old = run_scenario("cold_tail", engines=("simjax",), scale=0.05)
    new = run_scenario("cold_tail", spec=RunSpec(engines=("simjax",),
                                                 scale=0.05))
    assert len(old) == len(new) == 1
    for k, v in old[0].items():
        if isinstance(v, float) and k != "wall_s":
            assert v == new[0][k], k  # bitwise: same code path underneath


def test_run_scenario_rejects_spec_plus_legacy():
    with pytest.raises(TypeError, match="both spec="):
        run_scenario("cold_tail", scale=0.05, spec=RunSpec(scale=0.05))


def test_simulate_chunked_legacy_telemetry_warns():
    trace = synthesize(TC)
    pol = JaxPolicy(kind=0, keepalive_s=120)
    with pytest.warns(DeprecationWarning):
        old = simulate_chunked(trace, pol, telemetry=0)
    new = simulate_chunked(trace, pol, spec=RunSpec())
    for k, v in old.items():
        if isinstance(v, float):
            assert v == new[k], k


def test_evaluate_scenario_legacy_matches_spec():
    from repro.opt import evaluate_scenario
    pts = [{"keepalive_s": 60.0}, {"keepalive_s": 600.0}]
    with pytest.warns(DeprecationWarning):
        old = evaluate_scenario("cold_tail", pts, scale=0.05)
    new = evaluate_scenario("cold_tail", pts, spec=RunSpec(scale=0.05))
    assert [r["slowdown_geomean_p99"] for r in old] \
        == [r["slowdown_geomean_p99"] for r in new]


def test_frontier_typo_fails_loudly():
    from repro.scenarios.runner import frontier
    with pytest.raises(TypeError):
        frontier(scal=0.1)  # the old **kw signature swallowed this


# ---------------------------------------------------------------------------
# fleet.sweep legacy re-exports
# ---------------------------------------------------------------------------


def test_sweep_legacy_reexports_warn_and_forward():
    import repro.fleet.sweep as sweep
    from repro.opt.frontier import pareto_front
    from repro.opt.space import SWEEPABLE, grid_points
    with pytest.warns(DeprecationWarning, match="deprecated"):
        assert getattr(sweep, "pareto_front") is pareto_front
    # the nag is once per NAME, so each legacy name warns on first access
    with pytest.warns(DeprecationWarning):
        assert getattr(sweep, "grid_points") is grid_points
    with pytest.warns(DeprecationWarning):
        assert getattr(sweep, "SWEEPABLE") is SWEEPABLE
    with pytest.raises(AttributeError):
        sweep.not_a_thing


def test_sweep_legacy_warns_once_then_silent():
    import repro.fleet.sweep as sweep
    with pytest.warns(DeprecationWarning):
        sweep.pareto_front
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sweep.pareto_front

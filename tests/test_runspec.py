"""The unified RunSpec API: construction/validation, and the post-soak
contract that ``spec=RunSpec(...)`` is the ONLY calling convention — the
legacy loose-kwarg shims and the fleet.sweep re-exports are gone, so stale
call sites fail with ordinary TypeErrors instead of deprecation warnings."""

import pytest

from repro.core.runspec import RunSpec
from repro.core.simjax import JaxPolicy, simulate_chunked
from repro.core.trace import TraceConfig, synthesize
from repro.scenarios import run_scenario

TC = TraceConfig(num_functions=30, duration_s=600, target_total_rps=5, seed=11)


# ---------------------------------------------------------------------------
# RunSpec construction
# ---------------------------------------------------------------------------


def test_defaults_and_replace():
    spec = RunSpec()
    assert spec.scale == 1.0
    assert spec.engines == ("eventsim", "simjax")
    assert spec.billing is None and spec.tier is None and spec.obs is None
    assert spec.telemetry == 0 and spec.devices == 0 and spec.cluster == 0.0
    assert not spec.force_oracle
    spec2 = spec.replace(scale=0.25, devices=4)
    assert (spec2.scale, spec2.devices) == (0.25, 4)
    assert spec.scale == 1.0  # frozen original untouched


def test_single_engine_string_normalizes_to_tuple():
    assert RunSpec(engines="simjax").engines == ("simjax",)


@pytest.mark.parametrize("bad", [
    {"scale": 0.0}, {"scale": -1.0}, {"scale": float("nan")},
    {"telemetry": -1}, {"devices": -2},
    {"cluster": -0.5}, {"cluster": float("inf")},
])
def test_validation_rejects(bad):
    with pytest.raises(ValueError):
        RunSpec(**bad)


def test_frozen():
    with pytest.raises(Exception):
        RunSpec().scale = 2.0


# ---------------------------------------------------------------------------
# the shims are GONE: loose kwargs fail as ordinary TypeErrors
# ---------------------------------------------------------------------------


def test_run_scenario_rejects_legacy_kwargs():
    with pytest.raises(TypeError):
        run_scenario("cold_tail", scale=0.05)
    with pytest.raises(TypeError):
        run_scenario("cold_tail", engines=("simjax",))
    with pytest.raises(TypeError):
        run_scenario("cold_tail", billing="ideal")


def test_run_scenario_spec_must_be_a_runspec():
    with pytest.raises(TypeError, match="must be a RunSpec"):
        run_scenario("cold_tail", spec={"scale": 0.05})


def test_simulate_chunked_rejects_legacy_kwargs():
    trace = synthesize(TC)
    pol = JaxPolicy(kind=0, keepalive_s=120)
    with pytest.raises(TypeError):
        simulate_chunked(trace, pol, telemetry=0)
    with pytest.raises(TypeError):
        simulate_chunked(trace, pol, billing="ideal")
    with pytest.raises(TypeError, match="must be a RunSpec"):
        simulate_chunked(trace, pol, spec={"telemetry": 4})


def test_evaluate_scenario_rejects_legacy_kwargs():
    from repro.opt import evaluate_scenario
    with pytest.raises(TypeError):
        evaluate_scenario("cold_tail", [{}], scale=0.05)
    with pytest.raises(TypeError, match="must be a RunSpec"):
        evaluate_scenario("cold_tail", [{}], spec=0.05)


def test_frontier_rejects_legacy_kwargs_and_typos():
    from repro.scenarios.runner import frontier
    with pytest.raises(TypeError):
        frontier(scale=0.1)     # the shim kwarg is gone
    with pytest.raises(TypeError):
        frontier(billing="gcr")
    with pytest.raises(TypeError):
        frontier(scal=0.1)      # the old **kw signature swallowed this


def test_runspec_module_has_no_shim_surface():
    import repro.core.runspec as runspec
    for name in ("resolve_spec", "warn_once", "_WARNED"):
        assert not hasattr(runspec, name), name


def test_spec_path_still_runs():
    rows = run_scenario("cold_tail", spec=RunSpec(engines=("simjax",),
                                                  scale=0.05))
    assert len(rows) == 1 and rows[0]["engine"] == "simjax"


# ---------------------------------------------------------------------------
# fleet.sweep re-exports are gone
# ---------------------------------------------------------------------------


def test_sweep_legacy_reexports_removed():
    import repro.fleet.sweep as sweep
    for name in ("pareto_front", "grid_points", "SWEEPABLE"):
        with pytest.raises(AttributeError):
            getattr(sweep, name)
    assert callable(sweep.sweep)   # the stable surface remains

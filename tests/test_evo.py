"""repro.opt.evo: NSGA primitives, genome encoding, budget accounting,
and the population engine (fast paths use an injected analytic evaluator;
the simulator-backed acceptance duel is marked slow)."""

import math

import numpy as np
import pytest

from repro.core.policy_api import get_family
from repro.opt import (DEFAULT_SPACE, SearchSpace, evo_search,
                       frontier_search, grid_budget)
from repro.opt.evo import (BudgetExhausted, EvalBudget, EvoConfig,
                           crowding_distance, genome_from_space,
                           non_dominated_sort, nsga_rank, point_key,
                           polynomial_mutation, sbx_crossover)


# ---------------------------------------------------------------------------
# NSGA primitives
# ---------------------------------------------------------------------------


def test_non_dominated_sort_three_front_fixture():
    # hand-built: front 0 = {0,1,2} (mutually non-dominated),
    # front 1 = {3,4}, front 2 = {5}
    F = np.array([
        [1.0, 9.0],   # 0
        [5.0, 5.0],   # 1
        [9.0, 1.0],   # 2
        [6.0, 6.0],   # 3: dominated by 1 only
        [2.0, 10.0],  # 4: dominated by 0 only
        [7.0, 7.0],   # 5: dominated by 1 and 3
    ])
    ranks, fronts = non_dominated_sort(F)
    assert ranks.tolist() == [0, 0, 0, 1, 1, 2]
    assert [sorted(f.tolist()) for f in fronts] == [[0, 1, 2], [3, 4], [5]]


def test_non_dominated_sort_quarantines_non_finite_rows():
    F = np.array([[1.0, 2.0], [np.nan, 1.0], [2.0, np.inf], [2.0, 3.0]])
    ranks, fronts = non_dominated_sort(F)
    # finite rows sort normally; NaN/inf rows share one extra last front
    assert ranks[0] == 0 and ranks[3] == 1
    assert sorted(fronts[-1].tolist()) == [1, 2]
    assert ranks[1] == ranks[2] == len(fronts) - 1


def test_non_dominated_sort_duplicates_share_a_front():
    F = np.array([[1.0, 1.0], [1.0, 1.0], [2.0, 2.0]])
    ranks, _ = non_dominated_sort(F)
    assert ranks.tolist() == [0, 0, 1]


def test_crowding_distance_boundaries_infinite():
    F = np.array([[1.0, 4.0], [2.0, 3.0], [3.0, 2.0], [4.0, 1.0]])
    d = crowding_distance(F, np.arange(4))
    assert math.isinf(d[0]) and math.isinf(d[3])
    assert np.isfinite(d[1]) and np.isfinite(d[2])


def test_nsga_rank_prefers_spread_within_front():
    F = np.array([[1.0, 5.0], [2.9, 3.05], [3.0, 3.0], [5.0, 1.0]])
    ranks, crowd = nsga_rank(F)
    assert ranks.tolist() == [0, 0, 0, 0]
    # the two near-duplicate interior points are less crowded-distant
    # than the boundary points
    assert crowd[1] < crowd[0] and crowd[2] < crowd[3]


# ---------------------------------------------------------------------------
# genome: bounds, integrality, inert-axis dropping
# ---------------------------------------------------------------------------

CELLS_SPACE = SearchSpace(
    policy={"keepalive_s": (60.0, 300.0, 1200.0),
            "spot_fraction": (0.0, 0.6),
            "cell_count": (2.0, 4.0, 8.0)},
    fleet={"util_target": (0.6, 0.8)})


def test_genome_drops_inert_axes_and_freezes_singletons():
    g = genome_from_space(DEFAULT_SPACE, ["sync"])
    # target (async) and spot_fraction (spot_aware) are inert under sync;
    # fleet knobs always ride
    assert set(gene.name for gene in g.genes) == \
        {"keepalive_s", "util_target", "warm_frac"}
    single = SearchSpace(policy={"keepalive_s": (60.0, 600.0)},
                         fleet={"warm_frac": (0.25,)})
    g2 = genome_from_space(single, ["sync"])
    assert dict(g2.fixed) == {"warm_frac": 0.25}
    assert g2.decode(g2.encode({"keepalive_s": 60.0}))["warm_frac"] == 0.25


def test_genome_rejects_grid_outside_axis_bounds():
    bad = SearchSpace(policy={"target": (0.01, 1.0)})   # axis lo is 0.05
    with pytest.raises(ValueError, match="leaves the declared axis bounds"):
        genome_from_space(bad, ["async"])


def test_variation_respects_axisspec_bounds():
    g = genome_from_space(CELLS_SPACE, ["cells"])
    fam = get_family("cells")
    lo, hi = g.lo, g.hi
    rng = np.random.default_rng(7)
    pts = CELLS_SPACE.points()
    for _ in range(200):
        a = g.encode(pts[rng.integers(len(pts))])
        b = g.encode(pts[rng.integers(len(pts))])
        c1, c2 = sbx_crossover(rng, a, b, lo, hi)
        child = polynomial_mutation(rng, c1, lo, hi, p_mut=1.0)
        pt = g.decode(child)
        for gene in g.genes:
            if not gene.fleet:
                ax = fam.axis(gene.name)
                assert ax.lo <= pt[gene.name] <= ax.hi, gene.name
            assert gene.lo <= pt[gene.name] <= gene.hi, gene.name
        _ = g.decode(c2)


def test_structural_cell_count_stays_integral_through_variation():
    g = genome_from_space(CELLS_SPACE, ["cells"])
    idx = [gene.name for gene in g.genes].index("cell_count")
    assert g.genes[idx].integer and g.genes[idx].structural
    rng = np.random.default_rng(3)
    lo, hi = g.lo, g.hi
    for _ in range(100):
        v = rng.uniform(lo, hi)
        c1, c2 = sbx_crossover(rng, v, rng.uniform(lo, hi), lo, hi)
        child = polynomial_mutation(rng, c1, lo, hi, p_mut=1.0)
        cc = g.decode(child)["cell_count"]
        assert cc == int(cc), "cell_count must decode to a whole number"
    # repair is idempotent
    v = rng.uniform(lo, hi)
    assert np.allclose(g.repair(g.repair(v)), g.repair(v))


def test_log_gene_roundtrip_and_point_key():
    g = genome_from_space(DEFAULT_SPACE, ["sync"])
    ka = next(gene for gene in g.genes if gene.name == "keepalive_s")
    assert ka.log   # [1, 86400] spans 2+ decades -> ratio-scaled
    pt = {"keepalive_s": 300.0, "util_target": 0.7, "warm_frac": 0.1}
    rt = g.decode(g.encode(pt))
    assert rt["keepalive_s"] == pytest.approx(300.0, rel=1e-12)
    assert point_key(rt) == point_key(g.decode(g.encode(rt)))


# ---------------------------------------------------------------------------
# EvalBudget: exact accounting
# ---------------------------------------------------------------------------


def test_budget_accounting_is_exact():
    b = EvalBudget(20)
    b.spend(6, "seed", "s1", 0)
    b.spend(6, "evolve", "s1", 1)
    assert b.spent == 12 and b.remaining == 8 and not b.exhausted
    b.record(40, "refine", "s1")          # off-budget work
    assert b.spent == 12 and b.recorded == 52
    assert b.by_stage() == {"seed": 6, "evolve": 6, "refine": 40}
    b.spend(8, "evolve", "s1", 2)
    assert b.exhausted and b.remaining == 0
    s = b.summary()
    assert s["total"] == 20 and s["spent"] == 20 and s["recorded"] == 60


def test_budget_overdraft_raises():
    b = EvalBudget(4)
    b.spend(3, "seed")
    assert b.can_afford(1) and not b.can_afford(2)
    with pytest.raises(BudgetExhausted):
        b.spend(2, "evolve")
    assert b.spent == 3                    # the failed spend left no entry
    with pytest.raises(ValueError):
        b.spend(-1, "evolve")
    with pytest.raises(ValueError):
        EvalBudget(0)


def test_grid_budget_prices_the_deduped_grid():
    # sync scenario: target & spot_fraction are inert -> 4*2*2 = 16 of 96
    assert grid_budget(DEFAULT_SPACE, ["fleet_cost_stress"]) == 16
    assert grid_budget(DEFAULT_SPACE,
                       ["fleet_cost_stress", "flash_crowd"]) == 16 + 12


# ---------------------------------------------------------------------------
# engine on an injected analytic evaluator (no simulator)
# ---------------------------------------------------------------------------


def _analytic_eval(sc, pts, scale):
    rows = []
    for p in pts:
        ka = p.get("keepalive_s", 100.0)
        wf = p.get("warm_frac", 0.0)
        cost = 100.0 + 0.05 * ka + 400.0 * wf
        slow = 1.0 + 300.0 / (ka + 10.0) + 0.3 / (wf + 0.1)
        rows.append({"cost_per_million": cost, "slowdown_geomean_p99": slow,
                     "sims": len(pts), "scenario": sc.name, "scale": scale,
                     "stage_wall_s": 0.0})
    return rows


def test_evo_engine_spends_exactly_the_budget():
    res = evo_search(["fleet_cost_stress"], scale=0.1, coarse_frac=1.0,
                     budget=30, seed=1, refine=False,
                     evaluate=_analytic_eval)
    assert res.algo == "evo"
    assert res.budget.spent == 30 and res.budget.total == 30
    # every registered candidate was evaluated (rows join on point ids)
    rows = res.coarse["fleet_cost_stress"]
    assert len(rows) == len(res.points) == 30
    assert [r["point_id"] for r in rows] == list(range(30))
    assert res.summary()["budget"]["spent"] == 30


def test_evo_engine_is_seed_deterministic():
    kw = dict(scale=0.1, coarse_frac=1.0, budget=24, refine=False,
              evaluate=_analytic_eval)
    a = evo_search(["fleet_cost_stress"], seed=5, **kw)
    b = evo_search(["fleet_cost_stress"], seed=5, **kw)
    c = evo_search(["fleet_cost_stress"], seed=6, **kw)
    assert a.points == b.points
    assert a.robust_ids == b.robust_ids
    assert c.points != a.points            # the seed is real entropy
    # no module-level randomness was touched: a fresh global draw does not
    # perturb a seeded search
    np.random.seed(0)
    np.random.random()
    d = evo_search(["fleet_cost_stress"], seed=5, **kw)
    assert d.points == a.points


def test_evo_engine_masks_forbidden_classes():
    forbidden = [{"keepalive_s": 60.0, "util_target": 0.6,
                  "warm_frac": 0.0}]
    res = evo_search(["fleet_cost_stress"], scale=0.1, coarse_frac=1.0,
                     budget=24, seed=0, refine=False,
                     evaluate=_analytic_eval, forbidden=forbidden)
    keys = {point_key(p) for p in res.points}
    from repro.opt.evo.genome import genome_from_space as gfs
    g = gfs(DEFAULT_SPACE, ["sync"])
    assert point_key(g.project(forbidden[0])) not in keys


def test_evo_engine_emits_generation_telemetry():
    from repro.obs import RunTelemetry
    tel = RunTelemetry()
    evo_search(["fleet_cost_stress"], scale=0.1, coarse_frac=1.0,
               budget=24, seed=0, refine=False, evaluate=_analytic_eval,
               telemetry=tel)
    gens = [e for e in tel.events if e["event"] == "evo_generation"]
    assert gens and gens[0]["stage"] == "seed"
    assert all("hypervolume" in e and "budget_spent" in e for e in gens)
    spent = [e["budget_spent"] for e in gens]
    assert spent == sorted(spent) and spent[-1] <= 24
    done = [e for e in tel.events if e["event"] == "evo_done"]
    assert len(done) == 1 and done[0]["budget"]["spent"] == spent[-1]


def test_evo_engine_budget_too_small_raises():
    with pytest.raises(ValueError, match="cannot seed"):
        evo_search(["fleet_cost_stress", "flash_crowd"], budget=3,
                   refine=False, evaluate=_analytic_eval)


def test_frontier_search_dispatches_and_rejects_unknown_algo():
    with pytest.raises(ValueError, match="unknown search algo"):
        frontier_search(["fleet_cost_stress"], algo="annealing")
    res = frontier_search(["fleet_cost_stress"], scale=0.1,
                          coarse_frac=1.0, algo="evo", budget=16, seed=0,
                          evo_config=EvoConfig(grad_steps=0))
    assert res.algo == "evo" and res.budget.spent == 16


def test_frontier_cli_unknown_algo_exits_2(capsys):
    from repro.launch.frontier import main
    assert main(["--algo", "bogus"]) == 2
    err = capsys.readouterr().err
    assert "unknown search algo" in err and "grid, evo" in err
    assert main(["--algo", "evo", "--budget", "-4"]) == 2


# ---------------------------------------------------------------------------
# the acceptance duel (real simulator)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_evo_matches_or_beats_grid_at_equal_budget():
    """Acceptance: at the grid's own budget (deduped sims), the population
    search's hypervolume on fleet_cost_stress at 0.1x is no worse than
    enumeration's."""
    from benchmarks.fig15_optimizer import compare
    r = compare("fleet_cost_stress", scale=0.1, seed=0)
    assert math.isfinite(r["evo_hv"]) and r["evo_hv"] > 0
    assert r["evo_hv"] >= r["grid_hv"] * (1.0 - 1e-9), r

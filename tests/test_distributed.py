"""Sharding rules + HLO cost analyzer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.launch.hlo_analysis import analyze_hlo_text


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_to_spec_drops_unknown_axes():
    mesh = _mesh()
    spec = sh.logical_to_spec(("batch", "seq", "heads"), mesh)
    assert spec == P(("data",), None, "model")


def test_fsdp_specs_sharding_first_free_dim():
    # spec computation works on an AbstractMesh: the production 16x16 shape
    # without needing 256 devices
    from jax.sharding import AbstractMesh
    mesh = AbstractMesh((16, 16), ("data", "model"))
    with sh.use_mesh(mesh):
        shapes = {"w": jax.ShapeDtypeStruct((2048, 16, 128), jnp.float32),
                  "norm": jax.ShapeDtypeStruct((2048,), jnp.float32)}
        specs = {"w": ("embed", "heads", None), "norm": (None,)}
        out = sh.fsdp_specs(specs, shapes)
    assert out["w"][0] == "fsdp"          # embed maps to nothing -> free
    assert out["norm"] == (None,)          # 1-D params untouched


def test_div_axis_guards_divisibility():
    mesh = _mesh()
    with sh.use_mesh(mesh):
        assert sh.div_axis("heads", 32) in ("heads", None)
        # axis size 1 -> always None
        assert sh.mesh_axis_size("heads") == 1


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert sh.shard(x, "batch", None) is x


# -- HLO analyzer --------------------------------------------------------------


def test_hlo_flops_scan_vs_unroll():
    x = jnp.ones((128, 128), jnp.float32)
    w = jnp.ones((128, 128), jnp.float32)

    def f_scan(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=16)[0]

    def f_unroll(x, w):
        for _ in range(16):
            x = x @ w
        return x

    fs = analyze_hlo_text(jax.jit(f_scan).lower(x, w).compile().as_text())
    fu = analyze_hlo_text(jax.jit(f_unroll).lower(x, w).compile().as_text())
    want = 2 * 16 * 128**3
    assert abs(fs["flops_per_device"] - want) / want < 0.05
    assert abs(fu["flops_per_device"] - want) / want < 0.05


def test_hlo_matches_xla_on_plain_matmul():
    a = jnp.ones((256, 512), jnp.bfloat16)
    b = jnp.ones((512, 1024), jnp.bfloat16)
    comp = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    mine = analyze_hlo_text(comp.as_text())
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert abs(mine["flops_per_device"] - ca["flops"]) / ca["flops"] < 0.02


def test_hlo_nested_scan_trip_counts():
    x = jnp.ones((64, 64), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    r = analyze_hlo_text(jax.jit(f).lower(x).compile().as_text())
    want = 2 * 15 * 64**3
    assert abs(r["flops_per_device"] - want) / want < 0.1


def test_cells_input_specs_have_shardings():
    from repro.configs import get_smoke_config
    from repro.launch import cells
    mesh = _mesh()
    cfg = get_smoke_config("granite-34b")
    fn, args, donate = cells.build_cell(cfg, "train_4k", mesh)
    leaves = jax.tree.leaves(args)
    assert all(hasattr(l, "sharding") and l.sharding is not None for l in leaves)
    assert donate == (0, 1)

"""Sharding rules + HLO cost analyzer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.launch.hlo_analysis import analyze_hlo_text


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_to_spec_drops_unknown_axes():
    mesh = _mesh()
    spec = sh.logical_to_spec(("batch", "seq", "heads"), mesh)
    assert spec == P(("data",), None, "model")


def _abstract_mesh(shape=(16, 16), names=("data", "model")):
    # spec computation works on an AbstractMesh: the production 16x16 shape
    # without needing 256 devices
    from jax.sharding import AbstractMesh
    return AbstractMesh(tuple(zip(names, shape)))


def test_fsdp_specs_sharding_first_free_dim():
    mesh = _abstract_mesh()
    with sh.use_mesh(mesh):
        shapes = {"w": jax.ShapeDtypeStruct((2048, 16, 128), jnp.float32),
                  "norm": jax.ShapeDtypeStruct((2048,), jnp.float32)}
        specs = {"w": ("embed", "heads", None), "norm": (None,)}
        out = sh.fsdp_specs(specs, shapes)
    assert out["w"][0] == "fsdp"          # embed maps to nothing -> free
    assert out["norm"] == (None,)          # 1-D params untouched


def test_resolve_preserves_tuple_rules_and_collapses_strings():
    # string rule -> bare axis; tuple rule -> tuple, even with one survivor
    assert sh._resolve("heads", ("data", "model")) == "model"
    assert sh._resolve("batch", ("data", "model")) == ("data",)
    assert sh._resolve("batch", ("pod", "data", "model")) == ("pod", "data")
    assert sh._resolve("batch", ("model",)) is None
    assert sh._resolve("unknown_axis", ("data", "model")) is None
    assert sh._resolve(None, ("data", "model")) is None


def test_sanitize_spec_non_divisible_dims():
    mesh = _abstract_mesh((4, 2), ("data", "model"))
    # dim 6 % 4 != 0 -> dropped; dim 8 % 2 == 0 -> kept
    spec = sh.sanitize_spec(P("data", "model"), (6, 8), mesh)
    assert spec == P(None, "model")
    # tuple entry: product of axis sizes (4*2=8) must divide the dim
    assert sh.sanitize_spec(P(("data", "model")), (16,), mesh) \
        == P(("data", "model"))
    assert sh.sanitize_spec(P(("data", "model")), (12,), mesh) == P(None)
    # single-survivor tuple entries (post-_resolve form) survive sanitize
    assert sh.sanitize_spec(P(("data",), None), (8, 3), mesh) == P(("data",), None)


def test_sanitize_spec_rank_mismatch():
    mesh = _abstract_mesh((4, 2), ("data", "model"))
    # spec longer than shape: trailing entries pass through untouched
    assert sh.sanitize_spec(P("data", "model"), (8,), mesh) == P("data", "model")
    # spec shorter than shape: missing dims stay unsharded
    assert sh.sanitize_spec(P("data"), (8, 6, 4), mesh) == P("data")


def test_fsdp_specs_edge_cases():
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    with sh.use_mesh(mesh):
        shapes = {
            # first dim non-divisible by fsdp=16 -> second free dim taken
            "w_odd": jax.ShapeDtypeStruct((1000, 4096), jnp.float32),
            # all dims occupied or too small -> untouched
            "w_small": jax.ShapeDtypeStruct((256, 256), jnp.float32),
            # spec is None -> treated as fully replicated, still sharded
            "w_none": jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
        }
        specs = {"w_odd": (None, None), "w_small": (None, None), "w_none": None}
        out = sh.fsdp_specs(specs, shapes)
    assert out["w_odd"] == (None, "fsdp")
    assert out["w_small"] == (None, None)
    assert out["w_none"] == ("fsdp", None)


def test_div_axis_guards_divisibility():
    mesh = _mesh()
    with sh.use_mesh(mesh):
        assert sh.div_axis("heads", 32) in ("heads", None)
        # axis size 1 -> always None
        assert sh.mesh_axis_size("heads") == 1


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert sh.shard(x, "batch", None) is x


# -- HLO analyzer --------------------------------------------------------------


def test_hlo_flops_scan_vs_unroll():
    x = jnp.ones((128, 128), jnp.float32)
    w = jnp.ones((128, 128), jnp.float32)

    def f_scan(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=16)[0]

    def f_unroll(x, w):
        for _ in range(16):
            x = x @ w
        return x

    fs = analyze_hlo_text(jax.jit(f_scan).lower(x, w).compile().as_text())
    fu = analyze_hlo_text(jax.jit(f_unroll).lower(x, w).compile().as_text())
    want = 2 * 16 * 128**3
    assert abs(fs["flops_per_device"] - want) / want < 0.05
    assert abs(fu["flops_per_device"] - want) / want < 0.05


def test_hlo_matches_xla_on_plain_matmul():
    a = jnp.ones((256, 512), jnp.bfloat16)
    b = jnp.ones((512, 1024), jnp.bfloat16)
    comp = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    mine = analyze_hlo_text(comp.as_text())
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert abs(mine["flops_per_device"] - ca["flops"]) / ca["flops"] < 0.02


def test_hlo_nested_scan_trip_counts():
    x = jnp.ones((64, 64), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    r = analyze_hlo_text(jax.jit(f).lower(x).compile().as_text())
    want = 2 * 15 * 64**3
    assert abs(r["flops_per_device"] - want) / want < 0.1


def test_cells_input_specs_have_shardings():
    from repro.configs import get_smoke_config
    from repro.launch import cells
    mesh = _mesh()
    cfg = get_smoke_config("granite-34b")
    fn, args, donate = cells.build_cell(cfg, "train_4k", mesh)
    leaves = jax.tree.leaves(args)
    assert all(hasattr(l, "sharding") and l.sharding is not None for l in leaves)
    assert donate == (0, 1)

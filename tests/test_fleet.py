"""Two-level autoscaling: node-fleet lifecycle, cost model, oracle/simjax
parity, control-plane capacity capping, and the vmapped parameter sweep."""

import math

import numpy as np
import pytest

from repro.core.cluster import DRAINING, GONE, PROVISIONING, UP, Cluster
from repro.core.control_plane import ControlPlane, SimWorkerBackend
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy
from repro.core.simjax import JaxFleet, JaxPolicy, simulate, summarize
from repro.core.trace import TraceConfig, synthesize
from repro.fleet import (FleetManager, NodeFleet, NodeType,
                         ScheduleFleetPolicy, ThresholdFleetPolicy,
                         UtilizationFleetPolicy, cost_from_sim, cost_report)
from repro.fleet.sweep import sweep
from repro.opt.frontier import pareto_front
from repro.opt.space import grid_points
from repro.serving.engine import ServeRequest

TC = TraceConfig(num_functions=60, duration_s=900, target_total_rps=10, seed=3)
NODE_MB = 8192.0
NT = NodeType(memory_mb=NODE_MB, provision_s=60.0, price_per_hour=1.0)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


def _fleet(min_nodes=1, max_nodes=64, util_target=0.7, warm_frac=0.25,
           cooldown_s=120.0):
    return NodeFleet(UtilizationFleetPolicy(min_nodes=min_nodes,
                                            max_nodes=max_nodes,
                                            util_target=util_target,
                                            warm_frac=warm_frac),
                     node_type=NT, cooldown_s=cooldown_s)


def _run(trace, policy_factory, fleet, initial_nodes=1):
    sim = EventSim(trace, Cluster(initial_nodes, node_memory_mb=NODE_MB),
                   policy_factory, SimConfig(), fleet=fleet)
    return sim.run()


# ---------------------------------------------------------------------------
# fleet policies
# ---------------------------------------------------------------------------


def test_warm_pool_sizing():
    p = UtilizationFleetPolicy(util_target=0.5, warm_frac=0.5, min_nodes=1,
                               max_nodes=100)
    # 10 nodes' worth of used memory at target 0.5 -> 20 needed, +50% warm
    assert p.desired(0.0, 10 * NODE_MB, NODE_MB, 5) == 30
    # warm pool never drops below one spare node when anything runs
    assert p.desired(0.0, 0.4 * NODE_MB, NODE_MB, 1) == 2
    # clamped at both ends
    assert p.desired(0.0, 0.0, NODE_MB, 0) == 1
    assert p.desired(0.0, 1000 * NODE_MB, NODE_MB, 5) == 100


def test_threshold_policy_cooldown_gates_repeat_fire():
    p = ThresholdFleetPolicy(high=0.8, low=0.3, change=2, cooldown_s=100,
                             min_nodes=1, max_nodes=10)
    assert p.desired(0.0, 9 * NODE_MB, NODE_MB, 10) == 10  # clamped, fired
    p2 = ThresholdFleetPolicy(high=0.8, low=0.3, change=2, cooldown_s=100,
                              min_nodes=1, max_nodes=20)
    assert p2.desired(0.0, 9 * NODE_MB, NODE_MB, 10) == 12
    # within cooldown: hold
    assert p2.desired(50.0, 9 * NODE_MB, NODE_MB, 12) == 12
    # after cooldown, low watermark scales down
    assert p2.desired(200.0, 1 * NODE_MB, NODE_MB, 12) == 10


def test_schedule_policy_piecewise_and_usage_floor():
    p = ScheduleFleetPolicy(entries=((0.0, 2), (600.0, 8), (1200.0, 3)),
                            min_nodes=1, max_nodes=16)
    assert p.desired(10.0, 0.0, NODE_MB, 2) == 2
    assert p.desired(700.0, 0.0, NODE_MB, 2) == 8
    assert p.desired(1500.0, 0.0, NODE_MB, 8) == 3
    # never below what usage occupies
    assert p.desired(1500.0, 6 * NODE_MB, NODE_MB, 8) == 6


# ---------------------------------------------------------------------------
# oracle: lifecycle behaviour
# ---------------------------------------------------------------------------


def test_fleet_scales_with_load_and_bills(trace):
    fleet = _fleet()
    res = _run(trace, lambda f: AsyncConcurrencyPolicy(window_s=60, target=0.7),
               fleet)
    m = compute(res)
    assert res.dropped == 0
    assert m.completed > 0
    assert m.node_provisions > 0            # grew beyond the single seed node
    assert m.nodes_mean > 1.0
    assert res.node_seconds > 0.0
    assert math.isclose(res.node_seconds,
                        res.node_samples.sum() * SimConfig().tick_s)


@pytest.mark.slow
def test_placement_failure_triggers_scale_up_not_drop(trace):
    # tiny max so the fleet saturates: requests must queue, never drop
    small = _fleet(max_nodes=2)
    res = _run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=600), small)
    assert res.dropped == 0
    # same trace WITHOUT a fleet on the same tiny cluster drops creations
    static = EventSim(synthesize(TC), Cluster(2, node_memory_mb=NODE_MB),
                      lambda f: SyncKeepalivePolicy(keepalive_s=600),
                      SimConfig()).run()
    assert static.dropped > 0


def test_drain_before_terminate():
    """A draining node lets in-flight work finish before termination."""
    cluster = Cluster(2, node_memory_mb=NODE_MB)
    fleet = _fleet(cooldown_s=0.0)
    node = cluster.nodes[0]
    node.used_mb = 100.0                      # a busy instance lives here
    cluster.start_drain(node)
    assert node.state == DRAINING
    assert not node.fits(10.0)                # no new placements while draining
    assert fleet.maybe_reclaim(cluster) == [] # still occupied: not reclaimed
    assert node.state == DRAINING
    cluster.release(node, 100.0)              # in-flight work finishes
    assert fleet.maybe_reclaim(cluster) == [node]
    assert node.state == GONE and not node.alive
    assert fleet.terminations == 1


@pytest.mark.slow
def test_scale_down_is_cooldown_gated(trace):
    fast = _run(trace, lambda f: AsyncConcurrencyPolicy(window_s=30, target=0.7),
                _fleet(cooldown_s=10.0))
    slow = _run(trace, lambda f: AsyncConcurrencyPolicy(window_s=30, target=0.7),
                _fleet(cooldown_s=600.0))
    # a long cooldown holds surplus nodes longer -> more billed node-time
    assert slow.node_seconds >= fast.node_seconds
    assert slow.node_terminations <= fast.node_terminations


def test_fleet_events_preserve_request_completion(trace):
    res = _run(trace, lambda f: AsyncConcurrencyPolicy(window_s=60, target=0.7),
               _fleet(cooldown_s=60.0))
    m = compute(res)
    base = compute(EventSim(synthesize(TC), Cluster(8), lambda f:
                            AsyncConcurrencyPolicy(window_s=60, target=0.7),
                            SimConfig()).run())
    # elasticity must not lose requests vs the static-cluster run
    assert m.completed >= base.completed * 0.98
    assert np.isfinite(m.slowdown_geomean_p99)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_cost_report_components_add_up():
    r = cost_report(node_seconds=7200.0, cpu_worker_overhead_s=3600.0,
                    cpu_master_overhead_s=1800.0, idle_node_share=0.5,
                    completed=1_000_000, node_type=NT)
    assert r.node_hours == pytest.approx(2.0)
    assert r.node_cost == pytest.approx(2.0 * NT.price_per_hour)
    assert r.total_cost == pytest.approx(r.node_cost + r.master_cost)
    assert r.cost_per_million == pytest.approx(r.total_cost)
    assert 0.0 < r.churn_cost < r.node_cost
    assert r.idle_cost == pytest.approx(0.5 * r.node_cost)


def test_longer_keepalive_costs_more_dollars(trace):
    cheap = cost_from_sim(_run(trace, lambda f: SyncKeepalivePolicy(30), _fleet()),
                          node_type=NT)
    warm = cost_from_sim(_run(trace, lambda f: SyncKeepalivePolicy(900), _fleet()),
                         node_type=NT)
    # keeping warm holds more nodes -> a bigger bill (the paper's trade-off
    # in dollars), and more of that bill is idle-attributed
    assert warm.node_hours > cheap.node_hours
    assert warm.total_cost > cheap.total_cost
    assert warm.idle_cost > cheap.idle_cost


# ---------------------------------------------------------------------------
# oracle vs vectorized simulator parity (acceptance criterion)
# ---------------------------------------------------------------------------


def test_fleet_parity_oracle_vs_simjax(trace):
    """EventSim and the lax.scan simulator agree on node counts and $-cost
    within 15% when the fleet layer is enabled (async reconciler: identical
    policy math on both sides)."""
    fleet = _fleet()
    res = _run(trace, lambda f: AsyncConcurrencyPolicy(window_s=60, target=0.7),
               fleet)
    m = compute(res)
    oracle_cost = cost_from_sim(res, node_type=NT)

    jf = JaxFleet(node_memory_mb=NODE_MB, provision_s=NT.provision_s,
                  min_nodes=1, max_nodes=64, util_target=0.7, warm_frac=0.25,
                  cooldown_s=120.0)
    jres = simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7), fleet=jf)
    s = summarize(jres)
    fluid_cost = cost_report(
        node_seconds=s["node_seconds"], cpu_worker_overhead_s=s["cpu_worker_s"],
        cpu_master_overhead_s=s["cpu_master_s"], idle_node_share=0.0,
        completed=int(s["completed"]), node_type=NT)

    assert m.nodes_mean == pytest.approx(s["nodes_mean"], rel=0.15)
    assert res.node_seconds == pytest.approx(s["node_seconds"], rel=0.15)
    assert oracle_cost.total_cost == pytest.approx(fluid_cost.total_cost, rel=0.15)
    assert oracle_cost.cost_per_million == pytest.approx(
        fluid_cost.cost_per_million, rel=0.15)


def test_simjax_fleet_capacity_caps_instances(trace):
    tight = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7),
                               fleet=JaxFleet(node_memory_mb=NODE_MB,
                                              min_nodes=1, max_nodes=2)))
    roomy = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7),
                               fleet=JaxFleet(node_memory_mb=NODE_MB,
                                              min_nodes=1, max_nodes=64)))
    assert tight["nodes_mean"] <= 2.0 + 1e-6
    assert roomy["nodes_mean"] > tight["nodes_mean"]
    # capacity starvation must surface as queueing delay, not lost load
    assert tight["slowdown_geomean_p99"] >= roomy["slowdown_geomean_p99"]


def test_simjax_warm_frac_adds_nodes(trace):
    lean = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7),
                              fleet=JaxFleet(node_memory_mb=NODE_MB, warm_frac=0.0)))
    padded = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7),
                                fleet=JaxFleet(node_memory_mb=NODE_MB, warm_frac=1.0)))
    assert padded["nodes_mean"] > lean["nodes_mean"]
    assert padded["node_seconds"] > lean["node_seconds"]


# ---------------------------------------------------------------------------
# vmapped parameter sweep
# ---------------------------------------------------------------------------


def test_sweep_grid_and_rows(trace):
    rows = sweep(trace, JaxPolicy(kind=0, keepalive_s=120),
                 JaxFleet(node_memory_mb=NODE_MB),
                 grid={"keepalive_s": [30.0, 600.0],
                       "warm_frac": [0.0, 0.5]},
                 node_type=NT)
    assert len(rows) == 4
    for r in rows:
        assert {"keepalive_s", "warm_frac", "nodes_mean",
                "cost_per_million", "slowdown_geomean_p99"} <= set(r)
        assert r["cost_per_million"] > 0
    by = {(r["keepalive_s"], r["warm_frac"]): r for r in rows}
    # a warm pool costs money; a long keepalive holds more instance memory
    assert by[(30.0, 0.5)]["nodes_mean"] > by[(30.0, 0.0)]["nodes_mean"]
    assert by[(600.0, 0.0)]["normalized_memory"] > by[(30.0, 0.0)]["normalized_memory"]


def test_sweep_matches_single_runs(trace):
    jf = JaxFleet(node_memory_mb=NODE_MB)
    rows = sweep(trace, JaxPolicy(kind=0, keepalive_s=120), jf,
                 grid={"keepalive_s": [60.0, 300.0]}, node_type=NT)
    for row in rows:
        single = summarize(simulate(
            trace, JaxPolicy(kind=0, keepalive_s=row["keepalive_s"]), fleet=jf))
        assert row["nodes_mean"] == pytest.approx(single["nodes_mean"], rel=1e-4)
        assert row["instances_mean"] == pytest.approx(
            single["instances_mean"], rel=1e-4)


def test_sweep_rejects_unknown_params(trace):
    with pytest.raises(ValueError):
        sweep(trace, JaxPolicy(kind=0), JaxFleet(), grid={"bogus": [1.0]})


def test_pareto_front_is_non_dominated():
    rows = [{"cost_per_million": c, "slowdown_geomean_p99": s}
            for c, s in [(1, 5), (2, 3), (3, 4), (4, 1), (5, 2)]]
    front = pareto_front(rows)
    assert [(r["cost_per_million"], r["slowdown_geomean_p99"]) for r in front] \
        == [(1, 5), (2, 3), (4, 1)]
    assert grid_points({"a": [1, 2], "b": [3]}) == [
        {"a": 1, "b": 3}, {"a": 2, "b": 3}]


# ---------------------------------------------------------------------------
# real control plane: FleetManager caps live instances
# ---------------------------------------------------------------------------


def test_fleet_manager_caps_and_scales_control_plane():
    backend = SimWorkerBackend(cold_start_s=0.2, default_service_s=2.0)
    fm = FleetManager(UtilizationFleetPolicy(min_nodes=1, max_nodes=4,
                                             util_target=0.7, warm_frac=0.0),
                      node_type=NodeType(provision_s=1.0),
                      instances_per_node=2, cooldown_s=30.0, initial_nodes=1)
    cp = ControlPlane(backend, lambda f: SyncKeepalivePolicy(keepalive_s=600),
                      num_functions=8, fleet=fm)
    # burst of 8 functions -> 8 creates wanted, capacity is 2 instances
    for fn in range(8):
        cp.submit(ServeRequest(rid=fn, fn=fn, prompt=[1], max_new_tokens=1,
                               arrival_t=0.0), 0.0)
    assert len(cp.instances) <= fm.capacity()
    assert cp.snapshot()["deferred_creates"] > 0
    # ticks advance the clock: fleet scales up, deferred creates land
    t = 0.0
    while len(cp.completed) < 8 and t < 60.0:
        t += 0.5
        cp.tick(t)
    assert len(cp.completed) == 8           # nothing dropped, all served
    assert fm.nodes_up > 1                  # placement pressure scaled nodes up
    assert fm.provisions > 0
    assert fm.node_seconds > 0.0
    snap = cp.snapshot()["fleet"]
    assert snap["capacity_instances"] == fm.nodes_up * 2


def test_fleet_manager_scales_down_after_cooldown():
    fm = FleetManager(UtilizationFleetPolicy(min_nodes=1, max_nodes=8,
                                             util_target=0.7, warm_frac=0.0),
                      node_type=NodeType(provision_s=0.5),
                      instances_per_node=2, cooldown_s=5.0, initial_nodes=6)
    fm.tick(0.0, live_instances=12)
    assert fm.nodes_total >= 6              # fully loaded: holds
    fm.tick(1.0, live_instances=0)          # load vanished
    down_to = fm.nodes_total
    assert down_to < 6
    fm.tick(2.0, live_instances=0)          # within cooldown: no further drop
    assert fm.nodes_total == down_to
    fm.tick(10.0, live_instances=0)         # cooldown elapsed
    assert fm.nodes_total <= down_to
    assert fm.nodes_total >= 1              # never below min_nodes

"""Serving engine + real control plane integration (real JAX replicas)."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.control_plane import (ControlPlane, JaxWorkerBackend,
                                      SimWorkerBackend)
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy
from repro.serving.engine import ModelReplica, ServeRequest

CFG = get_smoke_config("gemma3-4b").replace(param_dtype="bfloat16", remat="none")


@pytest.fixture(scope="module")
def replica():
    return ModelReplica(CFG, max_slots=2, max_seq=48)


def test_replica_cold_start_measured(replica):
    assert replica.cold_start_s > 0.01
    assert replica.memory_bytes() > 0


def test_replica_continuous_batching(replica):
    r1 = ServeRequest(rid=1, fn=0, prompt=[1, 2, 3], max_new_tokens=4)
    r2 = ServeRequest(rid=2, fn=0, prompt=[4, 5], max_new_tokens=6)
    assert replica.add(r1, 0.0) and replica.add(r2, 0.0)
    assert replica.free_slots == 0
    done = []
    for t in range(40):
        done += replica.step(float(t))
        if len(done) == 2:
            break
    assert {r.rid for r in done} == {1, 2}
    assert len(r1.output) == 4 and len(r2.output) == 6
    assert replica.free_slots == 2


def test_replica_greedy_decode_deterministic():
    rep1 = ModelReplica(CFG, max_slots=1, max_seq=32, seed=7)
    rep2 = ModelReplica(CFG, max_slots=1, max_seq=32, seed=7)
    outs = []
    for rep in (rep1, rep2):
        r = ServeRequest(rid=0, fn=0, prompt=[3, 1, 4], max_new_tokens=8)
        rep.add(r, 0.0)
        done = []
        for t in range(30):
            done += rep.step(float(t))
            if done:
                break
        outs.append(done[0].output)
    assert outs[0] == outs[1]


def test_control_plane_sim_backend_virtual_clock():
    backend = SimWorkerBackend(cold_start_s=1.0, default_service_s=0.3)
    cp = ControlPlane(backend, lambda f: SyncKeepalivePolicy(
        keepalive_s=5.0, container_concurrency=1), num_functions=1)
    # request at t=0 -> cold start; completion by ~1.3s
    cp.submit(ServeRequest(rid=0, fn=0, prompt=[], arrival_t=0.0), 0.0)
    t = 0.0
    while len(cp.completed) < 1 and t < 10:
        t += 0.1
        cp.tick(t)
    assert len(cp.completed) == 1
    assert backend.creations == 1
    # warm hit: second request needs no new instance
    cp.submit(ServeRequest(rid=1, fn=0, prompt=[], arrival_t=t), t)
    while len(cp.completed) < 2 and t < 20:
        t += 0.1
        cp.tick(t)
    assert backend.creations == 1
    # keepalive expiry tears it down
    for _ in range(80):
        t += 0.1
        cp.tick(t)
    assert backend.teardowns == 1
    assert cp.snapshot()["instances"] == 0


def test_control_plane_async_scales_up_and_down():
    backend = SimWorkerBackend(cold_start_s=0.5, default_service_s=1.0)
    cp = ControlPlane(backend, lambda f: AsyncConcurrencyPolicy(
        window_s=4.0, target=0.5, tick_s=0.5), num_functions=1)
    t = 0.0
    for i in range(8):   # burst of 8 concurrent requests
        cp.submit(ServeRequest(rid=i, fn=0, prompt=[], arrival_t=t), t)
    for _ in range(40):
        t += 0.25
        cp.tick(t)
    assert len(cp.completed) == 8
    assert backend.creations >= 2   # scaled out for the burst
    for _ in range(200):
        t += 0.25
        cp.tick(t)
    assert cp.snapshot()["instances"] == 0   # scaled back to zero


@pytest.mark.slow
def test_control_plane_with_real_jax_replicas():
    backend = JaxWorkerBackend(CFG, max_slots=2, max_seq=48)
    cp = ControlPlane(backend, lambda f: SyncKeepalivePolicy(
        keepalive_s=60.0, container_concurrency=2), num_functions=1)
    t0 = time.monotonic()
    now = lambda: time.monotonic() - t0
    for i in range(3):
        cp.submit(ServeRequest(rid=i, fn=0, prompt=[1, 2], max_new_tokens=3,
                               arrival_t=now()), now())
    deadline = time.monotonic() + 120
    while len(cp.completed) < 3 and time.monotonic() < deadline:
        cp.tick(now())
    assert len(cp.completed) == 3
    assert all(len(r.output) == 3 for r in cp.completed)
    assert backend.cold_start_times[0] > 0.01

"""Spot-fleet subsystem: capacity tiers, the seeded hazard market, oracle
eviction mechanics, per-tier billing, zero-hazard regression (bit-for-bit),
oracle-vs-simjax spot parity, and the fig12 savings claim."""

import math

import pytest

from repro.core.runspec import RunSpec
from repro.core.cluster import GONE, Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import SpotAwarePolicy, SyncKeepalivePolicy
from repro.core.simjax import JaxFleet, JaxPolicy, simulate, summarize
from repro.core.trace import TraceConfig, synthesize
from repro.fleet import (NodeFleet, NodeType, PriceBook,
                         UtilizationFleetPolicy, cost_from_sim, cost_report)
from repro.fleet.spot import (SPOT_DEFAULT, CapacityTier, SpotMarket,
                              SpotNodeFleet, get_tier, list_tiers)

TC = TraceConfig(num_functions=60, duration_s=900, target_total_rps=10, seed=3)
NODE_MB = 8192.0
NT = NodeType(memory_mb=NODE_MB, provision_s=60.0, price_per_hour=1.0)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


def _policy(min_nodes=1, max_nodes=64):
    return UtilizationFleetPolicy(min_nodes=min_nodes, max_nodes=max_nodes,
                                  util_target=0.7, warm_frac=0.25)


def _spot_fleet(spot_fraction=0.6, hazard=8.0, notice=120.0, seed=0,
                **kw):
    tier = CapacityTier("spot", hazard_per_hour=hazard,
                        reclaim_notice_s=notice)
    return SpotNodeFleet(_policy(**kw), node_type=NT, cooldown_s=120.0,
                         spot_fraction=spot_fraction,
                         market=SpotMarket(tier, seed=seed))


def _run(trace, fleet, policy_factory=None):
    factory = policy_factory or (lambda f: SpotAwarePolicy(
        keepalive_s=600, spot_fraction=fleet.spot_fraction
        if isinstance(fleet, SpotNodeFleet) else 0.0,
        hazard_per_hour=fleet.market.tier.hazard_per_hour
        if isinstance(fleet, SpotNodeFleet) else 0.0))
    return EventSim(trace, Cluster(1, node_memory_mb=NODE_MB), factory,
                    SimConfig(), fleet=fleet).run()


# ---------------------------------------------------------------------------
# tier registry
# ---------------------------------------------------------------------------


def test_tier_registry_and_friendly_lookup():
    assert {"on_demand", "spot"} <= set(list_tiers())
    assert get_tier("on_demand").hazard_per_hour == 0.0
    assert get_tier("spot").price_multiplier < 1.0
    assert get_tier("spot").discount == pytest.approx(
        1.0 - SPOT_DEFAULT.price_multiplier)
    with pytest.raises(KeyError, match="registered"):
        get_tier("preemptible-gpu")


# ---------------------------------------------------------------------------
# seeded hazard sampler (determinism property)
# ---------------------------------------------------------------------------


def test_market_seeded_determinism_and_rate():
    tier = CapacityTier("t", hazard_per_hour=120.0, reclaim_notice_s=60.0)
    nodes = list(range(40))

    def schedule(seed):
        mkt = SpotMarket(tier, seed=seed)
        out = []
        for t in range(0, 600, 2):
            out.append(tuple(mkt.preempted(float(t), nodes)))
        return out

    assert schedule(7) == schedule(7)          # identical seed -> identical
    assert schedule(7) != schedule(8)          # schedule; seeds decorrelate
    # frequency matches the hazard: p = 1 - exp(-h * dt) per node per poll
    draws = sum(len(s) for s in schedule(7))
    polls = 299 * len(nodes)                   # first poll covers dt=0
    p = -math.expm1(-120.0 / 3600.0 * 2.0)
    assert draws / polls == pytest.approx(p, rel=0.25)


def test_market_first_poll_and_zero_hazard_draw_nothing():
    mkt = SpotMarket(CapacityTier("t", hazard_per_hour=1e6), seed=0)
    assert mkt.preempted(0.0, list(range(10))) == []      # dt=0 interval
    calm = SpotMarket(CapacityTier("c", hazard_per_hour=0.0), seed=0)
    calm.preempted(0.0, list(range(10)))
    assert calm.preempted(100.0, list(range(10))) == []


# ---------------------------------------------------------------------------
# oracle eviction mechanics
# ---------------------------------------------------------------------------


def test_oracle_spot_fleet_evicts_and_completes(trace):
    fleet = _spot_fleet(spot_fraction=0.6, hazard=20.0)
    cluster = Cluster(1, node_memory_mb=NODE_MB)
    res = EventSim(trace, cluster,
                   lambda f: SpotAwarePolicy(keepalive_s=600,
                                             spot_fraction=0.6,
                                             hazard_per_hour=20.0),
                   SimConfig(), fleet=fleet).run()
    m = compute(res)
    assert res.dropped == 0                    # storms queue, never drop
    assert m.node_evictions > 0
    assert res.spot_node_seconds > 0.0
    assert res.spot_node_seconds < res.node_seconds
    # only spot nodes are ever preempted, and preempted nodes stay gone
    reclaimed = [n for n in cluster.nodes if n.state == GONE]
    assert any(n.spot for n in reclaimed)
    assert m.completed > 0


def test_eviction_kills_warm_and_requeues_in_flight():
    """A short reclaim notice on a long-running function forces in-flight
    work to re-queue at the deadline (the storm's worst case)."""
    tc = TraceConfig(num_functions=4, duration_s=600, target_total_rps=2.0,
                     seed=5, dur_median_s=10.0, dur_sigma=0.1)
    trace = synthesize(tc)
    fleet = _spot_fleet(spot_fraction=1.0, hazard=60.0, notice=1.0)
    res = _run(trace, fleet,
               policy_factory=lambda f: SyncKeepalivePolicy(keepalive_s=600))
    assert compute(res).node_evictions > 0
    assert sum(r.requeued for r in res.records) > 0
    assert res.dropped == 0


def test_tier_split_tracks_spot_fraction(trace):
    fleet = _spot_fleet(spot_fraction=0.5, hazard=0.0)
    res = _run(trace, fleet)
    # a hazardless spot tier still bills its share: ~half the node-seconds
    share = res.spot_node_seconds / res.node_seconds
    assert 0.2 < share < 0.8
    assert compute(res).node_evictions == 0


# ---------------------------------------------------------------------------
# zero-hazard regression: spot machinery at zero == the plain fleet
# ---------------------------------------------------------------------------


def test_zero_spot_oracle_bit_for_bit(trace):
    plain = _run(trace, NodeFleet(_policy(), node_type=NT, cooldown_s=120.0),
                 policy_factory=lambda f: SyncKeepalivePolicy(keepalive_s=600))
    spot0 = _run(trace, _spot_fleet(spot_fraction=0.0, hazard=0.0),
                 policy_factory=lambda f: SyncKeepalivePolicy(keepalive_s=600))
    assert plain.creations == spot0.creations
    assert plain.teardowns == spot0.teardowns
    assert plain.node_seconds == spot0.node_seconds
    assert len(plain.records) == len(spot0.records)
    for a, b in zip(plain.records, spot0.records):
        assert a.start == b.start and a.end == b.end
    assert spot0.spot_node_seconds == 0.0 and spot0.node_evictions == 0


def test_zero_spot_simjax_bit_for_bit(trace):
    jf = JaxFleet(node_memory_mb=NODE_MB)
    sync = summarize(simulate(trace, JaxPolicy(family="sync",
                                               keepalive_s=600), fleet=jf))
    spot0 = summarize(simulate(
        trace, JaxPolicy(family="spot_aware", keepalive_s=600,
                         extra={"spot_fraction": 0.0,
                                "hazard_per_hour": 0.0}), fleet=jf))
    for k in sync:
        assert sync[k] == spot0[k], k
    assert spot0["spot_nodes_mean"] == 0.0


def test_simjax_hazard_causes_storm(trace):
    """The traced eviction flux produces the storm signature: more
    creations, worse tail, a billed spot share."""
    jf = JaxFleet(node_memory_mb=NODE_MB)
    base = summarize(simulate(
        trace, JaxPolicy(family="spot_aware", keepalive_s=600,
                         extra={"spot_fraction": 0.6,
                                "hazard_per_hour": 0.0}), fleet=jf))
    storm = summarize(simulate(
        trace, JaxPolicy(family="spot_aware", keepalive_s=600,
                         extra={"spot_fraction": 0.6,
                                "hazard_per_hour": 20.0}), fleet=jf))
    assert storm["creation_rate"] > base["creation_rate"]
    assert storm["slowdown_geomean_p99"] >= base["slowdown_geomean_p99"]
    assert storm["spot_nodes_mean"] > 0.0
    assert storm["spot_node_seconds"] < storm["node_seconds"]


# ---------------------------------------------------------------------------
# per-tier billing
# ---------------------------------------------------------------------------


def test_cost_report_bills_tiers_separately():
    full = cost_report(node_seconds=7200.0, spot_node_seconds=3600.0,
                       cpu_worker_overhead_s=0.0, cpu_master_overhead_s=0.0,
                       idle_node_share=0.0, completed=1_000_000,
                       node_type=NT, prices=PriceBook(spot_discount=0.65))
    # 1h on-demand at 1.0 + 1h spot at 0.35
    assert full.node_cost == pytest.approx(1.0 + 0.35)
    # the discount must NOT apply fleet-wide
    fleetwide = 2.0 * (1.0 - 0.65)
    assert full.node_cost != pytest.approx(fleetwide)
    # no spot seconds -> discount changes nothing
    od = cost_report(node_seconds=7200.0, cpu_worker_overhead_s=0.0,
                     cpu_master_overhead_s=0.0, idle_node_share=0.0,
                     completed=1, node_type=NT,
                     prices=PriceBook(spot_discount=0.65))
    assert od.node_cost == pytest.approx(2.0)


def test_cost_from_sim_uses_metered_spot_seconds(trace):
    res = _run(trace, _spot_fleet(spot_fraction=0.6, hazard=0.0))
    discounted = cost_from_sim(res, node_type=NT,
                               prices=PriceBook(spot_discount=0.65))
    od_priced = cost_from_sim(res, node_type=NT, prices=PriceBook())
    saved = od_priced.node_cost - discounted.node_cost
    expect = res.spot_node_seconds / 3600.0 * NT.price_per_hour * 0.65
    assert saved == pytest.approx(expect, rel=1e-6)


# ---------------------------------------------------------------------------
# oracle vs simjax spot parity (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_spot_storm_parity_oracle_vs_simjax():
    """spot_storm at 0.25x: the fluid hazard/eviction flux holds the <=15%
    band on slowdown / memory / creation against the seed-AVERAGED oracle
    (the fluid is the hazard process's expectation, so parity is judged
    against the oracle's mean, not one Poisson realization)."""
    from repro.scenarios.runner import run_scenario
    sc = "spot_storm"
    fluid = run_scenario(sc, spec=RunSpec(engines=("simjax",),
                                          scale=0.25))[0]
    keys = ("slowdown_geomean_p99", "normalized_memory", "creation_rate")
    acc = {k: 0.0 for k in keys}
    seeds = (0, 1, 2)
    evictions = 0
    for seed in seeds:
        row = run_scenario(sc, sim=SimConfig(tick_s=1.0, seed=seed),
                           spec=RunSpec(engines=("eventsim",),
                                        scale=0.25))[0]
        evictions += row["node_evictions"]
        for k in keys:
            acc[k] += row[k] / len(seeds)
    assert evictions > 0                       # the storm actually storms
    for k in keys:
        gap = abs(acc[k] - fluid[k]) / abs(acc[k])
        assert gap <= 0.15, (k, gap, acc[k], fluid[k])


@pytest.mark.slow
def test_fig12_spot_beats_on_demand_oracle_confirmed():
    """Acceptance: the frontier finds a spot configuration strictly cheaper
    than the best all-on-demand point at equal-or-better p99, and the
    oracle confirms it (parity band + a strictly cheaper oracle bill)."""
    from benchmarks.fig12_spot_frontier import run
    rows, naive, winner, best_od, check = run()
    assert winner is not None
    assert winner["cost_per_million"] < best_od["cost_per_million"]
    assert winner["slowdown_geomean_p99"] <= best_od["slowdown_geomean_p99"]
    assert check["parity_ok"], check["gaps"]
    assert check["oracle_cheaper"], check


# ---------------------------------------------------------------------------
# CLI surfaces
# ---------------------------------------------------------------------------


def test_scenarios_cli_rejects_unknown_tier(capsys):
    from repro.launch.scenarios import main
    rc = main(["--scenario", "cold_tail", "--tier", "bogus-tier"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "unknown capacity tier" in err and "on_demand" in err


def test_cli_lists_include_spot(capsys):
    from repro.launch import frontier, scenarios
    assert scenarios.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "spot_storm" in out and "capacity tiers" in out
    assert frontier.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "spot_storm" in out
    assert "spot_fraction" in out and "hazard_per_hour" in out
    assert "reclaim_notice_s" in out


# ---------------------------------------------------------------------------
# spot_aware family registration
# ---------------------------------------------------------------------------


def test_spot_aware_family_axes_and_space():
    from repro.core.policy_api import get_family
    from repro.opt.space import DEFAULT_SPACE, active_knobs, sweepable_knobs
    fam = get_family("spot_aware")
    assert {"keepalive_s", "cc", "spot_fraction",
            "hazard_per_hour"} == set(fam.axis_names())
    assert set(fam.sweepable_axes()) <= sweepable_knobs()
    assert "spot_fraction" in active_knobs("spot_aware")
    assert "spot_fraction" not in active_knobs("sync")
    assert "spot_fraction" in DEFAULT_SPACE.policy
    with pytest.raises(ValueError, match="bounds"):
        JaxPolicy(family="spot_aware", keepalive_s=600,
                  extra={"spot_fraction": 1.5, "hazard_per_hour": 0.0})


def test_spot_headroom_holds_extra_warm(trace):
    """Hazard-scaled headroom: the spot-aware policy holds more instances
    than plain sync under the same (hazardless) conditions when the
    declared hazard is large."""
    jf = JaxFleet(node_memory_mb=NODE_MB)
    lean = summarize(simulate(
        trace, JaxPolicy(family="spot_aware", keepalive_s=600,
                         extra={"spot_fraction": 0.0,
                                "hazard_per_hour": 0.0}), fleet=jf))
    padded = summarize(simulate(
        trace, JaxPolicy(family="spot_aware", keepalive_s=600,
                         extra={"spot_fraction": 1.0,
                                "hazard_per_hour": 60.0}), fleet=jf))
    assert padded["instances_mean"] > lean["instances_mean"]

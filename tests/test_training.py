"""Training substrate: optimizer, microbatching, checkpoint fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import registry
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, jax_batch_at
from repro.training.optimizer import AdamWConfig, adamw_init, clip_by_global_norm
from repro.training.train_step import TrainConfig, make_train_step

pytestmark = pytest.mark.slow

CFG = get_smoke_config("gemma3-4b")


def _setup(tcfg=None):
    params = registry.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(CFG, tcfg or TrainConfig()))
    dc = DataConfig(vocab_size=CFG.vocab_size, seq_len=64, global_batch=4)
    return params, opt, step, dc


def test_overfit_single_batch():
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=50))
    params, opt, step, dc = _setup(tcfg)
    batch = jax_batch_at(dc, 0)
    first = last = None
    for i in range(20):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_microbatch_matches_full_batch_grads():
    """n_microbatches=2 must produce (numerically) the same update."""
    tcfg1 = TrainConfig(n_microbatches=1)
    tcfg2 = TrainConfig(n_microbatches=2)
    params, opt, _, dc = _setup()
    batch = jax_batch_at(dc, 3)
    s1 = jax.jit(make_train_step(CFG, tcfg1))
    s2 = jax.jit(make_train_step(CFG, tcfg2))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    # losses may differ slightly (per-micro mask normalization); grads close
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
              for a, b in zip(l1, l2))
    assert err < 5e-4, err


def test_grad_clip():
    tree = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 1.0
    new_norm = jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(new_norm), 1.0, rtol=1e-5)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    params, opt, step, dc = _setup()
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, {"p": params, "o": opt}, extra={"note": "x"})
    out = ckpt.restore_latest(d, {"p": params, "o": opt})
    assert out is not None
    step_no, tree, extra = out
    assert step_no == 10 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree["p"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a corrupted (uncommitted) checkpoint is skipped
    os.makedirs(os.path.join(d, "step_00000020"))
    assert ckpt.latest_step(d) == 10


def test_checkpoint_keep_gc(tmp_path):
    params, opt, _, _ = _setup()
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, {"p": params}, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("5".zfill(8))


def test_restart_resumes_identically(tmp_path):
    """Crash/restart reproduces the uninterrupted run exactly."""
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=20))
    d = str(tmp_path / "ck")
    dc = DataConfig(vocab_size=CFG.vocab_size, seq_len=32, global_batch=2)
    step = jax.jit(make_train_step(CFG, tcfg))

    # uninterrupted run: 6 steps
    p, o = registry.init_params(CFG, jax.random.PRNGKey(0)), None
    o = adamw_init(p)
    losses_a = []
    for i in range(6):
        p, o, m = step(p, o, jax_batch_at(dc, i))
        losses_a.append(float(m["loss"]))

    # interrupted run: 3 steps, checkpoint, "crash", restore, 3 more
    p2 = registry.init_params(CFG, jax.random.PRNGKey(0))
    o2 = adamw_init(p2)
    for i in range(3):
        p2, o2, m = step(p2, o2, jax_batch_at(dc, i))
    ckpt.save(d, 3, {"p": p2, "o": o2})
    del p2, o2
    s, tree, _ = ckpt.restore_latest(d, {"p": p, "o": o})
    p3, o3 = tree["p"], tree["o"]
    losses_b = []
    for i in range(s, 6):
        p3, o3, m = step(p3, o3, jax_batch_at(dc, i))
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-5, atol=1e-5)

"""Planet-scale machinery: the device-sharded chunked scan (1-device mesh
must be BIT-FOR-BIT the unsharded dispatch), long-tail function clustering
(exact for identical members, ≤1% on the planet trace), the fig9_planet
registration, and the unified CLI flag surface across all three launchers.

The multi-device tests skip on a 1-device host; CI's sharded-smoke job runs
this file under XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import numpy as np
import pytest

from repro.core.runspec import RunSpec
from repro.core.simjax import JaxFleet, JaxPolicy, simulate_chunked
from repro.core.trace import (FunctionProfile, RateTrace, TraceConfig,
                              synthesize, synthesize_rates)
from repro.opt import evaluate_points
from repro.scenarios import get_scenario, list_scenarios, run_scenario
from repro.scenarios.cluster import cluster_functions

# 61 functions: prime, so every device count > 1 forces the padded path
TC = TraceConfig(num_functions=61, duration_s=900, target_total_rps=8, seed=7)

FLOAT_KEYS = ("slowdown_geomean_p99", "normalized_memory", "creation_rate",
              "cpu_overhead", "instances_mean", "nodes_mean", "completed")
# the headline metrics the clustering approximation is allowed to move ≤1%
PARITY_KEYS = ("slowdown_geomean_p99", "normalized_memory", "creation_rate",
               "cpu_overhead")


def _ndev():
    import jax
    return len(jax.devices())


multi_device = pytest.mark.skipif(
    "len(__import__('jax').devices()) < 2",
    reason="needs >1 local device (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


# ---------------------------------------------------------------------------
# sharded scan parity
# ---------------------------------------------------------------------------


def test_one_device_mesh_is_bitwise_identical(trace):
    pol = JaxPolicy(kind=0, keepalive_s=120)
    base = simulate_chunked(trace, pol, chunk_ticks=128, spec=RunSpec())
    shard = simulate_chunked(trace, pol, chunk_ticks=128,
                             spec=RunSpec(devices=1))
    for k in FLOAT_KEYS:
        assert base[k] == shard[k], k


def test_one_device_mesh_bitwise_with_rate_trace():
    rt = synthesize_rates(TC, tick_s=2.0)
    pol = JaxPolicy(kind=1, window_s=60, target=0.7)
    base = simulate_chunked(rt, pol, dt=2.0, chunk_ticks=128, spec=RunSpec())
    shard = simulate_chunked(rt, pol, dt=2.0, chunk_ticks=128,
                             spec=RunSpec(devices=1))
    for k in FLOAT_KEYS:
        assert base[k] == shard[k], k


@multi_device
def test_multi_device_mesh_matches_unsharded(trace):
    pol = JaxPolicy(kind=0, keepalive_s=120)
    base = simulate_chunked(trace, pol, chunk_ticks=128, spec=RunSpec())
    shard = simulate_chunked(trace, pol, chunk_ticks=128,
                             spec=RunSpec(devices=_ndev()))
    for k in PARITY_KEYS:
        # cross-device psum reassociates float32 sums; agreement is tight
        # but not bitwise
        assert base[k] == pytest.approx(shard[k], rel=1e-4), k


def test_point_axis_sharding_one_device(trace):
    jf = JaxFleet(node_memory_mb=8192.0)
    pts = [{"keepalive_s": float(ka)} for ka in (60.0, 300.0, 600.0)]
    base = evaluate_points(trace, JaxPolicy(kind=0), jf, pts)
    shard = evaluate_points(trace, JaxPolicy(kind=0), jf, pts, devices=1)
    for rb, rs in zip(base, shard):
        for k in PARITY_KEYS:
            assert rb[k] == rs[k], k


@multi_device
def test_point_axis_sharding_multi_device(trace):
    jf = JaxFleet(node_memory_mb=8192.0)
    pts = [{"keepalive_s": float(ka)}
           for ka in (60.0, 120.0, 300.0, 600.0)]
    base = evaluate_points(trace, JaxPolicy(kind=0), jf, pts)
    shard = evaluate_points(trace, JaxPolicy(kind=0), jf, pts,
                            devices=_ndev())
    for rb, rs in zip(base, shard):
        for k in PARITY_KEYS:
            assert rb[k] == pytest.approx(rs[k], rel=1e-4), k


# ---------------------------------------------------------------------------
# function clustering
# ---------------------------------------------------------------------------


def _duplicated_rate_trace(k: int = 7, base_fns: int = 5,
                           seed: int = 3) -> RateTrace:
    """k identical copies of each of base_fns cold functions: the clustering
    exactness premise made literal."""
    rng = np.random.default_rng(seed)
    t_ticks = 300
    cols = rng.poisson(0.4, size=(t_ticks, base_fns)).astype(np.float32)
    counts = np.repeat(cols, k, axis=1)
    n = base_fns * k
    prof = FunctionProfile(
        rate=np.repeat(cols.mean(axis=0), k),
        dur_median=np.repeat(np.linspace(0.2, 1.5, base_fns), k),
        dur_sigma=np.full(n, 0.5),
        memory_mb=np.repeat(np.array([128.0, 256.0, 128.0, 512.0, 256.0]
                                     [:base_fns]), k),
        phase=np.zeros(n))
    return RateTrace(counts, 2.0, prof, float(t_ticks * 2.0))


def test_cluster_identical_members_is_exact():
    rt = _duplicated_rate_trace(k=7, base_fns=5)
    ct = cluster_functions(rt, below_rps=10.0)
    assert ct.num_functions == 5
    assert np.allclose(np.sort(ct.weights), [7.0] * 5)
    pol = JaxPolicy(kind=0, keepalive_s=120)
    full = simulate_chunked(rt, pol, dt=2.0, chunk_ticks=64, spec=RunSpec())
    clus = simulate_chunked(ct, pol, dt=2.0, chunk_ticks=64, spec=RunSpec())
    for k in PARITY_KEYS:
        # identical members evolve identically; only float reassociation
        # (weighted sum vs k-term sum) separates the two runs
        assert full[k] == pytest.approx(clus[k], rel=1e-5), k


def test_cluster_keeps_hot_functions_exact():
    rt = synthesize_rates(TC, tick_s=2.0)
    rates = np.asarray(rt.counts, np.float64).mean(axis=0) / rt.tick_s
    thr = float(np.median(rates))
    ct = cluster_functions(rt, below_rps=thr)
    assert ct.num_functions <= rt.num_functions
    # hot functions keep weight 1; total weight conserves the population
    assert np.isclose(ct.weights.sum(), rt.num_functions)
    assert (ct.weights >= 1.0 - 1e-9).all()


@pytest.mark.slow
def test_planet_clustered_parity_within_1pct():
    plain = run_scenario("fig9_planet",
                         spec=RunSpec(engines=("simjax",), scale=0.02))[0]
    clus = run_scenario("fig9_planet",
                        spec=RunSpec(engines=("simjax",), scale=0.02,
                                     cluster=1.0))[0]
    for k in PARITY_KEYS:
        rel = abs(plain[k] - clus[k]) / max(abs(plain[k]), 1e-9)
        assert rel <= 0.01, (k, rel)


# ---------------------------------------------------------------------------
# fig9_planet registration
# ---------------------------------------------------------------------------


def test_fig9_planet_registered():
    assert "fig9_planet" in list_scenarios()
    sc = get_scenario("fig9_planet")
    assert sc.rate_trace and not sc.oracle_ok
    assert sc.base.num_functions == 100_000
    rt = sc.build_trace(scale=0.01)
    assert isinstance(rt, RateTrace)
    assert rt.num_functions == 1000


def test_rate_scenarios_drop_oracle_even_forced():
    rows = run_scenario("fig9_planet",
                        spec=RunSpec(scale=0.01, force_oracle=True))
    assert [r["engine"] for r in rows] == ["simjax"]


# ---------------------------------------------------------------------------
# unified CLI flag surface
# ---------------------------------------------------------------------------

SHARED_FLAGS = ("--scale", "--billing", "--tier", "--devices", "--cluster")


def _parsers():
    from repro.launch import frontier, scenarios, trace as trace_cli
    return {"scenarios": scenarios.build_parser(),
            "frontier": frontier.build_parser(),
            "trace": trace_cli.build_parser()}


def test_all_launchers_accept_shared_flags():
    for name, ap in _parsers().items():
        opts = {s for a in ap._actions for s in a.option_strings}
        for flag in SHARED_FLAGS:
            assert flag in opts, (name, flag)


def test_shared_flag_defaults_match_runspec():
    spec = RunSpec()
    # the trace CLI takes a required positional scenario
    argv = {"scenarios": [], "frontier": [], "trace": ["cold_tail"]}
    for name, ap in _parsers().items():
        ns = ap.parse_args(argv[name])
        assert ns.billing is None and ns.tier is None, name
        assert ns.devices == spec.devices, name
        assert ns.cluster == spec.cluster, name


def test_validate_run_flags_exit2(capsys):
    import argparse
    from repro.launch.flags import validate_run_flags
    ns = argparse.Namespace(billing="bogus", tier=None, devices=0,
                            cluster=0.0)
    assert validate_run_flags(ns) == 2
    assert "unknown billing profile" in capsys.readouterr().err
    ns = argparse.Namespace(billing=None, tier="bogus", devices=0,
                            cluster=0.0)
    assert validate_run_flags(ns) == 2
    assert "unknown capacity tier" in capsys.readouterr().err
    ns = argparse.Namespace(billing=None, tier=None, devices=4096,
                            cluster=0.0)
    assert validate_run_flags(ns) == 2
    assert "host_platform_device_count" in capsys.readouterr().err
    ns = argparse.Namespace(billing=None, tier=None, devices=0,
                            cluster=-1.0)
    assert validate_run_flags(ns) == 2


def test_validate_run_flags_ok():
    import argparse
    from repro.fleet.spot import list_tiers
    from repro.launch.flags import validate_run_flags
    ns = argparse.Namespace(billing="aws_lambda", tier=list_tiers()[0],
                            devices=0, cluster=0.5)
    assert validate_run_flags(ns) == 0


def test_unknown_scenarios_exit2(capsys):
    from repro.launch.flags import unknown_scenarios
    assert unknown_scenarios(["cold_tail"]) == 0
    assert unknown_scenarios(["cold_tail", "nope"]) == 2
    assert "unknown scenario" in capsys.readouterr().err


def test_runspec_threads_devices_through_runner(trace):
    # run_scenario(devices=1) must agree bitwise with the unsharded run
    base = run_scenario("cold_tail",
                        spec=RunSpec(engines=("simjax",), scale=0.05))
    shard = run_scenario("cold_tail",
                         spec=RunSpec(engines=("simjax",), scale=0.05,
                                      devices=1))
    for k in PARITY_KEYS:
        assert base[0][k] == shard[0][k], k

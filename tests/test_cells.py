"""Multi-region cells: router flux conservation, topology validation, the
desired-state convergence policy, trivial-topology equivalence, and the
oracle-vs-fluid parity band for the three Fig. 14 scenarios."""

import dataclasses
import math

import numpy as np
import pytest

from repro.cells import (CellTopology, ConvergenceFleetPolicy,
                         ReactiveTrigger, ScheduledTrigger, build_cell_traces)
from repro.cells.traffic import (failover_dist, failover_dist_np,
                                 flux_matrix, spill_fraction)
from repro.core.eventsim import SimConfig
from repro.core.runspec import RunSpec
from repro.core.simjax import simulate_chunked
from repro.core.trace import synthesize
from repro.scenarios import get_scenario, parity_report, run_scenario


# ---------------------------------------------------------------------------
# router flux: mass conservation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alive,spill,free", [
    ([1, 1, 1], [0.0, 0.0, 0.0], [4.0, 2.0, 1.0]),     # no spill
    ([1, 1, 1], [0.5, 1.0, 0.2], [4.0, 2.0, 1.0]),     # heavy spill
    ([1, 1, 1], [0.7, 0.7, 0.7], [0.0, 0.0, 0.0]),     # no free slots: home
    ([0, 1, 1], [0.0, 0.3, 0.0], [0.0, 3.0, 1.0]),     # one cell dead
    ([0, 0, 1], [1.0, 1.0, 1.0], [0.0, 0.0, 0.0]),     # only one survivor
])
def test_flux_matrix_rows_sum_to_one(alive, spill, free):
    a = np.asarray(alive, np.float32)
    fd = failover_dist(a, 0.5)
    m = np.asarray(flux_matrix(a, np.asarray(spill, np.float32),
                               np.asarray(free, np.float32), fd))
    np.testing.assert_allclose(m.sum(axis=1), 1.0, atol=1e-6)
    # a dead cell's row is exactly the failover distribution
    for c, al in enumerate(alive):
        if not al:
            np.testing.assert_allclose(m[c], np.asarray(fd), atol=1e-6)
    # routing an arrival matrix conserves total mass
    arr = np.arange(1.0, 13.0, dtype=np.float32).reshape(3, 4)
    routed = np.einsum("cd,cf->df", m, arr)
    assert routed.sum() == pytest.approx(arr.sum(), rel=1e-6)


def test_failover_dist_traced_matches_numpy():
    for alive in ([1, 1, 1, 1], [0, 1, 0, 1], [0, 0, 0, 0]):
        for skew in (0.0, 0.5, 2.0):
            a = np.asarray(alive, np.float64)
            np.testing.assert_allclose(
                np.asarray(failover_dist(a.astype(np.float32), skew)),
                failover_dist_np(a, skew), atol=1e-6)


def test_spill_fraction_gating():
    q = np.asarray([0.0, 50.0], np.float32)
    arr = np.asarray([10.0, 10.0], np.float32)
    slots = np.asarray([20.0, 20.0], np.float32)
    # threshold 0 disables spill exactly, even with a huge backlog
    assert np.asarray(spill_fraction(q, arr, slots, 0.0)).max() == 0.0
    s = np.asarray(spill_fraction(q, arr, slots, 1.0))
    assert s[0] == 0.0                       # under threshold: nothing spills
    assert 0.0 < s[1] <= 1.0                 # overflow spills, clipped


# ---------------------------------------------------------------------------
# topology spec
# ---------------------------------------------------------------------------


def test_topology_validation():
    with pytest.raises(ValueError):
        CellTopology(cell_count=0)
    with pytest.raises(ValueError):
        CellTopology(cell_count=2, fail_cell=2)
    with pytest.raises(ValueError):
        CellTopology(cell_count=2, fail_cell=0, fail_frac=1.5)
    with pytest.raises(ValueError):
        CellTopology(cell_count=2, hazard_corr=1.2)
    with pytest.raises(ValueError):
        CellTopology(cell_count=2,
                     scheduled=(ScheduledTrigger(3, 0.1, 0.2, 4),))
    with pytest.raises(ValueError):
        ScheduledTrigger(0, 0.5, 0.4, 2)
    with pytest.raises(ValueError):
        ReactiveTrigger("t", util_high=0.0, change=2)


def test_topology_triviality_and_weights():
    assert CellTopology(cell_count=1).is_trivial
    assert not CellTopology(cell_count=2).is_trivial
    assert not CellTopology(cell_count=1, hazard_corr=0.5).is_trivial
    assert not CellTopology(
        cell_count=1,
        reactive=(ReactiveTrigger("t", 0.9, 2),)).is_trivial
    w = CellTopology(cell_count=4, route_skew=0.5).weights()
    assert w.sum() == pytest.approx(1.0)
    assert (np.diff(w) < 0).all()            # skewed toward low-index cells
    u = CellTopology(cell_count=4).weights()
    np.testing.assert_allclose(u, 0.25)


def test_floor_schedule_matches_entries():
    topo = CellTopology(cell_count=3,
                        scheduled=(ScheduledTrigger(1, 0.25, 0.5, 6),
                                   ScheduledTrigger(1, 0.40, 0.6, 9),
                                   ScheduledTrigger(2, 0.00, 0.1, 3)))
    dur, dt = 1000.0, 1.0
    floors = topo.floor_schedule(1000, dt, dur)
    assert floors.shape == (1000, 3)
    assert floors[:, 0].max() == 0.0
    assert floors[300, 1] == 6.0             # first window only
    assert floors[450, 1] == 9.0             # overlap takes the max
    assert floors[550, 1] == 9.0
    assert floors[700, 1] == 0.0
    assert topo.schedule_entries(1, dur) == ((250.0, 500.0, 6),
                                             (400.0, 600.0, 9))
    assert topo.schedule_entries(0, dur) == ()


def test_build_cell_traces_partitions_exactly():
    sc = get_scenario("region_failover")
    traces = build_cell_traces(sc, scale=0.25)
    assert len(traces) == sc.cells.cell_count
    cfg = sc.scaled_config(0.25)
    base = synthesize(cfg)
    # TimeWarp preserves counts, so the partition conserves every invocation
    assert sum(len(t) for t in traces) == len(base)
    for t in traces:
        assert t.num_functions == base.num_functions    # shared id space
        assert t.profile is traces[0].profile           # one shared profile
    # skewed origin weights actually bias the split
    sizes = np.asarray([len(t) for t in traces], np.float64)
    assert (np.diff(sizes) < 0).all()


# ---------------------------------------------------------------------------
# convergence policy: scheduled + reactive desired-state sources
# ---------------------------------------------------------------------------


def test_convergence_policy_matches_utilization_when_trigger_free():
    pol = ConvergenceFleetPolicy(util_target=0.7, warm_frac=0.25)
    used, node_mb = 40_000.0, 16_384.0
    needed = math.ceil(used / (0.7 * node_mb) - 1e-9)
    warm = math.ceil(0.25 * needed - 1e-9)
    assert pol.desired(0.0, used, node_mb, nodes_now=4) == needed + warm
    assert pol.last_source is None


def test_convergence_policy_schedule_floor_binds():
    pol = ConvergenceFleetPolicy(util_target=0.7, warm_frac=0.25,
                                 schedule=((100.0, 200.0, 8),))
    assert pol.desired(50.0, 0.0, 16_384.0, 1) < 8
    assert pol.desired(150.0, 0.0, 16_384.0, 1) == 8
    assert pol.last_source == "schedule"
    assert pol.desired(250.0, 0.0, 16_384.0, 1) < 8    # window closed


def test_convergence_policy_reactive_latch_hold_and_cooldown():
    trig = ReactiveTrigger("burst", util_high=0.8, change=4, hold_s=50.0,
                           cooldown_s=200.0)
    pol = ConvergenceFleetPolicy(util_target=0.7, warm_frac=0.0,
                                 reactive=(trig,))
    node_mb = 10_000.0
    # util = 0.9 >= 0.8: fires, latches nodes_now + change
    assert pol.desired(10.0, 0.9 * 4 * node_mb, node_mb, 4) == 8
    assert pol.last_source == "burst"
    assert pol.last_cooldown_s == 200.0
    # hold keeps the floor up even after utilization collapses
    assert pol.desired(40.0, 0.0, node_mb, 8) == 8
    # hold expired (10 + 50 = 60) and cooldown (until 210) blocks re-fire
    assert pol.desired(100.0, 0.9 * 4 * node_mb, node_mb, 4) < 8
    # re-armed after the cooldown: fires again from the current count
    assert pol.desired(250.0, 0.9 * 6 * node_mb, node_mb, 6) == 10


# ---------------------------------------------------------------------------
# engines: trivial-topology equivalence and the C=1 bitwise guard
# ---------------------------------------------------------------------------


def test_cells_fluid_c1_is_bitwise_plain_scan():
    """The whole cells machinery (leading cell axis, router einsum, alive
    masks, per-cell accumulators) collapses EXACTLY to the plain chunked
    scan at one healthy cell — not approximately: bit-for-bit."""
    from repro.cells.fluid import run_cells_fluid
    sc = dataclasses.replace(get_scenario("region_failover"),
                             cells=CellTopology(cell_count=1))
    traces = build_cell_traces(sc, scale=0.25)
    sim = SimConfig(tick_s=sc.policy.tick_s)
    cells_row = run_cells_fluid(sc, traces, sim)
    plain_row = simulate_chunked(traces[0], sc.policy.to_jax(), sim=sim,
                                 dt=sim.tick_s, num_nodes=sc.num_nodes,
                                 fleet=sc.fleet, chunk_ticks=sc.chunk_ticks,
                                 spec=RunSpec())
    for key in ("slowdown_geomean_p99", "normalized_memory", "creation_rate",
                "nodes_mean", "cpu_overhead", "completed"):
        assert cells_row[key] == plain_row[key], key


def test_trivial_topology_runs_plain_path():
    """cells=CellTopology(1) with no failure/triggers/correlation is
    declared trivial, so run_scenario keeps the single-cluster engines."""
    sc = get_scenario("diurnal")
    trivial = dataclasses.replace(sc, cells=CellTopology(cell_count=1))
    rows = run_scenario(trivial, spec=RunSpec(scale=0.1,
                                              engines=("simjax",)))
    plain = run_scenario(sc, spec=RunSpec(scale=0.1, engines=("simjax",)))
    assert rows[0]["slowdown_geomean_p99"] == \
        plain[0]["slowdown_geomean_p99"]


def test_oracle_failover_truncates_dead_cell():
    """After the regional failure, the dead cell serves nothing: every
    surviving record of the failed cell ends before the failure time, and
    the survivors pick up its redirected traffic."""
    sc = get_scenario("region_failover")
    detail: dict = {}
    rows = run_scenario(sc, detail=detail,
                        spec=RunSpec(scale=0.1, engines=("eventsim",)))
    assert len(rows) == 1 and rows[0]["engine"] == "eventsim"
    cell_results = detail["cell_results"]
    assert len(cell_results) == sc.cells.cell_count
    duration = sc.scaled_config(0.1).duration_s
    t_fail = sc.cells.fail_time(duration)
    dead = cell_results[sc.cells.fail_cell]
    assert all(r.end <= t_fail + 1e-6 for r in dead.records)
    # survivors keep serving after the failure
    assert any(r.end > t_fail
               for c, res in enumerate(cell_results)
               if c != sc.cells.fail_cell for r in res.records)


# ---------------------------------------------------------------------------
# oracle-vs-fluid parity (Fig. 14 acceptance band)
# ---------------------------------------------------------------------------

# NOTE the creation-rate exclusion: like fig9_production, the partitioned
# warped traffic of region_failover makes per-cell per-function flows
# sparse, which is out-of-band for the Poisson-renewal expiry model's
# creation counter (a documented limitation — see EXPERIMENTS.md).  The
# slowdown and memory gates carry the acceptance criterion.


@pytest.mark.parametrize("name", ["follow_the_sun", "cell_hazard_corr"])
def test_cells_scenario_parity(name):
    rows = run_scenario(name, spec=RunSpec(scale=0.25))
    assert {r["engine"] for r in rows} == {"eventsim", "simjax"}
    gaps = parity_report(rows)
    assert gaps["slowdown_geomean_p99"] <= 0.15, gaps
    assert gaps["normalized_memory"] <= 0.15, gaps


def test_region_failover_parity_smoke():
    """One seed, loose band — the tight gate is the slow seed-averaged
    test below."""
    rows = run_scenario("region_failover", spec=RunSpec(scale=0.25))
    gaps = parity_report(rows)
    assert gaps["slowdown_geomean_p99"] <= 0.30, gaps
    assert gaps["normalized_memory"] <= 0.30, gaps


@pytest.mark.slow
def test_region_failover_parity_seed_averaged():
    """Acceptance: the failover-storm scenario holds the 15% band on the
    SEED-AVERAGED slowdown and memory gaps (single seeds wander a few
    points either side of the mean under the storm's resequencing)."""
    sc = get_scenario("region_failover")
    gaps = []
    for seed in (31, 131, 231):
        variant = dataclasses.replace(
            sc, base=dataclasses.replace(sc.base, seed=seed))
        gaps.append(parity_report(
            run_scenario(variant, spec=RunSpec(scale=0.25))))
    for metric in ("slowdown_geomean_p99", "normalized_memory"):
        mean = float(np.mean([g[metric] for g in gaps]))
        assert mean <= 0.15, (metric, gaps)


# ---------------------------------------------------------------------------
# sweeps: cell_count is a structural batch axis in the search layer
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cell_count_sweep_through_search():
    from repro.opt.search import evaluate_scenario
    pts = [{"keepalive_s": 300.0},
           {"keepalive_s": 300.0, "cell_count": 2.0},
           {"keepalive_s": 300.0, "route_skew": 1.5}]
    rows = evaluate_scenario("region_failover", pts,
                             spec=RunSpec(scale=0.25))
    assert [r["point_id"] for r in rows] == [0, 1, 2]
    for r in rows:
        assert np.isfinite(r["slowdown_geomean_p99"])
    # a different cell count is a genuinely different partition
    assert rows[1]["slowdown_geomean_p99"] != rows[0]["slowdown_geomean_p99"]
    # route_skew stays traced within the base cell-count group
    assert rows[2]["slowdown_geomean_p99"] != rows[0]["slowdown_geomean_p99"]

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_expert_ffn
from repro.kernels.rwkv6_scan import rwkv6_scan

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,H,K,D,causal,window,softcap", [
    (2, 128, 128, 4, 2, 64, True, None, None),     # GQA causal
    (1, 256, 256, 8, 8, 64, True, 64, None),       # MHA sliding window
    (2, 128, 128, 4, 4, 128, True, None, 50.0),    # softcap (gemma2)
    (1, 128, 128, 2, 1, 64, False, None, None),    # MQA bidirectional
    (1, 192, 192, 4, 2, 64, True, 32, 30.0),       # window + softcap, odd seq
])
def test_flash_attention_sweep(B, S, T, H, K, D, causal, window, softcap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, K, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, K, D)).astype(dtype)
    out_k = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=64, block_k=64,
                            interpret=True)
    out_r = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, impl="ref")
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,K,D,softcap", [
    (2, 256, 4, 2, 64, None),
    (1, 512, 8, 1, 128, None),
    (3, 128, 6, 6, 64, 50.0),
])
def test_decode_attention_sweep(B, T, H, K, D, softcap, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, 1, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, K, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, K, D)).astype(dtype)
    pos = jax.random.randint(ks[3], (B,), 1, T)
    o1 = decode_attention(q, k, v, pos, softcap=softcap, block_k=64, interpret=True)
    o2 = decode_attention(q, k, v, pos, softcap=softcap, impl="ref")
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_respects_position():
    """Keys beyond pos must not influence the output."""
    ks = jax.random.split(KEY, 4)
    B, T, H, K, D = 2, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, T, K, D))
    v = jax.random.normal(ks[2], (B, T, K, D))
    pos = jnp.array([40, 90])
    base = decode_attention(q, k, v, pos, block_k=64, interpret=True)
    k2 = k.at[:, 100:].set(999.0)
    v2 = v.at[:, 100:].set(-999.0)
    out = decode_attention(q, k2, v2, pos, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-6)


@pytest.mark.parametrize("B,T,H,D,chunk", [
    (2, 64, 4, 64, 16), (1, 48, 2, 32, 16), (2, 80, 3, 64, 16),
])
def test_rwkv6_scan_sweep(B, T, H, D, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, D))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, D)) * 0.5 - 1.0).clip(1e-4, 8.0)
    u = jax.random.normal(ks[4], (H, D)) * 0.2
    y1, s1 = rwkv6_scan(r, k, v, logw, u, chunk=chunk, interpret=True)
    y2, s2 = rwkv6_scan(r, k, v, logw, u, impl="ref")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=2e-3)


def test_rwkv6_hard_decay_stability():
    """logw at the clip floor (-8): exponent centering must not overflow."""
    B, T, H, D = 1, 64, 2, 32
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    logw = jnp.full((B, T, H, D), -8.0)
    u = jnp.zeros((H, D))
    y1, s1 = rwkv6_scan(r, k, v, logw, u, interpret=True)
    y2, s2 = rwkv6_scan(r, k, v, logw, u, impl="ref")
    assert np.isfinite(np.asarray(y1)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,d,f", [(4, 128, 256, 512), (8, 64, 128, 256),
                                     (2, 256, 128, 384)])
def test_moe_gemm_sweep(E, C, d, f, dtype):
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (E, C, d)) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (E, d, f)) / np.sqrt(d)).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f)) / np.sqrt(d)).astype(dtype)
    wo = (jax.random.normal(ks[3], (E, f, d)) / np.sqrt(f)).astype(dtype)
    o1 = moe_expert_ffn(x, wg, wu, wo, block_c=64, block_f=128, interpret=True)
    o2 = moe_expert_ffn(x, wg, wu, wo, impl="ref")
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=_tol(dtype) * 4, rtol=_tol(dtype) * 4)


@pytest.mark.parametrize("arch", ["gemma2-27b", "rwkv6-3b", "deepseek-moe-16b"])
def test_model_level_pallas_integration(arch):
    """Whole-model forward with Pallas kernels == jnp reference path."""
    from repro.configs import get_smoke_config
    from repro.models import registry
    cfg_ref = get_smoke_config(arch).replace(compute_dtype="float32",
                                             param_dtype="float32")
    cfg_pl = cfg_ref.replace(attn_impl="pallas_interpret")
    params = registry.init_params(cfg_ref, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg_ref.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    l_ref, _ = registry.forward(cfg_ref, params, batch)
    l_pl, _ = registry.forward(cfg_pl, params, batch)
    err = float(jnp.max(jnp.abs(l_ref - l_pl)) / (jnp.max(jnp.abs(l_ref)) + 1e-9))
    assert err < 2e-3

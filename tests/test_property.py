"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy
from repro.core.trace import TraceConfig, synthesize
from repro.models import layers

SETTINGS = dict(max_examples=15, deadline=None)


@given(seed=st.integers(0, 2**16), keepalive=st.floats(5.0, 900.0),
       rps=st.floats(1.0, 12.0))
@settings(**SETTINGS)
def test_sim_invariants_sync(seed, keepalive, rps):
    tc = TraceConfig(num_functions=20, duration_s=240, target_total_rps=rps,
                     seed=seed)
    trace = synthesize(tc)
    res = EventSim(trace, Cluster(6), lambda f: SyncKeepalivePolicy(keepalive),
                   SimConfig(seed=seed)).run()
    m = compute(res)
    if m.completed == 0:
        return
    # -- invariants from the paper's metric definitions --
    assert m.slowdown_geomean_p99 >= 1.0 or np.isnan(m.slowdown_geomean_p99)
    assert m.normalized_memory >= 1.0 or np.isnan(m.normalized_memory)
    assert m.creation_rate >= 0.0
    assert 0.0 <= m.worker_share <= 1.0
    assert m.cpu_overhead >= 0.0
    assert 0.0 <= m.cold_fraction <= 1.0
    # requests never finish before they start, never start before arrival
    for r in res.records:
        assert r.end >= r.start - 1e-9
        assert r.start >= r.arrival - 1e-9


@given(seed=st.integers(0, 2**16), window=st.floats(10.0, 600.0),
       target=st.floats(0.3, 1.0), cc=st.integers(1, 4))
@settings(**SETTINGS)
def test_sim_invariants_async(seed, window, target, cc):
    tc = TraceConfig(num_functions=15, duration_s=240, target_total_rps=6,
                     seed=seed)
    trace = synthesize(tc)
    res = EventSim(trace, Cluster(6),
                   lambda f: AsyncConcurrencyPolicy(window_s=window, target=target,
                                                    container_concurrency=cc),
                   SimConfig(seed=seed)).run()
    m = compute(res)
    if m.completed == 0:
        return
    assert m.normalized_memory >= 1.0 or np.isnan(m.normalized_memory)
    assert m.creation_rate >= 0.0
    assert res.creations >= 0 and res.teardowns >= 0


@given(st.integers(0, 2**16))
@settings(**SETTINGS)
def test_trace_synthesis_properties(seed):
    tc = TraceConfig(num_functions=30, duration_s=300, seed=seed)
    tr = synthesize(tc)
    assert (np.diff(tr.t) >= 0).all()
    assert (tr.t >= 0).all() and (tr.t <= tc.duration_s).all()
    assert (tr.dur >= 0.02).all() and (tr.dur <= tc.dur_cap_s).all()
    assert tr.fn.min() >= 0 and tr.fn.max() < tc.num_functions


@given(b=st.integers(1, 4), s=st.integers(2, 24), v=st.integers(8, 64),
       seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_cross_entropy_matches_naive(b, s, v, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (b, s, v))
    targets = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, v)
    got = layers.cross_entropy(logits, targets)
    probs = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.take_along_axis(probs, targets[..., None], axis=-1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-5)


@given(st.integers(0, 500))
@settings(**SETTINGS)
def test_data_pipeline_deterministic_resume(step):
    from repro.training.data import DataConfig, batch_at
    dc = DataConfig(vocab_size=128, seq_len=64, global_batch=2, seed=7)
    a = batch_at(dc, step)
    b = batch_at(dc, step)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["loss_mask"], b["loss_mask"])
    # mask zeroes exactly the separator positions
    sep = a["targets"] == 0
    assert (a["loss_mask"][sep] == 0).all()


@given(dims=st.lists(st.sampled_from([1, 2, 3, 15, 16, 32, 160, 2560]),
                     min_size=1, max_size=4))
@settings(**SETTINGS)
def test_sanitize_spec_always_valid(dims):
    """Sanitized specs never split a dim unevenly, whatever the shape."""
    import os
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import sanitize_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = P(*(["data", "model", ("data", "model"), None] * 1)[:len(dims)])
    out = sanitize_spec(spec, tuple(dims), mesh)
    for entry, d in zip(out, dims):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert d % size == 0

"""Unit + behaviour tests for the paper's core: policies, event sim, metrics."""

import math

import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute, queueing_cdf
from repro.core.policies import (AsyncConcurrencyPolicy, HybridHistogramPolicy,
                                 SyncKeepalivePolicy)
from repro.core.trace import (TraceConfig, make_profile, rate_matrix,
                              sample_functions, synthesize)

TC = TraceConfig(num_functions=60, duration_s=900, target_total_rps=10, seed=3)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


def _run(trace, policy_factory, failures=None, **sim_kw):
    sim = EventSim(trace, Cluster(8), policy_factory,
                   SimConfig(**sim_kw), failures=failures)
    return sim.run()


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


def test_sync_policy_creates_only_without_capacity():
    p = SyncKeepalivePolicy(keepalive_s=60)
    assert p.on_arrival(0.0, idle=0, busy_slots=0, starting=0, queued=0).create == 1
    assert p.on_arrival(0.0, idle=1, busy_slots=0, starting=0, queued=0).create == 0
    assert p.keepalive(0.0) == 60
    assert p.synchronous


def test_async_policy_window_average():
    p = AsyncConcurrencyPolicy(window_s=10, target=0.5, tick_s=2.0)
    # concurrency 4 sustained -> desired = ceil(4 / 0.5) = 8
    for _ in range(5):
        d = p.on_tick(0.0, concurrency=4.0, instances=0, starting=0, idle=0)
    assert d.create == 8
    # now zero load: average decays, eventually retire
    for _ in range(5):
        d = p.on_tick(0.0, concurrency=0.0, instances=8, starting=0, idle=8)
    assert d.retire > 0
    assert math.isinf(p.keepalive(0.0))


def test_async_cc_divides_desired():
    p1 = AsyncConcurrencyPolicy(window_s=2, target=1.0, container_concurrency=1, tick_s=2.0)
    p4 = AsyncConcurrencyPolicy(window_s=2, target=1.0, container_concurrency=4, tick_s=2.0)
    d1 = p1.on_tick(0.0, 8.0, 0, 0, 0)
    d4 = p4.on_tick(0.0, 8.0, 0, 0, 0)
    assert d1.create == 4 * d4.create


def test_hybrid_histogram_adapts():
    p = HybridHistogramPolicy(min_s=10, max_s=600)
    assert p.keepalive(0.0) == 10   # no samples yet
    t = 0.0
    for _ in range(50):
        p.on_arrival(t, 0, 0, 0, 0)
        t += 120.0                  # regular 2-min cadence
    ka = p.keepalive(t)
    assert 110 <= ka <= 600


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------


def test_trace_determinism_and_sorted(trace):
    t2 = synthesize(TC)
    assert len(trace) == len(t2) and np.allclose(trace.t, t2.t)
    assert (np.diff(trace.t) >= 0).all()
    assert trace.dur.min() >= 0.02


def test_invitro_sampler_preserves_load_shape():
    full = make_profile(TraceConfig(num_functions=2000, seed=1))
    sample = sample_functions(full, 200, seed=2)
    assert len(sample.rate) == 200
    # stratified sample spans the rate range and keeps the heavy tail
    assert sample.rate.max() > np.percentile(full.rate, 98)
    assert sample.rate.min() < np.percentile(full.rate, 5)


def test_rate_matrix_conserves_invocations(trace):
    rm = rate_matrix(trace, tick_s=1.0)
    assert rm.sum() == len(trace)
    assert rm.shape[1] == trace.num_functions


# ---------------------------------------------------------------------------
# event sim behaviour
# ---------------------------------------------------------------------------


def test_all_requests_complete(trace):
    res = _run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=60))
    m = compute(res)
    # every measured arrival completes (capacity is ample)
    assert m.completed > 0
    assert res.dropped == 0
    assert m.slowdown_geomean_p99 >= 1.0
    assert m.normalized_memory >= 1.0
    assert m.creation_rate >= 0.0


def test_keepalive_tradeoff_direction(trace):
    short = compute(_run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=30)))
    long = compute(_run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=900)))
    assert long.slowdown_geomean_p99 <= short.slowdown_geomean_p99
    assert long.normalized_memory >= short.normalized_memory
    assert long.creation_rate <= short.creation_rate
    assert long.cpu_overhead <= short.cpu_overhead
    assert long.cold_fraction <= short.cold_fraction


def test_async_window_tradeoff_direction(trace):
    short = compute(_run(trace, lambda f: AsyncConcurrencyPolicy(window_s=30)))
    long = compute(_run(trace, lambda f: AsyncConcurrencyPolicy(window_s=600)))
    assert long.creation_rate <= short.creation_rate
    assert long.normalized_memory >= short.normalized_memory
    assert long.slowdown_geomean_p99 <= short.slowdown_geomean_p99 * 1.1


def test_container_concurrency_reduces_churn(trace):
    cc1 = compute(_run(trace, lambda f: AsyncConcurrencyPolicy(
        window_s=60, target=0.7, container_concurrency=1)))
    cc4 = compute(_run(trace, lambda f: AsyncConcurrencyPolicy(
        window_s=60, target=0.7, container_concurrency=4)))
    assert cc4.creation_rate < cc1.creation_rate
    assert cc4.cpu_overhead < cc1.cpu_overhead


def test_worker_dominates_overhead(trace):
    m = compute(_run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=60)))
    assert m.worker_share > 0.5   # paper: ~80% of churn cost on workers


def test_sync_cold_fraction_small_at_long_keepalive(trace):
    m = compute(_run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=600)))
    assert m.cold_fraction < 0.05  # paper: ~0.5% at 10-min keepalive


def test_queueing_cdf_monotone(trace):
    res = _run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=60))
    x, y = queueing_cdf(res)
    assert (np.diff(x) >= -1e-12).all()
    assert (np.diff(y) >= 0).all()
    assert y[-1] == 1.0


def test_node_failure_requeues_and_recovers(trace):
    res = _run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=120),
               failures=[(500.0, 0), (500.0, 1)])
    m = compute(res)
    requeued = sum(r.requeued for r in res.records)
    assert m.completed > 0
    # work continues on the remaining nodes; slowdown finite
    assert np.isfinite(m.slowdown_geomean_p99)


def test_straggler_nodes_raise_tail():
    tc = TraceConfig(num_functions=40, duration_s=600, target_total_rps=8, seed=5)
    tr = synthesize(tc)
    normal = EventSim(tr, Cluster(8), lambda f: SyncKeepalivePolicy(600)).run()
    slow = EventSim(tr, Cluster(8, straggler_frac=0.5, straggler_slowdown=4.0, seed=1),
                    lambda f: SyncKeepalivePolicy(600)).run()
    assert compute(slow).slowdown_geomean_p99 > compute(normal).slowdown_geomean_p99


def test_hybrid_policy_beats_fixed_on_memory(trace):
    fixed = compute(_run(trace, lambda f: SyncKeepalivePolicy(keepalive_s=900)))
    hybrid = compute(_run(trace, lambda f: HybridHistogramPolicy(min_s=30, max_s=900)))
    # adaptive keepalive should hold less memory at comparable performance
    assert hybrid.normalized_memory < fixed.normalized_memory
    assert hybrid.slowdown_geomean_p99 < fixed.slowdown_geomean_p99 * 3

"""Vectorized (lax.scan) simulator: invariants + agreement with the oracle."""

import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import AsyncConcurrencyPolicy, SyncKeepalivePolicy
from repro.core.simjax import JaxPolicy, simulate, summarize
from repro.core.trace import TraceConfig, synthesize

TC = TraceConfig(num_functions=80, duration_s=1200, target_total_rps=12, seed=11)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


def test_simjax_invariants(trace):
    s = summarize(simulate(trace, JaxPolicy(kind=0, keepalive_s=120)))
    assert s["slowdown_geomean_p99"] >= 1.0
    assert s["normalized_memory"] >= 1.0
    assert s["creation_rate"] >= 0.0
    assert 0.0 <= s["worker_share"] <= 1.0


def test_simjax_keepalive_monotone(trace):
    rows = [summarize(simulate(trace, JaxPolicy(kind=0, keepalive_s=ka)))
            for ka in (30, 120, 600)]
    mem = [r["normalized_memory"] for r in rows]
    rate = [r["creation_rate"] for r in rows]
    assert mem == sorted(mem)
    assert rate == sorted(rate, reverse=True)


@pytest.mark.slow
def test_simjax_window_monotone(trace):
    rows = [summarize(simulate(trace, JaxPolicy(kind=1, window_s=w, target=0.7)))
            for w in (30, 120, 600)]
    rate = [r["creation_rate"] for r in rows]
    assert rate == sorted(rate, reverse=True)
    mem = [r["normalized_memory"] for r in rows]
    assert mem == sorted(mem)


def test_simjax_target_direction(trace):
    lo = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.5)))
    hi = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=1.0)))
    # smaller target -> more instances -> more memory (paper Table 1)
    assert lo["normalized_memory"] >= hi["normalized_memory"]
    assert lo["instances_mean"] >= hi["instances_mean"]


@pytest.mark.slow
def test_simjax_tracks_oracle_trends(trace):
    """Same trace, same policies: the fluid simulator must order configs the
    same way as the discrete-event oracle (Spearman-style check)."""
    kas = [30, 120, 600]
    oracle = [compute(EventSim(trace, Cluster(8),
                               lambda f, ka=ka: SyncKeepalivePolicy(ka)).run())
              for ka in kas]
    fluid = [summarize(simulate(trace, JaxPolicy(kind=0, keepalive_s=ka)))
             for ka in kas]
    for key_o, key_f in [("normalized_memory", "normalized_memory"),
                         ("creation_rate", "creation_rate"),
                         ("cpu_overhead", "cpu_overhead")]:
        a = np.argsort([getattr(m, key_o) for m in oracle])
        b = np.argsort([r[key_f] for r in fluid])
        assert (a == b).all(), (key_o, a, b)


def test_simjax_scales_to_thousands_of_functions():
    tc = TraceConfig(num_functions=2000, duration_s=600, target_total_rps=300,
                     seed=1)
    trace = synthesize(tc)
    s = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7)))
    assert np.isfinite(s["slowdown_geomean_p99"])
    assert s["instances_mean"] > 10
